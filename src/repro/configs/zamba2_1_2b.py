"""zamba2-1.2b [arXiv:2411.15242] — Mamba2 backbone + shared attention block."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # shared block uses MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,  # shared attn+MLP block applied every 6 SSM layers
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
