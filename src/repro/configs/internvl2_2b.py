"""internvl2-2b [arXiv:2404.16821] — InternViT + InternLM2 VLM.

We implement the InternLM2-1.8B language trunk (24L, GQA kv=8); the
InternViT vision encoder + MLP projector is the permitted stub —
``input_specs()`` supplies precomputed patch embeddings (256 tokens of
d_model) that are prepended to the text sequence.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_tokens=256,
    tie_embeddings=False,
    source="arXiv:2404.16821",
)
