"""grok-1-314b [hf:xai-org/grok-1] — 64L MoE 8e top-2, GQA kv=8."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    tie_embeddings=False,
    # 314B params: per-node replica cannot fit a 16-chip TP slice of a
    # single pod -> consensus over the pod axis, FSDP inside (DESIGN §5).
    consensus_axis="pod",
    source="hf:xai-org/grok-1",
)
