"""starcoder2-3b [arXiv:2402.19173] — 30L dense, GQA kv=2, RoPE."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_activation="gelu",
    rope_theta=100_000.0,
    tie_embeddings=True,
    source="arXiv:2402.19173",
)
