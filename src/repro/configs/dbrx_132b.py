"""dbrx-132b [hf:databricks/dbrx-base] — 40L fine-grained MoE 16e top-4."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    tie_embeddings=False,
    consensus_axis="pod",  # 132B total params
    source="hf:databricks/dbrx-base",
)
