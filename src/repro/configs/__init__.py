"""Config registry: ``get(name)`` / ``registry()`` / ``--arch`` ids."""

from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape
from repro.configs.grok_1_314b import CONFIG as grok_1_314b
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.starcoder2_3b import CONFIG as starcoder2_3b
from repro.configs.internvl2_2b import CONFIG as internvl2_2b
from repro.configs.mamba2_780m import CONFIG as mamba2_780m
from repro.configs.h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from repro.configs.dbrx_132b import CONFIG as dbrx_132b
from repro.configs.musicgen_large import CONFIG as musicgen_large
from repro.configs.gemma2_2b import CONFIG as gemma2_2b
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

_REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        grok_1_314b,
        qwen2_72b,
        starcoder2_3b,
        internvl2_2b,
        mamba2_780m,
        h2o_danube_1_8b,
        dbrx_132b,
        musicgen_large,
        gemma2_2b,
        zamba2_1_2b,
    ]
}


def registry() -> dict[str, ArchConfig]:
    return dict(_REGISTRY)


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get", "registry"]
