"""gemma2-2b [arXiv:2408.00118] — local/global alternating, logit softcap."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,  # even layers local (SWA), odd layers global
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norms=True,
    mlp_activation="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
