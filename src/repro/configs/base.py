"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (see sibling modules, each
citing its source), plus the paper's own ELM configs (sinc.py,
mnist.py). ``reduced()`` derives the CPU smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int  # 0 => attention-free (pure SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention variants ---
    attn_bias: bool = False  # qwen2: bias on QKV projections
    sliding_window: int | None = None  # SWA width (h2o-danube, gemma2 local)
    local_global_period: int = 0  # gemma2: every p-th layer is global, rest local
    attn_logit_softcap: float = 0.0  # gemma2: softcap on attention logits
    final_logit_softcap: float = 0.0  # gemma2: softcap on LM logits
    post_block_norms: bool = False  # gemma2: post-attn / post-ffn norms
    rope_theta: float = 10_000.0
    mlp_activation: str = "silu"  # "silu" (SwiGLU) | "gelu" (GeGLU)

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256  # SSD chunk length

    # --- hybrid (zamba2): shared attention block every k SSM layers ---
    hybrid_attn_every: int = 0

    # --- modality frontend (the one permitted stub) ---
    frontend: Literal["none", "vision", "audio"] = "none"
    frontend_tokens: int = 256  # patch/frame embeddings prepended per sample

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True

    # --- distribution defaults (see DESIGN.md §5) ---
    consensus_axis: Literal["data", "pod"] = "data"  # "pod" for >=70B archs
    gossip_kind: str = "ring"
    # Activation sharding over the "model" axis between blocks (§Perf):
    # "batch" = batch-parallel attention/MLP (fixes replicated-attention
    # archs whose head counts don't divide the TP axis); "seq" =
    # sequence parallelism (turns residual all-reduce into RS+AG and
    # shards activation memory). "none" = paper-faithful baseline.
    act_shard: Literal["none", "batch", "seq"] = "none"

    # citation for the numbers above
    source: str = ""

    def __post_init__(self):
        if self.family in ("ssm",) and self.num_heads:
            raise ValueError("pure SSM configs are attention-free")
        if self.family in ("moe",) and not self.num_experts:
            raise ValueError("moe family needs num_experts")
        if self.num_heads and self.num_kv_heads:
            if self.num_heads % self.num_kv_heads:
                raise ValueError("num_heads must be divisible by num_kv_heads")

    # ---- derived quantities -------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def uses_subquadratic_decode(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §6)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
            or self.local_global_period > 0
        )

    def param_count(self) -> int:
        """Exact parameter count (embeddings included once if tied)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            H, K, hd = self.num_heads, self.num_kv_heads, self.head_dim
            attn = d * H * hd + 2 * d * K * hd + H * hd * d
            if self.attn_bias:
                attn += (H + 2 * K) * hd
            if self.family == "moe":
                mlp = self.num_experts * 3 * d * f + d * self.num_experts
            else:
                mlp = 3 * d * f
            per_layer = attn + mlp + 2 * d
            if self.post_block_norms:
                per_layer += 2 * d
            n += self.num_layers * per_layer
        elif self.family in ("ssm", "hybrid"):
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            g = 1  # single SSM group
            in_proj = d * (2 * di + 2 * g * ds + nh)
            conv = (di + 2 * g * ds) * self.ssm_conv_width
            ssm_layer = in_proj + conv + 3 * nh + di + di * d + d
            n += self.num_layers * ssm_layer
            if self.family == "hybrid" and self.hybrid_attn_every:
                H, K, hd = self.num_heads, self.num_kv_heads, self.head_dim
                attn = d * H * hd + 2 * d * K * hd + H * hd * d
                n += attn + 3 * d * f + 2 * d  # one shared block
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.num_layers * (
            self.num_experts * 3 * d * f
        )
        return dense_like + self.num_layers * self.experts_per_token * 3 * d * f

    # ---- smoke-test reduction ------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family, toy size: <=2 layers, d_model<=256, <=4 experts."""
        H = min(self.num_heads, 4) if self.num_heads else 0
        K = 0
        if H:
            ratio = max(1, self.num_heads // max(self.num_kv_heads, 1))
            K = max(1, H // min(ratio, H))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=128,
            num_heads=H,
            num_kv_heads=K,
            head_dim=32,
            d_ff=256,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=(
                min(self.experts_per_token, 2) if self.experts_per_token else 0
            ),
            capacity_factor=4.0,  # avoid stochastic drops in smoke tests
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            sliding_window=64 if self.sliding_window else None,
            hybrid_attn_every=(2 if self.hybrid_attn_every else 0),
            frontend_tokens=8 if self.frontend != "none" else self.frontend_tokens,
            dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
