"""qwen2-72b [arXiv:2407.10671] — 80L dense, GQA kv=8, QKV bias."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    attn_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    consensus_axis="pod",  # 72B: FSDP inside a pod, consensus across pods
    source="arXiv:2407.10671",
)
