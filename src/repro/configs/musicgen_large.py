"""musicgen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

The EnCodec conv codec is the permitted stub: ``input_specs()`` supplies
precomputed codec-frame embeddings; the 48L transformer decoder trunk
(the assigned spec) is fully implemented, with logits over the 2048-way
codebook. (Fidelity note: the original uses learned sinusoidal
positions; we use RoPE — recorded in DESIGN.md as a TPU-stack deviation.)
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # MHA (kv == q heads)
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_activation="gelu",
    frontend="audio",
    frontend_tokens=0,  # decoder consumes codec token embeddings directly
    tie_embeddings=False,
    source="arXiv:2306.05284",
)
