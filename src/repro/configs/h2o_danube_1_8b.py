"""h2o-danube-1.8b [arXiv:2401.16818] — llama+mistral mix, sliding window."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    tie_embeddings=False,
    source="arXiv:2401.16818",
)
