"""mamba2-780m [arXiv:2405.21060] — 48L attention-free SSD."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
