"""SinC regression dataset (paper Test Case 1, eq. 29).

y(x) = sin(x)/x (1 at x=0); train inputs uniform on (-10, 10) with
uniform noise in [-0.2, 0.2] added to *training* targets only; test
targets noise-free. Defaults match the paper: V=4 nodes x N_i=1250 =
5000 train, 5000 test.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sinc(x: jax.Array) -> jax.Array:
    return jnp.where(x == 0, 1.0, jnp.sin(x) / jnp.where(x == 0, 1.0, x))


def make_sinc_dataset(
    key: jax.Array,
    num_nodes: int = 4,
    per_node: int = 1250,
    num_test: int = 5000,
    noise: float = 0.2,
):
    """Returns (X_nodes (V,Ni,1), Y_nodes (V,Ni,1), X_test (Nt,1), Y_test (Nt,1))."""
    kx, kn, kt = jax.random.split(key, 3)
    x = jax.random.uniform(
        kx, (num_nodes, per_node, 1), minval=-10.0, maxval=10.0
    )
    y = sinc(x)
    y = y + jax.random.uniform(kn, y.shape, minval=-noise, maxval=noise)
    xt = jax.random.uniform(kt, (num_test, 1), minval=-10.0, maxval=10.0)
    yt = sinc(xt)
    return x, y, xt, yt
