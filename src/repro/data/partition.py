"""Partitioning a global dataset across network nodes (paper Sec. III-B).

The paper divides the training set into V equal subsets; we also support
unequal (Dirichlet-skewed) splits to probe robustness claims.
"""

from __future__ import annotations

import numpy as np


def partition_sizes(N: int, V: int, skew: float = 0.0, seed: int = 0):
    """Per-node sample counts. skew=0 -> equal; skew>0 -> Dirichlet(1/skew)."""
    if skew <= 0:
        base = N // V
        sizes = [base] * V
        for i in range(N - base * V):
            sizes[i] += 1
        return sizes
    rng = np.random.default_rng(seed)
    w = rng.dirichlet([1.0 / skew] * V)
    sizes = np.maximum(1, np.floor(w * N).astype(int))
    # fix rounding drift
    while sizes.sum() > N:
        sizes[np.argmax(sizes)] -= 1
    while sizes.sum() < N:
        sizes[np.argmin(sizes)] += 1
    return sizes.tolist()


def partition_equal(X: np.ndarray, T: np.ndarray, V: int, seed: int = 0):
    """Shuffle + equal split -> stacked (V, N_i, ...) arrays.

    Drops the remainder (N % V) samples, matching the paper's equal-size
    protocol (N_i = 400 for V=25, N_i = 100 for V=100 on 10k samples).
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(X.shape[0])
    X, T = X[perm], T[perm]
    Ni = X.shape[0] // V
    X = X[: V * Ni].reshape(V, Ni, *X.shape[1:])
    T = T[: V * Ni].reshape(V, Ni, *T.shape[1:])
    return X, T
