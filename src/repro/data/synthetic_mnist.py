"""Procedural MNIST-like '3 vs 6' dataset (paper Test Case 2 surrogate).

The container is offline, so the real MNIST files are unavailable. This
module renders stroke-based 28x28 images of the digits 3 and 6 with
random affine jitter, stroke width, and pixel noise — same
dimensionality (784), same binary task, same scale (10k train / 1.8k
test) and the same V=25 / V=100 partition protocol as the paper.
Accuracy numbers are qualitative anchors against the paper's
0.8989/0.9200 (see DESIGN.md §7).
"""

from __future__ import annotations

import numpy as np

SIZE = 28


def _arc(center, radius, a0, a1, n=80):
    """Points of a circular arc; angles in degrees, image coords (row, col)."""
    th = np.linspace(np.deg2rad(a0), np.deg2rad(a1), n)
    rows = center[0] - radius * np.sin(th)
    cols = center[1] + radius * np.cos(th)
    return np.stack([rows, cols], axis=1)


def _digit3() -> np.ndarray:
    upper = _arc((9.5, 13.0), 4.5, 160.0, -80.0)
    lower = _arc((18.0, 13.0), 4.8, 80.0, -160.0)
    return np.concatenate([upper, lower], axis=0)


def _digit6() -> np.ndarray:
    loop = _arc((18.0, 13.5), 4.6, 0.0, 360.0)
    stem = _arc((14.0, 20.0), 9.5, 95.0, 175.0)
    return np.concatenate([loop, stem], axis=0)


def _render(points: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Jitter + splat stroke points with a Gaussian pen."""
    # random affine jitter around image center
    ang = rng.uniform(-0.25, 0.25)
    scale = rng.uniform(0.85, 1.1)
    shift = rng.uniform(-1.5, 1.5, size=2)
    c, s = np.cos(ang), np.sin(ang)
    rot = np.array([[c, -s], [s, c]])
    ctr = np.array([SIZE / 2, SIZE / 2])
    pts = (points - ctr) @ rot.T * scale + ctr + shift
    # per-point wobble
    pts = pts + rng.normal(0, 0.25, pts.shape)

    sigma = rng.uniform(0.7, 1.1)
    yy, xx = np.mgrid[0:SIZE, 0:SIZE]
    img = np.zeros((SIZE, SIZE))
    # vectorized splat
    d2 = (yy[None] - pts[:, 0, None, None]) ** 2 + (
        xx[None] - pts[:, 1, None, None]
    ) ** 2
    img = np.max(np.exp(-d2 / (2 * sigma**2)), axis=0)
    img = np.clip(img * rng.uniform(0.85, 1.0) * 255, 0, 255)
    img += rng.normal(0, 8.0, img.shape)  # sensor noise
    return np.clip(img, 0, 255)


def make_mnist36_dataset(
    seed: int = 0,
    num_train: int = 10_000,
    num_test: int = 1_800,
    normalize: bool = True,
):
    """Paper protocol: 5k train/digit, 900 test/digit, labels +1 (3) / -1 (6).

    Returns (X_train (N,784), T_train (N,1), X_test, T_test) float32.
    """
    rng = np.random.default_rng(seed)
    strokes = {1.0: _digit3(), -1.0: _digit6()}

    def batch(n):
        xs = np.empty((n, SIZE * SIZE), np.float32)
        ts = np.empty((n, 1), np.float32)
        labels = np.array([1.0, -1.0])
        for i in range(n):
            lab = labels[i % 2]
            xs[i] = _render(strokes[lab], rng).reshape(-1)
            ts[i] = lab
        perm = rng.permutation(n)
        return xs[perm], ts[perm]

    X_train, T_train = batch(num_train)
    X_test, T_test = batch(num_test)
    if normalize:
        X_train /= 255.0
        X_test /= 255.0
    return X_train, T_train, X_test, T_test
