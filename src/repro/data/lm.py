"""Synthetic language-model token pipeline for the assigned architectures.

Deterministic, seedable token streams with enough structure to make
training loss fall (order-2 Markov chains over the vocabulary), plus
stub embedding providers for the VLM / audio frontends (the one
permitted carve-out: frame/patch embeddings arrive precomputed).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Order-2 Markov token source (structured => learnable)."""

    vocab_size: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # successor table: each (prev2 hash) allows `branching` next tokens
        self._table = rng.integers(
            0, self.vocab_size, size=(4096, self.branching), dtype=np.int64
        )

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, batch)
        toks[:, 1] = rng.integers(0, self.vocab_size, batch)
        choice = rng.integers(0, self.branching, size=(batch, seq + 1))
        for t in range(2, seq + 1):
            h = (toks[:, t - 1] * 31 + toks[:, t - 2]) % 4096
            toks[:, t] = self._table[h, choice[:, t]]
        return toks


def make_lm_batches(
    vocab_size: int,
    batch: int,
    seq: int,
    num_batches: int,
    seed: int = 0,
):
    """Yield dicts {tokens (B,S) int32, labels (B,S) int32} (next-token)."""
    stream = TokenStream(vocab_size, seed)
    rng = np.random.default_rng(seed + 1)
    for _ in range(num_batches):
        toks = stream.sample(rng, batch, seq)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }


def make_embedding_batch(
    key: jax.Array, batch: int, seq: int, dim: int, dtype=jnp.bfloat16
):
    """Stub modality frontend output: precomputed patch/frame embeddings."""
    return jax.random.normal(key, (batch, seq, dim), dtype)
