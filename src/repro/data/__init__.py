from repro.data.partition import partition_equal, partition_sizes
from repro.data.sinc import make_sinc_dataset, sinc
from repro.data.synthetic_mnist import make_mnist36_dataset
from repro.data.lm import TokenStream, make_lm_batches

__all__ = [
    "partition_equal",
    "partition_sizes",
    "make_sinc_dataset",
    "sinc",
    "make_mnist36_dataset",
    "TokenStream",
    "make_lm_batches",
]
