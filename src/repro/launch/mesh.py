"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — the dry-run must set XLA_FLAGS
*before* the first backend initialization.
"""

from __future__ import annotations

import jax

from repro.utils import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod; multi-pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever local devices exist (tests / smokes)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return compat.make_mesh((data, model), ("data", "model"))
