"""DC-ELM head training launcher — the paper's algorithm as a first-class
feature on the production stack.

Freezes a backbone, streams each node's local token shard through it,
accumulates per-node ELM statistics (gram kernel), solves the local
ridge systems, and runs the paper's gossip iterations until the vocab
readouts agree across nodes. Compares against the fusion-center solution
(exact) to report consensus quality, then serves a held-out eval stream
through the ELM serving plane (``serving.ELMServer``) — each eval batch
is a request answered by a node replica's consensus readout.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.elm_head --arch gemma2-2b \
      --reduced --nodes 4 --batches 4 --iters 200
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get as get_config
from repro.core import consensus, dc_elm, engine, fusion_elm
from repro.core import stats as stats_lib
from repro.data.lm import TokenStream
from repro.models import Model


def _make_batch(cfg, toks, batch_size):
    batch = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (batch_size, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype),
        )
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser(description="DC-ELM head trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batches", type=int, default=4, help="chunks per node")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--C", type=float, default=16.0)
    ap.add_argument("--graph", default="ring")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--eval-batches", type=int, default=2,
        help="held-out batches served through the ELM serving plane",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    V = args.nodes
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))  # frozen backbone
    d, vocab = cfg.d_model, cfg.vocab_size

    feats = jax.jit(model.features)
    stream = TokenStream(cfg.vocab_size, args.seed)
    rng = np.random.default_rng(args.seed)

    # chunked accumulation through the statistics plane: each node's
    # SufficientStats folds batch after batch, H chunks never persist
    P_ = np.zeros((V, d, d), np.float32)
    Q_ = np.zeros((V, d, vocab), np.float32)
    for i in range(V):
        node = stats_lib.SufficientStats.zero(d, vocab)
        for _ in range(args.batches):
            toks = stream.sample(rng, args.batch, args.seq)
            batch = _make_batch(cfg, toks, args.batch)
            h = feats(params, batch).astype(jnp.float32).reshape(-1, d)
            node = node.merge(stats_lib.classification_moments(
                h, batch["labels"].reshape(-1), vocab
            ))
        P_[i], Q_[i] = np.asarray(node.P), np.asarray(node.Q)

    P_, Q_ = jnp.asarray(P_), jnp.asarray(Q_)
    graph = consensus.build(args.graph, V)
    state = dc_elm.simulate_init_from_stats(P_, Q_, args.C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, args.C)
    d0 = float(dc_elm.distance_to(state.betas, beta_star))
    eng = engine.simulated_dc_elm(graph, args.C, dtype=state.betas.dtype)
    final_betas, _ = eng.run(
        state.betas, state.omegas, graph.default_gamma(), args.iters
    )
    d1 = float(dc_elm.distance_to(final_betas, beta_star))
    cons = float(dc_elm.consensus_error(final_betas))
    fusion = fusion_elm.solve(jnp.sum(P_, 0), jnp.sum(Q_, 0), args.C)
    fusion_err = float(
        jnp.max(jnp.abs(fusion - beta_star)) / (1 + jnp.max(jnp.abs(beta_star)))
    )
    print(
        f"V={V} graph={graph.name} lambda2={graph.algebraic_connectivity:.3f}"
    )
    print(f"distance to centralized: {d0:.4f} -> {d1:.4f} ({args.iters} iters)")
    print(f"consensus disagreement:  {cons:.5f}")
    print(f"fusion-center check:     {fusion_err:.2e} (exact by construction)")

    # -- held-out eval, served through the ELM serving plane ---------------
    # Each eval batch's feature rows become one request; node replicas
    # answer round-robin with their consensus readout (feature_map=None:
    # the backbone already materialized h, the bucketed program runs the
    # readout contraction). Versioned store + micro-batching are the same
    # machinery as the online serve-while-train loop (DESIGN.md §11).
    from repro import serving

    # tokens are sampled (batch, seq+1) wide, so tokens[:, :-1] leaves
    # batch * seq feature rows per eval request — one bucket fits one
    # request exactly
    rows = args.batch * args.seq
    srv = serving.ELMServer(
        None, serving.BetaStore(final_betas), buckets=(rows,)
    )
    correct = total = 0
    for _ in range(max(args.eval_batches, 0)):
        toks = stream.sample(rng, args.batch, args.seq)
        batch = _make_batch(cfg, toks, args.batch)
        h = feats(params, batch).astype(jnp.float32).reshape(-1, d)
        logits = srv.predict(np.asarray(h))
        labels = np.asarray(batch["labels"]).reshape(-1)
        correct += int((logits.argmax(-1) == labels).sum())
        total += labels.size
    if total:
        st = srv.stats()
        print(
            f"served eval:             top-1 {correct / total:.4f} over "
            f"{total} tokens ({st['batches']} bucketed batches, "
            f"p50 {st['p50_ms']:.1f} ms)"
        )
    return d1


if __name__ == "__main__":
    main()
