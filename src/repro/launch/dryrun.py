import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-touching import: jax locks
# the device count at first backend initialization, and the production
# meshes below need 512 placeholder host devices.

from repro.launch.dryrun_lib import main  # noqa: E402

if __name__ == "__main__":
    main()
