"""Serving launcher: prefill a batch of prompts, then decode tokens.

Example (CPU smoke, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 2 --prompt-len 48 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get as get_config
from repro.data.lm import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser(description="batched serving driver")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--devices", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.devices == "production":
        mesh = make_production_mesh()
    else:
        d, m = (int(x) for x in args.devices.split("x"))
        mesh = make_host_mesh(d, m)
    del mesh  # host smoke path: default device placement

    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    stream = TokenStream(cfg.vocab_size, args.seed)
    rng = np.random.default_rng(args.seed)
    toks = stream.sample(rng, args.batch, args.prompt_len)[:, : args.prompt_len]
    prompts = jnp.asarray(toks, jnp.int32)

    max_seq = args.prompt_len + args.gen
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        max_seq += cfg.frontend_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq=max_seq))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.key(args.seed + 1)
    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature
            ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")
    print(
        f"decoded {args.gen} tokens/seq in {t_decode:.2f}s "
        f"({args.gen*args.batch/max(t_decode,1e-9):.1f} tok/s)"
    )
    print("sample:", np.asarray(gen[0])[:16])
    return gen


if __name__ == "__main__":
    main()
