"""Consensus (DC) training launcher.

Runs decentralized consensus training (the paper's mixing rule on deep
nets, DESIGN.md §3) for any assigned architecture on whatever devices
exist — the production entry point is identical, just with a real TPU
mesh instead of the host mesh.

Example (CPU smoke, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 20 --batch 4 --seq 64 --devices 1x1
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get as get_config
from repro.data.lm import TokenStream
from repro.distributed.steps import jit_train_step, make_train_bundle
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw, linear_warmup_cosine
from repro import ckpt as ckpt_lib


def main(argv=None):
    ap = argparse.ArgumentParser(description="DC consensus trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="per-node batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--gamma", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--devices", default="1x1",
        help="data x model for the host mesh, or 'production'/'multipod'",
    )
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.devices == "production":
        mesh = make_production_mesh()
    elif args.devices == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        d, m = (int(x) for x in args.devices.split("x"))
        mesh = make_host_mesh(d, m)

    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps))
    bundle = make_train_bundle(cfg, mesh, opt, gamma=args.gamma, seed=args.seed)
    V = bundle.node_count
    print(
        f"arch={cfg.name} V={V} nodes gamma={bundle.gamma:.4f} "
        f"params/node={cfg.param_count():,}"
    )
    state = bundle.init_fn(jax.random.key(args.seed))
    start_step = 0
    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            import os

            path = os.path.join(args.ckpt_dir, f"step_{latest:08d}.npz")
            params = ckpt_lib.load_pytree(path, state.params)
            state = state._replace(params=jax.device_put(
                params, bundle.state_shardings.params
            ))
            start_step = latest
            print(f"resumed from {path} at step {latest}")

    stream = TokenStream(cfg.vocab_size, args.seed)
    rng = np.random.default_rng(args.seed)

    def next_batch():
        toks = stream.sample(rng, V * args.batch, args.seq)
        toks = toks.reshape(V, args.batch, args.seq + 1)
        batch = {
            "tokens": jnp.asarray(toks[..., :-1], jnp.int32),
            "labels": jnp.asarray(toks[..., 1:], jnp.int32),
        }
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (V, args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype),
            )
        return batch

    batch = next_batch()
    batch_shape = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch
    )
    step_fn = jit_train_step(bundle, mesh, batch_shape)

    t0 = time.time()
    for i in range(start_step, args.steps):
        state, metrics = step_fn(state, batch)
        batch = next_batch()
        if args.log_every and (i % args.log_every == 0 or i == args.steps - 1):
            loss = float(jnp.mean(metrics["loss"]))
            print(f"step {i:5d} loss {loss:.4f} ({time.time()-t0:.1f}s)")
        if args.ckpt_every and args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            path = ckpt_lib.save_pytree(args.ckpt_dir, i + 1, state.params)
            print(f"  saved {path}")
    final_loss = float(jnp.mean(metrics["loss"]))
    print(f"done: final loss {final_loss:.4f}")
    return final_loss


if __name__ == "__main__":
    main()
