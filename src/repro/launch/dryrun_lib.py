"""Dry-run core: lower + compile every (arch x shape x mesh) combination.

Imported by launch/dryrun.py (which force-creates the 512 placeholder
devices *before* importing this module — see the assignment contract)
and by the roofline benchmark driver.
"""

from __future__ import annotations

import dataclasses
import json
import time
import traceback

import jax

from repro.analysis.roofline import roofline_from_compiled
from repro.configs import INPUT_SHAPES, get as get_config
from repro.configs.base import ArchConfig, InputShape
from repro.utils import compat
from repro.distributed import sharding as shd
from repro.distributed.steps import make_serve_bundle, make_train_bundle, jit_train_step
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    act_shard: str = "none"
    lower_s: float = 0.0
    compile_s: float = 0.0
    memory: dict | None = None
    roofline: dict | None = None

    def as_dict(self):
        d = dataclasses.asdict(self)
        return d


def _memory_dict(compiled) -> dict:
    m = compiled.memory_analysis()
    out = {}
    for f in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[f] = int(getattr(m, f, 0))
    out["peak_bytes_per_chip"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out


def _lower_train(cfg: ArchConfig, shape: InputShape, mesh, microbatches: int = 1):
    bundle = make_train_bundle(cfg, mesh, adamw(3e-4), microbatches=microbatches)
    state_shape = jax.eval_shape(bundle.init_fn, jax.random.key(0))
    batch_shape = specs_lib.train_batch_specs(cfg, shape, bundle.node_count)
    step = jit_train_step(bundle, mesh, batch_shape)
    return step.lower(state_shape, batch_shape)


def _lower_prefill(cfg: ArchConfig, shape: InputShape, mesh):
    bundle = make_serve_bundle(
        cfg, mesh, batch=shape.global_batch, max_seq=shape.seq_len
    )
    params_shape = specs_lib.params_specs(cfg)
    batch_shape = specs_lib.prefill_batch_specs(cfg, shape)
    bspecs = bundle.batch_pspec_fn(batch_shape)
    bsh = shd.shardings(mesh, bspecs)
    fn = jax.jit(
        bundle.prefill_fn,
        in_shardings=(bundle.param_shardings, bsh),
        out_shardings=(None, bundle.cache_shardings),
    )
    return fn.lower(params_shape, batch_shape)


def _lower_decode(cfg: ArchConfig, shape: InputShape, mesh):
    bundle = make_serve_bundle(
        cfg, mesh, batch=shape.global_batch, max_seq=shape.seq_len
    )
    params_shape = specs_lib.params_specs(cfg)
    cache_shape, tok_shape = specs_lib.decode_specs(cfg, shape)
    tok_specs = bundle.batch_pspec_fn(tok_shape)
    tok_sh = shd.shardings(mesh, tok_specs)
    fn = jax.jit(
        bundle.decode_fn,
        in_shardings=(
            bundle.param_shardings,
            bundle.cache_shardings,
            tok_sh,
        ),
        out_shardings=(None, bundle.cache_shardings),
        donate_argnums=(1,),
    )
    return fn.lower(params_shape, cache_shape, tok_shape)


def run_combo(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    want_roofline: bool = True,
    act_shard: str | None = None,
    remat: bool | None = None,
    microbatches: int = 1,
) -> DryrunResult:
    cfg = get_config(arch)
    if act_shard is not None:
        cfg = dataclasses.replace(cfg, act_shard=act_shard)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok_app, reason = specs_lib.applicable(cfg, shape)
    if not ok_app:
        return DryrunResult(
            arch=arch, shape=shape_name, mesh=mesh_name,
            ok=True, skipped=True, reason=reason,
        )
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        t0 = time.time()
        with compat.set_mesh(mesh):
            if shape.kind == "train":
                lowered = _lower_train(cfg, shape, mesh, microbatches)
            elif shape.kind == "prefill":
                lowered = _lower_prefill(cfg, shape, mesh)
            else:
                lowered = _lower_decode(cfg, shape, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        res = DryrunResult(
            arch=arch, shape=shape_name, mesh=mesh_name, ok=True,
            act_shard=cfg.act_shard,
            lower_s=t1 - t0, compile_s=t2 - t1,
            memory=_memory_dict(compiled),
        )
        if want_roofline:
            terms = roofline_from_compiled(
                compiled, cfg=cfg, shape=shape, mesh_name=mesh_name,
                chips=chips,
            )
            res.roofline = terms.as_dict()
        return res
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        return DryrunResult(
            arch=arch, shape=shape_name, mesh=mesh_name, ok=False,
            reason=f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}",
        )


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON result here")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument(
        "--act-shard", default=None, choices=["none", "batch", "seq"],
        help="activation-sharding override (perf experiments)",
    )
    ap.add_argument(
        "--no-remat", action="store_true",
        help="disable activation checkpointing (perf experiments)",
    )
    ap.add_argument(
        "--microbatches", type=int, default=1,
        help="gradient-accumulation splits of the per-node batch",
    )
    args = ap.parse_args(argv)

    res = run_combo(
        args.arch, args.shape, multi_pod=args.multi_pod,
        act_shard=args.act_shard,
        remat=False if args.no_remat else None,
        microbatches=args.microbatches,
    )
    payload = json.dumps(res.as_dict(), indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
    if not args.quiet:
        print(payload)
    if not res.ok:
        raise SystemExit(1)
