"""ShapeDtypeStruct input templates for every (arch x shape) combination.

``input_specs`` returns weak-type-correct, shardable stand-ins — no
device allocation — for the dry-run's .lower() calls, mirroring exactly
what launch/train.py and launch/serve.py feed at runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape
from repro.models import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(
    cfg: ArchConfig, shape: InputShape, node_count: int
) -> dict:
    """(V, b, S) token batches; VLM gets patch embeddings prepended."""
    V = max(node_count, 1)
    if shape.global_batch % V:
        raise ValueError(
            f"global_batch {shape.global_batch} not divisible by V={V}"
        )
    b = shape.global_batch // V
    S = shape.seq_len
    if cfg.family == "vlm":
        text = S - cfg.frontend_tokens
        return {
            "tokens": sds((V, b, text), jnp.int32),
            "labels": sds((V, b, text), jnp.int32),
            "image_embeds": sds(
                (V, b, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            ),
        }
    return {
        "tokens": sds((V, b, S), jnp.int32),
        "labels": sds((V, b, S), jnp.int32),
    }


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        text = S - cfg.frontend_tokens
        return {
            "tokens": sds((B, text), jnp.int32),
            "image_embeds": sds(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            ),
        }
    return {"tokens": sds((B, S), jnp.int32)}


def decode_specs(cfg: ArchConfig, shape: InputShape):
    """(cache template, one-token batch) for serve_step."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(model.init_cache, B, S, pos=0)
    )
    tokens = sds((B, 1), jnp.int32)
    return cache, tokens


def params_specs(cfg: ArchConfig, *, node_count: int | None = None):
    """Param template; node_count=None -> serve layout (no V dim)."""
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    if node_count is None:
        return shapes
    V = max(node_count, 1)
    return jax.tree.map(
        lambda s: sds((V,) + s.shape, s.dtype), shapes
    )


def applicable(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """The DESIGN.md §6 applicability rule."""
    if shape.name == "long_500k" and not cfg.uses_subquadratic_decode:
        return False, "pure full-attention arch: no sub-quadratic decode path"
    return True, ""


def all_combinations():
    from repro.configs import registry

    for arch, cfg in registry().items():
        for shape in INPUT_SHAPES.values():
            yield cfg, shape
