"""Minimal gradient-transform optimizers (optax is not installed here).

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, new_state)`` where
updates are *added* to params.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


class SGDState(NamedTuple):
    step: jax.Array
    momentum: object


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mom = (
            jax.tree.map(jnp.zeros_like, params) if momentum else None
        )
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        del params
        step = state.step + 1
        lr_t = _lr_at(lr, state.step)
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g, state.momentum, grads
            )
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -lr_t * (momentum * m + g), new_mom, grads
                )
            else:
                upd = jax.tree.map(lambda m: -lr_t * m, new_mom)
            return upd, SGDState(step=step, momentum=new_mom)
        upd = jax.tree.map(lambda g: -lr_t * g, grads)
        return upd, SGDState(step=step, momentum=None)

    return Optimizer(init=init, update=update)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float | None = 1.0,
) -> Optimizer:
    """AdamW with optional global-norm gradient clipping (LM default)."""

    def init(params):
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(jnp.zeros_like, params),
            nu=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state, params):
        if grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr_t = _lr_at(lr, state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads
        )
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p
            return -lr_t * step_

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(jnp.add, params, updates)
