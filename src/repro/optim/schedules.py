"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def sched(step):
        del step
        return jnp.asarray(value, jnp.float32)

    return sched


def cosine_decay(peak: float, total_steps: int, floor: float = 0.0):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))

    return sched


def linear_warmup_cosine(
    peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0
):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched
