"""Model facade: init / loss / prefill / decode for every arch family.

``Model(cfg)`` is a thin, stateless namespace of pure functions — params
are explicit pytrees so the distributed layers (consensus training,
FSDP, dry-run) can shard them freely.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.layers import (
    chunked_cross_entropy,
    embed,
    rms_norm,
    softcap,
    unembed,
)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        return tfm.init_params(key, self.cfg)

    # ------------------------------------------------------------- internals
    def _logits(self, params: dict, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = unembed(h, table)
        if cfg.final_logit_softcap:
            logits = softcap(logits, cfg.final_logit_softcap)
        return logits

    def _trunk(self, params, h, positions, *, want_cache: bool):
        cfg = self.cfg
        metrics: dict[str, jax.Array] = {}
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            h, kvs, metrics = tfm.dense_stack(
                params, h, positions, cfg, want_kv=want_cache
            )
            cache_parts = kvs
        elif cfg.family == "ssm":
            h, cache_parts = tfm.ssm_stack(params, h, cfg, want_state=want_cache)
        elif cfg.family == "hybrid":
            h, cache_parts = tfm.hybrid_stack(
                params, h, positions, cfg, want_cache=want_cache
            )
        else:
            raise ValueError(cfg.family)
        return h, cache_parts, metrics

    def _embed_inputs(self, params: dict, batch: dict) -> tuple[jax.Array, int]:
        """Returns (h (B,S_total,d), text_offset)."""
        cfg = self.cfg
        h = embed(batch["tokens"], params["embed"])
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(h.dtype)
            h = jnp.concatenate([img, h], axis=1)
            return h, img.shape[1]
        return h, 0

    # ------------------------------------------------------------------ loss
    def loss(self, params: dict, batch: dict):
        """Next-token CE (+ MoE aux). batch: tokens, labels[, image_embeds]."""
        cfg = self.cfg
        h, offset = self._embed_inputs(params, batch)
        positions = jnp.arange(h.shape[1])
        h, _, metrics = self._trunk(params, h, positions, want_cache=False)
        if offset:
            h = h[:, offset:]
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        ce = chunked_cross_entropy(
            h, table, batch["labels"],
            logit_softcap=cfg.final_logit_softcap,
        )
        total = ce
        if "moe_aux_loss" in metrics:
            total = total + cfg.router_aux_coef * metrics["moe_aux_loss"]
        metrics = dict(metrics, ce=ce)
        return total, metrics

    # ---------------------------------------------------------------- features
    def features(self, params: dict, batch: dict) -> jax.Array:
        """Final-norm hidden states h(x) — the ELM feature map when the
        backbone is frozen (paper Sec. V "unknown feature mapping")."""
        h, offset = self._embed_inputs(params, batch)
        positions = jnp.arange(h.shape[1])
        h, _, _ = self._trunk(params, h, positions, want_cache=False)
        if offset:
            h = h[:, offset:]
        return rms_norm(h, params["final_norm"], self.cfg.norm_eps)

    # --------------------------------------------------------------- prefill
    def prefill(self, params: dict, batch: dict, max_seq: int | None = None):
        """Full forward packing the decode cache.

        max_seq: cache capacity (>= prompt length); leaves headroom for
        subsequent decode_step calls. Defaults to the prompt length.
        Returns (last-token logits (B, vocab), cache dict).
        """
        cfg = self.cfg
        h, _offset = self._embed_inputs(params, batch)
        S = h.shape[1]
        positions = jnp.arange(S)
        h, cache_parts, _ = self._trunk(params, h, positions, want_cache=True)
        logits = self._logits(params, h[:, -1])
        cache = self._pack_cache(cache_parts, S, max_seq or S)
        return logits, cache

    def _cache_width(self, S: int, is_local: bool) -> int:
        cfg = self.cfg
        if is_local and cfg.sliding_window is not None:
            return min(cfg.sliding_window, S)
        return S

    def _pack_cache(self, cache_parts, S: int, max_seq: int) -> dict:
        cfg = self.cfg
        pos = jnp.asarray(S, jnp.int32)
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            k, v = cache_parts  # (L, B, S, K, hd)
            flags = tfm._is_local_flags(cfg)
            if cfg.local_global_period > 0:
                loc = [i for i in range(cfg.num_layers) if flags[i]]
                glob = [i for i in range(cfg.num_layers) if not flags[i]]
                W = self._cache_width(max_seq, True)
                kl, vl = jax.vmap(
                    lambda kk, vv: attn.prefill_into_cache(kk, vv, W)
                )(k[jnp.array(loc)], v[jnp.array(loc)])
                kg, vg = jax.vmap(
                    lambda kk, vv: attn.prefill_into_cache(kk, vv, max_seq)
                )(k[jnp.array(glob)], v[jnp.array(glob)])
                return {
                    "k_local": kl, "v_local": vl,
                    "k_global": kg, "v_global": vg,
                    "pos": pos,
                }
            W = self._cache_width(
                max_seq, cfg.sliding_window is not None
            )
            k, v = jax.vmap(
                lambda kk, vv: attn.prefill_into_cache(kk, vv, W)
            )(k, v)
            return {"k": k, "v": v, "pos": pos}
        if cfg.family == "ssm":
            states, conv_tails = cache_parts
            return {"state": states, "conv": conv_tails, "pos": pos}
        # hybrid
        (states, conv_tails), (sk, sv) = cache_parts
        sk, sv = jax.vmap(
            lambda kk, vv: attn.prefill_into_cache(kk, vv, max_seq)
        )(sk, sv)
        return {
            "state": states, "conv": conv_tails,
            "k_shared": sk, "v_shared": sv, "pos": pos,
        }

    # ------------------------------------------------------------ init_cache
    def init_cache(
        self, B: int, max_seq: int, *, pos: int = 0, ragged: bool = False
    ) -> dict:
        """Empty (or position-`pos`) decode cache with static shapes.

        ragged=True keeps a per-row (B,) position vector — each batch
        slot advances independently (continuous batching, serving/).
        """
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        p = (
            jnp.full((B,), pos, jnp.int32)
            if ragged
            else jnp.asarray(pos, jnp.int32)
        )
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            if cfg.local_global_period > 0:
                flags = tfm._is_local_flags(cfg)
                n_loc = int(flags.sum())
                n_glob = L - n_loc
                W = self._cache_width(max_seq, True)
                return {
                    "k_local": jnp.zeros((n_loc, B, W, K, hd), dt),
                    "v_local": jnp.zeros((n_loc, B, W, K, hd), dt),
                    "k_global": jnp.zeros((n_glob, B, max_seq, K, hd), dt),
                    "v_global": jnp.zeros((n_glob, B, max_seq, K, hd), dt),
                    "pos": p,
                }
            W = self._cache_width(max_seq, cfg.sliding_window is not None)
            return {
                "k": jnp.zeros((L, B, W, K, hd), dt),
                "v": jnp.zeros((L, B, W, K, hd), dt),
                "pos": p,
            }
        if cfg.family == "ssm":
            c = ssm_lib.init_ssm_cache(cfg, L, B, dt)
            return {"state": c["state"], "conv": c["conv"], "pos": p}
        # hybrid
        napp = len(range(0, L, cfg.hybrid_attn_every))
        c = ssm_lib.init_ssm_cache(cfg, L, B, dt)
        return {
            "state": c["state"], "conv": c["conv"],
            "k_shared": jnp.zeros((napp, B, max_seq, K, hd), dt),
            "v_shared": jnp.zeros((napp, B, max_seq, K, hd), dt),
            "pos": p,
        }

    # ----------------------------------------------------------- decode step
    def decode_step(self, params: dict, cache: dict, tokens: jax.Array):
        """One token for the whole batch. tokens (B, 1) -> logits (B, vocab)."""
        cfg = self.cfg
        pos = cache["pos"]
        h = embed(tokens, params["embed"])
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            h, cache = self._decode_dense(params, h, pos, cache)
        elif cfg.family == "ssm":
            h, cache = self._decode_ssm(params, h, cache)
        else:
            h, cache = self._decode_hybrid(params, h, pos, cache)
        cache = dict(cache, pos=pos + 1)
        logits = self._logits(params, h[:, 0])
        return logits, cache

    def _decode_dense(self, params, h, pos, cache):
        cfg = self.cfg
        if cfg.local_global_period > 0:
            return self._decode_mixed(params, h, pos, cache)
        windowed = cfg.sliding_window is not None

        def body(carry, xs):
            p, ck, cv = xs
            new_h, ck, cv = tfm.dense_block_decode(
                p, carry, pos, ck, cv, cfg, windowed=windowed
            )
            return new_h, (ck, cv)

        h, (ck, cv) = lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
        return h, dict(cache, k=ck, v=cv)

    def _decode_mixed(self, params, h, pos, cache):
        """gemma2: alternating local/global layers, two cache stacks."""
        cfg = self.cfg
        flags = tfm._is_local_flags(cfg)
        kl, vl = cache["k_local"], cache["v_local"]
        kg, vg = cache["k_global"], cache["v_global"]
        il = ig = 0
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda x: x[i], params["layers"])
            if bool(flags[i]):
                h, ck, cv = tfm.dense_block_decode(
                    p, h, pos, kl[il], vl[il], cfg, windowed=True
                )
                kl, vl = kl.at[il].set(ck), vl.at[il].set(cv)
                il += 1
            else:
                h, ck, cv = tfm.dense_block_decode(
                    p, h, pos, kg[ig], vg[ig], cfg, windowed=False
                )
                kg, vg = kg.at[ig].set(ck), vg.at[ig].set(cv)
                ig += 1
        return h, dict(
            cache, k_local=kl, v_local=vl, k_global=kg, v_global=vg
        )

    def _decode_ssm(self, params, h, cache):
        cfg = self.cfg

        def body(carry, xs):
            p, conv, state = xs
            hn = rms_norm(carry, p["ln"], cfg.norm_eps)
            out, conv, state = ssm_lib.mamba_decode_step(
                p["mamba"], hn, conv, state, cfg
            )
            return carry + out, (conv, state)

        h, (conv, state) = lax.scan(
            body, h, (params["layers"], cache["conv"], cache["state"])
        )
        return h, dict(cache, conv=conv, state=state)

    def _decode_hybrid(self, params, h, pos, cache):
        cfg = self.cfg
        L, k = cfg.num_layers, cfg.hybrid_attn_every
        conv, state = cache["conv"], cache["state"]
        sk, sv = cache["k_shared"], cache["v_shared"]

        def seg_body(carry, xs):
            p, cv_, st_ = xs
            hn = rms_norm(carry, p["ln"], cfg.norm_eps)
            out, cv_, st_ = ssm_lib.mamba_decode_step(
                p["mamba"], hn, cv_, st_, cfg
            )
            return carry + out, (cv_, st_)

        new_conv, new_state = [], []
        for si, start in enumerate(range(0, L, k)):
            end = min(start + k, L)
            h, ck, cvv = tfm.dense_block_decode(
                params["shared"], h, pos, sk[si], sv[si], cfg, windowed=False
            )
            sk, sv = sk.at[si].set(ck), sv.at[si].set(cvv)
            seg = lambda x: x[start:end]  # noqa: E731
            h, (c_, s_) = lax.scan(
                seg_body, h,
                (
                    jax.tree.map(seg, params["layers"]),
                    jax.tree.map(seg, conv),
                    seg(state),
                ),
            )
            new_conv.append(c_)
            new_state.append(s_)
        return h, dict(
            cache,
            conv=jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_conv),
            state=jnp.concatenate(new_state, 0),
            k_shared=sk, v_shared=sv,
        )
