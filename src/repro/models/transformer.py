"""Composable decoder stacks for all assigned architecture families.

Layer stacks are scanned (``lax.scan`` over stacked per-layer params) so
the lowered HLO stays compact at 26-80 layers, with optional remat.
Families:
  dense / vlm / audio : [norm -> GQA attn -> norm -> GLU MLP] x L
  moe                 : MLP replaced by top-k MoE
  ssm                 : [norm -> mamba2 block] x L
  hybrid              : ssm stack + one *shared* attn+MLP block applied
                        every `hybrid_attn_every` layers (zamba2)
Decode paths mirror each stack with KV / SSM caches.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    embed,
    glu_mlp,
    init_embedding,
    init_glu_mlp,
    init_rms_norm,
    rms_norm,
    unembed,
)

# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def init_dense_layer(key: jax.Array, cfg: ArchConfig) -> dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            cfg.attn_bias, dt,
        ),
        "ln2": init_rms_norm(cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.init_moe(
            k2, cfg.d_model, cfg.d_ff, cfg.num_experts, dt
        )
    else:
        p["mlp"] = init_glu_mlp(k2, cfg.d_model, cfg.d_ff, dt)
    if cfg.post_block_norms:
        p["ln1_post"] = init_rms_norm(cfg.d_model)
        p["ln2_post"] = init_rms_norm(cfg.d_model)
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head, k_shared = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(
            k_head, cfg.vocab_size, cfg.d_model, dt
        )
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        params["layers"] = jax.vmap(
            lambda k: init_dense_layer(k, cfg)
        )(layer_keys)
    elif cfg.family == "ssm":
        params["layers"] = jax.vmap(
            lambda k: {
                "ln": init_rms_norm(cfg.d_model),
                "mamba": ssm_lib.init_mamba_block(k, cfg, dt),
            }
        )(layer_keys)
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(
            lambda k: {
                "ln": init_rms_norm(cfg.d_model),
                "mamba": ssm_lib.init_mamba_block(k, cfg, dt),
            }
        )(layer_keys)
        params["shared"] = init_dense_layer(k_shared, cfg)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Layer-level forwards
# ---------------------------------------------------------------------------


def constrain_batch_dim(x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Shard dim 0 (batch) over "model" — used around attention so that
    archs whose head counts don't divide the TP axis (starcoder2: 24
    heads on 16 chips) compute attention batch-parallel instead of
    replicated. Active under act_shard == "batch"."""
    if cfg.act_shard != "batch":
        return x
    from repro.utils import compat

    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if "model" not in names:
        return x
    n = dict(mesh.shape)["model"]
    if x.shape[0] % n:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(*(["model"] + [None] * (x.ndim - 1)))
    )


def constrain_acts(h: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Optional activation-sharding constraint over the "model" axis.

    Applied at block boundaries ((B, S, d) residual stream, possibly
    under a node-dim vmap). No-op when cfg.act_shard == "none", when no
    mesh is in context, or when the dim doesn't divide the axis.
    """
    if cfg.act_shard == "none":
        return h
    from repro.utils import compat

    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if "model" not in names:
        return h
    n = dict(mesh.shape)["model"]
    dim = 0 if cfg.act_shard == "batch" else 1
    if h.ndim < 3 or h.shape[dim] % n:
        return h
    from jax.sharding import PartitionSpec as P

    spec = [None] * h.ndim
    spec[dim] = "model"
    return jax.lax.with_sharding_constraint(h, P(*spec))


def _is_local_flags(cfg: ArchConfig):
    """Per-layer sliding-window flag (STATIC numpy — also used for cache
    layout decisions under eval_shape).

    gemma2: layers alternate local (even) / global (odd). Pure-SWA archs
    (danube): every layer local. Others: none.
    """
    import numpy as np

    idx = np.arange(cfg.num_layers)
    if cfg.local_global_period > 0:
        return (idx % cfg.local_global_period) != (cfg.local_global_period - 1)
    if cfg.sliding_window is not None:
        return np.ones((cfg.num_layers,), bool)
    return np.zeros((cfg.num_layers,), bool)


def dense_block(
    p: dict,
    h: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    is_local: jax.Array,
    *,
    want_kv: bool,
):
    """One dense/moe block on full sequences. Returns (h, kv, metrics)."""
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(
        p["attn"], hn, cfg.num_heads, cfg.num_kv_heads, cfg.rope_theta, positions
    )
    q = constrain_batch_dim(q, cfg)
    k = constrain_batch_dim(k, cfg)
    v = constrain_batch_dim(v, cfg)
    flash = functools.partial(
        attn.flash_attention,
        q, k, v,
        q_positions=positions,
        k_positions=positions,
        causal=True,
        attn_softcap=cfg.attn_logit_softcap,
    )
    if cfg.sliding_window is None:
        out = flash(window=None)
    elif cfg.local_global_period > 0:
        out = lax.cond(
            is_local,
            lambda: flash(window=cfg.sliding_window),
            lambda: flash(window=None),
        )
    else:
        out = flash(window=cfg.sliding_window)
    out = attn.out_project(p["attn"], out)
    if cfg.post_block_norms:
        out = rms_norm(out, p["ln1_post"], cfg.norm_eps)
    h = h + out

    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    metrics = {}
    if "moe" in p:
        mlp_out, metrics = moe_lib.moe_ffn(
            p["moe"], hn,
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        mlp_out = glu_mlp(hn, p["mlp"], cfg.mlp_activation)
    if cfg.post_block_norms:
        mlp_out = rms_norm(mlp_out, p["ln2_post"], cfg.norm_eps)
    h = constrain_acts(h + mlp_out, cfg)
    kv = (k, v) if want_kv else None
    return h, kv, metrics


def dense_block_decode(
    p: dict,
    h: jax.Array,  # (B, 1, d)
    pos: jax.Array,  # () absolute position, or (B,) ragged per-row
    cache_k: jax.Array,  # (B, Sc, K, hd)
    cache_v: jax.Array,
    cfg: ArchConfig,
    *,
    windowed: bool,
):
    """One block, one token, against a cache. Returns (h, ck, cv)."""
    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    rope_pos = pos[:, None] if jnp.ndim(pos) else pos[None]
    q, k, v = attn.qkv_project(
        p["attn"], hn, cfg.num_heads, cfg.num_kv_heads, cfg.rope_theta,
        rope_pos,
    )
    cache_k, cache_v = attn.decode_update_layer(
        cache_k, cache_v, k, v, pos, windowed=windowed
    )
    out = attn.decode_attend(
        q, cache_k, cache_v, pos,
        windowed=windowed,
        window=cfg.sliding_window if windowed else None,
        cap=cfg.attn_logit_softcap,
    )
    out = attn.out_project(p["attn"], out)
    if cfg.post_block_norms:
        out = rms_norm(out, p["ln1_post"], cfg.norm_eps)
    h = h + out
    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        mlp_out, _ = moe_lib.moe_ffn(
            p["moe"], hn,
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        mlp_out = glu_mlp(hn, p["mlp"], cfg.mlp_activation)
    if cfg.post_block_norms:
        mlp_out = rms_norm(mlp_out, p["ln2_post"], cfg.norm_eps)
    return h + mlp_out, cache_k, cache_v


# ---------------------------------------------------------------------------
# Full-sequence stacks (train / prefill)
# ---------------------------------------------------------------------------


def dense_stack(
    params: dict,
    h: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    want_kv: bool,
):
    """Scan the dense/moe stack. Returns (h, stacked kv | None, metrics)."""
    flags = _is_local_flags(cfg)
    h = constrain_acts(h, cfg)

    def body(carry, xs):
        p, is_local = xs
        new_h, kv, metrics = dense_block(
            p, carry, positions, cfg, is_local, want_kv=want_kv
        )
        ys = (kv, metrics) if want_kv else (None, metrics)
        return new_h, ys

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (kvs, metrics) = lax.scan(
        body, h, (params["layers"], jnp.asarray(flags))
    )
    metrics = {k: jnp.mean(v) for k, v in metrics.items()}
    return h, kvs, metrics


def ssm_stack(params: dict, h: jax.Array, cfg: ArchConfig, *, want_state: bool):
    """Scan the pure-SSM stack. Returns (h, stacked (state, conv) | None)."""

    h = constrain_acts(h, cfg)

    def body(carry, p):
        hn = rms_norm(carry, p["ln"], cfg.norm_eps)
        out, state, conv_tail = ssm_lib.mamba_forward(p["mamba"], hn, cfg)
        ys = (state, conv_tail) if want_state else None
        return constrain_acts(carry + out, cfg), ys

    if cfg.remat:
        body = jax.checkpoint(body)
    h, ys = lax.scan(body, h, params["layers"])
    return h, ys


def hybrid_stack(
    params: dict,
    h: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    want_cache: bool,
):
    """Zamba2-style stack: shared attn block every k SSM layers.

    Returns (h, (ssm_cache_stacks, shared_kv_stack) | None).
    """
    k = cfg.hybrid_attn_every
    L = cfg.num_layers
    app_points = list(range(0, L, k))  # layers preceded by the shared block

    h = constrain_acts(h, cfg)

    def seg_body(carry, p):
        hn = rms_norm(carry, p["ln"], cfg.norm_eps)
        out, state, conv_tail = ssm_lib.mamba_forward(p["mamba"], hn, cfg)
        ys = (state, conv_tail) if want_cache else None
        return constrain_acts(carry + out, cfg), ys

    if cfg.remat:
        seg_body = jax.checkpoint(seg_body)

    shared_kvs = []
    ssm_states, ssm_convs = [], []
    for si, start in enumerate(app_points):
        end = min(start + k, L)
        # shared attention block (same params every application)
        sh, kv, _ = dense_block(
            params["shared"], h, positions, cfg,
            jnp.asarray(False),
            want_kv=want_cache,
        )
        h = sh
        if want_cache:
            shared_kvs.append(kv)
        seg_params = jax.tree.map(lambda x: x[start:end], params["layers"])
        h, ys = lax.scan(seg_body, h, seg_params)
        if want_cache:
            ssm_states.append(ys[0])
            ssm_convs.append(ys[1])
        del si
    if not want_cache:
        return h, None
    cache = (
        (
            jnp.concatenate(ssm_states, 0),
            jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *ssm_convs),
        ),
        (
            jnp.stack([kv[0] for kv in shared_kvs]),
            jnp.stack([kv[1] for kv in shared_kvs]),
        ),
    )
    return h, cache
