"""Common neural-net layers (pure functions over param pytrees)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # (1 + scale) parameterization (gemma-style; zero-init == identity)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def init_rms_norm(d: int, dtype=jnp.float32):
    return jnp.zeros((d,), dtype)


_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def glu_mlp(x: jax.Array, p: dict, activation: str = "silu") -> jax.Array:
    """Gated MLP: act(x Wg) * (x Wu) Wd  (SwiGLU / GeGLU)."""
    act = _ACT[activation]
    gate = act(x @ p["w_gate"])
    up = x @ p["w_up"]
    return (gate * up) @ p["w_down"]


def init_glu_mlp(key: jax.Array, d: int, f: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(h: jax.Array, table: jax.Array) -> jax.Array:
    """Logits via tied embedding table (vocab, d)."""
    return jnp.einsum("...d,vd->...v", h, table)


def chunked_cross_entropy(
    h: jax.Array,  # (B, S, d) final hidden states
    table: jax.Array,  # (vocab, d) unembedding
    labels: jax.Array,  # (B, S)
    *,
    logit_softcap: float = 0.0,
    chunk: int = 512,
    ignore: int = -1,
) -> jax.Array:
    """CE without materializing the (B, S, vocab) logits tensor.

    Sequence is processed in chunks under jax.checkpoint: each chunk's
    logits exist only transiently (forward AND backward), which is what
    keeps the 256k-vocab architectures inside HBM at 1M-token batches.
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore)
    nc = (S + pad) // chunk
    hr = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def per_chunk(args):
        hc, lc = args  # (B, c, d), (B, c)
        logits = jnp.einsum("bcd,vd->bcv", hc, table).astype(jnp.float32)
        if logit_softcap:
            logits = softcap(logits, logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc != ignore).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    sums, counts = jax.lax.map(per_chunk, (hr, lr))
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)


def cross_entropy(
    logits: jax.Array, labels: jax.Array, ignore: int = -1
) -> jax.Array:
    """Mean CE over positions with label != ignore. logits f32-cast."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
