"""Rotary position embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10_000.0
) -> jax.Array:
    """x: (B, S, H, hd), positions: (S,) or (B, S) absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    if angles.ndim == 2:  # (S, hd/2) -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
