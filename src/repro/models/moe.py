"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

TPU-idiomatic dense-dispatch design (MaxText-style): tokens are scattered
into an (E, C, d) buffer (C = capacity), experts run as one grouped
einsum on the MXU, results gather back with router weights. FLOPs scale
with top-k (active experts), not with E — matching the paper-roofline
MODEL_FLOPS = 6 * N_active * D accounting.

Dispatch bookkeeping is strictly PER SEQUENCE (the batch dim is kept as
a leading axis through the one-hot cumsum and the scatter), so the whole
dispatch/combine stays local to each data shard — the global-cumsum
formulation forced XLA to all-reduce (E, C_global, d) partial scatter
buffers across the data axis (~43 GB f32 per grok layer; see
EXPERIMENTS.md §Perf iteration B2, which removed it).

Sharding: the expert dimension E is sharded over the "model" axis when
E divides it (dbrx: 16 | 16 -> true expert parallelism, GSPMD inserts
the all-to-all at the dispatch/combine reshards); otherwise d_ff is
sharded over "model" (grok: 8 experts < 16 chips -> tensor-parallel
experts). See distributed/sharding.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe(key, d: int, f: int, E: int, dtype):
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) * s_out).astype(dtype),
    }


def moe_ffn(
    p: dict,
    x: jax.Array,  # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation=jax.nn.silu,
):
    """Returns (out (B,S,d), aux_metrics dict incl. load-balance loss)."""
    B, S, d = x.shape
    E = p["router"].shape[-1]

    # --- route (per token) ---
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (B, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- load-balance auxiliary loss (Switch-style) ---
    assign = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B, S, k, E)
    frac_tokens = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux_loss = E * jnp.sum(frac_tokens * frac_probs) / top_k

    # --- capacity-based dispatch, PER SEQUENCE (shard-local) ---
    C = max(int(capacity_factor * top_k * S / E), top_k)
    flat_e = expert_idx.reshape(B, S * top_k)  # (B, N)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, N, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot  # (B, N, E)
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = flat_pos < C  # (B, N)
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    safe_e = jnp.where(keep, flat_e, 0)
    safe_p = jnp.where(keep, flat_pos, 0)

    tok_idx = jnp.repeat(jnp.arange(S), top_k)  # (N,) source token per slot
    x_slots = jnp.take(x, tok_idx, axis=1)  # (B, N, d)
    x_slots = jnp.where(keep[..., None], x_slots, 0).astype(x.dtype)

    def scatter_row(xe, e, pos):
        buf = jnp.zeros((E, C, xe.shape[-1]), xe.dtype)
        return buf.at[e, pos].add(xe)

    buf = jax.vmap(scatter_row)(x_slots, safe_e, safe_p)  # (B, E, C, d)

    # --- expert computation (grouped einsum on the MXU) ---
    gate = activation(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    up = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", gate * up, p["w_down"])

    # --- combine: gather expert outputs back to tokens ---
    def gather_row(ob, e, pos):
        return ob[e, pos]  # (N, d)

    flat_out = jax.vmap(gather_row)(out_buf, safe_e, safe_p)  # (B, N, d)
    w = jnp.where(keep, gate_vals.reshape(B, S * top_k), 0.0).astype(x.dtype)
    flat_out = flat_out * w[..., None]
    # sum the k slots of each token: (B, S, k, d) -> (B, S, d)
    combined = jnp.sum(flat_out.reshape(B, S, top_k, d), axis=2)

    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_drop_frac": drop_frac,
    }
    return combined, metrics
