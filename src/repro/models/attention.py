"""Attention: GQA, sliding-window, local/global, softcap, KV-cache decode.

Training/prefill uses a chunked online-softmax ("flash-style") attention
written in pure JAX so that it lowers everywhere (the Pallas TPU kernel
in kernels/attn.py has identical semantics and is swapped in by ops.py on
TPU backends). Memory stays O(S * chunk) instead of O(S^2).

Layouts:
  x          (B, S, D)
  q          (B, S, K, G, hd)   K = kv heads, G = H // K query groups
  k, v       (B, S, K, hd)
  out        (B, S, D)

Sliding-window layers use an exact banded gather (no wasted blocks);
full causal layers scan all KV chunks with per-block masks (the known
2x block waste of maskless scanning is recorded in EXPERIMENTS §Perf and
eliminated in the Pallas kernel by grid skipping).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import softcap as _softcap
from repro.models.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(key, d: int, H: int, K: int, hd: int, bias: bool, dtype):
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, K, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, K, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d)) * ((H * hd) ** -0.5)).astype(
            dtype
        ),
    }
    if bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


def qkv_project(p, x, H: int, K: int, theta: float, positions):
    """x (B,S,D) -> q (B,S,K,G,hd), k,v (B,S,K,hd), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    B, S, _, hd = k.shape
    q = q.reshape(B, S, K, H // K, hd)
    return q, k, v


def out_project(p, attn_out):
    """(B, S, K, G, hd) -> (B, S, D)."""
    B, S, K, G, hd = attn_out.shape
    return jnp.einsum(
        "bshk,hkd->bsd", attn_out.reshape(B, S, K * G, hd), p["wo"]
    )


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention for train / prefill
# ---------------------------------------------------------------------------


def _block_attend(q, k, v, mask, cap: float, scale: float):
    """One (Cq x Ck) block. Returns (raw weighted values, block max, block sum).

    q (B,Cq,K,G,hd), k/v (B,Ck,K,hd), mask (Cq,Ck) or None.
    """
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    )
    logits = logits * scale
    if cap:
        logits = _softcap(logits, cap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)  # (B,K,G,Cq)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o, m, l


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: jax.Array,  # (Sq,) absolute
    k_positions: jax.Array,  # (Sk,) absolute
    causal: bool = True,
    window: int | None = None,
    attn_softcap: float = 0.0,
    q_chunk: int = 512,
    k_chunk: int = 512,
) -> jax.Array:
    """Chunked online-softmax attention. Returns (B, Sq, K, G, hd)."""
    B, Sq, K, G, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    if Sq % q_chunk or Sk % k_chunk:
        raise ValueError(f"chunk sizes must divide seq: {Sq}%{q_chunk}, {Sk}%{k_chunk}")
    nq, nk = Sq // q_chunk, Sk // k_chunk

    if window is not None:
        return _banded_attention(
            q, k, v, q_positions, k_positions, window, attn_softcap,
            q_chunk, scale,
        )

    qr = q.reshape(B, nq, q_chunk, K, G, hd)
    qp = q_positions.reshape(nq, q_chunk)
    kr = k.reshape(B, nk, k_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, k_chunk, K, hd).transpose(1, 0, 2, 3, 4)
    kp = k_positions.reshape(nk, k_chunk)

    def per_q_chunk(args):
        qc, qpos = args  # (B,Cq,K,G,hd), (Cq,)

        def per_k_chunk(carry, kv):
            acc, m, l = carry
            kc, vc, kpos = kv
            mask = qpos[:, None] >= kpos[None, :] if causal else None
            o, bm, bl = _block_attend(qc, kc, vc, mask, attn_softcap, scale)
            new_m = jnp.maximum(m, bm)
            r_old = jnp.exp(m - new_m)
            r_new = jnp.exp(bm - new_m)
            acc = acc * r_old[..., None].transpose(0, 3, 1, 2, 4) + (
                o * r_new[..., None].transpose(0, 3, 1, 2, 4)
            )
            l = l * r_old + bl * r_new
            return (acc, new_m, l), None

        acc0 = jnp.zeros(qc.shape, jnp.float32)
        m0 = jnp.full((B, K, G, qc.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc.shape[1]), jnp.float32)
        (acc, m, l), _ = lax.scan(per_k_chunk, (acc0, m0, l0), (kr, vr, kp))
        denom = l[..., None].transpose(0, 3, 1, 2, 4)
        return (acc / jnp.maximum(denom, 1e-30)).astype(q.dtype)

    out = lax.map(per_q_chunk, (qr.transpose(1, 0, 2, 3, 4, 5), qp))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G, hd)


def _banded_attention(
    q, k, v, q_positions, k_positions, window, cap, q_chunk, scale
):
    """Exact sliding-window attention: each q chunk gathers its KV band.

    Band width = window + q_chunk (static), so FLOPs are O(S * window).
    Assumes q and k cover the same contiguous positions (train/prefill).
    """
    B, Sq, K, G, hd = q.shape
    nq = Sq // q_chunk
    band = window + q_chunk

    # pad KV on the left so every band gather is in range
    pad = band
    kpad = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    kpos_pad = jnp.pad(k_positions, (pad, 0), constant_values=-1_000_000_000)

    qr = q.reshape(B, nq, q_chunk, K, G, hd)
    qp = q_positions.reshape(nq, q_chunk)

    def per_q_chunk(i, qc, qpos):
        start = i * q_chunk + pad - window  # leftmost needed kv (padded idx)
        kb = lax.dynamic_slice_in_dim(kpad, start, band, axis=1)
        vb = lax.dynamic_slice_in_dim(vpad, start, band, axis=1)
        kp = lax.dynamic_slice_in_dim(kpos_pad, start, band, axis=0)
        mask = (qpos[:, None] >= kp[None, :]) & (
            qpos[:, None] - kp[None, :] < window
        )
        o, m, l = _block_attend(qc, kb, vb, mask, cap, scale)
        denom = l[..., None].transpose(0, 3, 1, 2, 4)
        return (o / jnp.maximum(denom, 1e-30)).astype(q.dtype)

    out = lax.map(
        lambda args: per_q_chunk(*args),
        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5), qp),
    )
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G, hd)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Per-layer-stack KV cache.

    k, v: (L, B, S_cache, K, hd) — S_cache = window for SWA layers (ring
    buffer), else max sequence length. RoPE is pre-applied to stored k.
    pos:  () int32 — absolute position of the next token.
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array

    @property
    def s_cache(self) -> int:
        return self.k.shape[2]


def init_cache(L: int, B: int, s_cache: int, K: int, hd: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((L, B, s_cache, K, hd), dtype),
        v=jnp.zeros((L, B, s_cache, K, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_slot_positions(s_cache: int, pos: jax.Array) -> jax.Array:
    """Absolute position stored in each ring-buffer slot after writing pos.

    slot i holds the largest p <= pos with p % s_cache == i; slots never
    written yet get a negative position (masked out). pos may be a
    scalar (synchronized batch) or (B,) (ragged slots — continuous
    batching); the result broadcasts accordingly.
    """
    i = jnp.arange(s_cache)
    pos = jnp.asarray(pos)
    if pos.ndim:  # (B,) -> (B, s_cache)
        p = pos[:, None] - ((pos[:, None] - i[None]) % s_cache)
    else:
        p = pos - ((pos - i) % s_cache)
    return jnp.where(p >= 0, p, -1_000_000_000)


def decode_update_layer(
    cache_k, cache_v, k_new, v_new, pos, *, windowed: bool
):
    """Write one token's (B,1,K,hd) KV at absolute `pos` into (B,Sc,K,hd).

    pos scalar: synchronized write; pos (B,): per-row (ragged) write.
    """
    Sc = cache_k.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim:  # ragged: per-row scatter
        slot = (pos % Sc) if windowed else jnp.minimum(pos, Sc - 1)
        rows = jnp.arange(cache_k.shape[0])
        ck = cache_k.at[rows, slot].set(k_new[:, 0])
        cv = cache_v.at[rows, slot].set(v_new[:, 0])
        return ck, cv
    slot = (pos % Sc) if windowed else pos
    ck = lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    return ck, cv


def decode_attend(
    q, cache_k, cache_v, pos, *, windowed: bool, window, cap: float
):
    """Single-token attention over the cache.

    q (B,1,K,G,hd); cache (B,Sc,K,hd); pos = current absolute position,
    scalar or per-row (B,).
    """
    B, _, K, G, hd = q.shape
    Sc = cache_k.shape[1]
    scale = hd ** -0.5
    pos = jnp.asarray(pos)
    pos_b = pos if pos.ndim else jnp.broadcast_to(pos, (B,))
    if windowed:
        kpos = cache_slot_positions(Sc, pos_b)  # (B, Sc)
    else:
        idx = jnp.arange(Sc)
        kpos = jnp.where(idx[None] <= pos_b[:, None], idx[None],
                         -1_000_000_000)
    valid = kpos >= 0
    if window is not None:
        valid = valid & (pos_b[:, None] - kpos < window)
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, cache_k, preferred_element_type=jnp.float32
    )
    logits = logits * scale
    if cap:
        logits = _softcap(logits, cap)
    logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cache_v.dtype), cache_v)


def prefill_into_cache(k, v, s_cache: int):
    """Pack a full-prefill (B,S,K,hd) KV into a cache of width s_cache.

    Full cache (s_cache >= S): left-aligned write. Ring cache
    (s_cache < S): keep the last s_cache tokens at their ring slots.
    """
    B, S, K, hd = k.shape
    if s_cache >= S:
        pad = ((0, 0), (0, s_cache - S), (0, 0), (0, 0))
        return jnp.pad(k, pad), jnp.pad(v, pad)
    # ring: slot i holds abs pos p = last p < S with p % s_cache == i
    i = jnp.arange(s_cache)
    last = S - 1
    p = last - ((last - i) % s_cache)
    return jnp.take(k, p, axis=1), jnp.take(v, p, axis=1)
