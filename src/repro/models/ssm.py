"""Mamba2 (SSD) block — arXiv:2405.21060, single SSM group.

Block: [z|x|B|C|dt] projections; causal depthwise conv + SiLU on x/B/C;
SSD scan over heads; gated RMSNorm; out_proj. Decode keeps per-component
conv ring caches and the (nh, hd, ds) SSM state — O(1) memory per token,
which is what qualifies the SSM/hybrid archs for long_500k.

The five input projections are stored as *separate* matrices (not the
fused in_proj of the reference CUDA implementation) so that tensor
parallelism shards cleanly along SSM heads: w_z / w_x / w_dt column-
shard over the "model" axis (head-major layout), while the small shared
B/C projections stay replicated. This is the TPU adaptation of Mamba2's
"heads are embarrassingly parallel" property (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.ssd_ops import ssd
from repro.kernels.ssd_ref import ssd_decode_step
from repro.models.layers import rms_norm


def init_mamba_block(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    u = jax.random.uniform(ks[0], (nh,), minval=1e-3, maxval=0.1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))
    return {
        "w_z": (jax.random.normal(ks[1], (d, di)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[2], (d, di)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[3], (d, ds)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[4], (d, ds)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[5], (d, nh)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[6], (W, di)) * 0.3).astype(dtype),
        "conv_B": (jax.random.normal(ks[7], (W, ds)) * 0.3).astype(dtype),
        "conv_C": (jax.random.normal(ks[8], (W, ds)) * 0.3).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((ds,), dtype),
        "conv_bC": jnp.zeros((ds,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(
            jax.random.uniform(ks[0], (nh,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": (jax.random.normal(key, (di, d)) * di**-0.5).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C) + SiLU."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = None
    for i in range(W):  # small W: unrolled adds fuse well
        term = pad[:, i : i + x.shape[1], :] * w[i]
        out = term if out is None else out + term
    return jax.nn.silu(out + b)


def mamba_forward(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ArchConfig,
    *,
    initial_state: jax.Array | None = None,
):
    """Train/prefill path. Returns (out (B,S,d), final state, conv tails)."""
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bsz, S, _ = x.shape
    z = x @ p["w_z"]
    xs_raw = x @ p["w_x"]
    B_raw = x @ p["w_B"]
    C_raw = x @ p["w_C"]
    dt = x @ p["w_dt"]
    W = cfg.ssm_conv_width
    conv_tails = {
        "x": xs_raw[:, -(W - 1) :, :],
        "B": B_raw[:, -(W - 1) :, :],
        "C": C_raw[:, -(W - 1) :, :],
    }
    xs = _causal_conv(xs_raw, p["conv_x"], p["conv_bx"])
    Bm = _causal_conv(B_raw, p["conv_B"], p["conv_bB"])
    Cm = _causal_conv(C_raw, p["conv_C"], p["conv_bC"])
    xs = xs.reshape(Bsz, S, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd(
        xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk, initial_state=initial_state
    )
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y.astype(x.dtype) @ p["out_proj"]).astype(x.dtype)
    return out, state, conv_tails


def init_ssm_cache(cfg: ArchConfig, L: int, B: int, dtype):
    """Per-layer-stack decode cache: conv rings + SSM state."""
    W = cfg.ssm_conv_width
    return {
        "conv": {
            "x": jnp.zeros((L, B, W - 1, cfg.d_inner), dtype),
            "B": jnp.zeros((L, B, W - 1, cfg.ssm_state), dtype),
            "C": jnp.zeros((L, B, W - 1, cfg.ssm_state), dtype),
        },
        "state": jnp.zeros(
            (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }


def _conv_step(hist: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """hist (B,W-1,C), new (B,C) -> (conv output (B,C), new hist)."""
    full = jnp.concatenate([hist, new[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", full, w) + b
    return jax.nn.silu(out), full[:, 1:, :]


def mamba_decode_step(
    p: dict,
    x_t: jax.Array,  # (B, 1, d)
    conv_cache: dict,  # {"x": (B,W-1,di), "B": ..., "C": ...}
    state: jax.Array,  # (B, nh, hd, ds) f32
    cfg: ArchConfig,
):
    """One-token recurrent step. Returns (out (B,1,d), conv_cache, state)."""
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xt = x_t[:, 0]
    z = xt @ p["w_z"]
    xs_raw = xt @ p["w_x"]
    B_raw = xt @ p["w_B"]
    C_raw = xt @ p["w_C"]
    dt = jax.nn.softplus(
        (xt @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )
    xs, cx = _conv_step(conv_cache["x"], xs_raw, p["conv_x"], p["conv_bx"])
    B_t, cB = _conv_step(conv_cache["B"], B_raw, p["conv_B"], p["conv_bB"])
    C_t, cC = _conv_step(conv_cache["C"], C_raw, p["conv_C"], p["conv_bC"])
    xs = xs.reshape(-1, nh, hd)
    A = -jnp.exp(p["A_log"])
    new_state, y = ssd_decode_step(state, xs, dt, A, B_t, C_t)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(-1, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y.astype(x_t.dtype) @ p["out_proj"]).astype(x_t.dtype)
    return out[:, None, :], {"x": cx, "B": cB, "C": cC}, new_state
