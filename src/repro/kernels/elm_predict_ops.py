"""Dispatching wrapper for the fused predict kernel.

Backend policy (mirrors elm_stats_ops):
  * TPU              -> the Pallas kernel (H never touches HBM)
  * use_kernel=True elsewhere -> the kernel in interpret mode
    (correctness path for tests; slow)
  * otherwise        -> ``elm_predict_scan``, the jitted lax.scan
    streaming implementation — fused-by-construction on CPU/GPU (peak
    memory is one chunk's working set, not the (N, L) hidden matrix)

``predict_map`` is the FeatureMap-level entry point every prediction
consumer routes through (``ELM.__call__``, ``dc_elm.node_predict``,
``serving.elm_server``): fusable affine/RBF maps take the fused path
when the result dtype is f32-or-narrower; f64 fidelity runs and
non-fusable maps (frozen deep backbones) materialize H for the call.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_predict(
    X, W, b, beta, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, **kw,
):
    """Y = g(X W + b) @ beta without materializing H.

    For activation="rbf" pass W = centers^T and b = gamma. Returns the
    oracle's result dtype (the promoted X/W/beta chain) with f32
    accumulation inside.
    """
    from repro.kernels.elm_predict_ref import predict_dtype

    out_dtype = predict_dtype(X, W, beta)
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        from repro.kernels.elm_predict import elm_predict_pallas

        Y = elm_predict_pallas(
            X, W, b, beta, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
        return Y.astype(out_dtype)
    from repro.kernels.elm_predict_ref import elm_predict_scan

    kw.pop("block_l", None)
    chunk = kw.pop("block_n", None)
    if chunk is not None:
        kw["chunk"] = chunk
    return elm_predict_scan(
        X, W, b, beta, activation=activation, **kw
    ).astype(out_dtype)


def predict_map(
    x, feature_map, beta, *, use_kernel: bool | None = None, **kw,
):
    """f(x) = h(x) @ beta for any FeatureMap, fused where fusable.

    x: (..., D) with arbitrary leading dims (flattened to rows for the
    kernel and restored). feature_map=None means x already *is* the
    (materialized) feature matrix — the serving path for deep-backbone
    heads, where the hidden layer cannot be refused into the kernel.
    """
    from repro.core.stats import fusable_params

    if feature_map is None:
        return x @ beta
    params = fusable_params(feature_map)
    if params is None or jnp.result_type(x, beta) == jnp.float64:
        # non-fusable map (deep backbone) or the f64 fidelity path:
        # materialize H for this call only
        return feature_map(x) @ beta
    W, b, activation = params
    lead = x.shape[:-1]
    rows = x.reshape(-1, x.shape[-1])
    if rows.shape[0] == 0:  # the tiled paths cannot grid over N = 0
        return feature_map(x) @ beta
    Y = fused_predict(
        rows, W, b, beta, activation=activation, use_kernel=use_kernel,
        **kw,
    )
    return Y.reshape(*lead, beta.shape[-1])
