"""Dispatching wrapper for the fused predict kernel.

Backend policy (mirrors elm_stats_ops):
  * TPU              -> the Pallas kernel (H never touches HBM)
  * use_kernel=True elsewhere -> the kernel in interpret mode
    (correctness path for tests; slow)
  * otherwise        -> ``elm_predict_scan``, the jitted lax.scan
    streaming implementation — fused-by-construction on CPU/GPU (peak
    memory is one chunk's working set, not the (N, L) hidden matrix)

Block-knob mapping (Pallas grid -> scan fallback): ``block_n`` maps to
the scan's ``chunk`` (rows resident per streaming step); ``block_l``
has no scan equivalent (the scan computes all L hidden columns per
chunk) and a non-None value raises instead of being silently dropped.
Passing both ``block_n`` and ``chunk`` to the scan path is a conflict
and raises. The shared mapper is ``elm_stats_ops.scan_kwargs``.

Tuning policy (kernels/autotune.py): ``tuning="cached"`` (default)
consults the measured-winner cache (TUNED_kernels.json) for this
problem point and backend — explicit block kwargs always win, and a
cache miss keeps the hard-coded defaults, so cold-start behavior is
unchanged. ``tuning="off"`` never consults; ``tuning={...}`` applies
an explicit config dict.

``predict_map`` is the FeatureMap-level entry point every prediction
consumer routes through (``ELM.__call__``, ``dc_elm.node_predict``,
``serving.elm_server``): fusable affine/RBF maps take the fused path
when the result dtype is f32-or-narrower; f64 fidelity runs and
non-fusable maps (frozen deep backbones) materialize H for the call.

``predict_stacked`` is the multi-tenant twin: rows carry tenant ids
into a stacked (T, L, M) beta tensor, the shared hidden tile is
computed once per row and contracted against per-row gathered beta
tiles (``op="stacked"`` in the tuned cache; its scan fallback is a
jitted gather-then-contract over row chunks). One launch serves every
tenant in the batch — ``serving.elm_server`` in multi-tenant mode is
the request-level consumer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.elm_stats_ops import force_interpret, scan_kwargs


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_predict(
    X, W, b, beta, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, tuning="cached", **kw,
):
    """Y = g(X W + b) @ beta without materializing H.

    For activation="rbf" pass W = centers^T and b = gamma. Returns the
    oracle's result dtype (the promoted X/W/beta chain) with f32
    accumulation inside. ``tuning`` selects the block-knob policy (see
    module docstring).
    """
    from repro.kernels.elm_predict_ref import predict_dtype

    out_dtype = predict_dtype(X, W, beta)
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    kw = autotune.resolve_config(
        kw, tuning, op="predict", impl="pallas" if use else "scan",
        N=X.shape[0], D=X.shape[1], L=W.shape[1], M=beta.shape[1],
        dtype=X.dtype,
    )
    if use:
        from repro.kernels.elm_predict import elm_predict_pallas

        if kw.get("chunk") is not None:
            raise ValueError(
                "chunk is the scan-fallback knob; the Pallas kernel "
                "takes block_n/block_l"
            )
        kw.pop("chunk", None)
        Y = elm_predict_pallas(
            X, W, b, beta, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
        return Y.astype(out_dtype)
    from repro.kernels.elm_predict_ref import elm_predict_scan

    return elm_predict_scan(
        X, W, b, beta, activation=activation, **scan_kwargs(kw)
    ).astype(out_dtype)


def predict_map(
    x, feature_map, beta, *, use_kernel: bool | None = None,
    tuning="cached", **kw,
):
    """f(x) = h(x) @ beta for any FeatureMap, fused where fusable.

    x: (..., D) with arbitrary leading dims (flattened to rows for the
    kernel and restored). feature_map=None means x already *is* the
    (materialized) feature matrix — the serving path for deep-backbone
    heads, where the hidden layer cannot be refused into the kernel.
    """
    from repro.core.stats import fusable_params

    if feature_map is None:
        return x @ beta
    params = fusable_params(feature_map)
    if params is None or jnp.result_type(x, beta) == jnp.float64:
        # non-fusable map (deep backbone) or the f64 fidelity path:
        # materialize H for this call only
        return feature_map(x) @ beta
    W, b, activation = params
    lead = x.shape[:-1]
    rows = x.reshape(-1, x.shape[-1])
    if rows.shape[0] == 0:  # the tiled paths cannot grid over N = 0
        return feature_map(x) @ beta
    Y = fused_predict(
        rows, W, b, beta, activation=activation, use_kernel=use_kernel,
        tuning=tuning, **kw,
    )
    return Y.reshape(*lead, beta.shape[-1])


def fused_predict_stacked(
    X, W, b, betas, tenant_ids, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, tuning="cached", **kw,
):
    """Y[n] = g(X W + b)[n] @ betas[tenant_ids[n]] without
    materializing H: one launch for a batch mixing many tenants.

    betas: (T, L, M) stacked per-tenant readouts over the ONE shared
    feature map; tenant_ids: (N,) int row -> tenant slot. Returns the
    oracle's promoted result dtype with f32 accumulation inside.
    """
    from repro.kernels.elm_predict_ref import stacked_dtype

    out_dtype = stacked_dtype(X, W, betas)
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    kw = autotune.resolve_config(
        kw, tuning, op="stacked", impl="pallas" if use else "scan",
        N=X.shape[0], D=X.shape[1], L=W.shape[1], M=betas.shape[2],
        dtype=X.dtype, T=betas.shape[0],
    )
    if use:
        from repro.kernels.elm_predict import elm_predict_stacked_pallas

        if kw.get("chunk") is not None:
            raise ValueError(
                "chunk is the scan-fallback knob; the Pallas kernel "
                "takes block_n/block_l"
            )
        kw.pop("chunk", None)
        Y = elm_predict_stacked_pallas(
            X, W, b, betas, tenant_ids, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
        return Y.astype(out_dtype)
    from repro.kernels.elm_predict_ref import elm_predict_stacked_scan

    return elm_predict_stacked_scan(
        X, W, b, betas, tenant_ids, activation=activation,
        **scan_kwargs(kw),
    ).astype(out_dtype)


def predict_stacked(
    x, feature_map, betas, tenant_ids, *,
    use_kernel: bool | None = None, tuning="cached", **kw,
):
    """f_t(x) = h(x) @ betas[t] per row, fused where fusable.

    The multi-tenant ``predict_map``: x (N, D) rows, betas (T, L, M),
    tenant_ids (N,) int. feature_map=None means x already IS the
    feature matrix (deep-backbone serving); non-fusable maps and the
    f64 fidelity path materialize H and gather-contract per row.
    """
    from repro.core.stats import fusable_params
    from repro.kernels.elm_predict_ref import _gather_contract

    ids = jnp.asarray(tenant_ids, jnp.int32)
    if feature_map is None:
        op = jnp.promote_types(x.dtype, betas.dtype)
        return _gather_contract(
            x.astype(op), betas.astype(op), ids
        ).astype(op)
    params = fusable_params(feature_map)
    if (
        params is None
        or jnp.result_type(x, betas) == jnp.float64
        or x.shape[0] == 0  # the tiled paths cannot grid over N = 0
    ):
        H = feature_map(x)
        op = jnp.promote_types(H.dtype, betas.dtype)
        return _gather_contract(
            H.astype(op), betas.astype(op), ids
        ).astype(op)
    W, b, activation = params
    return fused_predict_stacked(
        x, W, b, betas, ids, activation=activation,
        use_kernel=use_kernel, tuning=tuning, **kw,
    )
