"""Pallas TPU kernel: chunked Mamba2 SSD scan.

Grid = (batch, heads, chunks) with chunks innermost: the per-(b, h)
running state (hd, ds) lives in a VMEM scratch buffer across the
sequential chunk iterations — the HBM traffic is exactly one pass over
x/dt/B/C and one write of y (plus the final state), i.e. the kernel is
bandwidth-optimal for the SSD recurrence. Within a chunk the quadratic
"duality" form runs on the MXU: (Q,ds)x(ds,Q) and (Q,Q)x(Q,hd) matmuls.

VMEM working set per step (Q=256, hd=64, ds=128, f32):
  x (Q,hd) 64K + B/C (Q,ds) 2*128K + att (Q,Q) 256K + state (hd,ds) 32K
  ~= 0.6 MiB  << ~16 MiB/core.

Shapes (kernel layout, produced by the ssd_pallas wrapper):
  x   (B, NH, nc, Q, hd)
  dt  (B, NH, nc, Q)      positive step sizes
  adt (B, NH, nc, Q)      dt * A  (negative log-decays)
  Bm  (B, nc, Q, ds)      shared across heads (single SSM group)
  Cm  (B, nc, Q, ds)
  h0  (B, NH, hd, ds)     initial state
outputs
  y   (B, NH, nc, Q, hd)
  hT  (B, NH, hd, ds)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, adt_ref, b_ref, c_ref, h0_ref,
    y_ref, hT_ref,
    state,  # VMEM scratch (hd, ds) f32
    *, num_chunks: int,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _load_init():
        state[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, hd)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    adt = adt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, ds)
    Q = x.shape[0]

    cum = jnp.cumsum(adt)  # (Q,)
    # --- intra-chunk quadratic form ---
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, Q) = C B^T
    decay = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    att = cb * decay * dt[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    att = jnp.where(row >= col, att, 0.0)
    y = jax.lax.dot_general(
        att, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, hd)
    # --- inter-chunk: carried state contribution ---
    h = state[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, ds) x (hd, ds)^T -> (Q, hd)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    # --- state update ---
    total = cum[-1]
    w = jnp.exp(total - cum) * dt  # (Q,)
    state[...] = jnp.exp(total) * h + jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (hd, ds)

    @pl.when(c_idx == num_chunks - 1)
    def _write_final():
        hT_ref[0, 0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,  # (b, s, nh, hd)
    dt: jax.Array,  # (b, s, nh)
    A: jax.Array,  # (nh,)
    B: jax.Array,  # (b, s, ds)
    C: jax.Array,  # (b, s, ds)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
    interpret: bool = False,
):
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(b, nh, nc, chunk, hd)
    dtt = dt.transpose(0, 2, 1).reshape(b, nh, nc, chunk)
    adt = dtt * A[None, :, None, None].astype(dtt.dtype)
    Bm = B.reshape(b, nc, chunk, ds)
    Cm = C.reshape(b, nc, chunk, ds)
    h0 = (
        jnp.zeros((b, nh, hd, ds), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    kern = functools.partial(_ssd_kernel, num_chunks=nc)
    y, hT = pl.pallas_call(
        kern,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hd), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, j, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, hd), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, nc, chunk, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, adt, Bm, Cm, h0)
    y = y.reshape(b, nh, sp, hd).transpose(0, 2, 1, 3)[:, :s]
    return y, hT
