"""Fused consensus-round Pallas kernel over padded neighbor lists.

One grid pass applies the paper's eq. (20) update

    beta_i += scale * Omega_i @ (sum_s w[i,s] beta[idx[i,s]] - deg_i beta_i)

for a block of ``block_v`` nodes per program: the neighbor beta tiles
are gathered from a VMEM-resident copy of the full state, the Laplacian
is accumulated in VMEM registers (f32), and the Omega contraction +
state update write straight to the output block — the ``(V, L, M)``
Laplacian never exists in HBM. Layout inside the kernel is
``(V, M, L)``: L (128-aligned) rides the lane dimension so the state
stays physically compact for small M (the (V, L, M) layout would pad
M to a full 128-lane tile and blow the VMEM budget ~16x at M=8).

Arms:

* ``elm_gossip_pallas`` — ``num_rounds`` rounds as an outer
  ``lax.scan`` over per-round kernel launches (the state round-trips
  HBM between rounds; the Laplacian still never does). bf16 payload
  (``compress="bf16"``) casts the gathered/self payload in-kernel and
  accumulates in f32, matching ``mixers.compress_payload``. An
  explicitly encoded ``payload=`` operand (int8-roundtripped replicas
  from core/compression.py) is gathered instead of the state —
  the fused CompressedMixer round (single-round only: the payload is
  re-encoded outside per round).
* ``elm_gossip_pallas_multiround`` — the small-state arm: the whole
  state, Omegas and every topology snapshot stay resident in VMEM and
  an in-kernel ``lax.fori_loop`` runs all rounds back-to-back, so the
  state skips its per-round HBM round-trips too. Gate on
  ``multiround_vmem_bytes`` (see elm_gossip_ops).

Off TPU both arms run under ``interpret=True`` for correctness tests;
the production CPU path is ``elm_gossip_ref.elm_gossip_scan``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rup(x: int, m: int) -> int:
    return x + (-x) % m


def _lap_tile(src, idx, wts, deg, lo, block_v, d_max, bf16):
    """f32 Laplacian for the node block starting at ``lo``.

    src: (Vp, Mp, Lp) gather source (state or encoded payload);
    idx/wts: (block_v, d_pad); deg: (block_v,). The gathered tiles are
    VMEM values — this accumulation is the fusion.
    """
    if bf16:
        src = src.astype(jnp.bfloat16)
    p_tile = jax.lax.dynamic_slice_in_dim(src, lo, block_v, axis=0)
    lap0 = -deg[:, None, None] * p_tile.astype(jnp.float32)

    def acc(s, lap):
        col = jax.lax.dynamic_index_in_dim(idx, s, axis=1, keepdims=False)
        ws = jax.lax.dynamic_index_in_dim(wts, s, axis=1, keepdims=False)
        g = jnp.take(src, col, axis=0).astype(jnp.float32)
        return lap + ws[:, None, None] * g

    return jax.lax.fori_loop(0, d_max, acc, lap0)


def _apply_omega(beta_tile, omega, lap, scale):
    """beta + scale * Omega @ lap in the (M, L) lane layout.

    upd[v, m, l] = sum_k omega[v, l, k] * lap[v, m, k] — contracting
    both lane (k) dims on the MXU with f32 accumulation.
    """
    upd = jax.lax.dot_general(
        lap,
        omega,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return beta_tile + scale * upd


def _round_kernel(
    scale_ref, beta_ref, omega_ref, idx_ref, w_ref, deg_ref, out_ref,
    *, block_v, d_max, bf16,
):
    i = pl.program_id(0)
    beta_full = beta_ref[...]
    lap = _lap_tile(
        beta_full, idx_ref[...], w_ref[...].astype(jnp.float32),
        deg_ref[...][:, 0].astype(jnp.float32), i * block_v, block_v,
        d_max, bf16,
    )
    beta_tile = jax.lax.dynamic_slice_in_dim(
        beta_full, i * block_v, block_v, axis=0
    )
    out_ref[...] = _apply_omega(
        beta_tile, omega_ref[...], lap, scale_ref[0, 0]
    )


def _round_kernel_payload(
    scale_ref, beta_ref, pay_ref, omega_ref, idx_ref, w_ref, deg_ref,
    out_ref, *, block_v, d_max,
):
    i = pl.program_id(0)
    lap = _lap_tile(
        pay_ref[...], idx_ref[...], w_ref[...].astype(jnp.float32),
        deg_ref[...][:, 0].astype(jnp.float32), i * block_v, block_v,
        d_max, bf16=False,
    )
    beta_tile = jax.lax.dynamic_slice_in_dim(
        beta_ref[...], i * block_v, block_v, axis=0
    )
    out_ref[...] = _apply_omega(
        beta_tile, omega_ref[...], lap, scale_ref[0, 0]
    )


def _multiround_kernel(
    scale_ref, beta_ref, omega_ref, idx_ref, w_ref, deg_ref, out_ref,
    *, d_max, num_snapshots, num_rounds, bf16,
):
    omega = omega_ref[...]
    idx_all = idx_ref[...]
    w_all = w_ref[...].astype(jnp.float32)
    deg_all = deg_ref[...].astype(jnp.float32)
    scale = scale_ref[0, 0]
    V = omega.shape[0]

    def round_fn(k, b):
        s = jax.lax.rem(k, num_snapshots)
        idx = jax.lax.dynamic_index_in_dim(idx_all, s, 0, keepdims=False)
        wts = jax.lax.dynamic_index_in_dim(w_all, s, 0, keepdims=False)
        deg = jax.lax.dynamic_index_in_dim(deg_all, s, 0, keepdims=False)
        lap = _lap_tile(b, idx, wts, deg, 0, V, d_max, bf16)
        return _apply_omega(b, omega, lap, scale)

    out_ref[...] = jax.lax.fori_loop(0, num_rounds, round_fn, beta_ref[...])


# ---------------------------------------------------------------------------
# Padding / layout
# ---------------------------------------------------------------------------


def _prep(betas, omegas, idx, w, deg, block_v):
    """(V, L, M) -> padded kernel operands in the (V, M, L) layout."""
    V, L, M = betas.shape
    bv = min(max(int(block_v), 1), _rup(V, 1))
    Vp = _rup(V, bv)
    Lp = _rup(L, 128)
    Mp = _rup(M, 8)
    dp = _rup(idx.shape[-1], 128)
    bt = jnp.transpose(betas, (0, 2, 1)).astype(jnp.float32)
    bt = jnp.pad(bt, ((0, Vp - V), (0, Mp - M), (0, Lp - L)))
    om = jnp.pad(
        omegas.astype(jnp.float32),
        ((0, Vp - V), (0, Lp - L), (0, Lp - L)),
    )
    ip = jnp.pad(idx, ((0, 0), (0, Vp - V), (0, dp - idx.shape[-1])))
    wp = jnp.pad(
        w.astype(jnp.float32),
        ((0, 0), (0, Vp - V), (0, dp - w.shape[-1])),
    )
    dg = jnp.pad(deg.astype(jnp.float32), ((0, 0), (0, Vp - V)))
    return bt, om, ip, wp, dg, (Vp, Lp, Mp, dp, bv)


def _unpack(out, V, L, M, dtype):
    return jnp.transpose(out[:V, :M, :L], (0, 2, 1)).astype(dtype)


def _snapshot(arr, k):
    S = arr.shape[0]
    return arr[0] if S == 1 else jnp.take(arr, jnp.mod(k, S), axis=0)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def elm_gossip_pallas(
    betas, omegas, idx, w, deg, scale, *, num_rounds=1, block_v=8,
    compress=None, payload=None, interpret=False,
):
    """num_rounds fused eq. (20) rounds, one kernel launch per round.

    betas: (V, L, M); omegas: (V, L, L); idx/w: (S, V, d_max);
    deg: (S, V); scale = gamma / (VC) (scalar, may be traced).
    compress="bf16" casts the gossiped payload in-kernel;
    payload=(V, L, M) gathers an explicitly encoded payload instead
    (single round only — the encoder reruns between rounds).
    """
    if payload is not None and num_rounds != 1:
        raise ValueError(
            "an explicit payload= is re-encoded outside the kernel every "
            f"round, so it implies num_rounds=1 (got {num_rounds})"
        )
    bf16 = compress == "bf16"
    V, L, M = betas.shape
    d_max = idx.shape[-1]
    bt, om, ip, wp, dg, (Vp, Lp, Mp, dp, bv) = _prep(
        betas, omegas, idx, w, deg, block_v
    )
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    grid = (Vp // bv,)
    full = pl.BlockSpec((Vp, Mp, Lp), lambda i: (0, 0, 0))
    tiled3 = pl.BlockSpec((bv, Mp, Lp), lambda i: (i, 0, 0))
    omega_spec = pl.BlockSpec((bv, Lp, Lp), lambda i: (i, 0, 0))
    list_spec = pl.BlockSpec((bv, dp), lambda i: (i, 0))
    deg_spec = pl.BlockSpec((bv, 1), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((Vp, Mp, Lp), jnp.float32)

    if payload is None:
        kernel = functools.partial(
            _round_kernel, block_v=bv, d_max=d_max, bf16=bf16
        )
        in_specs = [scale_spec, full, omega_spec, list_spec, list_spec,
                    deg_spec]

        def one_round(b, k):
            out = pl.pallas_call(
                kernel, grid=grid, in_specs=in_specs,
                out_specs=tiled3, out_shape=out_shape,
                interpret=interpret,
            )(
                scale, b, om, _snapshot(ip, k), _snapshot(wp, k),
                _snapshot(dg, k)[:, None],
            )
            return out, None

        if num_rounds == 1:
            out = one_round(bt, 0)[0]
        else:
            out, _ = jax.lax.scan(one_round, bt, jnp.arange(num_rounds))
        return _unpack(out, V, L, M, betas.dtype)

    pt = jnp.transpose(payload, (0, 2, 1)).astype(jnp.float32)
    pt = jnp.pad(pt, ((0, Vp - V), (0, Mp - M), (0, Lp - L)))
    kernel = functools.partial(
        _round_kernel_payload, block_v=bv, d_max=d_max
    )
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[scale_spec, full, full, omega_spec, list_spec,
                  list_spec, deg_spec],
        out_specs=tiled3, out_shape=out_shape, interpret=interpret,
    )(scale, bt, pt, om, ip[0], wp[0], dg[0][:, None])
    return _unpack(out, V, L, M, betas.dtype)


def elm_gossip_pallas_multiround(
    betas, omegas, idx, w, deg, scale, *, num_rounds, compress=None,
    interpret=False,
):
    """All rounds in one kernel: state resident in VMEM throughout.

    Small-state arm — gate callers on ``multiround_vmem_bytes``. The
    topology snapshots (time-varying bases, FaultyMixer masked periods)
    ride along in VMEM and round k picks snapshot k % S in-kernel.
    """
    bf16 = compress == "bf16"
    V, L, M = betas.shape
    S, _, d_max = idx.shape
    bt, om, ip, wp, dg, (Vp, Lp, Mp, dp, _) = _prep(
        betas, omegas, idx, w, deg, block_v=V
    )
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    def whole(*dims):
        return pl.BlockSpec(dims, lambda: (0,) * len(dims))

    kernel = functools.partial(
        _multiround_kernel, d_max=d_max, num_snapshots=S,
        num_rounds=num_rounds, bf16=bf16,
    )
    out = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            whole(1, 1), whole(Vp, Mp, Lp), whole(Vp, Lp, Lp),
            whole(S, Vp, dp), whole(S, Vp, dp), whole(S, Vp),
        ],
        out_specs=whole(Vp, Mp, Lp),
        out_shape=jax.ShapeDtypeStruct((Vp, Mp, Lp), jnp.float32),
        interpret=interpret,
    )(scale, bt, om, ip, wp, dg)
    return _unpack(out, V, L, M, betas.dtype)


def multiround_vmem_bytes(V, L, M, S, d_max) -> int:
    """Resident bytes of the multi-round arm (everything in VMEM)."""
    Vp, Lp, Mp, dp = V, _rup(L, 128), _rup(M, 8), _rup(d_max, 128)
    state = 4 * Vp * Mp * Lp  # beta in + out + lap accumulator
    return 3 * state + 4 * Vp * Lp * Lp + S * Vp * (4 * dp + 4 * dp + 4)
