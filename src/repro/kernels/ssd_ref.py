"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Chunked algorithm from Dao & Gu, arXiv:2405.21060 (sec. 6): within a
chunk of Q steps the recurrence is computed as a masked attention-like
quadratic form; across chunks a linear scan carries the (nh, hd, ds)
state. Single SSM group (g = 1): B and C are shared across heads.

Shapes:
  x   (b, s, nh, hd)   inputs (already conv'd/activated)
  dt  (b, s, nh)       positive step sizes (softplus applied)
  A   (nh,)            negative decay rates
  B   (b, s, ds)       input projections
  C   (b, s, ds)       output projections
returns
  y           (b, s, nh, hd)
  final_state (b, nh, hd, ds)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_reference(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
):
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt = 0 on padded steps => decay 1, zero input: exact identity
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_padded = s + pad
    nc = s_padded // chunk

    f32 = jnp.float32
    xr = x.reshape(b, nc, chunk, nh, hd)
    dtr = dt.reshape(b, nc, chunk, nh).astype(f32)
    Br = B.reshape(b, nc, chunk, ds).astype(f32)
    Cr = C.reshape(b, nc, chunk, ds).astype(f32)
    # log-decay increments and within-chunk cumulative sums
    adt = dtr * A.astype(f32)  # (b, nc, Q, nh), negative
    cum = jnp.cumsum(adt, axis=2)  # (b, nc, Q, nh)

    h0 = (
        jnp.zeros((b, nh, hd, ds), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def per_chunk(h, inp):
        xc, dtc, bc, cc, cumc = inp
        # xc (b,Q,nh,hd) dtc/cumc (b,Q,nh) bc/cc (b,Q,ds)
        # --- intra-chunk quadratic (the "duality" attention form) ---
        cb = jnp.einsum("bin,bjn->bij", cc, bc)  # (b,Q,Q)
        # valid (i >= j) entries have cum_i - cum_j <= 0; clamp the
        # masked upper triangle so exp can't overflow (inf * 0 -> NaN
        # in the backward pass otherwise).
        diff = jnp.minimum(
            cumc[:, :, None, :] - cumc[:, None, :, :], 0.0
        )  # (b,i,j,h)
        decay = jnp.exp(diff)
        att = cb[..., None] * decay * dtc[:, None, :, :]  # (b,i,j,h)
        att = jnp.where(tri[None, :, :, None], att, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", att, xc.astype(f32))
        # --- inter-chunk: contribution of the carried state ---
        state_decay = jnp.exp(cumc)  # (b,Q,nh) decay from chunk start to i
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", cc, h, state_decay
        )
        y = (y_intra + y_inter).astype(x.dtype)
        # --- new carried state ---
        total = cumc[:, -1, :]  # (b,nh) full-chunk log decay
        w = jnp.exp(total[:, None, :] - cumc) * dtc  # (b,Q,nh)
        new_h = h * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqn,bqhp,bqh->bhpn", bc, xc.astype(f32), w
        )
        return new_h, y

    inputs = (
        xr.transpose(1, 0, 2, 3, 4),
        dtr.transpose(1, 0, 2, 3),
        Br.transpose(1, 0, 2, 3),
        Cr.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    h_final, ys = lax.scan(per_chunk, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s_padded, nh, hd)[:, :s]
    return y, h_final.astype(jnp.float32)


def ssd_decode_step(
    state: jax.Array,  # (b, nh, hd, ds) f32
    x_t: jax.Array,  # (b, nh, hd)
    dt_t: jax.Array,  # (b, nh)
    A: jax.Array,  # (nh,)
    B_t: jax.Array,  # (b, ds)
    C_t: jax.Array,  # (b, ds)
):
    """One recurrent step: h <- e^{dt A} h + dt x B^T ; y = h C."""
    f32 = jnp.float32
    a = jnp.exp(dt_t.astype(f32) * A.astype(f32))  # (b, nh)
    upd = (dt_t[..., None].astype(f32) * x_t.astype(f32))[..., None] * B_t[
        :, None, None, :
    ].astype(f32)
    new_state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    return new_state, y.astype(x_t.dtype)


def ssd_naive_reference(x, dt, A, B, C, *, initial_state=None):
    """O(s) step-by-step recurrence — the ground truth for the chunked form."""
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    h = (
        jnp.zeros((b, nh, hd, ds), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dtt, bt, ct = inp
        h, y = ssd_decode_step(h, xt, dtt, A, bt, ct)
        return h, y

    inputs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        B.transpose(1, 0, 2),
        C.transpose(1, 0, 2),
    )
    h, ys = lax.scan(step, h, inputs)
    return ys.transpose(1, 0, 2, 3), h
