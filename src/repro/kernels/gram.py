"""Pallas TPU kernel: blocked Gram matrix P = H^T H (+ cross moment H^T T).

This is the paper's compute hot spot: every DC-ELM node computes
P_i = H_i^T H_i (N_i x L inputs, L x L output) once per training round
and per online chunk. On TPU we tile for the MXU:

  grid = (L/bl, L/bl, N/bn)   -- n innermost so the (bl, bl) f32 output
                                 block stays resident in VMEM while the
                                 N dimension streams through
  A-block (bn, bl) at rows n, cols i      } both operands stream from
  B-block (bn, bl) at rows n, cols j      } HBM once per (i, j) pass

VMEM working set = 2 * bn * bl * in_bytes + bl * bl * 4. With the
defaults (bn=512, bl=256, bf16) that is 2*512*256*2 + 256*256*4 =
0.78 MiB -- far under the ~16 MiB/core budget, and bl=256 keeps the MXU
matmul dims at multiples of 128.

Accumulation is f32 regardless of input dtype (ridge solves downstream
are sensitive to Gram conditioning).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] += jax.lax.dot_general(
        a, b,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract rows: A^T B
        preferred_element_type=jnp.float32,
    )


def _gram_sym_kernel(a_ref, b_ref, o_ref):
    """Symmetry-exploiting variant: skip strictly-lower (i > j) blocks.

    P = H^T H is symmetric, so only the upper block triangle hits the
    MXU — ~2x FLOP reduction at large L (the kernel-level §Perf
    iteration for the paper's hot spot). The wrapper mirrors the result.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i <= j)
    def _compute():
        o_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(
    jax.jit, static_argnames=("block_l", "block_n", "interpret", "symmetric")
)
def gram_pallas(
    H: jax.Array,
    *,
    block_l: int = 256,
    block_n: int = 512,
    interpret: bool = False,
    symmetric: bool = True,
) -> jax.Array:
    """P = H^T H via pl.pallas_call. H: (N, L) -> (L, L) f32.

    symmetric=True computes only the upper block triangle (~2x fewer
    MXU flops) and mirrors it.
    """
    N, L = H.shape
    bl = min(block_l, L)
    bn = min(block_n, N)
    # pad to tile multiples (zero rows/cols contribute nothing)
    pN, pL = (-N) % bn, (-L) % bl
    if pN or pL:
        H = jnp.pad(H, ((0, pN), (0, pL)))
    N2, L2 = H.shape
    grid = (L2 // bl, L2 // bl, N2 // bn)
    out = pl.pallas_call(
        _gram_sym_kernel if symmetric else _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, n: (n, i)),
            pl.BlockSpec((bn, bl), lambda i, j, n: (n, j)),
        ],
        out_specs=pl.BlockSpec((bl, bl), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((L2, L2), jnp.float32),
        interpret=interpret,
    )(H, H)
    out = out[:L, :L]
    if symmetric:
        upper = jnp.triu(out)
        out = upper + upper.T - jnp.diag(jnp.diag(upper))
    return out


def _cross_kernel(h_ref, t_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        h_ref[...], t_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_l", "block_m", "block_n", "interpret")
)
def cross_pallas(
    H: jax.Array,
    T: jax.Array,
    *,
    block_l: int = 256,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Q = H^T T. H: (N, L), T: (N, M) -> (L, M) f32."""
    N, L = H.shape
    _, M = T.shape
    bl, bm, bn = min(block_l, L), min(block_m, M), min(block_n, N)
    pN, pL, pM = (-N) % bn, (-L) % bl, (-M) % bm
    if pN or pL:
        H = jnp.pad(H, ((0, pN), (0, pL)))
    if pN or pM:
        T = jnp.pad(T, ((0, pN), (0, pM)))
    N2, L2 = H.shape
    M2 = T.shape[1]
    grid = (L2 // bl, M2 // bm, N2 // bn)
    out = pl.pallas_call(
        _cross_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, n: (n, i)),
            pl.BlockSpec((bn, bm), lambda i, j, n: (n, j)),
        ],
        out_specs=pl.BlockSpec((bl, bm), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((L2, M2), jnp.float32),
        interpret=interpret,
    )(H, T)
    return out[:L, :M]
