"""Measured kernel autotuner: roofline-pruned sweep, versioned cache.

The fused planes (kernels/elm_stats.py, kernels/elm_predict.py and
their lax.scan fallbacks) expose block knobs — ``block_n``/``block_l``
on the Pallas grid, ``chunk`` on the scan — whose optimum moves with
the problem point (N, D, L, M, dtype) and the backend. Hand-picked
values demonstrably lose away from the point they were picked at
(BENCH_stats.json once shipped a 0.54x row at N=8192). This module
makes the selection a *measured* decision:

1. **Candidate grid.** ``candidates`` enumerates power-of-two block
   sizes clamped to the problem dims, always including the current
   hard-coded defaults so a tuned cache can never be worse than the
   untuned code path on the machine that produced it.
2. **Roofline pruning.** ``roofline_prune`` scores each candidate with
   the same terms as ``analysis/roofline.py`` — a working-set test
   (does the candidate's resident set fit the VMEM/cache budget?) and
   a ``max(t_compute, t_memory)`` estimate built on the module's
   PEAK_FLOPS / HBM_BW constants (used for *relative* ranking; the
   constants cancel out of the comparison). Candidates whose working
   set blows the budget, or whose estimate is dominated (> PRUNE_FACTOR
   x the best in-budget estimate), are discarded before any
   measurement.
3. **Measurement.** Survivors are timed with the exact harness the
   plane benchmarks use (``benchmarks/_bench_util.py`` imports it from
   here): one warm-up call, then block_until_ready-bracketed repeats,
   *interleaved round-robin* across candidates so machine-speed drift
   (frequency scaling, noisy neighbours) hits every candidate equally
   instead of deciding the winner.
4. **Cache.** Winners persist to a schema-versioned JSON
   (``TUNED_kernels.json`` at the repo root by default, override with
   ``cache_path=`` or the ``REPRO_TUNED_CACHE`` env var), keyed by
   (op, impl, N, D, L, M, dtype, backend). Each entry records the
   winning config, its measured wall time, the jax version and the full
   measured sweep. An in-process LRU memo sits on top so the dispatch
   wrappers can consult the cache at trace time for free.

Lookup policy: exact point first, then the nearest-N entry for the
same (op, impl, D, L, M, dtype, backend) within a 4x ratio (serving
buckets hit the tuned table without tuning every batch shape), else
miss — and on a miss the dispatchers keep today's defaults, so
cold-start behavior is unchanged. A jax upgrade does not invalidate
entries outright (block optima are shape-driven, not version-driven);
instead ``tools/bench_gate.py`` re-measures nightly and *warns* when a
committed winner drifts >1.5x from fresh measurements.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import threading
import time
from collections import OrderedDict
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis.roofline import HBM_BW, PEAK_FLOPS

SCHEMA_VERSION = 1
OPS = ("stats", "preact_stats", "predict", "stacked", "gossip")
IMPLS = ("scan", "pallas")

#: working-set budgets for the pruning test (bytes): VMEM for the
#: Pallas grid, an L2/L3-ish cache budget for the scan fallback
VMEM_BUDGET = 16 * 2**20
CACHE_BUDGET = 32 * 2**20
#: candidates whose roofline estimate exceeds the best in-budget
#: estimate by this factor are pruned without measurement
PRUNE_FACTOR = 1.5
#: measured walls within this factor of the fastest are considered a
#: tie; ties on the scan impl break toward the largest chunk
TIE_FACTOR = 1.03

#: the hard-coded defaults the dispatchers fall back to on a cache
#: miss (elm_stats_scan / elm_predict_scan / *_pallas signatures)
DEFAULTS = {
    ("stats", "scan"): {"chunk": 2048},
    ("preact_stats", "scan"): {"chunk": 2048},
    ("predict", "scan"): {"chunk": 4096},
    # stacked: the gathered (chunk, L, M) beta tiles dominate the
    # working set, so the default chunk sits below the single-beta scan
    ("stacked", "scan"): {"chunk": 2048},
    ("stats", "pallas"): {"block_n": 512, "block_l": 256},
    ("preact_stats", "pallas"): {"block_n": 512, "block_l": 256},
    ("predict", "pallas"): {"block_n": 512, "block_l": 256},
    ("stacked", "pallas"): {"block_n": 256, "block_l": 256},
    # gossip: the point maps V -> N and d_max -> D (kernels/elm_gossip);
    # scan "chunk" is neighbor slots per gather step, pallas "block_n"
    # is the node tile block_v
    ("gossip", "scan"): {"chunk": 8},
    ("gossip", "pallas"): {"block_n": 8},
}

_REPO_ROOT = Path(__file__).resolve().parents[3]


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNED_CACHE", str(_REPO_ROOT / "TUNED_kernels.json")
    )


# ---------------------------------------------------------------------------
# Timing harness (shared with benchmarks/_bench_util.py)
# ---------------------------------------------------------------------------


def timeit_ms(fn, *args, repeats=3):
    """Min wall ms over `repeats` bracketed calls after one warm-up.

    The minimum, not the mean: scheduler preemptions and cache-state
    noise only ever make a call *slower*, so the min is the best
    estimate of the program's intrinsic cost — and the statistic least
    likely to flip a close fused-vs-unfused ratio between runs.
    """
    out = fn(*args)
    jax.block_until_ready(out)
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def paired_timeit_ms(fns, *args, repeats=3):
    """Interleaved min wall ms for several callables over shared args.

    The machine's speed can drift a lot on second timescales (CPU
    frequency scaling, noisy neighbours). Timing callables in separate
    back-to-back ``timeit_ms`` blocks bakes that drift into their
    *ratio* — enough to flip a close fused-vs-unfused comparison.
    Round-robin interleaving (repeat 1 of every fn, repeat 2 of every
    fn, ...) exposes all callables to the same machine episodes, so
    drift cancels out of the ratios and only the intrinsic cost
    difference survives the per-fn min.
    """
    for fn in fns:  # one warm-up each (compile + first-touch)
        jax.block_until_ready(fn(*args))
    best = [math.inf] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e3 for b in best]


# ---------------------------------------------------------------------------
# Points, candidates, roofline pruning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunePoint:
    """One (op, impl, problem, backend) tuning coordinate.

    ``T`` is the stacked-beta tenant count — the new block axis of the
    multi-tenant predict. It is required for op="stacked" and joins
    the cache key there (the beta block scales with T); the other ops
    keep T=0 and their keys are byte-identical to the pre-stacked
    schema, so committed caches stay valid.
    """

    op: str  # "stats" | "predict" | "stacked" | "gossip"
    impl: str  # "scan" | "pallas"
    N: int
    D: int
    L: int
    M: int
    dtype: str
    backend: str
    T: int = 0  # tenant count; stacked op only

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        if self.impl not in IMPLS:
            raise ValueError(
                f"impl must be one of {IMPLS}, got {self.impl!r}"
            )
        if self.op == "stacked" and self.T <= 0:
            raise ValueError(
                f"op='stacked' needs a tenant count T >= 1, got {self.T}"
            )

    @property
    def key(self) -> str:
        t = f"_T{self.T}" if self.T else ""
        return (
            f"{self.op}/{self.impl}/N{self.N}_D{self.D}_L{self.L}"
            f"_M{self.M}{t}_{self.dtype}/{self.backend}"
        )

    @property
    def itemsize(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    @property
    def flops(self) -> float:
        """Useful flops of the op (config-independent)."""
        N, D, L, M = self.N, self.D, self.L, self.M
        if self.op == "stats":
            return 2.0 * N * D * L + 2.0 * N * L * (L + M)
        if self.op == "preact_stats":
            # vertical mode: the feature matmul already happened across
            # column-sliced nodes; only bias+activation+moments remain
            return 2.0 * N * L * (L + M)
        if self.op == "gossip":
            # per round: neighbor-weighted gather-accumulate over D
            # slots plus the (L, L) @ (L, M) Omega contraction per node
            return 2.0 * N * D * L * M + 2.0 * N * L * L * M
        # predict and stacked share the useful-flop count: the stacked
        # gather adds traffic, not MACs
        return 2.0 * N * L * (D + M)


def candidates(point: TunePoint) -> list[dict]:
    """Power-of-two block grid clamped to the problem dims.

    Always contains the hard-coded default (clamped), so measuring the
    survivors can never produce a cache entry worse than the untuned
    path on the machine that measured it.
    """
    out = []
    if point.op == "gossip":
        if point.impl == "scan":
            # chunk = neighbor slots per gather step, capped at d_max
            chunks = {min(c, point.D) for c in (1, 2, 4, 8, 16, 32, 64)}
            chunks.add(min(DEFAULTS[("gossip", "scan")]["chunk"], point.D))
            return [{"chunk": c} for c in sorted(chunks)]
        bns = {min(b, point.N) for b in (8, 16, 32, 64)}
        bns.add(min(DEFAULTS[("gossip", "pallas")]["block_n"], point.N))
        return [{"block_n": b} for b in sorted(bns)]
    if point.impl == "scan":
        grid = (
            (256, 512, 1024, 2048, 4096)  # gathered tiles cap the chunk
            if point.op == "stacked"
            else (512, 1024, 2048, 4096, 8192, 16384)
        )
        chunks = {min(c, point.N) for c in grid}
        chunks.add(min(DEFAULTS[(point.op, "scan")]["chunk"], point.N))
        out = [{"chunk": c} for c in sorted(chunks)]
    else:
        bns = {min(b, point.N) for b in (128, 256, 512, 1024)}
        bls = {min(b, point.L) for b in (128, 256, 512)}
        d = DEFAULTS[(point.op, "pallas")]
        bns.add(min(d["block_n"], point.N))
        bls.add(min(d["block_l"], point.L))
        out = [
            {"block_n": bn, "block_l": bl}
            for bn in sorted(bns)
            for bl in sorted(bls)
        ]
    return out


def working_set_bytes(point: TunePoint, cfg: dict) -> float:
    """Resident bytes a candidate keeps hot (the VMEM/cache test)."""
    s = point.itemsize
    D, L, M, T = point.D, point.L, point.M, point.T
    if point.op == "gossip":
        N = point.N
        if point.impl == "scan":
            # state + f32 lap carry + the gathered (V, chunk, L*M) tile
            # (the chunk knob's term) + omegas + lists
            c = cfg["chunk"]
            return (
                s * N * L * M
                + 4.0 * N * L * M * (1 + c)
                + s * N * L * L
                + 8.0 * N * D
            )
        # pallas: full state resident + per-tile omega/lap/out blocks
        bn = cfg["block_n"]
        return (
            4.0 * N * L * M
            + 4.0 * bn * (L * L + 2 * L * M)
            + 8.0 * bn * D
        )
    if point.impl == "scan":
        c = cfg["chunk"]
        if point.op == "stats":
            # X/T chunk + W + H tile + f32 moment carries
            return s * (c * D + D * L + c * L + c * M) + 4.0 * (
                L * L + L * M
            )
        if point.op == "preact_stats":
            # Z chunk + H tile + T chunk + f32 moment carries
            return s * (2 * c * L + c * M) + 4.0 * (L * L + L * M)
        if point.op == "stacked":
            # X chunk + W + H tile + stacked betas + gathered per-row
            # beta tiles (the term that caps the chunk) + Y chunk
            return s * (c * D + D * L + c * L + c * M) + 4.0 * (
                T * L * M + c * L * M
            )
        # predict: X chunk + W + H tile + beta + Y chunk
        return s * (c * D + D * L + c * L + c * M) + 4.0 * L * M
    bn, bl = cfg["block_n"], cfg["block_l"]
    if point.op == "stats":
        # X tile + two W blocks + two H tiles + T tile + f32 P/Q blocks
        return s * (bn * D + 2 * D * bl + 2 * bn * bl + bn * M) + 4.0 * (
            bl * bl + bl * M
        )
    if point.op == "preact_stats":
        # two Z tiles + two H tiles + T tile + f32 P/Q blocks
        return s * (4 * bn * bl + bn * M) + 4.0 * (bl * bl + bl * M)
    if point.op == "stacked":
        # X tile + W block + H tile + (T, bl, M) beta block + gathered
        # (bn, bl, M) tiles + f32 out block
        return s * (bn * D + D * bl + bn * bl) + 4.0 * (
            T * bl * M + bn * bl * M + bn * M
        )
    # predict: X tile + W block + H tile + beta block + f32 out block
    return s * (bn * D + D * bl + bn * bl + bl * M) + 4.0 * bn * M


def hbm_bytes(point: TunePoint, cfg: dict) -> float:
    """Modeled off-chip traffic for a candidate (roofline memory term).

    Captures the block-size tradeoff: small blocks re-touch the f32
    accumulators (scan) or re-stream X per (i, j) block pair (Pallas);
    large blocks spill the hidden tile out of the working-set budget.
    """
    s = point.itemsize
    N, D, L, M, T = point.N, point.D, point.L, point.M, point.T
    if point.op == "gossip":
        # per round: state read+write, omegas, neighbor lists; the scan
        # materializes the gathered (V, chunk, L*M) tiles — an extra
        # round trip when a tile spills the cache budget
        base = 4.0 * (2.0 * N * L * M + N * L * L) + 8.0 * N * D
        if point.impl == "scan":
            c = cfg["chunk"]
            base += 4.0 * N * D * L * M
            if 4.0 * N * c * L * M > CACHE_BUDGET / 2:
                base += 4.0 * N * D * L * M
        return base
    if point.impl == "scan":
        c = cfg["chunk"]
        steps = math.ceil(N / c)
        if point.op == "preact_stats":
            base = s * (N * L + N * M)  # Z and T stream through once
        else:
            base = s * (N * D + N * M)  # X and T stream through once
        carry = 2.0 * 4 * (L * L + L * M) * steps  # P/Q read+write per step
        # the hidden tile spills past the cache budget -> extra round trip
        spill = s * N * L if s * c * L > CACHE_BUDGET / 2 else 0.0
        out = (
            4.0 * (L * L + L * M)
            if point.op in ("stats", "preact_stats")
            else s * N * M
        )
        if point.op == "stacked":
            # the gathered (c, L, M) beta tiles are materialized per
            # step: N*L*M of gather traffic across the whole run
            base += 4.0 * N * L * M
        return base + carry + spill + out
    bn, bl = cfg["block_n"], cfg["block_l"]
    jblocks = math.ceil(L / bl)
    if point.op == "stats":
        # X re-streams once per upper-triangle (i, j) block pair
        xpasses = jblocks * (jblocks + 1) / 2
        return (
            s * N * D * xpasses
            + s * D * L * jblocks * math.ceil(N / bn)
            + 4.0 * (L * L + L * M)
        )
    if point.op == "preact_stats":
        # two (bn, bl) Z tiles per upper-triangle (i, j) block pair
        zpasses = jblocks * (jblocks + 1) / 2
        return s * 2.0 * N * bl * zpasses + 4.0 * (L * L + L * M)
    # predict/stacked: X re-streams once per j (L) block; the stacked
    # path additionally re-reads the (T, bl, M) beta block per grid
    # step and gathers (bn, bl, M) per-row tiles
    base = s * N * D * jblocks + s * D * L * math.ceil(N / bn) + s * N * M
    if point.op == "stacked":
        base += 4.0 * (T * L * M * math.ceil(N / bn) + N * L * M)
    return base


def estimate(point: TunePoint, cfg: dict) -> dict:
    """Roofline terms for one candidate (relative ranking only)."""
    t_compute = point.flops / PEAK_FLOPS
    t_memory = hbm_bytes(point, cfg) / HBM_BW
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_estimate": max(t_compute, t_memory),
        "working_set": working_set_bytes(point, cfg),
    }


def roofline_prune(
    point: TunePoint, cands: list[dict], *, factor: float = PRUNE_FACTOR
) -> tuple[list[dict], list[dict]]:
    """(kept, pruned): drop candidates whose working set blows the
    VMEM/cache budget or whose roofline estimate is dominated."""
    budget = VMEM_BUDGET if point.impl == "pallas" else CACHE_BUDGET
    scored = [(estimate(point, c), c) for c in cands]
    in_budget = [sc for sc in scored if sc[0]["working_set"] <= budget]
    if not in_budget:  # degenerate point: keep the smallest working set
        in_budget = [min(scored, key=lambda sc: sc[0]["working_set"])]
    best = min(sc[0]["t_estimate"] for sc in in_budget)
    kept, pruned = [], []
    for est, c in in_budget:
        (kept if est["t_estimate"] <= factor * best else pruned).append(c)
    pruned.extend(c for est, c in scored if (est, c) not in in_budget)
    return kept, pruned


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def _problem(point: TunePoint):
    """The measurement arrays — same construction as the benches."""
    dt = jnp.dtype(point.dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    if point.op == "gossip":
        # V <- N nodes, d_max <- D neighbor slots; a synthetic regular
        # graph (random indices, unit weights) matches the gather cost
        V, d = point.N, point.D
        betas = jax.random.normal(ks[0], (V, point.L, point.M)).astype(dt)
        omegas = jax.random.normal(
            ks[1], (V, point.L, point.L)
        ).astype(dt)
        idx = jax.random.randint(ks[2], (1, V, d), 0, V, dtype=jnp.int32)
        w = jnp.ones((1, V, d), dt)
        deg = jnp.full((1, V), float(d), dt)
        return betas, omegas, idx, w, deg, 0.01
    if point.op == "preact_stats":
        Z = jax.random.normal(ks[0], (point.N, point.L)).astype(dt)
        b = jax.random.normal(ks[2], (point.L,)).astype(jnp.float32)
        T = jax.random.normal(ks[3], (point.N, point.M)).astype(dt)
        return Z, b, T
    X = jax.random.normal(ks[0], (point.N, point.D)).astype(dt)
    W = jax.random.normal(ks[1], (point.D, point.L)).astype(dt)
    b = jax.random.normal(ks[2], (point.L,)).astype(jnp.float32)
    if point.op == "stats":
        T = jax.random.normal(ks[3], (point.N, point.M)).astype(dt)
        return X, W, b, T
    beta = jax.random.normal(
        ks[3], (point.L, point.M), dtype=jnp.float32
    )
    if point.op == "stacked":
        betas = jax.random.normal(
            ks[3], (point.T, point.L, point.M), dtype=jnp.float32
        )
        tids = jax.random.randint(
            jax.random.key(1), (point.N,), 0, point.T, dtype=jnp.int32
        )
        return X, W, b, betas, tids
    return X, W, b, beta


def candidate_fn(point: TunePoint, cfg: dict):
    """A jitted callable running the point's op with one candidate."""
    if point.op == "gossip":
        # a short fixed round count: enough for the per-round cost to
        # dominate the scan setup, cheap enough to sweep
        if point.impl == "scan":
            from repro.kernels.elm_gossip_ref import elm_gossip_scan

            return jax.jit(
                functools.partial(
                    elm_gossip_scan, num_rounds=4, chunk=cfg["chunk"]
                )
            )
        from repro.kernels.elm_gossip import elm_gossip_pallas

        return jax.jit(
            functools.partial(
                elm_gossip_pallas, num_rounds=4,
                block_v=cfg["block_n"],
                interpret=jax.default_backend() != "tpu",
            )
        )
    if point.impl == "scan":
        if point.op == "stats":
            from repro.kernels.elm_stats_ref import elm_stats_scan

            return jax.jit(
                functools.partial(
                    elm_stats_scan, activation="sigmoid",
                    chunk=cfg["chunk"],
                )
            )
        if point.op == "preact_stats":
            from repro.kernels.elm_stats_ref import preact_stats_scan

            return jax.jit(
                functools.partial(
                    preact_stats_scan, activation="sigmoid",
                    chunk=cfg["chunk"],
                )
            )
        if point.op == "stacked":
            from repro.kernels.elm_predict_ref import (
                elm_predict_stacked_scan,
            )

            return jax.jit(
                functools.partial(
                    elm_predict_stacked_scan, activation="sigmoid",
                    chunk=cfg["chunk"],
                )
            )
        from repro.kernels.elm_predict_ref import elm_predict_scan

        return jax.jit(
            functools.partial(
                elm_predict_scan, activation="sigmoid", chunk=cfg["chunk"]
            )
        )
    if point.op == "stats":
        from repro.kernels.elm_stats import elm_stats_pallas

        return jax.jit(
            functools.partial(
                elm_stats_pallas, activation="sigmoid", **cfg
            )
        )
    if point.op == "preact_stats":
        from repro.kernels.elm_stats import elm_preact_stats_pallas

        return jax.jit(
            functools.partial(
                elm_preact_stats_pallas, activation="sigmoid", **cfg
            )
        )
    if point.op == "stacked":
        from repro.kernels.elm_predict import elm_predict_stacked_pallas

        return jax.jit(
            functools.partial(
                elm_predict_stacked_pallas, activation="sigmoid", **cfg
            )
        )
    from repro.kernels.elm_predict import elm_predict_pallas

    return jax.jit(
        functools.partial(elm_predict_pallas, activation="sigmoid", **cfg)
    )


def measure_candidates(
    point: TunePoint, cands: list[dict], *, repeats: int = 2
) -> list[dict]:
    """Time each candidate on the point's problem; sorted fastest first.

    Candidates are measured round-robin (``paired_timeit_ms``) so the
    winner reflects intrinsic cost, not which candidate happened to run
    during a fast spell of a drifting machine.
    """
    args = _problem(point)
    fns = [candidate_fn(point, cfg) for cfg in cands]
    walls = paired_timeit_ms(fns, *args, repeats=repeats)
    results = [
        {"config": cfg, "wall_ms": ms} for cfg, ms in zip(cands, walls)
    ]
    return sorted(results, key=lambda r: r["wall_ms"])


# ---------------------------------------------------------------------------
# Cache (JSON file + in-process LRU memo)
# ---------------------------------------------------------------------------

_MEMO_SIZE = 256
_memo: OrderedDict = OrderedDict()
_json_cache: dict = {}  # path -> (mtime, payload)
_lock = threading.Lock()


def clear_memo() -> None:
    """Drop the in-process lookup memo (tests; after cache edits)."""
    with _lock:
        _memo.clear()
        _json_cache.clear()


def load_cache(cache_path: str | None = None) -> dict:
    """The parsed cache payload ({"schema": .., "entries": {..}})."""
    path = cache_path or default_cache_path()
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {"schema": SCHEMA_VERSION, "entries": {}}
    with _lock:
        hit = _json_cache.get(path)
        if hit is not None and hit[0] == mtime:
            return hit[1]
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return {"schema": SCHEMA_VERSION, "entries": {}}
    if payload.get("schema") != SCHEMA_VERSION:
        # unknown future schema: behave as a miss everywhere rather
        # than misapply configs recorded under different semantics
        payload = {"schema": SCHEMA_VERSION, "entries": {}}
    with _lock:
        _json_cache[path] = (mtime, payload)
    return payload


def _save_cache(payload: dict, cache_path: str) -> None:
    tmp = cache_path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, cache_path)
    clear_memo()


def _resolve_point(op, N, D, L, M, dtype, backend, impl, T=0) -> TunePoint:
    backend = backend or jax.default_backend()
    impl = impl or ("pallas" if backend == "tpu" else "scan")
    return TunePoint(
        op=op, impl=impl, N=int(N), D=int(D), L=int(L), M=int(M),
        dtype=str(jnp.dtype(dtype)), backend=backend, T=int(T),
    )


def lookup(
    op: str, N: int, D: int, L: int, M: int, dtype, *,
    backend: str | None = None, impl: str | None = None,
    cache_path: str | None = None, T: int = 0,
) -> dict | None:
    """The tuned config for a point, or None on a cache miss.

    Exact key first, then the nearest-N entry for the same
    (op, impl, D, L, M, [T,] dtype, backend) within a 4x N ratio.
    Memoized in-process (LRU of {_MEMO_SIZE}) so trace-time
    consultation from the dispatch wrappers is effectively free.
    """
    point = _resolve_point(op, N, D, L, M, dtype, backend, impl, T)
    path = cache_path or default_cache_path()
    memo_key = (path, point.key)
    with _lock:
        if memo_key in _memo:
            _memo.move_to_end(memo_key)
            return _memo[memo_key]
    entries = load_cache(path)["entries"]
    cfg = None
    hit = entries.get(point.key)
    if hit is not None:
        cfg = dict(hit["config"])
    else:
        t = f"_T{point.T}" if point.T else ""
        suffix = (
            f"_D{point.D}_L{point.L}_M{point.M}{t}_{point.dtype}"
            f"/{point.backend}"
        )
        prefix = f"{point.op}/{point.impl}/N"
        best_ratio = 4.0
        for key, entry in entries.items():
            if not (key.startswith(prefix) and key.endswith(suffix)):
                continue
            n = int(key[len(prefix):].split("_", 1)[0])
            ratio = max(n, point.N) / max(1, min(n, point.N))
            if ratio <= best_ratio:
                best_ratio = ratio
                cfg = dict(entry["config"])
    with _lock:
        _memo[memo_key] = cfg
        _memo.move_to_end(memo_key)
        while len(_memo) > _MEMO_SIZE:
            _memo.popitem(last=False)
    return cfg


def tune(
    op: str, N: int, D: int, L: int, M: int, dtype, *,
    backend: str | None = None, impl: str | None = None,
    repeats: int = 2, cache_path: str | None = None,
    force: bool = False, prune_factor: float = PRUNE_FACTOR,
    T: int = 0,
) -> dict:
    """Sweep-and-cache one point; returns the winning config.

    Generates the candidate grid, roofline-prunes it, measures the
    survivors and persists the winner. Scan candidates within
    ``TIE_FACTOR`` of the fastest are treated as a measurement tie and
    the largest chunk among them wins (at ``chunk >= N`` the scan
    degenerates to the single fused program — the noise-robust choice
    at compute-bound points where streaming has nothing to win). With
    an existing cache entry and ``force=False`` this is a read (no
    measurement).
    """
    point = _resolve_point(op, N, D, L, M, dtype, backend, impl, T)
    path = cache_path or default_cache_path()
    payload = load_cache(path)
    if not force:
        hit = payload["entries"].get(point.key)
        if hit is not None:
            return dict(hit["config"])
    cands = candidates(point)
    kept, pruned = roofline_prune(point, cands, factor=prune_factor)
    results = measure_candidates(point, kept, repeats=repeats)
    best = results[0]
    if point.impl == "scan" and len(results) > 1:
        # candidates within timing noise of the best are ties: prefer
        # the largest chunk among them — fewer scan steps, and at
        # chunk >= N the scan degenerates to the single fused program,
        # which cannot lose to the unfused pipeline it is identical to
        tol = TIE_FACTOR * best["wall_ms"]
        near = [r for r in results if r["wall_ms"] <= tol]
        best = max(near, key=lambda r: r["config"]["chunk"])
    # deep-copy the payload before mutating: load_cache may return the
    # process-wide cached object
    payload = json.loads(json.dumps(payload))
    payload["entries"][point.key] = {
        "config": best["config"],
        "wall_ms": best["wall_ms"],
        "jax": jax.__version__,
        "backend": point.backend,
        "candidates": len(cands),
        "pruned": len(pruned),
        "sweep": results,
    }
    _save_cache(payload, path)
    return dict(best["config"])


# ---------------------------------------------------------------------------
# Dispatcher integration
# ---------------------------------------------------------------------------


def resolve_config(
    kw: dict, tuning, *, op: str, impl: str,
    N: int, D: int, L: int, M: int, dtype,
    backend: str | None = None, cache_path: str | None = None,
    T: int = 0,
) -> dict:
    """Merge the tuning policy into a dispatcher's block kwargs.

    tuning="cached" (the default everywhere): consult the tuned cache
    — unless the caller already passed any block knob explicitly, which
    always wins. tuning="off": never consult. tuning=<dict>: use that
    config (explicit kwargs still win over it).
    """
    if tuning == "off" or tuning is None:
        return kw
    explicit = any(
        kw.get(k) is not None for k in ("chunk", "block_n", "block_l")
    )
    if isinstance(tuning, dict):
        cfg = tuning
    elif tuning == "cached":
        if explicit:
            return kw
        cfg = lookup(
            op, N, D, L, M, dtype,
            backend=backend, impl=impl, cache_path=cache_path, T=T,
        )
        if cfg is None:
            return kw
    else:
        raise ValueError(
            f'tuning must be "cached", "off" or an explicit config '
            f"dict, got {tuning!r}"
        )
    merged = dict(cfg)
    merged.update(kw)  # explicit caller kwargs win
    return merged
