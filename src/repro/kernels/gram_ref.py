"""Pure-jnp oracles for the Gram kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_reference(H: jax.Array) -> jax.Array:
    """P = H^T H with f32 accumulation."""
    return jax.lax.dot_general(
        H, H,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def cross_reference(H: jax.Array, T: jax.Array) -> jax.Array:
    """Q = H^T T with f32 accumulation."""
    return jax.lax.dot_general(
        H, T,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
