"""Backend dispatch for the fused gossip round (eq. (20) hot loop).

``fused_gossip_rounds`` / ``fused_gossip_round`` pick the execution
arm the same way the stats/predict planes do:

* TPU (f32 state): the Pallas kernel — the in-kernel multi-round arm
  when the whole state + snapshots fit the VMEM budget, else one
  kernel launch per round under an outer scan.
* everywhere else: the jitted neighbor-list scan fallback
  (``elm_gossip_ref.elm_gossip_scan``), chunked over neighbor slots.

Block knobs resolve through ``kernels/autotune.py`` at
``tuning="cached"`` (op="gossip"; the point maps V -> N and
d_max -> D, so the cache key carries ``V, d_max, L, M, dtype``
exactly like the other planes carry their dims). Explicit ``chunk=``
/ ``block_v=`` kwargs always win.

``prefers_dense`` is the degenerate-graph escape hatch: on dense
graphs (d_max ~ V — complete topologies, or any graph at very small
V where the Omega term dominates) the neighbor gather does the same
MACs as the ``(V,V) @ (V, L*M)`` matmul with worse locality, so the
``analysis/roofline.py`` gossip-round model is consulted and the
caller (``mixers.NeighborMixer``) lowers to the dense round program —
the fused and unfused paths become the same executable, speedup 1.0
by identity (the PR 6 convention).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.analysis.roofline import gossip_round_terms
from repro.kernels import autotune
from repro.kernels.elm_stats_ops import force_interpret
from repro.kernels.elm_gossip_ref import (
    elm_gossip_scan,
    gossip_round_payload,
)

#: modeled dense-round slack on TPU: the neighbor arm must beat the
#: dense matmul round by this factor before it is preferred (gathers
#: have worse locality than a matmul at equal FLOPs)
DENSE_SLACK = 1.25

#: off-TPU slack: XLA:CPU lowers the dense round to BLAS GEMMs
#: running near peak while the neighbor gather+contract runs ~4-5x
#: below it (measured on the benchmarks/consensus_bench.py grid), so
#: the dense arm's zero-edge MACs only lose once the modeled compute
#: ratio clears that efficiency gap
DENSE_SLACK_OFF_TPU = 5.0


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def prefers_dense(
    V: int, d_max: int, L: int, M: int, *, slack: float | None = None
) -> bool:
    """True when the dense matmul round is modeled no slower than the
    neighbor-gather round (within ``slack``).

    The two arms stream the same state/Omega bytes (the memory term is
    shared and cancels), so the choice reduces to the compute term: the
    dense round spends ``2 V^2 L M`` extra MACs on zero edges, which
    only matters once it rivals the shared ``2 V L^2 M`` Omega cost —
    i.e. once ``V`` rivals ``L``. Below that (small V, or L large
    relative to V, or complete-ish graphs where fan-in ~ V anyway) the
    dense matmul's locality wins.

    ``slack`` defaults per backend: ``DENSE_SLACK`` on TPU (both arms
    run near the roofline there) and ``DENSE_SLACK_OFF_TPU`` elsewhere
    (a BLAS GEMM is far more efficient per FLOP than a gather, so the
    modeled ratio must clear the measured efficiency gap first).
    """
    if slack is None:
        slack = DENSE_SLACK if _on_tpu() else DENSE_SLACK_OFF_TPU
    tn = gossip_round_terms(V, d_max, L, M)["t_compute"]
    td = gossip_round_terms(V, d_max, L, M, dense=True)["t_compute"]
    return td <= slack * tn


def laplacian_prefers_dense(V: int, d_max: int) -> bool:
    """Laplacian-only arm choice (no Omega term): the gather wins only
    on genuinely sparse graphs."""
    return 2 * d_max >= V


_scan_jit = jax.jit(
    elm_gossip_scan,
    static_argnames=("num_rounds", "compress", "chunk"),
)

_round_payload_jit = jax.jit(
    gossip_round_payload, static_argnames=("chunk",)
)


def _resolve(kw, tuning, *, V, d_max, L, M, dtype, impl):
    cfg = autotune.resolve_config(
        kw, tuning, op="gossip", impl=impl,
        N=V, D=d_max, L=L, M=M, dtype=dtype,
    )
    return cfg


def fused_gossip_rounds(
    betas, omegas, idx, w, deg, scale, *, num_rounds, compress=None,
    use_kernel=None, tuning="cached", chunk=None, block_v=None,
    interpret=None,
):
    """num_rounds fused eq. (20) rounds over padded neighbor lists.

    betas (V, L, M), omegas (V, L, L), idx/w (S, V, d_max), deg (S, V)
    — round k mixes with snapshot k % S; scale = gamma / (VC).
    use_kernel: force the Pallas arm (default: TPU and f32 state only).
    """
    V, L, M = betas.shape
    S, _, d_max = idx.shape
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    if betas.dtype != jnp.float32:
        use = False  # the kernel accumulates/stores f32 only
    if use:
        if chunk is not None:
            raise ValueError(
                "chunk= is the scan fallback's knob; the Pallas arm "
                "takes block_v="
            )
        from repro.kernels.elm_gossip import (
            elm_gossip_pallas,
            elm_gossip_pallas_multiround,
            multiround_vmem_bytes,
        )

        cfg = _resolve(
            {"block_n": block_v}, tuning,
            V=V, d_max=d_max, L=L, M=M, dtype=betas.dtype, impl="pallas",
        )
        bv = cfg.get("block_n") or autotune.DEFAULTS[
            ("gossip", "pallas")
        ]["block_n"]
        interp = (not _on_tpu()) if interpret is None else interpret
        if (
            multiround_vmem_bytes(V, L, M, S, d_max)
            <= autotune.VMEM_BUDGET
        ):
            fn = jax.jit(
                functools.partial(
                    elm_gossip_pallas_multiround, num_rounds=num_rounds,
                    compress=compress, interpret=interp,
                )
            )
        else:
            fn = jax.jit(
                functools.partial(
                    elm_gossip_pallas, num_rounds=num_rounds,
                    compress=compress, block_v=int(bv), interpret=interp,
                )
            )
        return fn(betas, omegas, idx, w, deg, scale)
    if block_v is not None:
        raise ValueError(
            "block_v= is the Pallas arm's knob; the scan fallback "
            "takes chunk="
        )
    cfg = _resolve(
        {"chunk": chunk}, tuning,
        V=V, d_max=d_max, L=L, M=M, dtype=betas.dtype, impl="scan",
    )
    c = cfg.get("chunk") or autotune.DEFAULTS[("gossip", "scan")]["chunk"]
    return _scan_jit(
        betas, omegas, idx, w, deg, scale,
        num_rounds=num_rounds, compress=compress, chunk=int(c),
    )


def fused_gossip_round(
    betas, payload, omegas, idx_k, w_k, deg_k, scale, *,
    use_kernel=None, tuning="cached", chunk=None, block_v=None,
    interpret=None,
):
    """One fused round over an explicitly encoded payload.

    The CompressedMixer arm: ``payload`` is the receivers' view of the
    network (e.g. int8-roundtripped replicas x̂, already encoded with
    the round/node key schedule of core/compression.py); the Laplacian
    is formed from it and the update applied to ``betas``. idx_k/w_k:
    (V, d_max) — one already-selected snapshot; deg_k: (V,).
    """
    V, L, M = betas.shape
    d_max = idx_k.shape[-1]
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    if betas.dtype != jnp.float32 or payload.dtype != jnp.float32:
        use = False
    if use:
        from repro.kernels.elm_gossip import elm_gossip_pallas

        cfg = _resolve(
            {"block_n": block_v}, tuning,
            V=V, d_max=d_max, L=L, M=M, dtype=betas.dtype, impl="pallas",
        )
        bv = cfg.get("block_n") or autotune.DEFAULTS[
            ("gossip", "pallas")
        ]["block_n"]
        interp = (not _on_tpu()) if interpret is None else interpret
        return elm_gossip_pallas(
            betas, omegas, idx_k[None], w_k[None], deg_k[None], scale,
            num_rounds=1, payload=payload, block_v=int(bv),
            interpret=interp,
        )
    cfg = _resolve(
        {"chunk": chunk}, tuning,
        V=V, d_max=d_max, L=L, M=M, dtype=betas.dtype, impl="scan",
    )
    c = cfg.get("chunk") or autotune.DEFAULTS[("gossip", "scan")]["chunk"]
    return _round_payload_jit(
        betas, payload, omegas, idx_k, w_k, deg_k, scale,
        chunk=int(c),
    )
