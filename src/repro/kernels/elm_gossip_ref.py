"""Reference + scan-fallback implementations of the fused gossip round.

The consensus plane's hot loop is the paper's eq. (20) update

    beta_i += (gamma / VC) * Omega_i @ lap_i,
    lap_i   = sum_{j in N_i} a_ij (beta_j - beta_i),

which ``core/mixers.DenseMixer`` evaluates as a dense ``(V, V) @
(V, L*M)`` matmul — V^2 work even for hypercube/ring graphs whose
degree is ~log V. This module is the neighbor-sparse formulation over a
padded CSR-style neighbor list: per node, ``d_max`` neighbor slots of
(index, weight), zero-weight slots padding short rows. Three layers:

* ``neighbor_lists`` — build the padded lists from dense adjacency
  snapshots (concrete arrays; done once at mixer construction).
* ``gossip_round_reference`` — the single-round oracle: full-gather
  einsum, no chunking. This is what the Pallas kernel and the scan
  fallback are parity-tested against (and it is itself pinned to the
  DenseMixer + DCELMRule round within f32 tolerance).
* ``elm_gossip_scan`` — the jitted off-TPU fallback: ``lax.scan`` over
  rounds, the Laplacian accumulated over neighbor-slot *chunks* so the
  gathered ``(V, chunk, L, M)`` tile — not the full ``(V, d_max, L,
  M)`` gather — bounds peak memory. ``chunk`` is the knob
  ``kernels/autotune.py`` sweeps for ``op="gossip"``.

Payload semantics match the mixers: ``compress="bf16"`` rounds each
element of the gossiped payload to bf16 before the Laplacian is formed
(accumulation stays >= f32), exactly ``mixers.compress_payload``. The
state/output dtype is never widened.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

#: payload modes the kernel plane understands; richer wire formats
#: (int8/topk/event-triggered) enter through an explicit ``payload=``
#: operand encoded by core/compression.py.
PAYLOAD_MODES = (None, "none", "bf16")


def _check_compress(compress):
    if compress not in PAYLOAD_MODES:
        raise ValueError(
            f"unknown gossip payload mode {compress!r}: the kernel plane "
            f"accepts {PAYLOAD_MODES}; int8/top-k payloads are encoded by "
            "core/compression.py and passed in via payload="
        )
    return None if compress == "none" else compress


def _payload(betas, compress):
    if _check_compress(compress) == "bf16":
        return betas.astype(jnp.bfloat16)
    return betas


def _acc_dtype(payload_dtype):
    """Accumulate the Laplacian at least in f32 (mixers._mix_dtype)."""
    return jnp.promote_types(payload_dtype, jnp.float32)


# ---------------------------------------------------------------------------
# Neighbor-list construction
# ---------------------------------------------------------------------------


def neighbor_lists(adjacencies):
    """Padded CSR-style neighbor lists from dense adjacency snapshots.

    adjacencies: concrete (V, V) or (S, V, V) array (time-varying bases
    keep their leading snapshot axis). Returns ``(idx, w, deg)``:

    * idx: (S, V, d_max) int32 — neighbor indices, short rows padded
      with index 0;
    * w:   (S, V, d_max) — edge weights a_ij, padding slots 0.0 (so a
      padded slot's gathered contribution vanishes — this is also how
      FaultyMixer edge-keep masks fold in: a dropped edge is a
      zero-weight slot in that round's masked snapshot);
    * deg: (S, V) — weighted degrees sum_j a_ij.

    d_max is the max live-neighbor count over all snapshots (>= 1 so
    shapes stay non-empty on edgeless graphs).
    """
    adj = np.asarray(adjacencies)
    if adj.ndim == 2:
        adj = adj[None]
    if adj.ndim != 3 or adj.shape[-1] != adj.shape[-2]:
        raise ValueError(
            f"adjacencies must be (V,V) or (S,V,V), got {adj.shape}"
        )
    S, V, _ = adj.shape
    counts = (adj != 0).sum(axis=-1)
    d_max = max(int(counts.max(initial=0)), 1)
    idx = np.zeros((S, V, d_max), np.int32)
    w = np.zeros((S, V, d_max), adj.dtype)
    for s in range(S):
        for i in range(V):
            nbrs = np.nonzero(adj[s, i])[0]
            idx[s, i, : len(nbrs)] = nbrs
            w[s, i, : len(nbrs)] = adj[s, i, nbrs]
    deg = adj.sum(axis=-1)
    return jnp.asarray(idx), jnp.asarray(w), jnp.asarray(deg)


def _snapshot(arr, k):
    """Round k's slice of a leading-snapshot-axis array (k % S)."""
    S = arr.shape[0]
    if S == 1:
        return arr[0]
    return jnp.take(arr, jnp.mod(k, S), axis=0)


# ---------------------------------------------------------------------------
# Single-round bodies
# ---------------------------------------------------------------------------


def neighbor_laplacian(payload, idx_k, w_k, deg_k, *, chunk=None):
    """lap_i = sum_s w[i,s] payload[idx[i,s]] - deg_i payload_i.

    payload: (V, ...) — any trailing shape; idx_k/w_k: (V, d_max) one
    snapshot; deg_k: (V,). Accumulates in ``_acc_dtype(payload.dtype)``
    over neighbor-slot chunks of size ``chunk`` (default: all slots in
    one gather). Returns the accumulation-dtype Laplacian.
    """
    V, d_max = idx_k.shape
    dt = _acc_dtype(payload.dtype)
    p = payload.astype(dt)
    trail = p.shape[1:]
    pf = p.reshape(V, -1)
    c = d_max if chunk is None else max(1, min(int(chunk), d_max))
    pad = (-d_max) % c
    if pad:
        idx_k = jnp.pad(idx_k, ((0, 0), (0, pad)))
        w_k = jnp.pad(w_k, ((0, 0), (0, pad)))
    steps = (d_max + pad) // c
    wc = w_k.astype(dt)
    lap0 = -deg_k.astype(dt)[:, None] * pf
    if steps == 1:
        g = jnp.take(pf, idx_k, axis=0)  # (V, c, F)
        lap = lap0 + jnp.einsum("vc,vcf->vf", wc, g)
    else:
        ic = idx_k.reshape(V, steps, c).transpose(1, 0, 2)  # (steps, V, c)
        ws = wc.reshape(V, steps, c).transpose(1, 0, 2)

        def acc(lap, sc):
            sl, sw = sc
            g = jnp.take(pf, sl, axis=0)  # (V, c, F)
            return lap + jnp.einsum("vc,vcf->vf", sw, g), None

        lap, _ = lax.scan(acc, lap0, (ic, ws))
    return lap.reshape((V,) + trail)


def gossip_round_reference(
    betas, omegas, idx_k, w_k, deg_k, scale, *, compress=None
):
    """One eq. (20) round from a padded neighbor list (the oracle).

    betas: (V, L, M) state; omegas: (V, L, L); scale = gamma / (V C).
    Mirrors the DenseMixer + DCELMRule composition: the Laplacian is
    cast back to the state dtype before the Omega contraction, so the
    f32 parity with the dense path is exact up to accumulation order.
    """
    p = _payload(betas, compress)
    lap = neighbor_laplacian(p, idx_k, w_k, deg_k).astype(betas.dtype)
    upd = jnp.einsum("vlk,vkm->vlm", omegas, lap)
    return (betas + scale * upd).astype(betas.dtype)


def gossip_round_payload(
    betas, payload, omegas, idx_k, w_k, deg_k, scale, *, chunk=None
):
    """One round with an explicitly encoded payload (CompressedMixer).

    The Laplacian is formed entirely from ``payload`` (the receivers'
    view of the network — e.g. int8-roundtripped replicas x̂), then the
    update is applied to ``betas``: exactly ``rule(x,
    base.laplacian(x̂, k))`` with the gather/contract pair fused into
    one jitted body.
    """
    lap = neighbor_laplacian(
        payload, idx_k, w_k, deg_k, chunk=chunk
    ).astype(betas.dtype)
    upd = jnp.einsum("vlk,vkm->vlm", omegas, lap)
    return (betas + scale * upd).astype(betas.dtype)


# ---------------------------------------------------------------------------
# Multi-round scan fallback (the off-TPU production path)
# ---------------------------------------------------------------------------


def elm_gossip_scan(
    betas, omegas, idx, w, deg, scale, *, num_rounds, compress=None,
    chunk=None,
):
    """num_rounds fused eq. (20) rounds over the neighbor lists.

    idx/w: (S, V, d_max), deg: (S, V) — round k mixes with snapshot
    k % S (time-varying bases and FaultyMixer masked periods pass their
    whole period here). ``chunk`` bounds the gathered tile at
    (V, chunk, L*M); at ``chunk >= d_max`` the scan body degenerates to
    the single full-gather einsum of the reference oracle.
    """
    _check_compress(compress)

    def round_fn(b, k):
        nxt = gossip_round_reference(
            b, omegas, _snapshot(idx, k), _snapshot(w, k),
            _snapshot(deg, k), scale, compress=compress,
        ) if chunk is None else gossip_round_payload(
            b, _payload(b, compress), omegas, _snapshot(idx, k),
            _snapshot(w, k), _snapshot(deg, k), scale, chunk=chunk,
        )
        return nxt, None

    final, _ = lax.scan(round_fn, betas, jnp.arange(num_rounds))
    return final


# ---------------------------------------------------------------------------
# Dense-round program (the unfused subject + small/complete-graph arm)
# ---------------------------------------------------------------------------


def dense_gossip_rounds(
    betas, omegas, adj, deg, scale, *, num_rounds, compress=None
):
    """num_rounds rounds via the dense (V,V) @ (V, L*M) formulation.

    The exact DenseMixer.laplacian + DCELMRule composition (precomputed
    degrees, payload cast, >= f32 accumulation) as one jittable
    program: the benchmark's unfused subject, and the arm the
    dispatcher lowers to when the graph is too dense for neighbor
    gathers to win (``elm_gossip_ops.prefers_dense``). adj/deg carry a
    leading snapshot axis (S, V, V)/(S, V).
    """
    _check_compress(compress)
    V, L, M = betas.shape

    def round_fn(b, k):
        p = _payload(b.reshape(V, L * M), compress)
        dt = _acc_dtype(p.dtype)
        p = p.astype(dt)
        a_k = _snapshot(adj, k).astype(dt)
        d_k = _snapshot(deg, k).astype(dt)
        lap = (a_k @ p - d_k[:, None] * p).astype(b.dtype)
        upd = jnp.einsum("vlk,vkm->vlm", omegas, lap.reshape(V, L, M))
        return (b + scale * upd).astype(b.dtype), None

    final, _ = lax.scan(round_fn, betas, jnp.arange(num_rounds))
    return final
