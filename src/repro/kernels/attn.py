"""Pallas TPU kernel: blocked causal GQA flash attention.

Grid = (B*K, G, nq, nk), nk innermost. Online-softmax accumulators
(acc (bq,hd) f32, m/l (bq,1) f32) live in VMEM scratch across the KV
stream. Fully-masked blocks (k block start beyond the q block end) are
skipped with ``pl.when`` — the grid-level analogue of flash-attention's
causal block skipping, which the pure-jnp fallback in
models/attention.py cannot express (its known 2x block waste is one of
the §Perf items; this kernel is the TPU fix).

Layouts (wrapper ``flash_attention_pallas`` maps model shapes here):
  q (BK, G, S, hd)   BK = batch * kv_heads, G = query groups
  k (BK, S, hd)
  v (BK, S, hd)
  o (BK, G, S, hd)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, acc, m, l,
    *, bq: int, bk: int, nk: int, scale: float, softcap: float,
    window: int | None = None,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    # skip blocks fully masked by causality, and (for sliding-window
    # layers) blocks entirely left of every query's window
    in_band = j * bk <= i * bq + bq - 1
    if window is not None:
        in_band = in_band & (j * bk + bk - 1 > i * bq - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)  # (bk, hd)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = qpos >= kpos
        if window is not None:
            mask = mask & (qpos - kpos < window)
        logits = jnp.where(mask, logits, NEG_INF)
        bm = jnp.max(logits, axis=1, keepdims=True)  # (bq,1)
        new_m = jnp.maximum(m[...], bm)
        p = jnp.exp(logits - new_m)
        r = jnp.exp(m[...] - new_m)
        acc[...] = acc[...] * r + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        l[...] = l[...] * r + jnp.sum(p, axis=1, keepdims=True)
        m[...] = new_m

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "softcap", "window", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, S, K, G, hd)
    k: jax.Array,  # (B, S, K, hd)
    v: jax.Array,  # (B, S, K, hd)
    *,
    block_q: int = 256,
    block_k: int = 256,
    softcap: float = 0.0,
    window: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Causal GQA attention. Returns (B, S, K, G, hd)."""
    B, S, K, G, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise ValueError(f"S={S} must divide block sizes ({bq},{bk})")
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    qt = q.transpose(0, 2, 3, 1, 4).reshape(B * K, G, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)

    kern = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, scale=scale, softcap=softcap,
        window=window,
    )
    out = pl.pallas_call(
        kern,
        grid=(B * K, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)
