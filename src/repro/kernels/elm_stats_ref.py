"""Pure-jnp oracles for the fused feature->moment kernel.

Two references:

* ``elm_stats_reference`` — the semantic oracle: materialize H, then
  the gram/cross oracles. What the fused kernel must match.
* ``elm_stats_scan`` — the *streaming* jnp implementation: lax.scan
  over (chunk, D) tiles accumulating f32 moments, so peak memory is the
  chunk working set, not the (N, L) hidden matrix. This is the fused
  path on backends without the Pallas kernel (CPU jit), and the
  apples-to-apples "fused vs unfused" benchmark subject.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram_ref import cross_reference, gram_reference


def hidden_reference(X: jax.Array, W: jax.Array, b: jax.Array,
                     activation: str) -> jax.Array:
    """H = g(X W + b); for "rbf", W = centers^T and b = gamma."""
    from repro.core.features import ACTIVATIONS, rbf_squared_dists

    if activation == "rbf":
        return jnp.exp(-b * rbf_squared_dists(X, W.T))
    return ACTIVATIONS[activation](X @ W + b)


def elm_stats_reference(X, W, b, T, *, activation="sigmoid"):
    """(P, Q) via materialized H — the unfused two-pass pipeline."""
    H = hidden_reference(X, W, b, activation)
    return gram_reference(H), cross_reference(H, T)


def preact_reference(Z: jax.Array, b: jax.Array, activation: str) -> jax.Array:
    """H = g(Z + b) from an assembled preactivation (vertical mode).

    No "rbf" branch: gaussian nodes have no additive preactivation
    form, so vertical mode rejects them before reaching the kernels.
    """
    from repro.core.features import ACTIVATIONS

    if activation == "rbf":
        raise ValueError(
            "rbf has no preactivation form (h = exp(-gamma ||x - c||^2) "
            "is not g(z + b) for any additive z); vertical mode supports "
            "RandomFeatureMap activations only"
        )
    return ACTIVATIONS[activation](Z + b)


def preact_stats_reference(Z, b, T, *, activation="sigmoid"):
    """(P, Q) via materialized H = g(Z + b) — the unfused oracle."""
    H = preact_reference(Z, b, activation)
    return gram_reference(H), cross_reference(H, T)


@functools.partial(jax.jit, static_argnames=("activation", "chunk"))
def preact_stats_scan(Z, b, T, *, activation="sigmoid", chunk=2048):
    """(P, Q) streamed over an assembled preactivation Z in chunks.

    The vertical-mode twin of ``elm_stats_scan``: H = g(Z + b) is
    produced per (chunk, L) tile and consumed by the f32 moment
    accumulators, so the full (N, L) hidden matrix never exists.
    Ragged tails are masked to exact zeros like the Pallas kernel.
    """
    N, L = Z.shape
    M = T.shape[1]
    chunk = min(chunk, N)
    if chunk == N:
        # single-chunk point: one fused jit, no scan machinery —
        # bitwise-identical to the one-step scan (0 + x is exact)
        h = preact_reference(Z, b, activation).astype(Z.dtype)
        return gram_reference(h), cross_reference(h, T)
    pN = (-N) % chunk
    if pN:
        Z = jnp.pad(Z, ((0, pN), (0, 0)))
        T = jnp.pad(T, ((0, pN), (0, 0)))
    K = Z.shape[0] // chunk
    Zc = Z.reshape(K, chunk, L)
    Tc = T.reshape(K, chunk, M)
    starts = jnp.arange(K) * chunk
    row_ids = jnp.arange(chunk)[:, None]

    def step(carry, inp):
        P, Q = carry
        z, t, start = inp
        h = preact_reference(z, b, activation)
        if pN:  # only the padded tail needs masking (g(0) != 0)
            h = jnp.where(row_ids + start < N, h, 0.0)
        h = h.astype(z.dtype)
        P = P + gram_reference(h)
        Q = Q + cross_reference(h, t)
        return (P, Q), None

    zero = (
        jnp.zeros((L, L), jnp.float32),
        jnp.zeros((L, M), jnp.float32),
    )
    (P, Q), _ = jax.lax.scan(step, zero, (Zc, Tc, starts))
    return P, Q


@functools.partial(jax.jit, static_argnames=("activation", "chunk"))
def elm_stats_scan(X, W, b, T, *, activation="sigmoid", chunk=2048):
    """(P, Q) streamed over N in `chunk`-row tiles (H never full-size).

    Ragged tails are zero-padded and the hidden rows masked to exact
    zeros (g(0) != 0 in general), mirroring the Pallas kernel.
    """
    N, D = X.shape
    L = W.shape[1]
    M = T.shape[1]
    chunk = min(chunk, N)
    if chunk == N:
        # single-chunk point: the whole pipeline is one fused jit with
        # no scan machinery — bitwise-identical to the one-step scan
        # (f32 accumulators start at zero; 0 + x is exact)
        h = hidden_reference(X, W, b, activation).astype(X.dtype)
        return gram_reference(h), cross_reference(h, T)
    pN = (-N) % chunk
    if pN:
        X = jnp.pad(X, ((0, pN), (0, 0)))
        T = jnp.pad(T, ((0, pN), (0, 0)))
    K = X.shape[0] // chunk
    Xc = X.reshape(K, chunk, D)
    Tc = T.reshape(K, chunk, M)
    starts = jnp.arange(K) * chunk
    row_ids = jnp.arange(chunk)[:, None]

    def step(carry, inp):
        P, Q = carry
        x, t, start = inp
        h = hidden_reference(x, W, b, activation)
        if pN:  # only the padded tail needs masking (g(0) != 0)
            h = jnp.where(row_ids + start < N, h, 0.0)
        h = h.astype(x.dtype)
        P = P + gram_reference(h)
        Q = Q + cross_reference(h, t)
        return (P, Q), None

    zero = (
        jnp.zeros((L, L), jnp.float32),
        jnp.zeros((L, M), jnp.float32),
    )
    (P, Q), _ = jax.lax.scan(step, zero, (Xc, Tc, starts))
    return P, Q
