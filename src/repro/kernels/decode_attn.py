"""Pallas TPU kernel: single-token GQA attention over a long KV cache
("flash-decode") — the serving hot path for decode_32k / long_500k.

Grid = (B*K, nk) with the KV stream innermost; online-softmax
accumulators live in VMEM scratch, so HBM traffic is one pass over the
(possibly multi-hundred-thousand-token) cache and one (G, hd) output
write. Blocks entirely beyond the current position (or outside the
sliding window) are skipped with ``pl.when`` — for a ring-buffer SWA
cache the wrapper simply passes the window-sized cache.

Layouts (wrapper maps model shapes):
  q     (BK, G, hd)      one query token per sequence
  k, v  (BK, S, hd)      cache (RoPE pre-applied to k)
  pos   (1,) int32       absolute position of the query token
  out   (BK, G, hd)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    pos_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l,
    *, bk: int, nk: int, scale: float, window: int | None, softcap: float,
):
    j = pl.program_id(1)
    pos = pos_ref[0]

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    @pl.when(j * bk <= pos)  # skip blocks entirely in the future
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (G, bk)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = kpos <= pos
        if window is not None:
            valid = valid & (pos - kpos < window)
        logits = jnp.where(valid, logits, NEG_INF)
        bm = jnp.max(logits, axis=1, keepdims=True)  # (G, 1)
        new_m = jnp.maximum(m[...], bm)
        p = jnp.exp(logits - new_m)
        r = jnp.exp(m[...] - new_m)
        acc[...] = acc[...] * r + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        l[...] = l[...] * r + jnp.sum(p, axis=1, keepdims=True)
        m[...] = new_m

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc[...] / jnp.maximum(l[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "window", "softcap", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,  # (B, 1, K, G, hd)
    cache_k: jax.Array,  # (B, S, K, hd)
    cache_v: jax.Array,  # (B, S, K, hd)
    pos: jax.Array,  # () int32
    *,
    block_k: int = 512,
    window: int | None = None,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, 1, K, G, hd)."""
    B, _, K, G, hd = q.shape
    S = cache_k.shape[1]
    bk = min(block_k, S)
    pad = (-S) % bk
    if pad:
        cache_k = jnp.pad(cache_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_v = jnp.pad(cache_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S2 = S + pad
    nk = S2 // bk
    scale = hd ** -0.5

    qt = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kt = cache_k.transpose(0, 2, 1, 3).reshape(B * K, S2, hd)
    vt = cache_v.transpose(0, 2, 1, 3).reshape(B * K, S2, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kern = functools.partial(
        _decode_kernel, bk=bk, nk=nk, scale=scale, window=window,
        softcap=softcap,
    )
    out = pl.pallas_call(
        kern,
        grid=(B * K, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qt, kt, vt)
    return out.reshape(B, K, G, hd)[:, None].reshape(B, 1, K, G, hd)
