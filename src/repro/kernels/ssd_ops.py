"""Dispatching wrapper for the SSD scan.

``ssd`` picks the Pallas TPU kernel when running on TPU (or when forced
via ``use_kernel=True`` with interpret mode on CPU) and otherwise the
pure-jnp chunked oracle — identical semantics, so the model code never
branches.
"""

from __future__ import annotations

import jax

from repro.kernels.ssd_ref import ssd_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(
    x,
    dt,
    A,
    B,
    C,
    *,
    chunk: int = 256,
    initial_state=None,
    use_kernel: bool | None = None,
):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        from repro.kernels.ssd_scan import ssd_pallas

        return ssd_pallas(
            x,
            dt,
            A,
            B,
            C,
            chunk=chunk,
            initial_state=initial_state,
            interpret=not _on_tpu(),
        )
    return ssd_reference(x, dt, A, B, C, chunk=chunk, initial_state=initial_state)
