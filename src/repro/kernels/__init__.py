"""Pallas TPU kernels for the compute hot-spots.

  elm_stats.py / _ops.py / _ref.py      fused feature->moment pipeline
                                        H = g(XW+b), P += H^T H,
                                        Q += H^T T in one grid pass —
                                        H never written to HBM; feeds
                                        core/stats.py (the statistics
                                        plane, every execution path)
  elm_predict.py / _ops.py / _ref.py    fused predict pipeline
                                        Y = g(XW+b) @ beta, the serving
                                        twin — H stays in VMEM while the
                                        output block accumulates; feeds
                                        ELM.__call__, dc_elm.node_predict
                                        and serving/elm_server.py
  gram.py / gram_ops.py / gram_ref.py   P = H^T H, Q = H^T T from a
                                        *materialized* H (deep-backbone
                                        features and other non-fusable
                                        maps); symmetric block-triangle
  ssd_scan.py / ssd_ops.py / ssd_ref.py Mamba2 chunked SSD scan
  attn.py / attn_ops.py / attn_ref.py   causal/SWA GQA flash attention
  decode_attn.py                        flash-decode (one token vs a
                                        long KV cache, serving hot path)
  autotune.py                           measured block/chunk autotuner:
                                        roofline-pruned candidate sweep
                                        cached to TUNED_kernels.json;
                                        the elm_* ops wrappers consult
                                        it by default (tuning="cached")

Each kernel is a pl.pallas_call with explicit BlockSpec VMEM tiling,
validated against its pure-jnp oracle in interpret mode (tests/).
ops.py wrappers dispatch kernel-on-TPU / oracle-elsewhere.
"""

from repro.kernels import (  # noqa: F401
    attn_ops,
    autotune,
    elm_predict_ops,
    elm_stats_ops,
    gram_ops,
    ssd_ops,
)
