"""Pallas TPU kernels for the compute hot-spots.

  gram.py / gram_ops.py / gram_ref.py   P = H^T H, Q = H^T T — the
                                        paper's per-node statistic (the
                                        heaviest DC-ELM computation);
                                        symmetric block-triangle variant
  ssd_scan.py / ssd_ops.py / ssd_ref.py Mamba2 chunked SSD scan
  attn.py / attn_ops.py / attn_ref.py   causal/SWA GQA flash attention
  decode_attn.py                        flash-decode (one token vs a
                                        long KV cache, serving hot path)

Each kernel is a pl.pallas_call with explicit BlockSpec VMEM tiling,
validated against its pure-jnp oracle in interpret mode (tests/).
ops.py wrappers dispatch kernel-on-TPU / oracle-elsewhere.
"""

from repro.kernels import gram_ops, ssd_ops, attn_ops  # noqa: F401
