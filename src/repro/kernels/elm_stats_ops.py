"""Dispatching wrapper for the fused feature->moment kernel.

Backend policy (mirrors gram_ops):
  * TPU              -> the Pallas kernel (H never touches HBM)
  * use_kernel=True elsewhere -> the kernel in interpret mode
    (correctness path for tests; slow)
  * otherwise        -> ``elm_stats_scan``, the jitted lax.scan
    streaming implementation — fused-by-construction on CPU/GPU (peak
    memory is one chunk's working set, not the (N, L) hidden matrix)

Block-knob mapping (Pallas grid -> scan fallback):
  * ``block_n`` (rows per grid tile) maps to the scan's ``chunk`` —
    both are "rows resident per streaming step", and with
    chunk == block_n the two paths accumulate in the same f32 order
    (bitwise-pinned in tests/test_stats.py). Passing both ``block_n``
    and ``chunk`` to the scan path is a conflict and raises.
  * ``block_l`` (hidden columns per grid tile) has NO scan equivalent:
    the scan computes all L hidden columns per chunk in one matmul.
    Passing a non-None ``block_l`` to the scan path raises instead of
    being silently dropped.

Tuning policy (kernels/autotune.py): ``tuning="cached"`` (default)
consults the measured-winner cache (TUNED_kernels.json) for this
problem point and backend — explicit block kwargs always win, and a
cache miss keeps the hard-coded defaults, so cold-start behavior is
unchanged. ``tuning="off"`` never consults; ``tuning={...}`` applies
an explicit config dict.
"""

from __future__ import annotations

import os

import jax

from repro.kernels import autotune


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def force_interpret() -> bool:
    """True when CI's pallas-interpret leg forces the kernel paths.

    ``REPRO_FORCE_INTERPRET=1`` makes every kernel dispatcher whose
    caller left ``use_kernel=None`` take its Pallas branch (interpret
    mode off-TPU), so the kernel code paths are exercised on CPU
    runners instead of only the scan/oracle fallbacks. An explicit
    ``use_kernel=`` from the caller always wins.
    """
    return os.environ.get("REPRO_FORCE_INTERPRET", "").strip() not in ("", "0")


def scan_kwargs(kw: dict) -> dict:
    """Map Pallas block kwargs onto the scan fallback's ``chunk``.

    block_n -> chunk (same streaming role); block_l has no scan
    meaning and raises; both block_n and chunk is a conflict.
    """
    kw = dict(kw)
    if kw.get("block_l") is not None:
        raise ValueError(
            "block_l is a Pallas grid knob with no scan-fallback "
            "equivalent (the scan computes all L hidden columns per "
            "chunk); pass chunk= (or block_n=, which maps to chunk) "
            "instead, or drop block_l"
        )
    kw.pop("block_l", None)
    block_n = kw.pop("block_n", None)
    if block_n is not None:
        if kw.get("chunk") is not None:
            raise ValueError(
                f"both block_n={block_n} and chunk={kw['chunk']} were "
                "passed to the scan fallback; block_n maps to chunk — "
                "pass exactly one"
            )
        kw["chunk"] = block_n
    return kw


def fused_moments(
    X, W, b, T, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, tuning="cached", **kw,
):
    """(P, Q) f32 from raw inputs without materializing H.

    For activation="rbf" pass W = centers^T and b = gamma. ``tuning``
    selects the block-knob policy (see module docstring).
    """
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    kw = autotune.resolve_config(
        kw, tuning, op="stats", impl="pallas" if use else "scan",
        N=X.shape[0], D=X.shape[1], L=W.shape[1], M=T.shape[1],
        dtype=X.dtype,
    )
    if use:
        from repro.kernels.elm_stats import elm_stats_pallas

        if kw.get("chunk") is not None:
            raise ValueError(
                "chunk is the scan-fallback knob; the Pallas kernel "
                "takes block_n/block_l"
            )
        kw.pop("chunk", None)
        return elm_stats_pallas(
            X, W, b, T, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
    from repro.kernels.elm_stats_ref import elm_stats_scan

    return elm_stats_scan(X, W, b, T, activation=activation, **scan_kwargs(kw))


def fused_preact_moments(
    Z, b, T, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, tuning="cached", **kw,
):
    """(P, Q) f32 from an assembled preactivation without materializing H.

    The vertical-mode entry: Z = sum_i X_i W_i was already reduced
    across column-sliced nodes (core/vertical.py), so the kernel only
    applies bias + activation per tile before the moment accumulation.
    Same backend/tuning policy as ``fused_moments``; "rbf" is rejected
    (no additive preactivation form).
    """
    if activation == "rbf":
        raise ValueError(
            "rbf has no preactivation form; vertical mode supports "
            "RandomFeatureMap activations only"
        )
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    kw = autotune.resolve_config(
        kw, tuning, op="preact_stats", impl="pallas" if use else "scan",
        N=Z.shape[0], D=0, L=Z.shape[1], M=T.shape[1],
        dtype=Z.dtype,
    )
    if use:
        from repro.kernels.elm_stats import elm_preact_stats_pallas

        if kw.get("chunk") is not None:
            raise ValueError(
                "chunk is the scan-fallback knob; the Pallas kernel "
                "takes block_n/block_l"
            )
        kw.pop("chunk", None)
        return elm_preact_stats_pallas(
            Z, b, T, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
    from repro.kernels.elm_stats_ref import preact_stats_scan

    return preact_stats_scan(
        Z, b, T, activation=activation, **scan_kwargs(kw)
    )
