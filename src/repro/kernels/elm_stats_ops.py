"""Dispatching wrapper for the fused feature->moment kernel.

Backend policy (mirrors gram_ops):
  * TPU              -> the Pallas kernel (H never touches HBM)
  * use_kernel=True elsewhere -> the kernel in interpret mode
    (correctness path for tests; slow)
  * otherwise        -> ``elm_stats_scan``, the jitted lax.scan
    streaming implementation — fused-by-construction on CPU/GPU (peak
    memory is one chunk's working set, not the (N, L) hidden matrix)
"""

from __future__ import annotations

import jax


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_moments(
    X, W, b, T, *, activation: str = "sigmoid",
    use_kernel: bool | None = None, **kw,
):
    """(P, Q) f32 from raw inputs without materializing H.

    For activation="rbf" pass W = centers^T and b = gamma.
    """
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        from repro.kernels.elm_stats import elm_stats_pallas

        return elm_stats_pallas(
            X, W, b, T, activation=activation,
            interpret=not _on_tpu(), **kw,
        )
    from repro.kernels.elm_stats_ref import elm_stats_scan

    kw.pop("block_l", None)
    chunk = kw.pop("block_n", None)
    if chunk is not None:
        kw["chunk"] = chunk
    return elm_stats_scan(X, W, b, T, activation=activation, **kw)
