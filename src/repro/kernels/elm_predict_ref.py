"""Pure-jnp oracles for the fused predict kernel.

Four references:

* ``predict_reference`` — the semantic oracle: materialize H, then
  H @ beta. What the fused kernel must match (and the "unfused" subject
  of benchmarks/serving_bench.py).
* ``elm_predict_scan`` — the *streaming* jnp implementation: lax.scan
  over (chunk, D) row tiles, each producing its (chunk, M) output
  slice, so peak memory is the chunk working set, not the (N, L)
  hidden matrix. This is the fused path on backends without the Pallas
  kernel (CPU jit).
* ``predict_stacked_reference`` / ``elm_predict_stacked_scan`` — the
  multi-tenant twins: every row carries a tenant id into a stacked
  (T, L, M) beta tensor and the per-row readout is

      Y[n] = H[n] @ betas[tenant_ids[n]]

  (decentralized multi-task ELM, arXiv 1904.11366: many per-task
  readouts over ONE shared hidden layer). The gather-then-contract is
  a batched dot_general, identical between the oracle and the scan so
  the single-chunk scan degenerates to the oracle bitwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.elm_stats_ref import hidden_reference


def predict_dtype(X, W, beta) -> jnp.dtype:
    """The oracle's result dtype: the promoted operand chain."""
    return jnp.promote_types(jnp.promote_types(X.dtype, W.dtype), beta.dtype)


def predict_reference(X, W, b, beta, *, activation="sigmoid"):
    """Y via materialized H — the unfused two-pass pipeline."""
    H = hidden_reference(X, W, b, activation)
    op = jnp.promote_types(H.dtype, beta.dtype)
    return jax.lax.dot_general(
        H.astype(op), beta.astype(op),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(predict_dtype(X, W, beta))


@functools.partial(jax.jit, static_argnames=("activation", "chunk"))
def elm_predict_scan(X, W, b, beta, *, activation="sigmoid", chunk=4096):
    """Y streamed over N in `chunk`-row tiles (H never full-size).

    Ragged tails are zero-padded; the padded rows produce g(0)-valued
    hidden rows whose outputs are simply sliced off (unlike the moment
    kernel, predict needs no masking for correctness — no cross-row
    reduction — but the result rows past N are discarded all the same).
    """
    N, D = X.shape
    M = beta.shape[1]
    if N == 0:  # nothing to scan over
        op = jnp.promote_types(
            jnp.promote_types(X.dtype, W.dtype), beta.dtype
        )
        return jnp.zeros((0, M), op)
    chunk = min(chunk, N)
    op = jnp.promote_types(
        jnp.promote_types(X.dtype, W.dtype), beta.dtype
    )
    beta_op = beta.astype(op)
    if chunk == N:
        # single-chunk point: one fused jit, no scan machinery —
        # bitwise-identical to the one-step scan
        h = hidden_reference(X, W, b, activation).astype(op)
        return jax.lax.dot_general(
            h, beta_op,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(op)
    pN = (-N) % chunk
    if pN:
        X = jnp.pad(X, ((0, pN), (0, 0)))
    K = X.shape[0] // chunk
    Xc = X.reshape(K, chunk, D)

    def step(_, x):
        h = hidden_reference(x, W, b, activation).astype(op)
        y = jax.lax.dot_general(
            h, beta_op,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return None, y.astype(op)

    _, Yc = jax.lax.scan(step, None, Xc)
    return Yc.reshape(K * chunk, M)[:N]


# ---------------------------------------------------------------------------
# Stacked multi-tenant readouts (one shared hidden layer, T betas)
# ---------------------------------------------------------------------------


def stacked_dtype(X, W, betas) -> jnp.dtype:
    """Result dtype of the stacked oracle: the promoted operand chain."""
    return jnp.promote_types(
        jnp.promote_types(X.dtype, W.dtype), betas.dtype
    )


def _gather_contract(h, betas, tenant_ids):
    """Y[n] = h[n] @ betas[tenant_ids[n]] as one batched dot_general.

    The gathered (n, L, M) beta tiles contract against the hidden rows
    batch-wise; the SAME op in the oracle, the scan and the Pallas
    kernel, so per-row results are independent of how rows are packed
    into a launch (the differential-serving bitwise guarantee).
    """
    bg = jnp.take(betas, tenant_ids, axis=0)  # (n, L, M)
    y = jax.lax.dot_general(
        h[:, None, :], bg,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return y[:, 0, :]


def predict_stacked_reference(
    X, W, b, betas, tenant_ids, *, activation="sigmoid"
):
    """Multi-tenant oracle: materialized H, gather-then-contract.

    X: (N, D), betas: (T, L, M), tenant_ids: (N,) int into the T axis.
    """
    H = hidden_reference(X, W, b, activation)
    op = jnp.promote_types(H.dtype, betas.dtype)
    ids = jnp.asarray(tenant_ids, jnp.int32)
    return _gather_contract(
        H.astype(op), betas.astype(op), ids
    ).astype(stacked_dtype(X, W, betas))


@functools.partial(jax.jit, static_argnames=("activation", "chunk"))
def elm_predict_stacked_scan(
    X, W, b, betas, tenant_ids, *, activation="sigmoid", chunk=2048
):
    """Stacked predict streamed over N in `chunk`-row tiles.

    Peak memory is one chunk's working set — dominated by the gathered
    (chunk, L, M) beta tiles, which is why the default chunk sits below
    the single-tenant scan's. At ``chunk >= N`` this degenerates to the
    single fused program (bitwise the oracle's gather-then-contract).
    """
    N, D = X.shape
    M = betas.shape[2]
    op = stacked_dtype(X, W, betas)
    if N == 0:
        return jnp.zeros((0, M), op)
    ids = jnp.asarray(tenant_ids, jnp.int32)
    chunk = min(chunk, N)
    betas_op = betas.astype(op)
    if chunk == N:
        h = hidden_reference(X, W, b, activation).astype(op)
        return _gather_contract(h, betas_op, ids).astype(op)
    pN = (-N) % chunk
    if pN:
        X = jnp.pad(X, ((0, pN), (0, 0)))
        ids = jnp.pad(ids, (0, pN))  # id 0: sliced off below
    K = X.shape[0] // chunk
    Xc = X.reshape(K, chunk, D)
    idc = ids.reshape(K, chunk)

    def step(_, xi):
        x, i = xi
        h = hidden_reference(x, W, b, activation).astype(op)
        return None, _gather_contract(h, betas_op, i).astype(op)

    _, Yc = jax.lax.scan(step, None, (Xc, idc))
    return Yc.reshape(K * chunk, M)[:N]
