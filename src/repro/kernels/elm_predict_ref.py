"""Pure-jnp oracles for the fused predict kernel.

Two references:

* ``predict_reference`` — the semantic oracle: materialize H, then
  H @ beta. What the fused kernel must match (and the "unfused" subject
  of benchmarks/serving_bench.py).
* ``elm_predict_scan`` — the *streaming* jnp implementation: lax.scan
  over (chunk, D) row tiles, each producing its (chunk, M) output
  slice, so peak memory is the chunk working set, not the (N, L)
  hidden matrix. This is the fused path on backends without the Pallas
  kernel (CPU jit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.elm_stats_ref import hidden_reference


def predict_dtype(X, W, beta) -> jnp.dtype:
    """The oracle's result dtype: the promoted operand chain."""
    return jnp.promote_types(jnp.promote_types(X.dtype, W.dtype), beta.dtype)


def predict_reference(X, W, b, beta, *, activation="sigmoid"):
    """Y via materialized H — the unfused two-pass pipeline."""
    H = hidden_reference(X, W, b, activation)
    op = jnp.promote_types(H.dtype, beta.dtype)
    return jax.lax.dot_general(
        H.astype(op), beta.astype(op),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(predict_dtype(X, W, beta))


@functools.partial(jax.jit, static_argnames=("activation", "chunk"))
def elm_predict_scan(X, W, b, beta, *, activation="sigmoid", chunk=4096):
    """Y streamed over N in `chunk`-row tiles (H never full-size).

    Ragged tails are zero-padded; the padded rows produce g(0)-valued
    hidden rows whose outputs are simply sliced off (unlike the moment
    kernel, predict needs no masking for correctness — no cross-row
    reduction — but the result rows past N are discarded all the same).
    """
    N, D = X.shape
    M = beta.shape[1]
    if N == 0:  # nothing to scan over
        op = jnp.promote_types(
            jnp.promote_types(X.dtype, W.dtype), beta.dtype
        )
        return jnp.zeros((0, M), op)
    chunk = min(chunk, N)
    op = jnp.promote_types(
        jnp.promote_types(X.dtype, W.dtype), beta.dtype
    )
    beta_op = beta.astype(op)
    if chunk == N:
        # single-chunk point: one fused jit, no scan machinery —
        # bitwise-identical to the one-step scan
        h = hidden_reference(X, W, b, activation).astype(op)
        return jax.lax.dot_general(
            h, beta_op,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(op)
    pN = (-N) % chunk
    if pN:
        X = jnp.pad(X, ((0, pN), (0, 0)))
    K = X.shape[0] // chunk
    Xc = X.reshape(K, chunk, D)

    def step(_, x):
        h = hidden_reference(x, W, b, activation).astype(op)
        y = jax.lax.dot_general(
            h, beta_op,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return None, y.astype(op)

    _, Yc = jax.lax.scan(step, None, Xc)
    return Yc.reshape(K * chunk, M)[:N]
