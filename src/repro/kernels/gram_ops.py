"""Dispatching wrappers for the Gram kernels (TPU kernel vs jnp oracle)."""

from __future__ import annotations

import jax

from repro.kernels.elm_stats_ops import force_interpret
from repro.kernels.gram_ref import cross_reference, gram_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram(H, *, use_kernel: bool | None = None, **kw):
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    if use:
        from repro.kernels.gram import gram_pallas

        return gram_pallas(H, interpret=not _on_tpu(), **kw)
    return gram_reference(H)


def cross(H, T, *, use_kernel: bool | None = None, **kw):
    use = (_on_tpu() or force_interpret()) if use_kernel is None else use_kernel
    if use:
        from repro.kernels.gram import cross_pallas

        return cross_pallas(H, T, interpret=not _on_tpu(), **kw)
    return cross_reference(H, T)


def local_elm_stats(H, T, *, use_kernel: bool | None = None):
    """(P, Q) = (H^T H, H^T T) — one DC-ELM node's sufficient statistics."""
    return gram(H, use_kernel=use_kernel), cross(H, T, use_kernel=use_kernel)
