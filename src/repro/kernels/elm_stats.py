"""Pallas TPU kernel: fused feature -> moment pipeline for ELM statistics.

Algorithm 1 steps 1-3 in ONE grid pass over the *raw* inputs: each
(bn, D) tile of X streams through the MXU computing the hidden tile

    H_tile = g(X_tile @ W_blk + b_blk)          (bn, bl), VMEM only

and both f32 moments accumulate in the same pass

    P[i, j] += H_i^T H_j        (L, L)
    Q[i]    += H_i^T T_tile     (L, M)

so the (N, L) hidden matrix is **never written to HBM** — the paper's
"extremely large" N_i streams through a VMEM-resident working set. This
replaces the two-pass pipeline (materialize H, then kernels/gram.py)
for every raw-input entry point; `core/stats.py` is the consumer.

Tiling mirrors gram.py: grid = (L/bl, L/bl, N/bn) with n innermost so
the (bl, bl) f32 P block stays resident while N streams through. The Q
block rides the same grid — its index map is constant in (j, n), so it
stays resident for a whole row-block i and accumulates on the diagonal
visit (symmetric mode) or at j == 0.

Dtype policy: operands (X, W, H tiles) may be bf16 — the MXU matmuls
run with f32 accumulation (`preferred_element_type`), the activation is
applied in f32, and the H tile is cast back to the operand dtype before
the gram matmul, matching what the unfused oracle computes on a
materialized bf16 H. The cross moment promotes h to T's dtype instead
(f32 targets are never quantized down to a bf16 feature dtype — same
rule as `stats.hidden_moments`). P/Q are always f32 (ridge
conditioning).

Ragged N: padded rows cannot simply be zero-filled like gram.py's
(g(0) = 0.5 for sigmoid!) — the kernel masks hidden rows past N to
exact zeros, so padded tiles contribute nothing to either moment.

Activations come from the shared registry `features.ACTIVATIONS`;
"rbf" is the gaussian branch h = exp(-gamma * ||x - c||^2) computed via
the ||x||^2 - 2 x.c^T + ||c||^2 expansion on the same (bn, bl) tile
(pass W = centers^T and b = gamma).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def hidden_tile(x_ref, w_ref, b_ref, *, activation, rows_in_tile, out_dtype):
    """g(X_tile @ W_blk + b_blk), rows past `rows_in_tile` masked to 0.

    The one in-kernel hidden-layer implementation, shared by the fused
    moment kernel here and the fused predict kernel
    (kernels/elm_predict.py) so the two planes cannot drift.
    """
    from repro.core.features import ACTIVATIONS  # shared registry, no cycle

    x = x_ref[...]
    w = w_ref[...]
    s = jax.lax.dot_general(
        x, w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    b = b_ref[...].astype(jnp.float32)  # (1, bl): bias, or gamma for rbf
    if activation == "rbf":
        xf = x.astype(jnp.float32)
        wf = w.astype(jnp.float32)
        x_sq = jnp.sum(xf * xf, axis=1, keepdims=True)  # (bn, 1)
        c_sq = jnp.sum(wf * wf, axis=0, keepdims=True)  # (1, bl)
        d2 = jnp.maximum(x_sq - 2.0 * s + c_sq, 0.0)
        h = jnp.exp(-b * d2)
    else:
        h = ACTIVATIONS[activation](s + b)
    bn = h.shape[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    h = jnp.where(row_ids < rows_in_tile, h, 0.0)
    return h.astype(out_dtype)


def _elm_stats_kernel(
    x_ref, wi_ref, wj_ref, bi_ref, bj_ref, t_ref, p_ref, q_ref,
    *, activation, num_rows, block_n, symmetric, operand_dtype,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    n = pl.program_id(2)
    rows_in_tile = num_rows - n * block_n  # clamped by the iota compare

    @pl.when(n == 0)
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    # Q's block is constant in (j, n): first visit for row-block i is
    # (j=0, n=0) — init there even when the P compute below is skipped.
    @pl.when((n == 0) & (j == 0))
    def _init_q():
        q_ref[...] = jnp.zeros_like(q_ref)

    tile = functools.partial(
        hidden_tile, x_ref,
        activation=activation, rows_in_tile=rows_in_tile,
        out_dtype=operand_dtype,
    )

    def _accum():
        h_i = tile(wi_ref, bi_ref)
        if symmetric:
            # on the diagonal the j-tile IS the i-tile — reuse it
            h_j = jax.lax.cond(
                i == j, lambda: h_i, lambda: tile(wj_ref, bj_ref)
            )
        else:
            h_j = tile(wj_ref, bj_ref)
        p_ref[...] += jax.lax.dot_general(
            h_i, h_j,
            dimension_numbers=(((0,), (0,)), ((), ())),  # H_i^T H_j
            preferred_element_type=jnp.float32,
        )

        # Accumulate Q once per (i, n), reusing h_i: on the diagonal
        # visit in symmetric mode (always computed), at j == 0
        # otherwise. T may be wider than the operand dtype (f32 targets
        # with bf16 features) — promote h rather than quantize T.
        @pl.when(j == (i if symmetric else 0))
        def _accum_q():
            t = t_ref[...]
            q_ref[...] += jax.lax.dot_general(
                h_i.astype(t.dtype), t,
                dimension_numbers=(((0,), (0,)), ((), ())),  # H_i^T T
                preferred_element_type=jnp.float32,
            )

    if symmetric:
        pl.when(i <= j)(_accum)
    else:
        _accum()


def preact_tile(z_ref, b_ref, *, activation, rows_in_tile, out_dtype):
    """g(Z_tile + b_blk), rows past `rows_in_tile` masked to 0.

    The vertical-mode twin of ``hidden_tile``: the feature matmul
    already happened across column-sliced nodes (core/vertical.py
    assembled Z = sum_i X_i W_i on the wire), so the tile only applies
    bias + nonlinearity. The activation runs in f32 and the tile is
    cast back to the operand dtype, matching the fused pipeline's
    policy. No "rbf" branch: a gaussian node has no additive
    preactivation form, so vertical mode rejects it upstream.
    """
    from repro.core.features import ACTIVATIONS  # shared registry, no cycle

    z = z_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)  # (1, bl)
    h = ACTIVATIONS[activation](z + b)
    bn = h.shape[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    h = jnp.where(row_ids < rows_in_tile, h, 0.0)
    return h.astype(out_dtype)


def _elm_preact_kernel(
    zi_ref, zj_ref, bi_ref, bj_ref, t_ref, p_ref, q_ref,
    *, activation, num_rows, block_n, symmetric, operand_dtype,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    n = pl.program_id(2)
    rows_in_tile = num_rows - n * block_n  # clamped by the iota compare

    @pl.when(n == 0)
    def _init_p():
        p_ref[...] = jnp.zeros_like(p_ref)

    @pl.when((n == 0) & (j == 0))
    def _init_q():
        q_ref[...] = jnp.zeros_like(q_ref)

    tile = functools.partial(
        preact_tile,
        activation=activation, rows_in_tile=rows_in_tile,
        out_dtype=operand_dtype,
    )

    def _accum():
        h_i = tile(zi_ref, bi_ref)
        if symmetric:
            # on the diagonal the j-tile IS the i-tile — reuse it
            h_j = jax.lax.cond(
                i == j, lambda: h_i, lambda: tile(zj_ref, bj_ref)
            )
        else:
            h_j = tile(zj_ref, bj_ref)
        p_ref[...] += jax.lax.dot_general(
            h_i, h_j,
            dimension_numbers=(((0,), (0,)), ((), ())),  # H_i^T H_j
            preferred_element_type=jnp.float32,
        )

        @pl.when(j == (i if symmetric else 0))
        def _accum_q():
            t = t_ref[...]
            q_ref[...] += jax.lax.dot_general(
                h_i.astype(t.dtype), t,
                dimension_numbers=(((0,), (0,)), ((), ())),  # H_i^T T
                preferred_element_type=jnp.float32,
            )

    if symmetric:
        pl.when(i <= j)(_accum)
    else:
        _accum()


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "block_l", "block_n", "interpret", "symmetric"
    ),
)
def elm_preact_stats_pallas(
    Z: jax.Array,
    b: jax.Array,
    T: jax.Array,
    *,
    activation: str = "sigmoid",
    block_l: int = 256,
    block_n: int = 512,
    interpret: bool = False,
    symmetric: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(P, Q) = (H^T H, H^T T) with H = g(Z + b) fused in VMEM.

    Z: (N, L) assembled preactivation, b: (L,), T: (N, M) -> P: (L, L)
    f32, Q: (L, M) f32. The grid mirrors ``elm_stats_pallas`` — only
    the tile producer changes (no feature matmul; Z streams straight
    from HBM in (bn, bl) tiles). Padded L columns evaluate g(0) != 0
    but land outside the [:L, :L] slice, exactly like padded W columns
    in the fused pipeline; padded N rows are masked in-kernel.
    """
    N, L = Z.shape
    M = T.shape[1]
    bl = min(block_l, L)
    bn = min(block_n, N)
    pN, pL, pM = (-N) % bn, (-L) % bl, (-M) % 128
    if pN or pL:
        Z = jnp.pad(Z, ((0, pN), (0, pL)))
    b2 = jnp.pad(b, (0, pL))[None, :].astype(jnp.float32)  # (1, L2), 2D
    if pN or pM:
        T = jnp.pad(T, ((0, pN), (0, pM)))
    T = T.astype(jnp.promote_types(Z.dtype, T.dtype))
    N2, L2, M2 = Z.shape[0], Z.shape[1], T.shape[1]
    grid = (L2 // bl, L2 // bl, N2 // bn)
    kernel = functools.partial(
        _elm_preact_kernel,
        activation=activation, num_rows=N, block_n=bn,
        symmetric=symmetric, operand_dtype=Z.dtype,
    )
    P, Q = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, n: (n, i)),  # Z_i
            pl.BlockSpec((bn, bl), lambda i, j, n: (n, j)),  # Z_j
            pl.BlockSpec((1, bl), lambda i, j, n: (0, i)),   # b_i
            pl.BlockSpec((1, bl), lambda i, j, n: (0, j)),   # b_j
            pl.BlockSpec((bn, M2), lambda i, j, n: (n, 0)),  # T
        ],
        out_specs=[
            pl.BlockSpec((bl, bl), lambda i, j, n: (i, j)),
            pl.BlockSpec((bl, M2), lambda i, j, n: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L2, L2), jnp.float32),
            jax.ShapeDtypeStruct((L2, M2), jnp.float32),
        ],
        interpret=interpret,
    )(Z, Z, b2, b2, T)
    P = P[:L, :L]
    Q = Q[:L, :M]
    if symmetric:
        upper = jnp.triu(P)
        P = upper + upper.T - jnp.diag(jnp.diag(upper))
    return P, Q


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "block_l", "block_n", "interpret", "symmetric"
    ),
)
def elm_stats_pallas(
    X: jax.Array,
    W: jax.Array,
    b: jax.Array,
    T: jax.Array,
    *,
    activation: str = "sigmoid",
    block_l: int = 256,
    block_n: int = 512,
    interpret: bool = False,
    symmetric: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(P, Q) = (H^T H, H^T T) with H = g(X W + b) fused in VMEM.

    X: (N, D), W: (D, L), b: (L,), T: (N, M) -> P: (L, L) f32,
    Q: (L, M) f32. For activation="rbf" pass W = centers^T (D, L) and
    b = gamma (L,). symmetric=True computes only the upper block
    triangle of P (~2x fewer MXU flops) and mirrors it.
    """
    N, D = X.shape
    L = W.shape[1]
    M = T.shape[1]
    bl = min(block_l, L)
    bn = min(block_n, N)
    # pad to tile multiples; padded X *rows* are masked inside the
    # kernel (g(0) != 0 in general), padded L/M/D extents are sliced or
    # contribute exact zeros
    pN, pL, pD, pM = (-N) % bn, (-L) % bl, (-D) % 128, (-M) % 128
    if pN or pD:
        X = jnp.pad(X, ((0, pN), (0, pD)))
    if pL or pD:
        W = jnp.pad(W, ((0, pD), (0, pL)))
    b2 = jnp.pad(b, (0, pL))[None, :].astype(jnp.float32)  # (1, L2), 2D
    if pN or pM:
        T = jnp.pad(T, ((0, pN), (0, pM)))
    # feature matmul runs at the feature dtype (bf16 operands, f32
    # acc); the targets keep their own precision — the Q dot promotes
    # h to T's dtype instead of quantizing f32 targets down to bf16
    W = W.astype(X.dtype)
    T = T.astype(jnp.promote_types(X.dtype, T.dtype))
    N2, L2, M2 = X.shape[0], W.shape[1], T.shape[1]
    grid = (L2 // bl, L2 // bl, N2 // bn)
    kernel = functools.partial(
        _elm_stats_kernel,
        activation=activation, num_rows=N, block_n=bn,
        symmetric=symmetric, operand_dtype=X.dtype,
    )
    P, Q = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, X.shape[1]), lambda i, j, n: (n, 0)),  # X
            pl.BlockSpec((W.shape[0], bl), lambda i, j, n: (0, i)),  # W_i
            pl.BlockSpec((W.shape[0], bl), lambda i, j, n: (0, j)),  # W_j
            pl.BlockSpec((1, bl), lambda i, j, n: (0, i)),           # b_i
            pl.BlockSpec((1, bl), lambda i, j, n: (0, j)),           # b_j
            pl.BlockSpec((bn, M2), lambda i, j, n: (n, 0)),          # T
        ],
        out_specs=[
            pl.BlockSpec((bl, bl), lambda i, j, n: (i, j)),
            pl.BlockSpec((bl, M2), lambda i, j, n: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L2, L2), jnp.float32),
            jax.ShapeDtypeStruct((L2, M2), jnp.float32),
        ],
        interpret=interpret,
    )(X, W, W, b2, b2, T)
    P = P[:L, :L]
    Q = Q[:L, :M]
    if symmetric:
        upper = jnp.triu(P)
        P = upper + upper.T - jnp.diag(jnp.diag(upper))
    return P, Q
