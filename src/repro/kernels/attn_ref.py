"""Pure-jnp oracle for causal GQA attention (naive full softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (B, S, K, G, hd)
    k: jax.Array,  # (B, S, K, hd)
    v: jax.Array,  # (B, S, K, hd)
    *,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, K, G, hd = q.shape
    scale = hd ** -0.5
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(S)
    mask = pos[:, None] >= pos[None, :]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
