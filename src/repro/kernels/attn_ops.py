"""Dispatcher for the attention kernel (TPU kernel vs jnp chunked path).

models/attention.py calls its own chunked jnp implementation directly on
non-TPU backends (it supports windows and mixed local/global); this
wrapper exposes the Pallas kernel for TPU runs and for interpret-mode
validation against the oracle.
"""

from __future__ import annotations

import jax

from repro.kernels.attn_ref import attention_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def causal_attention(q, k, v, *, softcap: float = 0.0, use_kernel=None, **kw):
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        from repro.kernels.attn import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, softcap=softcap, interpret=not _on_tpu(), **kw
        )
    return attention_reference(q, k, v, softcap=softcap)


def decode_attention(
    q, cache_k, cache_v, pos, *, window=None, softcap: float = 0.0,
    use_kernel=None, **kw,
):
    """Single-token attention over a KV cache (flash-decode on TPU)."""
    use = _on_tpu() if use_kernel is None else use_kernel
    if use:
        from repro.kernels.decode_attn import flash_decode_pallas

        return flash_decode_pallas(
            q, cache_k, cache_v, pos, window=window, softcap=softcap,
            interpret=not _on_tpu(), **kw,
        )
    from repro.models.attention import decode_attend

    return decode_attend(
        q, cache_k, cache_v, pos, windowed=False, window=window, cap=softcap
    )
