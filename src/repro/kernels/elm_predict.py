"""Pallas TPU kernel: fused predict pipeline Y = g(X W + b) @ beta.

The serving-side twin of kernels/elm_stats.py: the paper's output map
(eq. 2)

    f(x) = sum_l beta_l g(w_l, b_l, x)  =  H beta,  H = g(X W + b)

in ONE grid pass over the *raw* inputs. Each (bn, D) tile of X streams
through the MXU computing the hidden tile

    H_tile = g(X_tile @ W_blk + b_blk)          (bn, bl), VMEM only

and the f32 output block accumulates in the same pass

    Y[i] += H_tile @ beta_blk                   (bn, M)

so the (N, L) hidden matrix is **never written to HBM** — a query batch
costs one HBM read of X and one HBM write of Y, the rest lives in VMEM.
This replaces the two-pass path (materialize H, then H @ beta) on every
prediction entry point; `kernels/elm_predict_ops.py` is the dispatching
wrapper and `serving/elm_server.py` the request-level consumer.

Tiling: grid = (N/bn, L/bl) with l innermost so the (bn, M) f32 output
block stays resident while the hidden dimension streams through. The
same ``hidden_tile`` body as the stats kernel supplies H (shared
ACTIVATIONS registry; "rbf" via the ||x||^2 - 2 x.c^T + ||c||^2
expansion with W = centers^T and b = gamma).

Dtype policy: operands (X, W, H tiles) may be bf16 — the MXU matmuls
run with f32 accumulation (`preferred_element_type`), the activation is
applied in f32, and the H tile is cast back to the operand dtype before
the output matmul, matching the unfused oracle on a materialized bf16
H. beta may be wider than the features (f32 readout over bf16
features): the output dot promotes h to beta's dtype rather than
quantizing beta down — the same rule as elm_stats' cross moment. Y
accumulates in f32; the wrapper casts to the oracle's result dtype.

Ragged N: padded rows cannot simply be zero-filled (g(0) != 0 for
sigmoid), so hidden rows past N are masked to exact zeros — the padded
Y rows are then exact zeros too, and are sliced off. Padded L columns
are harmless by construction: beta's padded rows are zero, so the
g(0)-valued padded hidden columns contribute nothing.

Stacked multi-tenant path (``elm_predict_stacked_pallas``): a
micro-batch mixing many tenants carries per-row ids into a stacked
(T, L, M) beta tensor. The shared hidden tile g(XW+b) is computed
ONCE per (bn, bl) grid step — exactly as above — and contracts against
the per-row gathered beta tiles

    Y[i] += batched_dot(H_tile, betas[tid[i], l_blk])    (bn, M)

so serving T tenants costs one launch, not T: the feature work is
shared, only the readout gather is per-tenant (decentralized
multi-task ELM, arXiv 1904.11366). The beta block is (T, bl, M) — the
T axis rides whole while L is blocked — and the row gather is a
jnp.take inside the kernel (VMEM gather; for tenant counts whose
stacked block outgrows VMEM, shrink ``block_l`` — the autotuner sweeps
it). Masked padded rows carry tenant id 0; their hidden rows are
exact zeros so the gathered beta contributes nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.elm_stats import hidden_tile


def _elm_predict_kernel(
    x_ref, w_ref, b_ref, beta_ref, y_ref,
    *, activation, num_rows, block_n, operand_dtype,
):
    i = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    # rows past N are masked to exact zeros inside hidden_tile (only
    # the last row-block can be ragged; the iota compare clamps the rest)
    h = hidden_tile(
        x_ref, w_ref, b_ref,
        activation=activation,
        rows_in_tile=num_rows - i * block_n,
        out_dtype=operand_dtype,
    )
    beta = beta_ref[...]
    y_ref[...] += jax.lax.dot_general(
        h.astype(beta.dtype), beta,
        dimension_numbers=(((1,), (0,)), ((), ())),  # H @ beta
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_l", "block_n", "interpret"),
)
def elm_predict_pallas(
    X: jax.Array,
    W: jax.Array,
    b: jax.Array,
    beta: jax.Array,
    *,
    activation: str = "sigmoid",
    block_l: int = 256,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Y = g(X W + b) @ beta with H fused in VMEM.

    X: (N, D), W: (D, L), b: (L,), beta: (L, M) -> Y: (N, M) f32.
    For activation="rbf" pass W = centers^T (D, L) and b = gamma (L,).
    """
    N, D = X.shape
    L = W.shape[1]
    M = beta.shape[1]
    bl = min(block_l, L)
    bn = min(block_n, N)
    # pad to tile multiples; padded X *rows* are masked inside the
    # kernel (g(0) != 0 in general), padded L rows of beta are zero so
    # the padded hidden columns contribute exact zeros, padded D/M
    # extents contribute zeros or are sliced
    pN, pL, pD, pM = (-N) % bn, (-L) % bl, (-D) % 128, (-M) % 128
    if pN or pD:
        X = jnp.pad(X, ((0, pN), (0, pD)))
    if pL or pD:
        W = jnp.pad(W, ((0, pD), (0, pL)))
    b2 = jnp.pad(b, (0, pL))[None, :].astype(jnp.float32)  # (1, L2), 2D
    if pL or pM:
        beta = jnp.pad(beta, ((0, pL), (0, pM)))
    # the feature matmul runs at the feature dtype (bf16 operands, f32
    # acc); the readout keeps its own precision — the output dot
    # promotes h to beta's dtype instead of quantizing beta down
    W = W.astype(X.dtype)
    beta = beta.astype(jnp.promote_types(X.dtype, beta.dtype))
    N2, L2, M2 = X.shape[0], W.shape[1], beta.shape[1]
    grid = (N2 // bn, L2 // bl)
    kernel = functools.partial(
        _elm_predict_kernel,
        activation=activation, num_rows=N, block_n=bn,
        operand_dtype=X.dtype,
    )
    Y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, X.shape[1]), lambda i, l: (i, 0)),   # X
            pl.BlockSpec((W.shape[0], bl), lambda i, l: (0, l)),   # W
            pl.BlockSpec((1, bl), lambda i, l: (0, l)),            # b
            pl.BlockSpec((bl, M2), lambda i, l: (l, 0)),           # beta
        ],
        out_specs=pl.BlockSpec((bn, M2), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N2, M2), jnp.float32),
        interpret=interpret,
    )(X, W, b2, beta)
    return Y[:N, :M]


def _elm_predict_stacked_kernel(
    x_ref, w_ref, b_ref, beta_ref, tid_ref, y_ref,
    *, activation, num_rows, block_n, operand_dtype,
):
    i = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    h = hidden_tile(
        x_ref, w_ref, b_ref,
        activation=activation,
        rows_in_tile=num_rows - i * block_n,
        out_dtype=operand_dtype,
    )
    betas = beta_ref[...]  # (T, bl, M): whole T axis, L blocked
    tids = tid_ref[...][:, 0]  # (bn,)
    bg = jnp.take(betas, tids, axis=0)  # (bn, bl, M) per-row beta tiles
    # batched row contraction: Y[n] += h[n] @ bg[n] — same dot_general
    # as the scan/oracle `_gather_contract`, so per-row results do not
    # depend on launch packing
    y = jax.lax.dot_general(
        h.astype(betas.dtype)[:, None, :], bg,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] += y[:, 0, :]


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_l", "block_n", "interpret"),
)
def elm_predict_stacked_pallas(
    X: jax.Array,
    W: jax.Array,
    b: jax.Array,
    betas: jax.Array,
    tenant_ids: jax.Array,
    *,
    activation: str = "sigmoid",
    block_l: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Y[n] = g(X W + b)[n] @ betas[tenant_ids[n]] with H fused in VMEM.

    X: (N, D), W: (D, L), b: (L,), betas: (T, L, M), tenant_ids: (N,)
    int32 -> Y: (N, M) f32. One launch serves every tenant in the
    batch; the shared hidden tile is computed once per grid step.
    """
    N, D = X.shape
    L = W.shape[1]
    T, _, M = betas.shape
    bl = min(block_l, L)
    bn = min(block_n, N)
    pN, pL, pD, pM = (-N) % bn, (-L) % bl, (-D) % 128, (-M) % 128
    if pN or pD:
        X = jnp.pad(X, ((0, pN), (0, pD)))
    if pL or pD:
        W = jnp.pad(W, ((0, pD), (0, pL)))
    b2 = jnp.pad(b, (0, pL))[None, :].astype(jnp.float32)
    if pL or pM:
        betas = jnp.pad(betas, ((0, 0), (0, pL), (0, pM)))
    # padded rows gather tenant 0's beta but their hidden rows are
    # masked to exact zeros, so the contribution is exactly zero
    tids = jnp.asarray(tenant_ids, jnp.int32)
    if pN:
        tids = jnp.pad(tids, (0, pN))
    tids2 = tids[:, None]  # (N2, 1): TPU wants >= 2D operands
    W = W.astype(X.dtype)
    betas = betas.astype(jnp.promote_types(X.dtype, betas.dtype))
    N2, L2, M2 = X.shape[0], W.shape[1], betas.shape[2]
    grid = (N2 // bn, L2 // bl)
    kernel = functools.partial(
        _elm_predict_stacked_kernel,
        activation=activation, num_rows=N, block_n=bn,
        operand_dtype=X.dtype,
    )
    Y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, X.shape[1]), lambda i, l: (i, 0)),   # X
            pl.BlockSpec((W.shape[0], bl), lambda i, l: (0, l)),   # W
            pl.BlockSpec((1, bl), lambda i, l: (0, l)),            # b
            pl.BlockSpec((T, bl, M2), lambda i, l: (0, l, 0)),     # betas
            pl.BlockSpec((bn, 1), lambda i, l: (i, 0)),            # tids
        ],
        out_specs=pl.BlockSpec((bn, M2), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N2, M2), jnp.float32),
        interpret=interpret,
    )(X, W, b2, betas, tids2)
    return Y[:N, :M]
