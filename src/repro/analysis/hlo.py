"""Trip-count-weighted analysis of post-partitioning HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — a
scanned 80-layer stack reports ~1/80th of its real FLOPs, and every
collective inside a scan is likewise undercounted. This module parses
``compiled.as_text()`` into its computations, builds the call graph
(while bodies, fusions, calls, conditionals), and propagates execution
weights:

  * while body/condition: x known_trip_count (backend_config)
  * call / fusion / async wrappers: x1
  * conditional branches: x 1/num_branches (expected value for a
    data-dependent branch; exact for gemma2's alternating local/global
    cond inside the layer scan)

Per-op accounting, aggregated with those weights:
  flops      2 * prod(result dims) * prod(contracted dims) per dot op
             (MXU flops; elementwise VPU flops are excluded — roofline
             compute on TPU is MXU-bound)
  collective result-shape bytes per all-gather / all-reduce /
             reduce-scatter / all-to-all / collective-permute
  hbm bytes  ~2x result bytes of materialized top-of-computation ops
             (one write + amortized one read; fusion internals excluded)
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s*([\w\-]+)\("
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"(\d+)"')
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations|true_computation|false_computation)="
    r"\{?%?([\w.\-,%\s]+)\}?"
)
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(shape_str: str):
    """'(f32[2,3], bf16[4])' or 'f32[2,3]' -> [(dtype, [dims])]."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return tot


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    shapes: list  # [(dtype, dims)]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list  # [OpInfo]
    shapes: dict  # op name -> shapes (incl. parameters)


def parse_module(hlo_text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw.rstrip())  # drop /*index=N*/
        stripped = line.strip()
        if line.endswith("{") and "->" in line and not line.startswith(" "):
            toks = stripped.split()
            name_tok = toks[1] if toks[0] == "ENTRY" else toks[0]
            name = name_tok.lstrip("%")
            cur = Computation(name=name, ops=[], shapes={})
            comps[name] = cur
            if toks[0] == "ENTRY":
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            # parameter lines: %p = f32[2,3] parameter(0) match _DEF_RE
            continue
        name, shape_str, kind = m.groups()
        shapes = _shape_list(shape_str)
        cur.shapes[name] = shapes
        cur.ops.append(OpInfo(name=name, kind=kind, shapes=shapes, line=line))
    return {"computations": comps, "entry": entry}


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    """2 * |result| * |contracted dims of lhs|."""
    result = 1
    for _, dims in op.shapes:
        for d in dims:
            result *= d
    cm = _CONTRACT_RE.search(op.line)
    # operand names: first two %refs after the opcode's '('
    args = re.findall(r"%([\w.\-]+)", op.line.split("(", 1)[1])
    lhs_shapes = comp.shapes.get(args[0]) if args else None
    contracted = 1
    if cm and lhs_shapes:
        dims = lhs_shapes[0][1]
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(dims):
                contracted *= dims[idx]
    return 2.0 * result * contracted


_SKIP_BYTES_KINDS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id",
}


@dataclasses.dataclass
class ModuleStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    count_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    @property
    def collective_bytes_total(self) -> float:
        return sum(self.bytes_by_kind.values())


def analyze_module(hlo_text: str) -> ModuleStats:
    mod = parse_module(hlo_text)
    comps = mod["computations"]
    entry = mod["entry"]
    stats = ModuleStats()
    if entry is None:
        return stats

    # (execution weight, hbm-accounting weight): fusion bodies execute
    # but their internal ops never touch HBM — only the fusion's own
    # result buffer does (counted at the call site).
    weights: dict[str, list] = defaultdict(lambda: [0.0, 0.0])

    def visit(name: str, weight: float, bw: float, depth: int = 0):
        if name not in comps or depth > 50:
            return
        comp = comps[name]
        weights[name][0] += weight
        weights[name][1] += bw
        for op in comp.ops:
            if op.kind == "while":
                tm = _TRIP_RE.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
                body = _CALLED_RE.search(op.line)
                cond = _COND_RE.search(op.line)
                if body:
                    visit(body.group(1), weight * trip, bw * trip, depth + 1)
                if cond:
                    visit(cond.group(1), weight * (trip + 1), 0.0, depth + 1)
            elif op.kind == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.line.split("(", 1)[1])
                called = [b for b in branches if b in comps]
                if called:
                    w = weight / len(called)
                    bww = bw / len(called)
                    for b in called:
                        visit(b, w, bww, depth + 1)
            elif op.kind in ("call", "async-start"):
                cm = _CALLED_RE.search(op.line)
                if cm and cm.group(1) in comps:
                    visit(cm.group(1), weight, bw, depth + 1)
            elif op.kind in ("fusion", "custom-call"):
                cm = _CALLED_RE.search(op.line)
                if cm and cm.group(1) in comps:
                    visit(cm.group(1), weight, 0.0, depth + 1)

    visit(entry, 1.0, 1.0)

    for name, (w, bw) in weights.items():
        comp = comps[name]
        for op in comp.ops:
            base = op.kind.replace("-start", "").replace("-done", "")
            if op.kind.endswith("-done"):
                continue  # async pair: count the -start only
            if base in COLLECTIVE_KINDS:
                b = _bytes_of(op.shapes)
                stats.bytes_by_kind[base] += w * b
                stats.count_by_kind[base] += w
            if op.kind == "dot":
                stats.flops += w * _dot_flops(op, comp)
            if op.kind not in _SKIP_BYTES_KINDS:
                stats.hbm_bytes += bw * 2.0 * _bytes_of(op.shapes)
    return stats


# Back-compat shim used by older call sites/tests.
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    s = analyze_module(hlo_text)
    return CollectiveStats(
        bytes_by_kind=s.bytes_by_kind, count_by_kind=s.count_by_kind
    )
