"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = wire_bytes / (chips * ICI_BW)

Hardware constants (TPU v5e-like, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

FLOPs / HBM bytes / collective bytes come from the trip-count-weighted
HLO analyzer in analysis/hlo.py — ``compiled.cost_analysis()`` counts
while-loop (lax.scan) bodies once, so a scanned 80-layer stack would be
undercounted ~80x; the raw cost_analysis numbers are still recorded for
reference. All quantities are per-chip (the partitioned module is the
per-device program). Collective wire bytes apply per-kind ring factors
to result-shape sums.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.hlo import CollectiveStats, ModuleStats, analyze_module

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (conservative single-link figure)

# ring-algorithm wire factors applied to result-shape bytes
_WIRE_FACTOR = {
    "all-gather": 1.0,  # each device receives ~result bytes
    "all-reduce": 2.0,  # reduce-scatter + all-gather phases
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,  # one neighbor hop, send == recv
}


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    collectives: CollectiveStats
    peak_memory_per_chip: float  # from memory_analysis
    model_flops: float  # 6*N(active)*D analytic
    chips: int
    raw_cost_analysis: dict | None = None  # XLA's (scan-undercounted) view

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (all chips)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time_estimate(self) -> float:
        """Roofline lower bound (no overlap assumed across terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collective_bytes_by_kind": self.collectives.bytes_by_kind,
            "collective_count_by_kind": self.collectives.count_by_kind,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time_estimate_s": self.step_time_estimate,
            "raw_cost_analysis": self.raw_cost_analysis,
        }


# ---------------------------------------------------------------------------
# Gossip-round time model (the consensus plane's eq. (20) hot loop)
# ---------------------------------------------------------------------------


def gossip_round_terms(
    V: int, d_max: int, L: int, M: int, *, itemsize: int = 4,
    dense: bool = False,
) -> dict:
    """Roofline terms for one eq. (20) consensus round.

    Per round every node forms lap_i = sum_j a_ij (beta_j - beta_i)
    over ``d_max`` neighbors (``V`` fan-in on the ``dense=True``
    matmul formulation) and contracts it against Omega_i — the
    ``2*V*L*L*M`` Omega FLOPs both formulations share. HBM traffic is
    the state in+out, the Omegas, and the neighbor lists (or the dense
    adjacency); ``gather_bytes`` is the neighbor-gather volume the
    fused kernel keeps in VMEM (reported separately — it only hits HBM
    when an unfused path materializes the gathered tiles).

    Used for relative ranking (candidate pruning in
    ``kernels/autotune.py`` op="gossip", the dense-vs-neighbor arm
    choice in ``kernels/elm_gossip_ops.py``, and the
    ``benchmarks/micro.py --profile consensus`` rows) — the absolute
    constants cancel out of those comparisons.
    """
    fanin = V if dense else d_max
    flops = 2.0 * V * fanin * L * M + 2.0 * V * L * L * M
    state = itemsize * (2.0 * V * L * M + V * L * L)
    lists = itemsize * V * V if dense else 2.0 * itemsize * V * d_max
    gather_bytes = itemsize * V * fanin * L * M
    t_compute = flops / PEAK_FLOPS
    t_memory = (state + lists) / HBM_BW
    return {
        "flops": flops,
        "hbm_bytes": state + lists,
        "gather_bytes": gather_bytes,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_round": max(t_compute, t_memory),
    }


def model_flops_estimate(cfg, shape) -> float:
    """6 * N_active * D for training; 2 * N_active * D_tokens for decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_from_compiled(
    compiled,
    *,
    cfg,
    shape,
    mesh_name: str,
    chips: int,
) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    raw = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    text = compiled.as_text()
    mod: ModuleStats = analyze_module(text)
    flops = mod.flops
    hbm = mod.hbm_bytes
    colls = CollectiveStats(
        bytes_by_kind=mod.bytes_by_kind, count_by_kind=mod.count_by_kind
    )
    wire = 0.0
    for kind, b in colls.bytes_by_kind.items():
        wire += _WIRE_FACTOR[kind] * b
    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    return RooflineTerms(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        wire_bytes_per_chip=wire,
        collectives=colls,
        peak_memory_per_chip=peak,
        model_flops=model_flops_estimate(cfg, shape),
        chips=chips,
    )
