from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import RooflineTerms, roofline_from_compiled

__all__ = ["collective_bytes", "RooflineTerms", "roofline_from_compiled"]
