"""Checkpointing: pytree <-> .npz with key-path flattening.

Deliberately dependency-free (no orbax here). Arrays are gathered to
host; restore re-shards via the caller's shardings if provided.
Layout: <dir>/step_<k>.npz with keys like 'params/layers/attn/wq'.
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz can't store ml_dtypes
            flat[key + "@bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _seg(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def save_pytree(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # keep .npz suffix so np.savez doesn't append
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of `like` (names must match)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = "/".join(_seg(p) for p in path_keys)
        if key + "@bf16" in flat:
            import ml_dtypes

            arr = flat[key + "@bf16"].view(ml_dtypes.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing {key!r}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None
