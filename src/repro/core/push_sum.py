"""Push-sum / ratio-consensus mass algebra for the async runtime.

The synchronous plane (core/engine.py) needs a *symmetric* Laplacian
every round — dropped links must be dropped on both ends or the
zero-gradient-sum invariant bends. Real networks give you neither
symmetry nor rounds. Push-sum (ratio consensus) removes both
assumptions: each node carries a mass pair

    sigma_i = (A-mass, Q-mass)   with  A_i = I/(VC) + P_i,  Q_i = H_i^T T_i
    rho_i   = scalar counting mass, rho_i(0) = 1

and on every local firing splits its current mass equally between
itself and its out-neighbors. Because every split *conserves* total
mass, the ratios sigma_i / rho_i converge to the network averages
(mean A, mean Q) on any jointly-reachable directed sequence — and the
node estimate

    beta_i = (sigma_A_i / rho_i)^{-1} (sigma_Q_i / rho_i)
           = solve(sigma_A_i, sigma_Q_i)

converges to the *centralized* solution beta* = (I/C + sum P)^{-1}
sum Q exactly, not just to consensus: scale the averaged moments by V
and the ridge term comes out right. This is why the async engine
gossips the moments (A_i, Q_i) instead of betas — unlike Laplacian
mixing of betas, the fixed point is beta* under loss, delay, and
asymmetric timing.

**Loss-proof counters.** A dropped message must not destroy mass, so
transmissions use running sums (robust ratio consensus): the sender
accumulates everything it ever shipped on edge i->j into a cumulative
counter mu[i->j] and transmits *the counter*; the receiver remembers
the last counter value it processed, nu[i->j], and applies the
difference. A lost message leaves its mass "in flight" inside
mu - nu until any later message on that edge delivers it; stale or
reordered deliveries are no-ops (guarded by a sequence number — the
newest counter subsumes them). The per-event conservation law

    sum_i sigma_i + sum_{(i,j)} (mu[i->j] - nu[i->j]) = sum_i sigma_i(0)

holds *exactly* (up to float roundoff) after every fire, delivery,
drop, and reorder — it is the async plane's zero-gradient-sum
analogue, asserted by tests and the nightly seed-sweep stress job.

This module is the pure state algebra (init / split / absorb /
conservation accounting) on numpy arrays; the event scheduler that
drives it lives in core/async_engine.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Mass:
    """One node's (or one edge counter's) mass triple."""

    A: np.ndarray  # (L, L) accumulated ridge-Gram mass
    Q: np.ndarray  # (L, M) accumulated cross-moment mass
    rho: float  # scalar counting mass

    def copy(self) -> "Mass":
        return Mass(A=self.A.copy(), Q=self.Q.copy(), rho=float(self.rho))

    @classmethod
    def zeros(cls, L: int, M: int, dtype=np.float64) -> "Mass":
        return cls(
            A=np.zeros((L, L), dtype=dtype),
            Q=np.zeros((L, M), dtype=dtype),
            rho=0.0,
        )

    def add_scaled(self, other: "Mass", w: float) -> None:
        """self += w * other (in place)."""
        self.A += w * other.A
        self.Q += w * other.Q
        self.rho += w * other.rho

    def add_diff(self, latest: "Mass", processed: "Mass") -> None:
        """self += (latest - processed) — absorb a cumulative counter's
        unprocessed remainder (in place)."""
        self.A += latest.A - processed.A
        self.Q += latest.Q - processed.Q
        self.rho += latest.rho - processed.rho

    def scale(self, w: float) -> None:
        """self *= w (in place) — the kept share after a split."""
        self.A *= w
        self.Q *= w
        self.rho *= w


def init_masses(P: np.ndarray, Q: np.ndarray, C: float) -> list[Mass]:
    """Per-node initial mass from local statistics.

    P: (V, L, L) local Grams H_i^T H_i, Q: (V, L, M) cross moments.
    Node i starts with sigma = (I/(VC) + P_i, Q_i) and rho = 1 — the
    same (paper eq. 21) ridge-regularized moments the synchronous
    plane's Omega_i inverts, kept *uninverted* here because sums of
    moments are meaningful where sums of inverses are not.
    """
    P = np.asarray(P, dtype=np.float64)
    Q = np.asarray(Q, dtype=np.float64)
    V, L = P.shape[0], P.shape[1]
    ridge = np.eye(L) / (V * float(C))
    return [Mass(A=ridge + P[i], Q=Q[i].copy(), rho=1.0) for i in range(V)]


def estimate(mass: Mass) -> np.ndarray:
    """beta_i = solve(sigma_A / rho, sigma_Q / rho) = solve(sigma_A,
    sigma_Q) — rho cancels in the ratio, but keeping it nonzero is what
    guarantees sigma_A is (a positive multiple of) an SPD matrix."""
    if mass.rho <= 0.0:
        raise ValueError(
            f"cannot estimate from nonpositive counting mass {mass.rho}"
        )
    return np.linalg.solve(mass.A, mass.Q)


def split_share(out_degree: int) -> float:
    """Equal split over self + out-neighbors (standard push-sum)."""
    return 1.0 / (out_degree + 1.0)


def conservation_residual(
    sigmas: list[Mass],
    mu: dict,
    nu: dict,
    total0: Mass,
) -> float:
    """Max-abs violation of the conservation law, relative to the
    initial totals:

        sum_i sigma_i + sum_edges (mu - nu)  ==  total0 .

    mu/nu: dicts keyed by directed edge (i, j) holding cumulative
    Mass counters (sent / processed). Exact up to roundoff no matter
    which messages were dropped, delayed, or reordered.
    """
    L, M = total0.A.shape[0], total0.Q.shape[1]
    acc = Mass.zeros(L, M)
    for s in sigmas:
        acc.add_scaled(s, 1.0)
    for key, sent in mu.items():
        acc.add_scaled(sent, 1.0)
        got = nu.get(key)
        if got is not None:
            acc.add_scaled(got, -1.0)
    scale = max(
        float(np.max(np.abs(total0.A))),
        float(np.max(np.abs(total0.Q))),
        float(abs(total0.rho)),
        1.0,
    )
    err = max(
        float(np.max(np.abs(acc.A - total0.A))),
        float(np.max(np.abs(acc.Q - total0.Q))),
        float(abs(acc.rho - total0.rho)),
    )
    return err / scale


def total_mass(sigmas: list[Mass]) -> Mass:
    """Plain sum of node masses (the conserved quantity at t=0, before
    anything is in flight)."""
    L, M = sigmas[0].A.shape[0], sigmas[0].Q.shape[1]
    acc = Mass.zeros(L, M)
    for s in sigmas:
        acc.add_scaled(s, 1.0)
    return acc
