"""Pluggable communication backends ("mixers") for the ConsensusEngine.

A Mixer answers one question — *how does the network Laplacian term*

    lap_i = sum_{j in N_i} a_ij (x_j - x_i)

*get computed for this execution substrate?* — and one follow-up: *how
do we scan many consensus rounds in that substrate?* Everything about
the update rule itself (DC-ELM's preconditioned step, plain averaging,
D-PSGD parameter mixing) lives in ``core/engine.py`` and is shared by
all mixers.

Two implementations:

* ``DenseMixer`` — all V nodes stacked on the leading axis of every
  leaf, mixing via the dense adjacency (optionally a sequence of
  adjacencies for time-varying topologies). Single-device / vmap path;
  supports arbitrary graphs incl. the paper's random geometric ones.

* ``PpermuteMixer`` — node i is the shard at mesh position i along the
  consensus axes; mixing is neighbor-only ``lax.ppermute`` gossip
  (core/gossip.py) under ``shard_map``. ICI-realizable topologies only.
  This is the production path.

Both accept the inline gossip payload compression knob (``None`` /
``"none"`` / ``"bf16"``): the payload is quantized before the
Laplacian is formed, and the (bounded, gamma-scaled) delta is applied
back in the state dtype. Richer wire formats — int8 with per-tile
scales, top-k sparsification, error feedback, event-triggered
rounds — are ``core/compression.CompressedMixer``, which wraps any
mixer in this file.

``FaultyMixer`` composes over either of the two: it replays a
per-round edge keep-mask stream (``consensus.FaultModel``) so links
drop, burst-fail, or whole nodes crash and rejoin, while the update
rule and execution substrate stay untouched.

Every mixer records exact bytes-on-wire accounting
(``compression.WireStats``) on ``last_wire_stats`` after each ``run``;
the engine surfaces it as ``ConsensusEngine.wire_stats``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import gossip
from repro.core.consensus import Graph
from repro.utils import compat


#: modes the inline ``compress=`` knob understands; richer wire formats
#: (int8 / top-k / event-triggered) live in ``core/compression.py``.
INLINE_COMPRESS_MODES = (None, "none", "bf16")


def _normalize_compress(mode: str | None) -> str | None:
    """Canonicalize the inline knob: ``None`` and ``"none"`` are the
    same (no compression); unknown modes fail at construction time."""
    if mode in (None, "none"):
        return None
    if mode == "bf16":
        return mode
    raise ValueError(
        f"unknown gossip compression {mode!r}: the inline mixer knob "
        f"accepts {INLINE_COMPRESS_MODES}. For int8 / top-k / "
        "event-triggered wire formats build a core.compression."
        "CompressionSpec and wrap the engine with "
        "engine.with_compression(...) (or pass the spec straight to the "
        "engine constructors' compress=)."
    )


def compress_payload(x: jax.Array, mode: str | None) -> jax.Array:
    """Quantize a gossip payload (paper Sec. V: 'reduction of the amount
    of information exchanging')."""
    mode = _normalize_compress(mode)
    if mode is None:
        return x
    return x.astype(jnp.bfloat16)


def _mix_dtype(payload_dtype) -> jnp.dtype:
    """Accumulate the Laplacian at least in f32 (bf16 payloads upcast)."""
    return jnp.promote_types(payload_dtype, jnp.float32)


class DenseMixer:
    """Dense-adjacency mixing over a stacked leading node axis.

    adjacencies: (V, V) for a static graph, or (S, V, V) for a
    time-varying sequence — round k mixes with snapshot k % S.
    """

    def __init__(self, adjacencies, *, compress: str | None = None):
        adjacencies = jnp.asarray(adjacencies)
        if adjacencies.ndim == 2:
            adjacencies = adjacencies[None]
        if adjacencies.ndim != 3 or (
            adjacencies.shape[-1] != adjacencies.shape[-2]
        ):
            raise ValueError(
                f"adjacencies must be (V,V) or (S,V,V), got {adjacencies.shape}"
            )
        self.adjacencies = adjacencies
        # weighted degrees per snapshot, computed once: every scanned
        # round used to redo the (S, V, V) reduction under the trace
        self.degrees = jnp.sum(adjacencies, axis=-1)
        self.compress = _normalize_compress(compress)
        self.last_wire_stats = None
        self.total_bytes_on_wire = 0

    @classmethod
    def from_graphs(
        cls,
        graphs: Graph | Sequence[Graph],
        *,
        dtype=jnp.float32,
        compress: str | None = None,
    ) -> "DenseMixer":
        if isinstance(graphs, Graph):
            graphs = [graphs]
        adjs = np.stack([np.asarray(g.adjacency) for g in graphs])
        return cls(jnp.asarray(adjs, dtype=dtype), compress=compress)

    @property
    def num_nodes(self) -> int:
        return self.adjacencies.shape[-1]

    def gamma_upper_bound(self) -> float:
        """Paper Thm. 2: 1 / max_k d_max(G_k), joint over snapshots.
        Requires concrete adjacencies (not under a trace)."""
        d_max = float(jnp.max(self.degrees))
        return 1.0 / d_max

    def default_gamma(self, safety: float = 0.9) -> float:
        """safety * gamma_upper_bound() (paper Thm. 2 bound)."""
        return safety * self.gamma_upper_bound()

    def _adjacency(self, k):
        if self.adjacencies.shape[0] == 1:
            return self.adjacencies[0]
        return self.adjacencies[k % self.adjacencies.shape[0]]

    def _degree_row(self, k):
        if self.degrees.shape[0] == 1:
            return self.degrees[0]
        return self.degrees[k % self.degrees.shape[0]]

    def laplacian(self, x, k=0):
        """Stacked Laplacian term, one leaf at a time: A @ x - deg * x."""
        adj = self._adjacency(k)
        deg = self._degree_row(k)

        def leaf(v):
            flat = v.reshape(v.shape[0], -1)
            payload = compress_payload(flat, self.compress)
            dt = _mix_dtype(payload.dtype)
            p = payload.astype(dt)
            a = adj.astype(dt)
            lap = a @ p - deg.astype(dt)[:, None] * p
            return lap.astype(v.dtype).reshape(v.shape)

        return jax.tree.map(leaf, x)

    def apply_round(self, rule, x, payload, aux, gamma, k=0):
        """One consensus round where the gossiped payload differs from
        the state — the ``CompressedMixer`` hot path, where ``payload``
        is the receivers' decoded view x̂ of the network while the
        update applies to the true state ``x``. Subclasses may fuse the
        gather + rule into one program; this default is the exact
        composition ``rule(x, laplacian(payload, k), aux, gamma)``.
        """
        return rule(x, self.laplacian(payload, k), aux, gamma)

    def run(
        self,
        rule,
        x,
        aux,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        """Scan ``rule(x, laplacian(x, k), aux, gamma)`` for num_iters rounds."""
        del state_spec, aux_spec  # placement hints are a sharded concern

        def f(carry, k):
            nxt = rule(carry, self.laplacian(carry, k), aux, gamma)
            out = trace_fn(nxt) if trace_fn is not None else jnp.zeros(())
            return nxt, out

        final, traces = lax.scan(f, x, jnp.arange(num_iters))
        self._record_wire(x, num_iters)
        return final, (traces if trace_fn is not None else None)

    def _record_wire(self, x, num_iters: int) -> None:
        """Exact bytes-on-wire: every live directed edge moves one
        payload per round (shape-only — safe under tracing)."""
        from repro.core import compression

        compression.record_wire_stats(self, compression.compute_wire_stats(
            self.compress, compression.dense_out_degrees(self.adjacencies),
            x, self.num_nodes, num_iters,
        ))


class NeighborMixer(DenseMixer):
    """Neighbor-sparse mixing through the fused gossip kernel plane.

    Semantically a ``DenseMixer`` (same constructor, same Laplacian,
    same wire accounting — everything composes: ``FaultyMixer``,
    ``CompressedMixer``, elastic membership), but the adjacency is
    additionally lowered at construction to padded CSR-style neighbor
    lists (``kernels/elm_gossip_ref.neighbor_lists``) and the hot paths
    dispatch to ``kernels/elm_gossip_ops``:

    * ``run`` with a ``DCELMRule`` over stacked f32 betas executes the
      whole round loop as the fused gossip kernel (Pallas on TPU, a
      jitted neighbor-list scan elsewhere) — the dense ``(V, V) @
      (V, L*M)`` matmul and its HBM-round-tripped Laplacian never
      materialize.
    * ``apply_round`` (the CompressedMixer hot path) fuses the
      payload-gather + Omega contraction of one round.
    * ``laplacian`` gathers over neighbor slots instead of the dense
      matmul whenever the graph is genuinely sparse (2 d_max < V).

    On graphs too dense for gathers to win (complete-ish topologies,
    or small V relative to L — ``elm_gossip_ops.prefers_dense``) every
    path falls back to the exact DenseMixer program, so selecting this
    mixer is always safe; parity with ``DenseMixer`` is pinned to f32
    tolerance in tests/test_gossip_kernel.py.
    """

    def __init__(self, adjacencies, *, compress: str | None = None):
        super().__init__(adjacencies, compress=compress)
        from repro.kernels import elm_gossip_ref

        idx, w, _ = elm_gossip_ref.neighbor_lists(self.adjacencies)
        self.neighbor_idx = idx
        self.neighbor_w = w
        self.d_max = int(idx.shape[-1])

    def _lists_row(self, k):
        if self.adjacencies.shape[0] == 1:
            return self.neighbor_idx[0], self.neighbor_w[0], self.degrees[0]
        S = self.adjacencies.shape[0]
        return (
            self.neighbor_idx[k % S],
            self.neighbor_w[k % S],
            self.degrees[k % S],
        )

    def laplacian(self, x, k=0):
        from repro.kernels import elm_gossip_ops, elm_gossip_ref

        if elm_gossip_ops.laplacian_prefers_dense(
            self.num_nodes, self.d_max
        ):
            return super().laplacian(x, k)
        idx_k, w_k, deg_k = self._lists_row(k)

        def leaf(v):
            flat = v.reshape(v.shape[0], -1)
            payload = compress_payload(flat, self.compress)
            lap = elm_gossip_ref.neighbor_laplacian(
                payload, idx_k, w_k, deg_k
            )
            return lap.astype(v.dtype).reshape(v.shape)

        return jax.tree.map(leaf, x)

    def _fused_ok(self, rule, x, aux, gamma, *, allow_bf16: bool) -> bool:
        """The fused kernel covers exactly the DC-ELM hot path: stacked
        f32 (V, L, M) betas, (V, L, L) Omegas, a concrete-or-traced
        gamma, inline payload mode None/bf16, on a graph sparse enough
        for the gather formulation to win."""
        from repro.core.engine import DCELMRule
        from repro.kernels import elm_gossip_ops

        if not isinstance(rule, DCELMRule) or gamma is None:
            return False
        if self.compress is not None and not allow_bf16:
            return False
        if not (
            isinstance(x, jax.Array)
            and x.ndim == 3
            and x.dtype == jnp.float32
        ):
            return False
        V, L, M = x.shape
        if V != self.num_nodes:
            return False
        if not (
            isinstance(aux, jax.Array)
            and aux.shape == (V, L, L)
            and aux.dtype == jnp.float32
        ):
            return False
        return not elm_gossip_ops.prefers_dense(V, self.d_max, L, M)

    def _scale(self, rule, gamma):
        return gamma / (rule.num_nodes * rule.C)

    def apply_round(self, rule, x, payload, aux, gamma, k=0):
        fusable = (
            self.compress is None
            and isinstance(payload, jax.Array)
            and payload.ndim == 3
            and payload.dtype == jnp.float32
            and self._fused_ok(rule, x, aux, gamma, allow_bf16=False)
        )
        if not fusable:
            return super().apply_round(rule, x, payload, aux, gamma, k)
        from repro.kernels import elm_gossip_ops

        idx_k, w_k, deg_k = self._lists_row(k)
        return elm_gossip_ops.fused_gossip_round(
            x, payload, aux, idx_k, w_k, deg_k, self._scale(rule, gamma)
        )

    def run(
        self,
        rule,
        x,
        aux,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        if (
            trace_fn is not None
            or num_iters <= 0
            or not self._fused_ok(rule, x, aux, gamma, allow_bf16=True)
        ):
            return super().run(
                rule, x, aux, gamma, num_iters, trace_fn, state_spec,
                aux_spec,
            )
        from repro.kernels import elm_gossip_ops

        final = elm_gossip_ops.fused_gossip_rounds(
            x, aux, self.neighbor_idx, self.neighbor_w, self.degrees,
            self._scale(rule, gamma), num_rounds=num_iters,
            compress=self.compress,
        )
        self._record_wire(x, num_iters)
        return final, None


@dataclasses.dataclass(frozen=True)
class PpermuteMixer:
    """ppermute-gossip mixing for ICI topologies (gossip.GossipSpec).

    ``laplacian`` is usable inside any caller-managed ``shard_map``
    (that is how distributed/steps.py mixes model-sharded replicas);
    ``run`` additionally owns the shard_map + scan wrapping for the
    standard layout where state leaves carry a leading node axis of
    size V = prod(consensus axes), sharded across those axes.
    """

    spec: gossip.GossipSpec
    axis_sizes: dict
    mesh: jax.sharding.Mesh | None = None
    compress: str | None = None
    # jitted shard_map(scan) programs keyed by (rule, num_iters, specs,
    # has_aux) — reusing the engine across calls (the streaming loop
    # pattern) then hits the compile cache instead of retracing.
    _programs: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    def __post_init__(self):
        # wire accounting is mutable state on a frozen dataclass; it is
        # written through compression.record_wire_stats
        object.__setattr__(self, "compress", _normalize_compress(self.compress))
        object.__setattr__(self, "last_wire_stats", None)
        object.__setattr__(self, "total_bytes_on_wire", 0)

    def _record_wire(self, x, num_iters: int) -> None:
        from repro.core import compression

        deg = self.spec.degree(self.axis_sizes)
        compression.record_wire_stats(self, compression.compute_wire_stats(
            self.compress,
            np.full((1, self.num_nodes), deg, dtype=np.int64),
            x, self.num_nodes, num_iters,
        ))

    @classmethod
    def for_mesh(
        cls,
        mesh: jax.sharding.Mesh,
        spec: gossip.GossipSpec,
        *,
        compress: str | None = None,
    ) -> "PpermuteMixer":
        gossip.validate_spec(spec, mesh)
        return cls(
            spec=spec,
            axis_sizes=gossip.mesh_axis_sizes(mesh),
            mesh=mesh,
            compress=compress,
        )

    @property
    def num_nodes(self) -> int:
        return self.spec.num_nodes(self.axis_sizes)

    def gamma_upper_bound(self) -> float:
        return self.spec.gamma_upper_bound(self.axis_sizes)

    def default_gamma(self, safety: float = 0.9) -> float:
        return safety * self.gamma_upper_bound()

    def node_pspec(self) -> P:
        """PartitionSpec placing the leading node axis on the consensus axes."""
        axes = self.spec.axes
        return P(axes if len(axes) > 1 else axes[0])

    def laplacian(self, x, k=0):
        """Neighbor Laplacian via ppermute — call inside shard_map."""
        del k  # ICI topologies are static; snapshots don't vary per round
        if self.compress is not None:
            payload = jax.tree.map(
                lambda v: compress_payload(v, self.compress), x
            )
        else:
            payload = x
        lap = gossip.neighbor_laplacian(payload, self.spec, self.axis_sizes)
        return jax.tree.map(lambda v, d: d.astype(v.dtype), x, lap)

    def run(
        self,
        rule,
        x,
        aux,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        """shard_map(scan(rule ∘ laplacian)) on the mesh: one collective
        program for the whole consensus run, neighbor-only ICI traffic
        inside. Programs are cached per (rule, num_iters, specs) and
        take gamma as a traced argument, so repeated calls on the same
        mixer — e.g. every streaming chunk event — compile once.
        """
        if trace_fn is not None:
            raise NotImplementedError(
                "per-round traces are a simulated-path (DenseMixer) feature"
            )
        if self.mesh is None:
            raise ValueError(
                "PpermuteMixer.run needs a mesh; build via for_mesh(...)"
            )
        sspec = self.node_pspec() if state_spec is None else state_spec
        aspec = self.node_pspec() if aux_spec is None else aux_spec
        key = (rule, num_iters, sspec, aspec, aux is None)
        fn = self._programs.get(key)
        if fn is None:
            if aux is None:

                def scanned(b, g):
                    def f(carry, k):
                        return rule(carry, self.laplacian(carry, k), None, g), None

                    final, _ = lax.scan(f, b, jnp.arange(num_iters))
                    return final

                fn = jax.jit(compat.shard_map(
                    scanned, self.mesh, in_specs=(sspec, P()), out_specs=sspec
                ))
            else:

                def scanned(b, o, g):
                    def f(carry, k):
                        return rule(carry, self.laplacian(carry, k), o, g), None

                    final, _ = lax.scan(f, b, jnp.arange(num_iters))
                    return final

                fn = jax.jit(compat.shard_map(
                    scanned, self.mesh,
                    in_specs=(sspec, aspec, P()), out_specs=sspec,
                ))
            self._programs[key] = fn
        gamma = jnp.asarray(gamma)
        self._record_wire(x, num_iters)
        if aux is None:
            return fn(x, gamma), None
        return fn(x, aux, gamma), None


class FaultyMixer:
    """Fault-injection wrapper: a base mixer plus per-round edge masks.

    ``edge_keep`` is an (R, V, V) symmetric 0/1 stream (typically
    ``consensus.FaultModel.edge_keep``); round k mixes with mask
    k % R. Composition per base:

    * ``DenseMixer`` — each round's dense adjacency is multiplied by
      its mask; time-varying bases compose (snapshot k % S, mask
      k % R) over one period of length lcm(S, R).

    * ``PpermuteMixer`` — the masks are folded onto the ppermute
      schedule (``gossip.fold_edge_keep``) and each permutation's
      received contribution is weighted inside the shard_map body, so
      a dropped link contributes zero to the Laplacian while the
      collective schedule — and therefore the compiled
      ``shard_map(scan)`` program — is byte-identical to the
      fault-free one. The folded masks enter the jitted program as a
      *traced* argument and programs are cached on the shared base
      mixer, so sweeping failure rates (new masks, same shapes) never
      recompiles.

    Fault masks only remove edges, so the base mixer's Thm. 2 step
    bound (``default_gamma``) remains valid for every masked snapshot.
    """

    def __init__(self, base, edge_keep):
        edge_keep = np.asarray(edge_keep, dtype=np.float32)
        if edge_keep.ndim == 2:
            edge_keep = edge_keep[None]
        V = base.num_nodes
        if edge_keep.ndim != 3 or edge_keep.shape[-2:] != (V, V):
            raise ValueError(
                f"edge_keep must be (R, {V}, {V}), got {edge_keep.shape}"
            )
        if not np.allclose(edge_keep, np.transpose(edge_keep, (0, 2, 1))):
            raise ValueError("edge_keep must be symmetric per round")
        self.base = base
        self.edge_keep = edge_keep
        self.num_rounds = edge_keep.shape[0]
        self.last_wire_stats = None
        self.total_bytes_on_wire = 0
        if isinstance(base, DenseMixer):
            S = base.adjacencies.shape[0]
            R = edge_keep.shape[0]
            period = math.lcm(S, R)
            masked = (
                np.asarray(base.adjacencies)[np.arange(period) % S]
                * edge_keep[np.arange(period) % R]
            )
            # type(base), not DenseMixer: a NeighborMixer base rebuilds
            # its padded neighbor lists from the masked period, folding
            # each round's edge-keep mask into per-neighbor-slot weights
            # (a dropped edge is a zero-weight slot), so the fused
            # kernel path survives fault injection
            self._dense = type(base)(
                jnp.asarray(masked, base.adjacencies.dtype),
                compress=base.compress,
            )
            self._keep = None
        elif isinstance(base, PpermuteMixer):
            self._dense = None
            self._keep = jnp.asarray(
                gossip.fold_edge_keep(base.spec, base.axis_sizes, edge_keep)
            )
        else:
            raise TypeError(
                f"FaultyMixer wraps DenseMixer or PpermuteMixer, got "
                f"{type(base).__name__}"
            )

    @classmethod
    def from_fault_model(cls, base, model, num_rounds: int) -> "FaultyMixer":
        """Wrap ``base`` with ``model``'s fault trace over num_rounds."""
        return cls(base, model.edge_keep(num_rounds))

    @property
    def num_nodes(self) -> int:
        return self.base.num_nodes

    @property
    def compress(self):
        return self.base.compress

    def gamma_upper_bound(self) -> float:
        """Faults only remove edges, so the base bound stays valid."""
        return self.base.gamma_upper_bound()

    def default_gamma(self, safety: float = 0.9) -> float:
        return self.base.default_gamma(safety)

    def node_pspec(self) -> P:
        return self.base.node_pspec()

    def laplacian(self, x, k=0):
        """Masked Laplacian for round k (k % R into the fault trace).

        Dense: directly callable. Ppermute: call inside a
        caller-managed shard_map over the base mesh — the shard finds
        its own mask row via its mesh position.
        """
        if self._dense is not None:
            return self._dense.laplacian(x, k)
        base = self.base
        my = gossip.global_node_index(base.spec, base.axis_sizes)
        keep = self._keep[jnp.mod(jnp.asarray(k), self.num_rounds), :, my]
        return self._masked_laplacian(x, keep)

    def apply_round(self, rule, x, payload, aux, gamma, k=0):
        """Masked round with an explicit payload — delegates to the
        masked-period inner mixer (dense bases only; the ppermute arm
        has no payload-splitting caller)."""
        if self._dense is None:
            raise NotImplementedError(
                "apply_round with an explicit payload is a dense-base "
                "feature"
            )
        return self._dense.apply_round(rule, x, payload, aux, gamma, k)

    def _masked_laplacian(self, x, keep):
        base = self.base
        if base.compress is not None:
            payload = jax.tree.map(
                lambda v: compress_payload(v, base.compress), x
            )
        else:
            payload = x
        lap = gossip.masked_neighbor_laplacian(
            payload, base.spec, base.axis_sizes, keep
        )
        return jax.tree.map(lambda v, d: d.astype(v.dtype), x, lap)

    def run(
        self,
        rule,
        x,
        aux,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        if self._dense is not None:
            out = self._dense.run(
                rule, x, aux, gamma, num_iters, trace_fn, state_spec,
                aux_spec,
            )
            # the masked-adjacency inner mixer counted only live links
            from repro.core import compression

            compression.record_wire_stats(
                self, self._dense.last_wire_stats
            )
            return out
        base = self.base
        if trace_fn is not None:
            raise NotImplementedError(
                "per-round traces are a simulated-path (DenseMixer) feature"
            )
        if base.mesh is None:
            raise ValueError(
                "FaultyMixer.run over ppermute needs a mesh; build the "
                "base via PpermuteMixer.for_mesh(...)"
            )
        sspec = self.node_pspec() if state_spec is None else state_spec
        aspec = self.node_pspec() if aux_spec is None else aux_spec
        # cache on the *base* mixer: the folded masks are a traced
        # input, so every FaultyMixer sharing this base (e.g. a
        # failure-rate sweep) reuses one compiled program per
        # (rule, num_iters, specs, mask period).
        key = (
            "faulty", rule, num_iters, sspec, aspec, aux is None,
            self._keep.shape,
        )
        fn = base._programs.get(key)
        if fn is None:
            R = self.num_rounds

            def scanned(b, o, keep_all, g):
                my = gossip.global_node_index(base.spec, base.axis_sizes)

                def f(carry, k):
                    keep = keep_all[jnp.mod(k, R), :, my]
                    lap = self._masked_laplacian(carry, keep)
                    return rule(carry, lap, o, g), None

                final, _ = lax.scan(f, b, jnp.arange(num_iters))
                return final

            if aux is None:
                fn = jax.jit(compat.shard_map(
                    lambda b, keep_all, g: scanned(b, None, keep_all, g),
                    base.mesh,
                    in_specs=(sspec, P(), P()),
                    out_specs=sspec,
                ))
            else:
                fn = jax.jit(compat.shard_map(
                    scanned,
                    base.mesh,
                    in_specs=(sspec, aspec, P(), P()),
                    out_specs=sspec,
                ))
            base._programs[key] = fn
        gamma = jnp.asarray(gamma)
        self._record_wire(x, num_iters)
        if aux is None:
            return fn(x, self._keep, gamma), None
        return fn(x, aux, self._keep, gamma), None

    def _record_wire(self, x, num_iters: int) -> None:
        """Exact live-link accounting over the folded ppermute masks:
        in-degree == out-degree per node because the edge masks are
        symmetric and the perm schedule covers both directions."""
        from repro.core import compression

        out_deg = (np.asarray(self._keep) != 0).sum(axis=1).astype(np.int64)
        compression.record_wire_stats(self, compression.compute_wire_stats(
            self.compress, out_deg, x, self.num_nodes, num_iters,
        ))
