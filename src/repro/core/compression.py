"""Compressed gossip: quantized / sparsified wire payloads with
CHOCO-style error feedback, event-triggered rounds, and exact
bytes-on-wire accounting.

The paper motivates DC-ELM for networks where "the amount of
information exchanging" is the binding constraint (Sec. V). The inline
mixer knob (``compress="bf16"``) halves the payload; this module is the
aggressive end of that axis.

**The replica scheme.** Naively quantizing the broadcast state leaves a
noise floor set by the *full* payload magnitude (the per-tile scale is
max|beta|/127 no matter how converged the network is). Instead, every
node maintains a public replica x̂_i — what its neighbors have
reconstructed about it — and each round transmits only the encoded
difference

    q_i = Q(x_i - x̂_i),   x̂_i <- x̂_i + q_i ,

while receivers integrate the same q_i into their copy of x̂_i and the
consensus Laplacian is formed over replicas:
lap_i = sum_j a_ij (x̂_j - x̂_i). This is CHOCO-gossip's error-feedback
memory: the residual x_i - x̂_i is exactly the information not yet
transmitted, it is carried in the engine state, and the quantizer's
per-tile scale *decays with it* — so int8 (even top-k) gossip
converges to the exact consensus instead of a quantization floor, and
the Thm. 2 contraction survives because the replica lag
||x - x̂|| = ||d - Q(d)|| is a contraction of the residual itself.

**Event-triggered rounds.** With ``event_threshold`` set, a node whose
residual RMS is below the threshold broadcasts nothing at all (zero
bytes; receivers' replicas simply don't move — skipping is a no-op, not
an error). Because residuals decay to zero, a converged network goes
*silent*, which is what makes compressed gossip pay off in reach-and-
hold serving windows and Algorithm 2 streaming.

**Faults.** Replica updates are incremental, so delta messages must
not be silently *lost* — the transport is modeled as reliable links
with outages (``FaultyMixer``): while a link is down its mix term is
gated to zero exactly as in the uncompressed fault layer, undelivered
deltas queue, and the queue flushes on recovery (one catch-up message,
since a sum of deltas is itself one delta). Every live receiver
therefore holds the same reconstruction x̂_j, and the compressed
Laplacian is simply the base mixer's (masked, time-varying, ...)
Laplacian evaluated over replicas instead of raw states.

``refresh_every=N`` additionally makes every N-th round an absolute
broadcast (same wire format, applied by assignment) for deployments
whose transport cannot guarantee delivery; ``error_feedback=False`` is
the memoryless ablation — every round an absolute broadcast — which
reproduces the classic quantize-the-state scheme and its bias floor.

See DESIGN.md §9 and ``examples/compressed_gossip.py``.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import gossip
from repro.core.mixers import DenseMixer, FaultyMixer, PpermuteMixer
from repro.utils import compat

MODES = ("none", "bf16", "int8", "topk")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Declarative wire format for gossip payloads.

    mode:   "none" | "bf16" | "int8" | "topk".
    tile:   int8 only — values sharing one f32 scale (max|x|/127 over
            the tile; 4 bytes of header on the wire per tile).
    k:      topk only — kept entries per message, as a fraction of the
            payload (float in (0, 1]) or an absolute count (int). Each
            kept entry ships its value plus a 4-byte index.
    error_feedback: CHOCO replica memory (see module docstring). False
            degrades to memoryless absolute quantization every round —
            the ablation showing the quantization-bias floor.
    event_threshold: skip a node's broadcast entirely when the RMS of
            its untransmitted residual x - x̂ is below this; None
            broadcasts every round. Skipped broadcasts cost 0 bytes.
    refresh_every: every N-th round is an absolute (non-incremental)
            broadcast that resynchronizes receiver replicas — required
            for exactness under fault traces; 0 never refreshes.
    seed:   PRNG stream for int8 stochastic rounding. Encoding is
            deterministic in (seed, round, node), so the simulated and
            sharded paths quantize identically and can be compared.
    """

    mode: str = "none"
    tile: int = 128
    k: float | int = 0.1
    error_feedback: bool = True
    event_threshold: float | None = None
    refresh_every: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown compression mode {self.mode!r}: expected one of "
                f"{MODES}"
            )
        if self.mode == "int8" and self.tile < 1:
            raise ValueError(f"int8 tile must be >= 1, got {self.tile}")
        if self.mode == "topk":
            if isinstance(self.k, float) and not 0.0 < self.k <= 1.0:
                raise ValueError(
                    f"topk fraction must be in (0, 1], got {self.k}"
                )
            if isinstance(self.k, int) and self.k < 1:
                raise ValueError(f"topk count must be >= 1, got {self.k}")
        if self.refresh_every < 0:
            raise ValueError(
                f"refresh_every must be >= 0, got {self.refresh_every}"
            )
        if self.event_threshold is not None and not self.error_feedback:
            raise ValueError(
                "event_threshold requires error_feedback: without the "
                "replica memory every round is an absolute broadcast "
                "(effective_refresh == 1), which forces every node to "
                "send and silently disables event triggering"
            )

    @classmethod
    def parse(cls, value) -> "CompressionSpec":
        """Normalize ``None`` / a mode string / a spec into a spec."""
        if value is None:
            return cls(mode="none")
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(mode=value)
        raise TypeError(
            f"compression must be None, a mode string {MODES}, or a "
            f"CompressionSpec, got {type(value).__name__}"
        )

    @property
    def is_identity(self) -> bool:
        return self.mode == "none" and self.event_threshold is None

    @property
    def effective_refresh(self) -> int:
        """Rounds between absolute broadcasts (1 = memoryless)."""
        if not self.error_feedback:
            return 1
        return self.refresh_every

    def topk_count(self, num_values: int) -> int:
        if isinstance(self.k, float):
            return max(1, min(num_values, round(self.k * num_values)))
        return min(num_values, self.k)

    def message_bytes(self, num_values: int, itemsize: int = 4) -> int:
        """Exact bytes one encoded message of ``num_values`` costs on
        the wire (payload + headers)."""
        if self.mode == "none":
            return num_values * itemsize
        if self.mode == "bf16":
            return num_values * 2
        if self.mode == "int8":
            # int8 codes + one f32 scale per tile
            return num_values + 4 * math.ceil(num_values / self.tile)
        # topk: kept values at state precision + int32 indices
        return self.topk_count(num_values) * (itemsize + 4)


# ---------------------------------------------------------------------------
# Encoders (the receiver's dequantized view; exact wire cost is accounted
# separately via CompressionSpec.message_bytes)
# ---------------------------------------------------------------------------


def int8_roundtrip(flat: jax.Array, tile: int, key: jax.Array) -> jax.Array:
    """Stochastically quantize a flat payload to int8 with per-tile
    scales and dequantize — the receiver's view of the message.

    Per tile of ``tile`` values: scale = max|x|/127, codes
    floor(x/scale + u) with u ~ U[0,1) (unbiased stochastic rounding),
    clipped to [-127, 127]. All-zero tiles round-trip exactly (scale 0
    encodes the zero code).
    """
    n = flat.shape[0]
    pad = (-n) % tile
    t = jnp.pad(flat, (0, pad)).reshape(-1, tile)
    amax = jnp.max(jnp.abs(t), axis=1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
    u = jax.random.uniform(key, t.shape, dtype=t.dtype)
    q = jnp.clip(jnp.floor(t / safe + u), -127.0, 127.0)
    deq = q * jnp.where(scale > 0, scale, jnp.zeros_like(scale))
    return deq.reshape(-1)[:n]


def topk_roundtrip(flat: jax.Array, count: int) -> jax.Array:
    """Keep exactly the ``count`` largest-magnitude entries, zero the
    rest. Ties break toward the lower index (stable argsort), so the
    kept set matches what ``message_bytes`` bills and is identical on
    the simulated and sharded paths.
    """
    idx = jnp.argsort(-jnp.abs(flat), stable=True)[:count]
    mask = jnp.zeros(flat.shape, jnp.bool_).at[idx].set(True)
    return jnp.where(mask, flat, jnp.zeros_like(flat))


def encode_flat(flat: jax.Array, spec: CompressionSpec, key) -> jax.Array:
    """Encode+decode one node's flat payload under ``spec``."""
    if spec.mode == "none":
        return flat
    if spec.mode == "bf16":
        return flat.astype(jnp.bfloat16).astype(flat.dtype)
    if spec.mode == "int8":
        return int8_roundtrip(flat, spec.tile, key)
    return topk_roundtrip(flat, spec.topk_count(flat.shape[0]))


def encode_tree(h, spec: CompressionSpec, key):
    """Encode one node's payload pytree, leaf keys folded from ``key``."""
    leaves, treedef = jax.tree.flatten(h)
    out = [
        encode_flat(
            v.reshape(-1), spec, jax.random.fold_in(key, i)
        ).reshape(v.shape)
        for i, v in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def residual_rms(d) -> jax.Array:
    """RMS of a residual pytree (the event-trigger statistic)."""
    leaves = jax.tree.leaves(d)
    sq = sum(jnp.sum(v.astype(jnp.float32) ** 2) for v in leaves)
    n = sum(v.size for v in leaves)
    return jnp.sqrt(sq / n)


# ---------------------------------------------------------------------------
# Wire accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireStats:
    """Exact bytes-on-wire for one consensus run.

    A "link" is one directed live edge in one round; each link moves
    one encoded message unless its sender was event-gated silent.
    ``bytes_uncompressed`` is what the same live links would have moved
    at full state precision with every broadcast sent — the
    uncompressed baseline for compression ratios.
    """

    rounds: int
    links_live: int
    links_sent: int
    bytes_on_wire: int
    bytes_uncompressed: int
    per_round_bytes: np.ndarray = dataclasses.field(compare=False)

    @property
    def links_skipped(self) -> int:
        return self.links_live - self.links_sent

    @property
    def compression_ratio(self) -> float:
        """bytes_on_wire / bytes_uncompressed (lower is better)."""
        if self.bytes_uncompressed == 0:
            return 1.0
        return self.bytes_on_wire / self.bytes_uncompressed

    def __add__(self, other: "WireStats") -> "WireStats":
        return WireStats(
            rounds=self.rounds + other.rounds,
            links_live=self.links_live + other.links_live,
            links_sent=self.links_sent + other.links_sent,
            bytes_on_wire=self.bytes_on_wire + other.bytes_on_wire,
            bytes_uncompressed=(
                self.bytes_uncompressed + other.bytes_uncompressed
            ),
            per_round_bytes=np.concatenate(
                [self.per_round_bytes, other.per_round_bytes]
            ),
        )


def payload_sizes(x, num_nodes: int) -> list[tuple[int, int]]:
    """Per-leaf (values_per_node, itemsize) for a stacked state pytree."""
    sizes = []
    for v in jax.tree.leaves(x):
        if v.shape[0] != num_nodes:
            raise ValueError(
                f"stacked leaf {v.shape} has no leading node axis of "
                f"{num_nodes}"
            )
        sizes.append((v.size // num_nodes, jnp.dtype(v.dtype).itemsize))
    return sizes


def node_message_bytes(
    spec: CompressionSpec, sizes: list[tuple[int, int]]
) -> tuple[int, int]:
    """(encoded, full-precision) bytes of one node's broadcast."""
    enc = sum(spec.message_bytes(n, itemsize) for n, itemsize in sizes)
    raw = sum(n * itemsize for n, itemsize in sizes)
    return enc, raw


def stats_from_links(
    out_degree: np.ndarray,
    num_iters: int,
    msg_bytes: int,
    raw_bytes: int,
    sent: np.ndarray | None = None,
    start: int = 0,
) -> WireStats:
    """Assemble WireStats from per-round live out-degrees.

    out_degree: (R, V) live outgoing links per node, replayed k % R
    starting at absolute round ``start``.
    sent: (num_iters, V) 0/1 broadcast flags; None = always sent.
    """
    out_degree = np.asarray(out_degree, dtype=np.int64)
    rows = out_degree[
        (start + np.arange(num_iters)) % out_degree.shape[0]
    ]
    live = rows.sum(axis=1)
    if sent is None:
        sent_links = live
    else:
        sent_links = (rows * np.asarray(sent, dtype=np.int64)).sum(axis=1)
    return WireStats(
        rounds=num_iters,
        links_live=int(live.sum()),
        links_sent=int(sent_links.sum()),
        bytes_on_wire=int(sent_links.sum()) * msg_bytes,
        bytes_uncompressed=int(live.sum()) * raw_bytes,
        per_round_bytes=sent_links * msg_bytes,
    )


def dense_out_degrees(adjacencies) -> np.ndarray:
    """(S, V) live out-degree table of dense adjacency snapshots."""
    adj = np.asarray(adjacencies)
    return (adj != 0).sum(axis=2).astype(np.int64)


def record_wire_stats(mixer, stats: WireStats | None) -> None:
    """Store a run's WireStats on a mixer and accumulate its byte
    counter — the one place the storage convention lives (uses
    ``object.__setattr__`` so frozen-dataclass mixers work too)."""
    object.__setattr__(mixer, "last_wire_stats", stats)
    if stats is not None:
        object.__setattr__(
            mixer, "total_bytes_on_wire",
            getattr(mixer, "total_bytes_on_wire", 0) + stats.bytes_on_wire,
        )


def compute_wire_stats(
    compress,
    out_degree: np.ndarray,
    x,
    num_nodes: int,
    num_iters: int,
    sent: np.ndarray | None = None,
    start: int = 0,
) -> WireStats | None:
    """The one wire-accounting body every mixer records through.

    compress: anything ``CompressionSpec.parse`` accepts (the inline
    mixer knob or a full spec). Returns None for states without a
    stacked node axis (nothing sensible to bill). Shape-only — safe
    under tracing, costs nothing on device.
    """
    spec = CompressionSpec.parse(compress)
    try:
        sizes = payload_sizes(x, num_nodes)
    except ValueError:  # state without a stacked node axis
        return None
    msg, raw = node_message_bytes(spec, sizes)
    return stats_from_links(out_degree, num_iters, msg, raw, sent, start)


# ---------------------------------------------------------------------------
# CompressedMixer
# ---------------------------------------------------------------------------


class CompressedMixer:
    """Compression wrapper: a base mixer plus a ``CompressionSpec``.

    Composes over ``DenseMixer``, ``PpermuteMixer``, or a
    ``FaultyMixer`` wrapping either (``engine.with_faults`` stacks the
    two in that order automatically). Per round, each node

    1. forms its residual d_i = x_i - x̂_i against its public replica;
    2. decides to broadcast: always, or — event-triggered — only when
       ``residual_rms(d_i) > event_threshold`` (refresh rounds always
       broadcast);
    3. encodes q_i = Q(d_i) (or Q(x_i) on a refresh round) — the
       encode happens *before* the wire, so only encoded messages
       cross a link — and every replica of node i (its own and its
       receivers', reliable-transport model) advances by q_i;
    4. mixes over replicas: lap_i = sum_j a_ij (x̂_j - x̂_i) is the
       *base* mixer's Laplacian evaluated at x̂, so fault masks and
       time-varying snapshots gate terms exactly like the uncompressed
       path.

    The compiled ``shard_map(scan)`` program is cached (keyed by
    rule/rounds/specs) so streaming events and spec sweeps compile
    once. ``run`` records exact wire accounting on
    ``self.last_wire_stats`` (surfaced as ``ConsensusEngine.wire_stats``)
    and accumulates ``total_bytes_on_wire`` across calls.

    ``laplacian``/``step`` are stateless (each call behaves like a
    refresh round: absolute encode, no replicas, no event gating); the
    replica-carrying iteration lives in ``run``. The replica memory and
    the absolute round counter persist across ``run``/``stream_chunk``
    calls on this mixer (x̂ is protocol state: a converged-and-quiet
    network stays quiet across streaming events, and blocked runs
    continue the PRNG / fault-trace / refresh streams); a state whose
    shapes change, or ``reset_replicas()``, cold-starts them.
    """

    def __init__(self, base, spec):
        self.spec = CompressionSpec.parse(spec)
        if not isinstance(base, (DenseMixer, PpermuteMixer, FaultyMixer)):
            raise TypeError(
                f"CompressedMixer wraps DenseMixer, PpermuteMixer, or "
                f"FaultyMixer, got {type(base).__name__}"
            )
        if base.compress is not None:
            raise ValueError(
                "the base mixer already has an inline compress= knob "
                f"({base.compress!r}); set it to None and express the "
                "wire format in the CompressionSpec instead"
            )
        self.base = base
        self.last_wire_stats: WireStats | None = None
        self.total_bytes_on_wire = 0
        self._programs: dict = {}
        # replica memory persists across run()/stream_chunk() calls on
        # this mixer: x̂ is real protocol state (what the network has
        # already been told), so a converged-and-quiet network STAYS
        # quiet across streaming events, and blocked runs continue the
        # PRNG / fault-trace / refresh streams instead of restarting
        # them. reset_replicas() forgets both.
        self._replica = None
        self._rounds_done = 0

    def reset_replicas(self) -> None:
        """Forget the replica memory and the absolute round counter
        (e.g. to replay a run from a cold network)."""
        self._replica = None
        self._rounds_done = 0

    def _initial_replicas(self, x):
        """(x̂0, absolute start round) for this run — the persisted
        state when it matches ``x``'s structure, else a cold start."""
        if self._replica is not None:
            prev = jax.tree.leaves(self._replica)
            cur = jax.tree.leaves(x)
            if (
                jax.tree.structure(self._replica) == jax.tree.structure(x)
                and len(prev) == len(cur)
                and all(
                    p.shape == c.shape and p.dtype == c.dtype
                    for p, c in zip(prev, cur)
                )
            ):
                return self._replica, self._rounds_done
        return jax.tree.map(jnp.zeros_like, x), 0

    # -- delegation --------------------------------------------------------

    @property
    def num_nodes(self) -> int:
        return self.base.num_nodes

    @property
    def compress(self):
        return self.base.compress  # always None; the spec supersedes it

    def gamma_upper_bound(self) -> float:
        return self.base.gamma_upper_bound()

    def default_gamma(self, safety: float = 0.9) -> float:
        return self.base.default_gamma(safety)

    def node_pspec(self) -> P:
        return self.base.node_pspec()

    # -- layout ------------------------------------------------------------

    @property
    def _dense_path(self) -> bool:
        base = self.base
        if isinstance(base, FaultyMixer):
            return base._dense is not None
        return isinstance(base, DenseMixer)

    @property
    def _pp(self) -> PpermuteMixer:
        base = self.base
        return base.base if isinstance(base, FaultyMixer) else base

    def _round_key(self, k):
        return jax.random.fold_in(jax.random.key(self.spec.seed), k)

    def _out_degrees(self) -> np.ndarray:
        """(R, V) live out-degree table for wire accounting."""
        base = self.base
        if isinstance(base, DenseMixer):
            return dense_out_degrees(base.adjacencies)
        if isinstance(base, FaultyMixer):
            if base._dense is not None:
                return dense_out_degrees(base._dense.adjacencies)
            # folded keep is (R, P, V) in-edge weights; symmetric masks
            # on undirected perms make in-degree == out-degree
            return (
                (np.asarray(base._keep) != 0).sum(axis=1).astype(np.int64)
            )
        sizes = self._pp.axis_sizes
        deg = self._pp.spec.degree(sizes)
        return np.full((1, self.num_nodes), deg, dtype=np.int64)

    def _record(
        self, x, num_iters: int, sent: np.ndarray | None, start: int = 0
    ) -> None:
        record_wire_stats(self, compute_wire_stats(
            self.spec, self._out_degrees(), x, self.num_nodes, num_iters,
            sent, start,
        ))

    # -- shared round body -------------------------------------------------

    def _send_gate(self, d, k):
        """1.0 when this node broadcasts in round k, else 0.0."""
        spec = self.spec
        one = jnp.ones(())
        if spec.event_threshold is None:
            return one
        sent = (residual_rms(d) > spec.event_threshold).astype(jnp.float32)
        N = spec.effective_refresh
        if N:
            sent = jnp.where(jnp.mod(k, N) == 0, one, sent)
        return sent

    def _refresh_flag(self, k):
        """1.0 on absolute-broadcast rounds, else 0.0 (scalar, traced)."""
        N = self.spec.effective_refresh
        if not N:
            return jnp.zeros(())
        return (jnp.mod(k, N) == 0).astype(jnp.float32)

    # -- stateless single round -------------------------------------------

    def laplacian(self, x, k=0):
        """One round's Laplacian over encoded payloads (stateless: no
        replica memory or event gating — every node absolute-encodes
        and broadcasts). On the ppermute path call inside a
        caller-managed shard_map."""
        spec = self.spec
        if spec.mode == "none":
            return self.base.laplacian(x, k)
        rk = self._round_key(k)
        if self._dense_path:
            V = self.num_nodes
            keys = jax.vmap(lambda i: jax.random.fold_in(rk, i))(
                jnp.arange(V)
            )
            p = jax.vmap(lambda h, key: encode_tree(h, spec, key))(x, keys)
        else:
            my = gossip.global_node_index(
                self._pp.spec, self._pp.axis_sizes
            )
            p = encode_tree(x, spec, jax.random.fold_in(rk, my))
        return self.base.laplacian(p, k)

    # -- scan drivers ------------------------------------------------------

    def run(
        self,
        rule,
        x,
        aux,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        if self.spec.is_identity:
            out = self.base.run(
                rule, x, aux, gamma, num_iters, trace_fn, state_spec,
                aux_spec,
            )
            self._record(x, num_iters, None)
            return out
        if self._dense_path:
            return self._run_dense(rule, x, aux, gamma, num_iters, trace_fn)
        return self._run_sharded(
            rule, x, aux, gamma, num_iters, trace_fn, state_spec, aux_spec
        )

    def _node_broadcast(self, xi, xhati, refresh, k, key):
        """One node's round: residual, event gate, encode. Returns
        (q, sent) — the (zero-if-silent) replica increment/refresh."""
        spec = self.spec
        di = jax.tree.map(jnp.subtract, xi, xhati)
        # absolute broadcast on refresh rounds, delta otherwise
        src = jax.tree.map(
            lambda dv, xv: refresh * xv + (1 - refresh) * dv, di, xi
        )
        sent = self._send_gate(di, k)
        q = encode_tree(src, spec, key)
        return jax.tree.map(lambda v: (sent * v).astype(v.dtype), q), sent

    def _advance_replicas(self, xhat, q, refresh):
        """x̂ <- x̂ + q (or q itself on refresh rounds). A silent node's
        q is zero, so skipping is a no-op for every replica."""
        return jax.tree.map(
            lambda h, qv: ((1 - refresh) * h + qv).astype(h.dtype), xhat, q
        )

    def _run_dense(self, rule, x, aux, gamma, num_iters, trace_fn):
        """Replica-tracking rounds on the stacked dense layout: carry
        (x, x̂), mix the *base* Laplacian over x̂."""
        V = self.num_nodes

        def round_fn(carry, k):
            x_, xhat = carry
            rk = self._round_key(k)
            keys = jax.vmap(lambda i: jax.random.fold_in(rk, i))(
                jnp.arange(V)
            )
            refresh = self._refresh_flag(k)
            q, sent = jax.vmap(
                lambda xi, hi, ki: self._node_broadcast(
                    xi, hi, refresh, k, ki
                )
            )(x_, xhat, keys)
            xhat2 = self._advance_replicas(xhat, q, refresh)
            # the base's apply_round fuses gather + rule where it can
            # (NeighborMixer -> kernels/elm_gossip_ops); the default is
            # the exact rule(x, base.laplacian(x̂, k)) composition
            nxt = self.base.apply_round(rule, x_, xhat2, aux, gamma, k)
            tr = trace_fn(nxt) if trace_fn is not None else jnp.zeros(())
            return (nxt, xhat2), (sent, tr)

        xhat0, k0 = self._initial_replicas(x)
        (final, xhat_f), (sent, traces) = lax.scan(
            round_fn, (x, xhat0), k0 + jnp.arange(num_iters)
        )
        self._replica = xhat_f
        self._rounds_done = k0 + num_iters
        self._record(x, num_iters, np.asarray(sent) > 0, start=k0)
        return final, (traces if trace_fn is not None else None)

    def _run_sharded(
        self, rule, x, aux, gamma, num_iters, trace_fn, state_spec, aux_spec
    ):
        """Replica-tracking rounds under shard_map: each shard carries
        its own x̂ plus one replica per in-edge permutation; only the
        encoded q crosses the ICI."""
        if trace_fn is not None:
            raise NotImplementedError(
                "per-round traces are a simulated-path (DenseMixer) feature"
            )
        pp = self._pp
        if pp.mesh is None:
            raise ValueError(
                "CompressedMixer.run over ppermute needs a mesh; build "
                "the base via PpermuteMixer.for_mesh(...)"
            )
        spec = self.spec
        base = self.base
        faulty = isinstance(base, FaultyMixer)
        sspec = self.node_pspec() if state_spec is None else state_spec
        aspec = self.node_pspec() if aux_spec is None else aux_spec
        # sent flags leave the program as a (num_iters, V) array so the
        # host can do exact per-round accounting
        sent_spec = P(None, pp.spec.axes if len(pp.spec.axes) > 1
                      else pp.spec.axes[0])
        key = (
            rule, num_iters, sspec, aspec, aux is None, spec,
            base._keep.shape if faulty else None,
        )
        fn = self._programs.get(key)
        if fn is None:
            R = base.num_rounds if faulty else 1

            def scanned(b, h0, o, keep_all, k0, g):
                my = gossip.global_node_index(pp.spec, pp.axis_sizes)

                def round_fn(carry, k):
                    x_, xhat = carry
                    refresh = self._refresh_flag(k)
                    node_key = jax.random.fold_in(self._round_key(k), my)
                    q, sent = self._node_broadcast(
                        x_, xhat, refresh, k, node_key
                    )
                    xhat2 = self._advance_replicas(xhat, q, refresh)
                    if faulty:
                        keep = keep_all[jnp.mod(k, R), :, my]
                        lap = gossip.masked_neighbor_laplacian(
                            xhat2, pp.spec, pp.axis_sizes, keep
                        )
                    else:
                        lap = gossip.neighbor_laplacian(
                            xhat2, pp.spec, pp.axis_sizes
                        )
                    lap = jax.tree.map(
                        lambda v, dl: dl.astype(v.dtype), x_, lap
                    )
                    nxt = rule(x_, lap, o, g)
                    return (nxt, xhat2), sent

                (final, xhat_f), sent = lax.scan(
                    round_fn, (b, h0), k0 + jnp.arange(num_iters)
                )
                return final, xhat_f, sent[:, None]

            if aux is None:
                fn = jax.jit(compat.shard_map(
                    lambda b, h0, keep_all, k0, g: scanned(
                        b, h0, None, keep_all, k0, g
                    ),
                    pp.mesh,
                    in_specs=(sspec, sspec, P(), P(), P()),
                    out_specs=(sspec, sspec, sent_spec),
                ))
            else:
                fn = jax.jit(compat.shard_map(
                    scanned,
                    pp.mesh,
                    in_specs=(sspec, sspec, aspec, P(), P(), P()),
                    out_specs=(sspec, sspec, sent_spec),
                ))
            self._programs[key] = fn
        gamma = jnp.asarray(gamma)
        keep_all = base._keep if faulty else jnp.zeros((1, 1, 1))
        xhat0, k0 = self._initial_replicas(x)
        k0_arr = jnp.asarray(k0)
        if aux is None:
            final, xhat_f, sent = fn(x, xhat0, keep_all, k0_arr, gamma)
        else:
            final, xhat_f, sent = fn(x, xhat0, aux, keep_all, k0_arr, gamma)
        self._replica = xhat_f
        self._rounds_done = k0 + num_iters
        self._record(x, num_iters, np.asarray(sent) > 0, start=k0)
        return final, None
