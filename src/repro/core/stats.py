"""The statistics plane: one producer of (P, Q, ||T||^2, Omega) for
every DC-ELM execution path.

Algorithm 1 steps 1-3 — h(x), P_i = H_i^T H_i, Q_i = H_i^T T_i,
Omega_i = (I/(VC) + P_i)^{-1} — used to be re-derived ad hoc at every
entry point (dc_elm.init_node, online.init_state, elm.solve_from_stats,
both elm_head layers), each with its own dtype policy and its own
explicit LU-based inverse. This module is now the single implementation:

* **Fused production.** ``SufficientStats.accumulate`` /
  ``from_raw`` stream raw (X, T) through the fused Pallas kernel
  (kernels/elm_stats.py) on TPU — the (N, L) hidden matrix is never
  materialized in HBM — or through the jitted lax.scan equivalent on
  CPU/GPU. Feature maps that cannot be fused (frozen deep backbones)
  fall back to per-chunk materialization via the gram kernels.

* **Chunked accumulation.** Stats are additive across any split of N
  (and across nodes), so ``zero -> accumulate* -> finalize`` handles
  N_i far beyond device memory. With a chunk size equal to the
  kernel's block_n the chunked stream is *bitwise* identical to the
  one-shot call (same f32 accumulation order; pinned in
  tests/test_stats.py).

* **Factorized solves.** ``finalize``/``omega_from_moments`` produce
  Omega via Cholesky (`cho_factor`/`cho_solve` on the SPD ridge Gram)
  — no dense-inverse call anywhere in src/ — and
  ``ridge_solve_moments``/``spd_solve`` are the shared beta solves for
  every ridge system (centralized, fusion-center, per-node).

Dtype policy: moments accumulate in f32 unless the inputs are f64 (the
fidelity experiments run x64 for the paper's stiff C = 2^8..2^14
solves); operands below f32 (bf16 inputs) still accumulate in f32.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core.features import RandomFeatureMap, RBFFeatureMap


def accum_dtype(*operands) -> jnp.dtype:
    """f32 accumulation, upgraded to f64 only by f64 inputs."""
    dt = jnp.result_type(*operands)
    return jnp.dtype(jnp.float64) if dt == jnp.float64 else jnp.dtype(
        jnp.float32
    )


def fusable_params(feature_map):
    """(W, b, activation) for the fused kernel, or None.

    RandomFeatureMap -> (weights, bias, activation); RBFFeatureMap ->
    (centers^T, gamma, "rbf"). Anything else (deep-backbone adapters)
    is not an affine/RBF map and takes the materialize-per-chunk path.
    """
    if isinstance(feature_map, RandomFeatureMap):
        return feature_map.weights, feature_map.bias, feature_map.activation
    if isinstance(feature_map, RBFFeatureMap):
        return feature_map.centers.T, feature_map.gamma, "rbf"
    return None


# ---------------------------------------------------------------------------
# Moment production
# ---------------------------------------------------------------------------


def hidden_moments(H: jax.Array, T: jax.Array, *, dtype=None):
    """(P, Q) = (H^T H, H^T T) from a materialized H, f32/f64 acc.

    The gram contraction keeps H's operand dtype (bf16 operands feed
    the MXU) with `preferred_element_type` accumulation; the cross
    moment promotes its operands to the wider of H/T so f32 targets are
    never quantized down to a bf16 feature dtype.
    """
    dtype = accum_dtype(H, T) if dtype is None else dtype
    P = jax.lax.dot_general(
        H, H, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=dtype,
    )
    op = jnp.promote_types(H.dtype, T.dtype)
    Q = jax.lax.dot_general(
        H.astype(op), T.astype(op),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=dtype,
    )
    return P, Q


def raw_moments(
    X: jax.Array, T: jax.Array, feature_map, *,
    use_kernel: bool | None = None, dtype=None, **kw,
):
    """(P, Q) from raw inputs; fused (H never materialized) when the
    feature map is affine/RBF and the accumulator is f32."""
    dtype = accum_dtype(X, T) if dtype is None else jnp.dtype(dtype)
    params = fusable_params(feature_map)
    if params is not None and dtype == jnp.float32:
        from repro.kernels import elm_stats_ops

        W, b, activation = params
        return elm_stats_ops.fused_moments(
            X, W, b, T, activation=activation, use_kernel=use_kernel, **kw
        )
    # non-fusable feature map (deep backbone) or f64 fidelity path:
    # materialize H for this call only — callers chunk N
    return hidden_moments(feature_map(X), T, dtype=dtype)


# ---------------------------------------------------------------------------
# SufficientStats
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SufficientStats:
    """One node's additive ELM statistics.

    P:     (L, L) moment H^T H
    Q:     (L, M) cross moment H^T T
    t_sq:  ()     ||T||^2 (closes the expanded quadratic, paper eq. 18)
    count: ()     samples seen
    """

    P: jax.Array
    Q: jax.Array
    t_sq: jax.Array
    count: jax.Array

    @classmethod
    def zero(cls, L: int, M: int, dtype=jnp.float32) -> "SufficientStats":
        return cls(
            P=jnp.zeros((L, L), dtype),
            Q=jnp.zeros((L, M), dtype),
            t_sq=jnp.zeros((), dtype),
            count=jnp.zeros((), dtype),
        )

    @property
    def num_features(self) -> int:
        return self.P.shape[-1]

    @property
    def num_targets(self) -> int:
        return self.Q.shape[-1]

    def accumulate(
        self, X_chunk: jax.Array, T_chunk: jax.Array, feature_map, *,
        use_kernel: bool | None = None, **kw,
    ) -> "SufficientStats":
        """Fold one raw (X, T) chunk in — the streaming entry point."""
        dP, dQ = raw_moments(
            X_chunk, T_chunk, feature_map,
            use_kernel=use_kernel, dtype=self.P.dtype, **kw,
        )
        return self._add(dP, dQ, T_chunk)

    def accumulate_hidden(
        self, H_chunk: jax.Array, T_chunk: jax.Array
    ) -> "SufficientStats":
        """Fold a chunk whose features are already materialized."""
        dP, dQ = hidden_moments(H_chunk, T_chunk, dtype=self.P.dtype)
        return self._add(dP, dQ, T_chunk)

    def _add(self, dP, dQ, T_chunk) -> "SufficientStats":
        dt = self.P.dtype
        Tf = T_chunk.astype(dt)
        return SufficientStats(
            P=self.P + dP.astype(dt),
            Q=self.Q + dQ.astype(dt),
            t_sq=self.t_sq + jnp.sum(Tf * Tf),
            count=self.count + jnp.asarray(T_chunk.shape[0], dt),
        )

    def merge(self, other: "SufficientStats") -> "SufficientStats":
        """Additive fusion (across chunks or across nodes)."""
        return SufficientStats(
            P=self.P + other.P, Q=self.Q + other.Q,
            t_sq=self.t_sq + other.t_sq, count=self.count + other.count,
        )

    def finalize(self, C: float, V: int = 1):
        """(Omega, beta0): the paper's eq. 21 node init, via Cholesky.

        Omega = (I/(VC) + P)^{-1}, beta0 = Omega Q. beta0 is computed
        as Omega @ Q (not a second solve) so it equals the streaming
        re-seed ``online.reseed_betas`` bit-for-bit.
        """
        omega = omega_from_moments(self.P, C, V)
        return omega, omega @ self.Q


def from_hidden(H: jax.Array, T: jax.Array, *, dtype=None) -> SufficientStats:
    """One-shot stats from a materialized H (the legacy entry shape)."""
    dtype = accum_dtype(H, T) if dtype is None else jnp.dtype(dtype)
    L, M = H.shape[-1], T.shape[-1]
    return SufficientStats.zero(L, M, dtype).accumulate_hidden(H, T)


def from_raw(
    X: jax.Array, T: jax.Array, feature_map, *,
    chunk: int | None = None, use_kernel: bool | None = None,
    dtype=None, **kw,
) -> SufficientStats:
    """Stats from raw inputs; H is never materialized on fusable maps.

    chunk: split N into chunks of this many rows (the kernel already
    streams N internally, so chunking matters when X itself exceeds
    device memory or the feature map is non-fusable).
    """
    dtype = accum_dtype(X, T) if dtype is None else jnp.dtype(dtype)
    L = feature_map.num_features
    M = T.shape[-1]
    s = SufficientStats.zero(L, M, dtype)
    if chunk is None:
        return s.accumulate(X, T, feature_map, use_kernel=use_kernel, **kw)
    N = X.shape[0]
    for start in range(0, N, chunk):
        s = s.accumulate(
            X[start:start + chunk], T[start:start + chunk], feature_map,
            use_kernel=use_kernel, **kw,
        )
    return s


def classification_moments(
    H: jax.Array, labels: jax.Array, num_classes: int, *,
    mask: jax.Array | None = None, use_kernel: bool | None = None,
) -> SufficientStats:
    """Stats for one-hot targets without materializing the one-hot.

    P via the gram kernel on the (masked) features, Q = H^T onehot via
    segment-sum, ||T||^2 = number of valid labels. mask: bool (N,)
    marking rows that count (invalid rows are zeroed out of H).
    """
    from repro.kernels import gram_ops

    if mask is None:
        mask = labels >= 0
    Hm = jnp.where(mask[:, None], H, 0.0).astype(H.dtype)
    P = gram_ops.gram(Hm, use_kernel=use_kernel)
    Q = jax.ops.segment_sum(
        Hm.astype(jnp.float32), jnp.maximum(labels, 0),
        num_segments=num_classes,
    ).T
    n = jnp.sum(mask.astype(jnp.float32))
    return SufficientStats(
        P=P, Q=Q, t_sq=n, count=n,  # ||onehot||^2 == valid-row count
    )


# ---------------------------------------------------------------------------
# Factorized solves — the only Omega/beta producers in src/
# ---------------------------------------------------------------------------


def spd_solve(A: jax.Array, B: jax.Array) -> jax.Array:
    """Solve A X = B for symmetric positive-definite A via Cholesky."""
    return cho_solve(cho_factor(A), B)


def omega_from_moments(P: jax.Array, C: float, V: int = 1) -> jax.Array:
    """Omega = (I/(VC) + P)^{-1} — THE preconditioner producer.

    The ridge Gram is SPD by construction, so the Cholesky factor
    always exists; cho_solve against I beats an LU-based inverse on
    both flops and accuracy for the paper's stiff C values.
    """
    L = P.shape[-1]
    eye = jnp.eye(L, dtype=P.dtype)
    return spd_solve(eye / (V * C) + P, eye)


def finalize_moments(P: jax.Array, Q: jax.Array, C: float, V: int = 1):
    """(Omega, beta0) from bare moments (paper eq. 21)."""
    omega = omega_from_moments(P, C, V)
    return omega, omega @ Q


def ridge_solve_moments(P: jax.Array, Q: jax.Array, C: float) -> jax.Array:
    """beta = (I/C + P)^{-1} Q via Cholesky — when Omega itself is not
    needed (centralized / fusion-center solves)."""
    L = P.shape[-1]
    return spd_solve(jnp.eye(L, dtype=P.dtype) / C + P, Q)
