"""DC-ELM — the paper's Algorithm 1, batch form.

Per-node state and iteration (paper eqs. 20-21):

    P_i = H_i^T H_i,  Q_i = H_i^T T_i
    Omega_i = (I_L / (V C) + P_i)^{-1}
    beta_i(0) = Omega_i Q_i                                   (local ridge)
    beta_i(k+1) = beta_i(k)
        + (gamma / (V C)) * Omega_i * sum_{j in N_i} a_ij (beta_j - beta_i)

with 0 < gamma < 1/d_max. Theorem 2: on a connected graph, beta_i(k) ->
beta* (the centralized solution) for every node.

The iteration itself is implemented once, in core/engine.py
(``DCELMRule`` under a ``ConsensusEngine``); this module keeps the
paper-facing state/statistics helpers plus the historical entry points
as thin wrappers over the engine:

* ``simulate_*`` — all V nodes live on one device as a leading axis;
  mixing uses the dense adjacency (``mixers.DenseMixer``). Ground-truth
  path used by the fidelity experiments (SinC / MNIST reproductions)
  and by tests — supports arbitrary graphs (incl. the paper's random
  geometric ones).

* ``sharded_*`` — node i is the shard at mesh position i along the
  consensus axes; mixing is neighbor-only ``lax.ppermute`` gossip
  (``mixers.PpermuteMixer`` over core/gossip.py) under ``shard_map``.
  This is the production path.

Robustness and wire-format layers compose around either path at the
engine level: ``engine.with_faults`` (per-round edge keep-masks),
``engine.with_compression`` / the constructors' ``compress=`` knob
(bf16 / int8 / top-k payloads with error feedback — DESIGN.md §8–§9).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engine as engine_lib
from repro.core import gossip, mixers
from repro.core import stats as stats_lib
from repro.core.consensus import Graph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DCELMState:
    """Stacked per-node DC-ELM state.

    betas:  (V, L, M)  node estimates beta_i(k)
    omegas: (V, L, L)  frozen preconditioners Omega_i
    k:      iteration counter
    """

    betas: jax.Array
    omegas: jax.Array
    k: jax.Array

    @property
    def num_nodes(self) -> int:
        return self.betas.shape[0]


# ---------------------------------------------------------------------------
# Local statistics (identical for both paths)
# ---------------------------------------------------------------------------


def local_stats(H: jax.Array, T: jax.Array):
    """P = H^T H and Q = H^T T for one node's local data.

    Thin wrapper over the statistics plane (`core/stats.py`) for
    callers that already hold a materialized H; raw-input callers use
    ``simulate_init_raw`` / ``stats.from_raw`` and never build H.
    Accumulation follows the plane's dtype policy: f32 floor (bf16
    features accumulate in f32), f64 preserved.
    """
    return stats_lib.hidden_moments(H, T)


def init_node(P_: jax.Array, Q_: jax.Array, C: float, V: int):
    """Omega_i and beta_i(0) from local stats (paper eq. 21).

    Delegates to the statistics plane's Cholesky factorization — the
    only Omega producer in the codebase.
    """
    return stats_lib.finalize_moments(P_, Q_, C, V)


def node_objective(beta: jax.Array, P_: jax.Array, Q_: jax.Array,
                   T_sq: jax.Array, C: float, V: int) -> jax.Array:
    """u_i(beta) = 1/2 ||beta||^2 + VC/2 ||H_i beta - T_i||^2 (paper eq. 18).

    Uses the expanded quadratic so only the O(L^2) stats are needed:
    ||H beta - T||^2 = tr(beta^T P beta) - 2 tr(beta^T Q) + ||T||^2.
    """
    quad = jnp.sum(beta * (P_ @ beta)) - 2.0 * jnp.sum(beta * Q_) + T_sq
    return 0.5 * jnp.sum(beta * beta) + 0.5 * V * C * quad


def gradient_sum(state: DCELMState, P_: jax.Array, Q_: jax.Array, C: float):
    """sum_i grad u_i(beta_i) — zero along the invariant manifold (eq. 12).

    grad u_i(beta) = beta + VC (P_i beta - Q_i).
    """
    V = state.num_nodes
    g = state.betas + V * C * (
        jnp.einsum("vlk,vkm->vlm", P_, state.betas) - Q_
    )
    return jnp.sum(g, axis=0)


# ---------------------------------------------------------------------------
# Simulated (single-device, arbitrary graph) path
# ---------------------------------------------------------------------------


def simulate_init(
    H_nodes: jax.Array, T_nodes: jax.Array, C: float
) -> tuple[DCELMState, jax.Array, jax.Array]:
    """Initialize from stacked per-node data H:(V,Ni,L), T:(V,Ni,M).

    Returns (state, P:(V,L,L), Q:(V,L,M)).
    """
    V = H_nodes.shape[0]
    P_, Q_ = jax.vmap(local_stats)(H_nodes, T_nodes)
    omegas, betas = jax.vmap(lambda p, q: init_node(p, q, C, V))(P_, Q_)
    return DCELMState(betas=betas, omegas=omegas, k=jnp.zeros((), jnp.int32)), P_, Q_


def simulate_init_raw(
    X_nodes: jax.Array,
    T_nodes: jax.Array,
    feature_map,
    C: float,
    *,
    use_kernel: bool | None = None,
) -> tuple[DCELMState, jax.Array, jax.Array]:
    """Initialize straight from raw inputs X:(V,Ni,D), T:(V,Ni,M).

    Algorithm 1 steps 1-3 through the statistics plane: on fusable
    feature maps the (Ni, L) hidden matrices are never materialized —
    each node's tiles stream feature->moment fused (kernels/elm_stats).
    Returns (state, P:(V,L,L), Q:(V,L,M)) like ``simulate_init``.
    """
    if T_nodes.ndim == 2:
        T_nodes = T_nodes[..., None]
    V = X_nodes.shape[0]
    P_, Q_ = jax.vmap(
        lambda x, t: stats_lib.raw_moments(
            x, t, feature_map, use_kernel=use_kernel,
            dtype=stats_lib.accum_dtype(x, t),
        )
    )(X_nodes, T_nodes)
    omegas, betas = jax.vmap(lambda p, q: init_node(p, q, C, V))(P_, Q_)
    return DCELMState(betas=betas, omegas=omegas, k=jnp.zeros((), jnp.int32)), P_, Q_


def simulate_init_from_stats(P_: jax.Array, Q_: jax.Array, C: float) -> DCELMState:
    V = P_.shape[0]
    omegas, betas = jax.vmap(lambda p, q: init_node(p, q, C, V))(P_, Q_)
    return DCELMState(betas=betas, omegas=omegas, k=jnp.zeros((), jnp.int32))


def simulate_init_vertical(
    X_slices, T: jax.Array, feature_map, C: float, graph, **kw
):
    """Initialize from column-partitioned inputs (vertical mode).

    Node i holds ``X_slices[i] = X[:, lo_i:hi_i]`` — the same rows,
    disjoint feature columns. Partial preactivations are sum-reduced
    over ``graph`` (optionally masked, see core/secure.py) before the
    nonlinearity, so the assembled stats match the horizontal plane
    bitwise in f64. Every node seeds at the centralized optimum via
    the P/V, Q/V scaling trick. Thin wrapper over
    ``core.vertical.simulate_init`` — see there for ``secure=``,
    ``faults=`` and kernel-dispatch keywords.

    Returns (DCELMState, SufficientStats, ReduceReport).
    """
    from repro.core import vertical

    return vertical.simulate_init(X_slices, T, feature_map, C, graph, **kw)


@functools.partial(jax.jit, static_argnames=("C",))
def simulate_step(
    state: DCELMState, adjacency: jax.Array, gamma: jax.Array, C: float
) -> DCELMState:
    """One synchronous DC-ELM round on a dense adjacency (paper eq. 20)."""
    eng = engine_lib.ConsensusEngine(
        mixers.DenseMixer(adjacency),
        engine_lib.DCELMRule(state.num_nodes, C),
    )
    new_betas = eng.step(state.betas, state.omegas, gamma)
    return dataclasses.replace(state, betas=new_betas, k=state.k + 1)


def simulate_run(
    state: DCELMState,
    graph: Graph,
    gamma: float,
    C: float,
    num_iters: int,
    *,
    trace_fn: Callable[[jax.Array], jax.Array] | None = None,
    check_gamma: bool = True,
):
    """Run num_iters rounds through the engine's scan driver.

    trace_fn: optional per-iteration metric over stacked betas (e.g. the
    paper's average empirical risk R_d(k), eq. 32).
    check_gamma=False skips the Thm. 2 bound validation (deliberate
    divergence experiments like paper Fig. 4(a)). Returns
    (final_state, traces or None).
    """
    eng = engine_lib.simulated_dc_elm(graph, C, dtype=state.betas.dtype)
    gamma = jnp.asarray(gamma, dtype=state.betas.dtype)
    betas, traces = eng.run(
        state.betas, state.omegas, gamma, num_iters, trace_fn=trace_fn,
        check_gamma=check_gamma,
    )
    final = dataclasses.replace(state, betas=betas, k=state.k + num_iters)
    return final, traces


def simulate_train(
    key: jax.Array,
    X_nodes: jax.Array,
    T_nodes: jax.Array,
    *,
    num_features: int,
    C: float,
    graph: Graph,
    gamma: float | None = None,
    num_iters: int = 100,
    activation: str = "sigmoid",
    trace_fn: Callable | None = None,
):
    """End-to-end DC-ELM (Algorithm 1) on stacked node data X:(V,Ni,D)."""
    from repro.core.features import make_random_features

    fmap = make_random_features(key, X_nodes.shape[-1], num_features, activation)
    state, _, _ = simulate_init_raw(X_nodes, T_nodes, fmap, C)
    if gamma is None:
        gamma = graph.default_gamma()
    final, traces = simulate_run(
        state, graph, gamma, C, num_iters, trace_fn=trace_fn
    )
    return fmap, final, traces


def simulate_run_time_varying(
    state: DCELMState,
    graphs: list[Graph],
    gamma: float,
    C: float,
    num_iters: int,
    *,
    trace_fn: Callable[[jax.Array], jax.Array] | None = None,
    check_gamma: bool = True,
):
    """DC-ELM over a time-varying topology (paper Sec. V future work).

    Round k uses graphs[k % len(graphs)]. The zero-gradient-sum
    invariant holds for every symmetric graph in the sequence, and
    consensus requires only *joint* connectivity (the union graph is
    connected) — each individual snapshot may be disconnected. gamma
    must satisfy the bound for the max degree across snapshots.
    """
    eng = engine_lib.simulated_dc_elm(
        list(graphs), C, dtype=state.betas.dtype
    )
    gamma = jnp.asarray(gamma, dtype=state.betas.dtype)
    betas, traces = eng.run(
        state.betas, state.omegas, gamma, num_iters, trace_fn=trace_fn,
        check_gamma=check_gamma,
    )
    final = dataclasses.replace(state, betas=betas, k=state.k + num_iters)
    return final, traces


def joint_gamma_bound(graphs: list[Graph]) -> float:
    """1 / max_k d_max(G_k) — the safe step size across all snapshots."""
    return 1.0 / max(g.d_max for g in graphs)


# ---------------------------------------------------------------------------
# Sharded (multi-device, ppermute gossip) path
# ---------------------------------------------------------------------------


def sharded_step_fn(
    mesh: jax.sharding.Mesh,
    spec: gossip.GossipSpec,
    C: float,
):
    """Build the jitted sharded DC-ELM round.

    State arrays carry a leading node axis of size V = prod(consensus
    axes) sharded across those axes; inside shard_map each shard sees its
    own (1, L, M) slice and exchanges only with mesh neighbors.
    """
    from repro.utils import compat

    eng = engine_lib.sharded_dc_elm(mesh, spec, C)
    nspec = eng.mixer.node_pspec()

    def body(betas, omegas, gamma):
        # betas: (1, L, M) local shard
        return eng.step(betas, omegas, gamma)

    shard = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(nspec, nspec, P()),
        out_specs=nspec,
    )
    return jax.jit(shard)


def sharded_run(
    mesh: jax.sharding.Mesh,
    spec: gossip.GossipSpec,
    betas: jax.Array,
    omegas: jax.Array,
    gamma: float,
    C: float,
    num_iters: int,
):
    """num_iters gossip rounds as one shard_map(scan) program on the mesh."""
    eng = engine_lib.sharded_dc_elm(mesh, spec, C)
    final, _ = eng.run(betas, omegas, gamma, num_iters)
    return final


# ---------------------------------------------------------------------------
# Node-local prediction (the paper's serve-at-every-node property)
# ---------------------------------------------------------------------------


def node_predict(
    fmap, betas: jax.Array, X: jax.Array, *,
    use_kernel: bool | None = None,
) -> jax.Array:
    """(V, N, M): every node's local answer on shared query rows X.

    The point of Algorithm 1/2 is that each node keeps a usable model
    at every round — any node can answer a query with its own beta_i.
    Queries go through the fused predict kernel exactly once
    (kernels/elm_predict.py: Y = g(XW+b) @ beta with H resident only
    in VMEM): the stacked betas fold into one (L, V*M) readout, so the
    dominant N*D*L feature work is shared across all V node models
    instead of being recomputed per node. The request-level front-end
    with micro-batching and hot-swap is ``serving.ELMServer``.
    """
    from repro.kernels import elm_predict_ops

    V, L, M = betas.shape
    wide = jnp.moveaxis(betas, 0, 1).reshape(L, V * M)
    Y = elm_predict_ops.predict_map(X, fmap, wide, use_kernel=use_kernel)
    return jnp.moveaxis(Y.reshape(*Y.shape[:-1], V, M), -2, 0)


# ---------------------------------------------------------------------------
# References used by tests
# ---------------------------------------------------------------------------


def centralized_from_node_stats(P_: jax.Array, Q_: jax.Array, C: float):
    """The fusion-center answer the distributed iterations must reach:

    beta* = (I/C + sum_i P_i)^{-1} (sum_i Q_i).
    """
    return stats_lib.ridge_solve_moments(
        jnp.sum(P_, axis=0), jnp.sum(Q_, axis=0), C
    )


def consensus_error(betas: jax.Array) -> jax.Array:
    """Max over nodes of ||beta_i - mean beta|| / (1 + ||mean beta||)."""
    mean = jnp.mean(betas, axis=0, keepdims=True)
    num = jnp.max(jnp.sqrt(jnp.sum((betas - mean) ** 2, axis=(1, 2))))
    den = 1.0 + jnp.sqrt(jnp.sum(mean**2))
    return num / den


def distance_to(betas: jax.Array, target: jax.Array) -> jax.Array:
    """Max over nodes of relative Frobenius distance to target."""
    num = jnp.sqrt(jnp.sum((betas - target[None]) ** 2, axis=(1, 2)))
    den = 1.0 + jnp.sqrt(jnp.sum(target**2))
    return jnp.max(num) / den


def average_empirical_risk_fn(fmap, X_test: jax.Array, T_test: jax.Array):
    """Paper eq. (32): R_d(k), averaged empirical risk across nodes.

    Returns a trace_fn(betas) suitable for simulate_run.
    """
    H_test = fmap(X_test)
    if T_test.ndim == 1:
        T_test = T_test[:, None]

    def trace(betas):
        preds = jnp.einsum("nl,vlm->vnm", H_test, betas)
        return jnp.mean(0.5 * jnp.abs(preds - T_test[None]))

    return trace


def test_error_fn(fmap, X_test: jax.Array, T_test: jax.Array):
    """Classification test-error trace (paper Fig. 7)."""
    H_test = fmap(X_test)
    labels = jnp.sign(T_test.reshape(-1))

    def trace(betas):
        preds = jnp.einsum("nl,vlm->vnm", H_test, betas)
        err = jnp.mean(jnp.sign(preds[..., 0]) != labels[None], axis=-1)
        return jnp.mean(err)

    return trace
