"""Online DC-ELM — the paper's Algorithm 2.

When a node's local data changes by a chunk (add DeltaS+ / remove
DeltaS-), the frozen preconditioner Omega_i = (I/(VC) + P_i)^{-1} and the
moment Q_i are updated in O(L^2 * DeltaN) via Sherman-Morrison-Woodbury
(paper eqs. 23-28) instead of re-inverting in O(L^3):

  remove (eq. 26):  Omega <- Omega + Omega dH^T (I_dN - dH Omega dH^T)^{-1} dH Omega
  add    (eq. 27):  Omega <- Omega - Omega dH^T (I_dN + dH Omega dH^T)^{-1} dH Omega
  and Q <- Q -/+ dH^T dT.

After the stat update, beta_i is re-seeded at the new local optimum
beta_i = Omega_i Q_i (Algorithm 2 step 13) — which restores the
zero-gradient-sum invariant — and consensus rounds resume.

This module owns the node-local statistics algebra only. The driver
that applies it across the network — batching the updates over the
stacked node axis, re-seeding, and running the consensus rounds on
either mixer — is ``engine.ConsensusEngine.stream_chunk`` (with
``stream_leave``/``stream_join`` handling whole-node churn via
``rescale_num_nodes``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import stats as stats_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OnlineNodeState:
    """One node's online-ELM sufficient statistics.

    omega: (L, L) current (I/(VC) + P)^{-1}
    Q:     (L, M) current H^T T
    """

    omega: jax.Array
    Q: jax.Array

    @property
    def beta(self) -> jax.Array:
        return self.omega @ self.Q


def init_state(H: jax.Array, T: jax.Array, C: float, V: int) -> OnlineNodeState:
    """Warm-up statistics via the statistics plane (Cholesky Omega)."""
    P_, Q_ = stats_lib.hidden_moments(H, T)
    return OnlineNodeState(
        omega=stats_lib.omega_from_moments(P_, C, V), Q=Q_
    )


def woodbury_add(omega: jax.Array, dH: jax.Array) -> jax.Array:
    """Rank-dN downdate of the inverse after ADDING rows dH (eq. 27)."""
    dN = dH.shape[0]
    S = jnp.eye(dN, dtype=omega.dtype) + dH @ omega @ dH.T
    K = omega @ dH.T
    return omega - K @ jnp.linalg.solve(S, K.T)


def woodbury_remove(omega: jax.Array, dH: jax.Array) -> jax.Array:
    """Rank-dN update of the inverse after REMOVING rows dH (eq. 26)."""
    dN = dH.shape[0]
    S = jnp.eye(dN, dtype=omega.dtype) - dH @ omega @ dH.T
    K = omega @ dH.T
    return omega + K @ jnp.linalg.solve(S, K.T)


@jax.jit
def remove_chunk(state: OnlineNodeState, dH: jax.Array, dT: jax.Array):
    """Algorithm 2, steps 5-8."""
    return OnlineNodeState(
        omega=woodbury_remove(state.omega, dH),
        Q=state.Q - dH.T @ dT,
    )


@jax.jit
def add_chunk(state: OnlineNodeState, dH: jax.Array, dT: jax.Array):
    """Algorithm 2, steps 9-12."""
    return OnlineNodeState(
        omega=woodbury_add(state.omega, dH),
        Q=state.Q + dH.T @ dT,
    )


def update_chunk(
    state: OnlineNodeState,
    added: tuple[jax.Array, jax.Array] | None = None,
    removed: tuple[jax.Array, jax.Array] | None = None,
) -> OnlineNodeState:
    """Apply remove-then-add, the paper's Algorithm 2 ordering."""
    if removed is not None:
        state = remove_chunk(state, *removed)
    if added is not None:
        state = add_chunk(state, *added)
    return state


def vertical_chunk(
    state: OnlineNodeState,
    X_new_slices,
    T_new: jax.Array,
    feature_map,
    *,
    remove: bool = False,
    graph=None,
    secure=None,
    faults=None,
    **kw,
):
    """Node-local Algorithm 2 update from column-sliced new rows.

    The chunk's rows arrive at every node at once (vertical mode: same
    samples, disjoint columns), so the assembled hidden chunk dH is
    shared — each node folds (dH/sqrt(V), dT/sqrt(V)) into its state,
    preserving the per-node stats = network-total/V invariant that the
    vertical init establishes. Reduction keywords (``secure=``,
    ``faults=``, ``start_round=``) pass through to
    ``core.vertical.reduce_partials``.

    Returns (OnlineNodeState, ReduceReport). For the full networked
    driver (consensus rounds included) use ``vertical.stream_chunk``.
    """
    from repro.core import vertical
    from repro.core.consensus import complete
    from repro.core.features import ACTIVATIONS

    vfmap = feature_map
    if graph is None:
        graph = complete(vfmap.num_nodes)
    partials = [
        vfmap.partial_preactivation(i, x)
        for i, x in enumerate(X_new_slices)
    ]
    dZ, report = vertical.reduce_partials(
        partials, graph, secure=secure, faults=faults, **kw
    )
    dH = ACTIVATIONS[vfmap.activation](dZ + vfmap.bias)
    if T_new.ndim == 1:
        T_new = T_new[:, None]
    scale = 1.0 / jnp.sqrt(jnp.asarray(float(graph.num_nodes), dH.dtype))
    chunk = (dH * scale, T_new.astype(dH.dtype) * scale)
    new = update_chunk(
        state,
        added=None if remove else chunk,
        removed=chunk if remove else None,
    )
    return new, report


# Batched (all V nodes at once) variants, used by the streaming driver
# ``ConsensusEngine.stream_chunk`` (engine.py).
batched_add_chunk = jax.jit(jax.vmap(add_chunk))
batched_remove_chunk = jax.jit(jax.vmap(remove_chunk))


def rescale_num_nodes(
    omega: jax.Array, V_old: int, V_new: int, C: float
) -> jax.Array:
    """Re-target Omega = (I/(V_old C) + P)^{-1} to a new network size.

    Elastic membership changes V, and V sits inside every node's frozen
    preconditioner through the ridge term I/(VC). The shift is
    delta * I with delta = 1/(V_new C) - 1/(V_old C), i.e. a rank-L
    identity "chunk": reuse the same Woodbury identities as data
    add/remove with dH = sqrt(|delta|) * I_L (add when the ridge
    stiffens — a node left — remove when it relaxes — a node joined).
    """
    if V_old == V_new:
        return omega
    delta = (1.0 / V_new - 1.0 / V_old) / C
    L = omega.shape[-1]
    dH = jnp.sqrt(jnp.asarray(abs(delta), omega.dtype)) * jnp.eye(
        L, dtype=omega.dtype
    )
    if delta > 0:
        return woodbury_add(omega, dH)
    return woodbury_remove(omega, dH)


batched_rescale_num_nodes = jax.jit(
    jax.vmap(rescale_num_nodes, in_axes=(0, None, None, None)),
    static_argnums=(1, 2, 3),
)


def reseed_betas(states: OnlineNodeState) -> jax.Array:
    """Stacked beta_i = Omega_i Q_i after an online update (step 13)."""
    return jnp.einsum("vlk,vkm->vlm", states.omega, states.Q)


@functools.partial(jax.jit, static_argnames=("C", "V"))
def direct_state(H: jax.Array, T: jax.Array, C: float, V: int) -> OnlineNodeState:
    """O(L^3) recompute-from-scratch reference for the Woodbury paths."""
    return init_state(H, T, C, V)
