"""ConsensusEngine — the one consensus update rule, every execution path.

The paper's fusion-center-free iteration (Algorithm 1, eq. 20)

    beta_i(k+1) = beta_i(k) + (gamma / VC) * Omega_i * lap_i,
    lap_i = sum_{j in N_i} a_ij (beta_j(k) - beta_i(k))

used to live in four hand-rolled copies (simulated step/run, the two
sharded bodies, plus per-consumer glue). It now lives **here, once**,
factored as

    Engine  =  Mixer (who computes lap_i, and where)   [core/mixers.py]
            x  UpdateRule (what lap_i does to the state)     [this file]

UpdateRules:
  * ``DCELMRule``   — the paper's preconditioned step (Omega_i metric).
  * ``AverageRule`` — identity metric: plain consensus averaging
    (gossip.neighbor_avg semantics) and D-PSGD parameter mixing
    (core/dsgd.py) over arbitrary pytrees.

On top of the round driver, ``stream_chunk`` implements **Algorithm 2**
end-to-end — Woodbury remove/add of a data chunk, beta re-seed at the
new local optimum, K consensus rounds — and runs on *both* mixers, so
the sharded production path gets online learning from the same code
the simulated fidelity path is tested with. Streaming also survives
churn: ``stream_leave``/``stream_join`` remove or add whole nodes
(their data shard included) with a rank-L Woodbury re-target of every
survivor's preconditioner, and ``with_faults`` wraps any engine's
mixer in a fault-injection layer (``mixers.FaultyMixer``).
``with_compression`` (or a ``compression.CompressionSpec`` handed to
any constructor's ``compress=``) wraps the mixer in a
``CompressedMixer`` — quantized/sparsified wire payloads with error
feedback and event-triggered rounds — and every run surfaces exact
bytes-on-wire accounting as ``ConsensusEngine.wire_stats``. See
DESIGN.md §4, §8 and §9.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gossip, online
from repro.core.compression import CompressedMixer, CompressionSpec
from repro.core.consensus import FaultModel, Graph
from repro.core.mixers import (
    DenseMixer,
    FaultyMixer,
    NeighborMixer,
    PpermuteMixer,
)


# ---------------------------------------------------------------------------
# Update rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DCELMRule:
    """Paper eq. (20): beta += (gamma/VC) * Omega @ lap.

    ``aux`` carries the stacked frozen preconditioners Omega_i with the
    same leading node axis as the state ((V, L, L) dense, (1, L, L) per
    shard) — the einsum below is identical in both layouts. This is the
    only implementation of the DC-ELM round body in the codebase.
    """

    num_nodes: int
    C: float

    def __call__(self, x, lap, aux, gamma):
        V, C = self.num_nodes, self.C
        update = jnp.einsum("vlk,vkm->vlm", aux, lap)
        return x + (gamma / (V * C)) * update


@dataclasses.dataclass(frozen=True)
class AverageRule:
    """Identity-metric mixing x += gamma * lap, per pytree leaf.

    The paper's rule with Omega_i = I: plain consensus averaging, and —
    applied to parameter pytrees after a local optimizer step — the
    D-PSGD mixing used by the deep-net trainer (core/dsgd.py), where the
    non-quadratic objective has no closed-form ELM preconditioner.
    """

    def __call__(self, x, lap, aux, gamma):
        del aux
        return jax.tree.map(lambda v, d: v + gamma * d.astype(v.dtype), x, lap)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConsensusEngine:
    """One consensus iteration = rule(state, mixer.laplacian(state)).

    Wire-format and robustness knobs compose around the mixer without
    touching the rule:

    * ``compress=`` on the convenience constructors — ``None``/"none"
      (no compression, the default) or "bf16" select the mixers' inline
      payload cast; an "int8"/"topk" mode string or a full
      ``compression.CompressionSpec`` (error feedback, event-triggered
      broadcasts) wraps the mixer in a ``CompressedMixer``.
      ``with_compression(eng, spec)`` does the same to an existing
      engine.
    * ``with_faults(eng, model_or_masks)`` injects a per-round edge
      keep-mask stream (``mixers.FaultyMixer``). The two stack —
      compression always sits outermost, so encoded payloads cross
      whatever links the fault trace left alive.

    After any ``run``/``stream_chunk``, ``eng.wire_stats`` holds the
    exact bytes-on-wire accounting of the rounds just executed
    (``compression.WireStats``), on every mixer stack.

    ``secure`` carries a ``secure.SecureAggregationSpec`` (set via
    ``with_secure_aggregation``) that the vertical plane
    (``core/vertical.py``) picks up for its sum-reductions — pairwise
    additive masks on the assembly payloads. It deliberately does NOT
    mask the per-round Laplacian gossip: lap_i is a *neighborhood*
    difference, not a network-wide sum, so pairwise masks would not
    cancel there; secure aggregation scopes to genuine sum-reductions.
    """

    mixer: Any
    rule: Callable
    secure: Any = None

    @property
    def wire_stats(self):
        """Exact ``compression.WireStats`` of the last run (or None)."""
        return getattr(self.mixer, "last_wire_stats", None)

    def gamma_upper_bound(self) -> float | None:
        """Thm. 2's 1/d_max for the *active* mixer (None if the mixer
        cannot say, e.g. traced adjacencies).

        Membership churn moves this bound: ``stream_join``'s default
        all-incumbent topology jumps d_max to ~V, so always re-read the
        bound from the engine ``stream_join``/``stream_leave`` return
        rather than reusing the pre-churn value.
        """
        fn = getattr(self.mixer, "gamma_upper_bound", None)
        if fn is None:
            return None
        try:
            return float(fn())
        except (
            TypeError,
            jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError,
        ):
            return None

    def _validate_gamma(self, gamma, check_gamma: bool) -> None:
        """Reject a concrete gamma outside (0, 1/d_max) of the active
        mixer — the silent-divergence bug after membership churn.

        Traced gammas (inside jit/shard_map) and mixers without a
        concrete bound are skipped; ``check_gamma=False`` is the escape
        hatch for deliberate above-bound experiments (paper Fig. 4(a)).
        """
        if not check_gamma or gamma is None:
            return
        try:
            g = float(gamma)
        except (
            TypeError,
            jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError,
        ):
            return
        bound = self.gamma_upper_bound()
        if bound is None:
            return
        if not 0.0 < g < bound:
            raise ValueError(
                f"gamma={g:.6g} violates Thm. 2's 0 < gamma < 1/d_max "
                f"= {bound:.6g} for the active mixer (the bound moves "
                "under membership churn — re-read it from the engine "
                "stream_join/stream_leave return). Pass "
                "check_gamma=False to run a deliberate divergence "
                "experiment."
            )

    def step(self, x, aux=None, gamma=None, k=0, *, check_gamma=True):
        """A single consensus round, in the mixer's execution context.

        For ``PpermuteMixer`` this must run inside a caller-managed
        shard_map (distributed/steps.py and core/elm_head.py do this to
        mix replicas whose leaves are further model-sharded); for
        ``DenseMixer`` it is directly callable/jittable. A concrete
        gamma is validated against the active mixer's Thm. 2 bound
        (``check_gamma=False`` opts out).
        """
        self._validate_gamma(gamma, check_gamma)
        return self.rule(x, self.mixer.laplacian(x, k), aux, gamma)

    def run(
        self,
        x,
        aux,
        gamma,
        num_iters: int,
        *,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
        check_gamma=True,
    ):
        """num_iters rounds under the mixer's scan driver.

        trace_fn: optional per-round metric over the stacked state
        (DenseMixer only). state_spec/aux_spec: PartitionSpec overrides
        for states whose trailing dims are also sharded (PpermuteMixer
        only). A concrete gamma is validated against the active mixer's
        Thm. 2 bound at entry (``check_gamma=False`` opts out for
        deliberate divergence experiments). Returns
        (final_state, traces or None).
        """
        self._validate_gamma(gamma, check_gamma)
        return self.mixer.run(
            self.rule, x, aux, gamma, num_iters, trace_fn, state_spec,
            aux_spec,
        )

    # -- streaming (paper Algorithm 2) ------------------------------------

    def stream_init(
        self,
        H_nodes=None,
        T_nodes=None,
        *,
        X_nodes=None,
        feature_map=None,
    ) -> "StreamState":
        """Per-node sufficient statistics + local ridge seed.

        Two entry shapes, both through the statistics plane
        (`core/stats.py`, Cholesky Omega):

        * materialized features: ``stream_init(H_nodes, T_nodes)`` with
          H:(V,Ni,L), T:(V,Ni,M);
        * raw inputs: ``stream_init(X_nodes=X, T_nodes=T,
          feature_map=fmap)`` with X:(V,Ni,D) — on fusable maps the
          hidden matrices are never materialized (fused kernel /
          streaming scan).

        Requires a DCELMRule.
        """
        C, V = self._ridge_constants()
        if X_nodes is not None:
            if H_nodes is not None:
                raise ValueError("pass either H_nodes or X_nodes, not both")
            if feature_map is None:
                raise ValueError("X_nodes requires feature_map=")
            if T_nodes is None:
                raise ValueError("X_nodes requires T_nodes= targets")
            from repro.core import stats as stats_lib

            if T_nodes.ndim == 2:
                T_nodes = T_nodes[..., None]

            def node(x, t):
                P_, Q_ = stats_lib.raw_moments(
                    x, t, feature_map,
                    dtype=stats_lib.accum_dtype(x, t),
                )
                return online.OnlineNodeState(
                    omega=stats_lib.omega_from_moments(P_, C, V), Q=Q_
                )

            states = jax.vmap(node)(X_nodes, T_nodes)
        else:
            states = jax.vmap(lambda h, t: online.init_state(h, t, C, V))(
                H_nodes, T_nodes
            )
        return StreamState(
            omegas=states.omega, Qs=states.Q, betas=online.reseed_betas(states)
        )

    def stream_chunk(
        self,
        state: "StreamState",
        added=None,
        removed=None,
        *,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
        publish_to=None,
        check_gamma=True,
    ):
        """One Algorithm 2 event on every node, end-to-end.

        added/removed: optional (dH, dT) pairs with stacked shapes
        (V, dN, L)/(V, dN, M). Steps 5-12: Woodbury remove-then-add in
        O(L^2 dN) per node; step 13: re-seed beta_i = Omega_i Q_i (which
        restores the zero-gradient-sum invariant); then ``num_iters``
        consensus rounds toward the new centralized solution. Works on
        both mixers — on PpermuteMixer the stat updates are node-local
        batched ops and only the rounds touch the ICI.

        Node-level churn (a whole member arriving/departing, not just
        its data chunks) is ``stream_leave``/``stream_join``, which
        rebuild the engine for the new V. After the event,
        ``self.wire_stats`` holds the exact bytes the rounds moved
        (and the mixer accumulates ``total_bytes_on_wire`` across
        events).

        publish_to: optional ``serving.BetaStore`` (anything with a
        ``publish(betas)`` method) — the post-consensus stacked betas
        are published as a fresh versioned snapshot, so a live
        ``serving.ELMServer`` hot-swaps onto the new model mid-traffic
        while the next chunks keep streaming (the serve-while-train
        loop; DESIGN.md §11).

        Returns (StreamState, traces or None).
        """
        self._ridge_constants()  # assert a DCELMRule before any work
        ostate = online.OnlineNodeState(omega=state.omegas, Q=state.Qs)
        if removed is not None:
            ostate = online.batched_remove_chunk(ostate, *removed)
        if added is not None:
            ostate = online.batched_add_chunk(ostate, *added)
        betas = online.reseed_betas(ostate)
        final, traces = self.run(
            betas,
            ostate.omega,
            gamma,
            num_iters,
            trace_fn=trace_fn,
            state_spec=state_spec,
            aux_spec=aux_spec,
            check_gamma=check_gamma,
        )
        if publish_to is not None:
            publish_to.publish(final)
        return (
            StreamState(omegas=ostate.omega, Qs=ostate.Q, betas=final),
            traces,
        )

    # -- elastic membership (beyond-paper: Algorithm 2 under churn) --------

    def stream_leave(
        self, state: "StreamState", node: int, *, graph: Graph | None = None
    ) -> tuple["ConsensusEngine", "StreamState"]:
        """Node ``node`` departs the network, taking its whole shard
        (data, statistics, estimate) with it.

        The centralized target becomes the solution over the remaining
        V-1 nodes' data, and V itself sits inside every surviving
        Omega_j through the ridge term I/(VC) — so each survivor
        re-targets its preconditioner with a rank-L Woodbury update
        (``online.rescale_num_nodes``) and re-seeds beta_j = Omega_j Q_j,
        restoring the zero-gradient-sum invariant for the smaller
        network. Returns ``(new_engine, new_state)`` — the engine is
        rebuilt for the (V-1)-node rule and topology, and
        ``new_engine.gamma_upper_bound()`` is the post-churn Thm. 2
        bound to step with (the pre-churn gamma may now be invalid).

        graph: the surviving communication graph; default = the base
        adjacency with ``node``'s row/column deleted (every snapshot,
        for time-varying bases). Membership is a data-plane change and
        needs re-stacked arrays, so it is a DenseMixer feature; on the
        sharded path model *link* loss with a FaultyMixer instead (the
        mesh shard cannot leave the physical device).
        """
        C, V = self._ridge_constants()
        if not 0 <= node < V:
            raise ValueError(f"node {node} out of range for V={V}")
        adjacencies = self._membership_adjacencies(graph, drop=node)
        keep = [i for i in range(V) if i != node]
        omegas = online.batched_rescale_num_nodes(
            state.omegas[jnp.asarray(keep)], V, V - 1, C
        )
        Qs = state.Qs[jnp.asarray(keep)]
        ostate = online.OnlineNodeState(omega=omegas, Q=Qs)
        new_engine = self._rewrap_faults(
            ConsensusEngine(
                self._dense_mixer_cls()(
                    adjacencies, compress=self._base_compress()
                ),
                DCELMRule(V - 1, C),
                secure=self.secure,
            ),
            drop=node,
        )
        return new_engine, StreamState(
            omegas=omegas, Qs=Qs, betas=online.reseed_betas(ostate)
        )

    def stream_join(
        self,
        state: "StreamState",
        H_new: jax.Array,
        T_new: jax.Array,
        *,
        graph: Graph | None = None,
    ) -> tuple["ConsensusEngine", "StreamState"]:
        """A new node joins with local data H_new:(Nn, L), T_new:(Nn, M).

        The joiner builds its statistics from scratch at the new
        network size; every incumbent re-targets Omega for V -> V+1 via
        the same rank-L Woodbury rescale and re-seeds. The joiner takes
        index V (append order). Returns ``(new_engine, new_state)``.

        graph: the enlarged communication graph; default = the base
        adjacency with the joiner connected to every incumbent — which
        jumps d_max to ~V, so a pre-churn gamma is very likely above
        the new Thm. 2 bound. Step with
        ``new_engine.gamma_upper_bound()`` /
        ``new_engine.mixer.default_gamma()``; the engine's gamma
        validation rejects a stale concrete gamma at run entry.
        """
        C, V = self._ridge_constants()
        adjacencies = self._membership_adjacencies(graph, add=True)
        omegas = online.batched_rescale_num_nodes(state.omegas, V, V + 1, C)
        joiner = online.init_state(H_new, T_new, C, V + 1)
        omegas = jnp.concatenate([omegas, joiner.omega[None]], axis=0)
        Qs = jnp.concatenate([state.Qs, joiner.Q[None]], axis=0)
        ostate = online.OnlineNodeState(omega=omegas, Q=Qs)
        new_engine = self._rewrap_faults(
            ConsensusEngine(
                self._dense_mixer_cls()(
                    adjacencies, compress=self._base_compress()
                ),
                DCELMRule(V + 1, C),
                secure=self.secure,
            ),
            add=True,
        )
        return new_engine, StreamState(
            omegas=omegas, Qs=Qs, betas=online.reseed_betas(ostate)
        )

    def _membership_adjacencies(
        self, graph: Graph | None, *, drop: int | None = None,
        add: bool = False,
    ) -> jnp.ndarray:
        """Adjacency snapshots for the post-churn network."""
        if graph is not None:
            return jnp.asarray(graph.adjacency, jnp.float32)[None]
        mixer = self.mixer
        while isinstance(mixer, (CompressedMixer, FaultyMixer)):
            mixer = mixer.base
        if not isinstance(mixer, DenseMixer):
            raise TypeError(
                "elastic membership resizes the stacked node axis and so "
                "needs a DenseMixer engine (or an explicit `graph=`); on "
                "the sharded path model link loss with a FaultyMixer"
            )
        adj = np.asarray(mixer.adjacencies)
        if drop is not None:
            adj = np.delete(np.delete(adj, drop, axis=1), drop, axis=2)
        if add:
            S, V = adj.shape[0], adj.shape[1]
            new = np.zeros((S, V + 1, V + 1), dtype=adj.dtype)
            new[:, :V, :V] = adj
            new[:, V, :V] = 1.0
            new[:, :V, V] = 1.0
            adj = new
        return jnp.asarray(adj)

    def _rewrap_faults(
        self, new_engine: "ConsensusEngine", *, drop: int | None = None,
        add: bool = False,
    ) -> "ConsensusEngine":
        """Carry FaultyMixer / CompressedMixer wrappers across a
        membership change.

        Fault masks are resized like the adjacency (departed row/column
        deleted; a joiner's links start all-up); a compression spec is
        re-applied on top unchanged. The transformed fault trace has
        NOT been re-certified for joint connectivity — re-run
        ``FaultModel.certify_jointly_connected`` on it if the churned
        network must keep the convergence guarantee.
        """
        mixer = self.mixer
        comp = mixer.spec if isinstance(mixer, CompressedMixer) else None
        if comp is not None:
            mixer = mixer.base
        if isinstance(mixer, FaultyMixer):
            keep = mixer.edge_keep
            if drop is not None:
                keep = np.delete(
                    np.delete(keep, drop, axis=1), drop, axis=2
                )
            if add:
                R, V = keep.shape[0], keep.shape[1]
                grown = np.ones((R, V + 1, V + 1), dtype=keep.dtype)
                grown[:, :V, :V] = keep
                keep = grown
            new_engine = with_faults(new_engine, keep)
        if comp is not None:
            new_engine = with_compression(new_engine, comp)
        return new_engine

    def _base_compress(self):
        return getattr(self.mixer, "compress", None)

    def _dense_mixer_cls(self) -> type:
        """The dense-layout mixer class membership churn rebuilds with —
        preserving a NeighborMixer (or other DenseMixer subclass)
        through the CompressedMixer/FaultyMixer wrapper chain, so e.g.
        a fused-kernel engine stays fused after stream_leave/join."""
        mixer = self.mixer
        while isinstance(mixer, (CompressedMixer, FaultyMixer)):
            mixer = mixer.base
        cls = type(mixer)
        return cls if issubclass(cls, DenseMixer) else DenseMixer

    def _ridge_constants(self) -> tuple[float, int]:
        if not isinstance(self.rule, DCELMRule):
            raise TypeError(
                "streaming (Algorithm 2) re-seeds beta = Omega @ Q and so "
                f"requires a DCELMRule, got {type(self.rule).__name__}"
            )
        return self.rule.C, self.rule.num_nodes


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Stacked per-node streaming state (Algorithm 2 carry).

    omegas: (V, L, L) current (I/(VC) + P_i)^{-1}
    Qs:     (V, L, M) current H_i^T T_i
    betas:  (V, L, M) node estimates after the last consensus rounds
    """

    omegas: jax.Array
    Qs: jax.Array
    betas: jax.Array


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------


def simulated_dc_elm(
    graphs: Graph | list[Graph] | jax.Array,
    C: float,
    *,
    dtype=jnp.float32,
    compress=None,
    mixer: str = "dense",
) -> ConsensusEngine:
    """DC-ELM over arbitrary dense graphs (the fidelity/simulation path).

    compress: None/"none" (default), "bf16" (inline payload cast), or an
    "int8"/"topk" mode string / ``compression.CompressionSpec`` (wraps
    the mixer in a ``CompressedMixer``).

    mixer: "dense" (default) mixes via the dense adjacency matmul;
    "neighbor" selects ``mixers.NeighborMixer`` — the fused gossip
    kernel plane over padded neighbor lists (dense-parity pinned), which
    falls back to the dense program on graphs too dense for gathers to
    win.
    """
    inline, spec = _split_compress(compress)
    try:
        cls = {"dense": DenseMixer, "neighbor": NeighborMixer}[mixer]
    except KeyError:
        raise ValueError(
            f'mixer must be "dense" or "neighbor", got {mixer!r}'
        ) from None
    if isinstance(graphs, (Graph, list)):
        mx = cls.from_graphs(graphs, dtype=dtype, compress=inline)
    else:
        mx = cls(graphs, compress=inline)
    eng = ConsensusEngine(mx, DCELMRule(mx.num_nodes, C))
    return with_compression(eng, spec) if spec is not None else eng


def sharded_dc_elm(
    mesh: jax.sharding.Mesh,
    spec: gossip.GossipSpec,
    C: float,
    *,
    compress=None,
) -> ConsensusEngine:
    """DC-ELM over mesh neighbors (the ppermute production path).

    compress: same knob as ``simulated_dc_elm`` — inline "bf16" or a
    ``CompressionSpec``/mode string for the compressed-gossip subsystem.
    """
    inline, cspec = _split_compress(compress)
    mixer = PpermuteMixer.for_mesh(mesh, spec, compress=inline)
    eng = ConsensusEngine(mixer, DCELMRule(mixer.num_nodes, C))
    return with_compression(eng, cspec) if cspec is not None else eng


def with_faults(
    eng: ConsensusEngine,
    faults,
    num_rounds: int | None = None,
) -> ConsensusEngine:
    """Wrap an engine's mixer in a ``FaultyMixer``.

    faults: a ``consensus.FaultModel`` (then ``num_rounds`` sets the
    fault-trace period) or a ready (R, V, V) edge keep-mask array. The
    update rule, step bound, and — on the sharded path — the compiled
    collective program are untouched; only dropped links stop
    contributing to the Laplacian.

    Stacks with compression: if the engine is already compressed, the
    fault layer slides *under* the ``CompressedMixer`` so encoded
    payloads cross whatever links the trace left alive.
    """
    if isinstance(eng.mixer, CompressedMixer):
        inner = with_faults(
            dataclasses.replace(eng, mixer=eng.mixer.base),
            faults, num_rounds,
        )
        return with_compression(inner, eng.mixer.spec)
    if isinstance(faults, FaultModel):
        if num_rounds is None:
            raise ValueError("num_rounds is required with a FaultModel")
        mixer = FaultyMixer.from_fault_model(eng.mixer, faults, num_rounds)
    else:
        mixer = FaultyMixer(eng.mixer, faults)
    return dataclasses.replace(eng, mixer=mixer)


def with_compression(eng: ConsensusEngine, spec) -> ConsensusEngine:
    """Wrap an engine's mixer in a ``compression.CompressedMixer``.

    spec: a ``CompressionSpec``, a mode string ("bf16" / "int8" /
    "topk"), or None/"none" (still wraps — useful for uniform wire
    accounting). Composes over a fault-injected engine; the update rule
    and Thm. 2 step bound are untouched (DESIGN.md §9).
    """
    return dataclasses.replace(eng, mixer=CompressedMixer(eng.mixer, spec))


def with_secure_aggregation(eng: ConsensusEngine, spec=True) -> ConsensusEngine:
    """Attach a secure-aggregation policy to an engine.

    spec: a ``secure.SecureAggregationSpec``, an int (shared PRNG
    seed), or True for the defaults. The vertical plane
    (``core/vertical.py``) reads ``eng.secure`` and applies pairwise
    additive masks — fixed-point, canceling exactly in the sum — to
    its assembly payloads; see the class docstring for why per-round
    Laplacian gossip is out of scope. Composes freely with
    ``with_faults`` (crash-time mask recovery rides the same
    ``FaultModel``) and ``with_compression``.
    """
    from repro.core.secure import SecureAggregationSpec

    return dataclasses.replace(
        eng, secure=SecureAggregationSpec.parse(spec)
    )


def _split_compress(compress):
    """Constructor ``compress=`` knob -> (inline mixer mode, spec).

    None/"none"/"bf16" ride the mixers' inline payload cast; a richer
    mode string or a ``CompressionSpec`` becomes a ``CompressedMixer``
    wrap (so ``simulated_dc_elm(g, C, compress=CompressionSpec(...))``
    just works).
    """
    if compress is None or compress in ("none", "bf16"):
        return compress, None
    return None, CompressionSpec.parse(compress)


def simulated_averaging(adjacency, *, compress=None) -> ConsensusEngine:
    """Plain consensus averaging / D-PSGD mixing on a dense adjacency."""
    inline, spec = _split_compress(compress)
    eng = ConsensusEngine(
        DenseMixer(adjacency, compress=inline), AverageRule()
    )
    return with_compression(eng, spec) if spec is not None else eng


def sharded_averaging(
    spec: gossip.GossipSpec,
    axis_sizes: dict,
    *,
    mesh: jax.sharding.Mesh | None = None,
    compress=None,
) -> ConsensusEngine:
    """Plain consensus averaging / D-PSGD mixing via ppermute gossip."""
    inline, cspec = _split_compress(compress)
    eng = ConsensusEngine(
        PpermuteMixer(
            spec=spec, axis_sizes=dict(axis_sizes), mesh=mesh,
            compress=inline,
        ),
        AverageRule(),
    )
    return with_compression(eng, cspec) if cspec is not None else eng
