"""ConsensusEngine — the one consensus update rule, every execution path.

The paper's fusion-center-free iteration (Algorithm 1, eq. 20)

    beta_i(k+1) = beta_i(k) + (gamma / VC) * Omega_i * lap_i,
    lap_i = sum_{j in N_i} a_ij (beta_j(k) - beta_i(k))

used to live in four hand-rolled copies (simulated step/run, the two
sharded bodies, plus per-consumer glue). It now lives **here, once**,
factored as

    Engine  =  Mixer (who computes lap_i, and where)   [core/mixers.py]
            x  UpdateRule (what lap_i does to the state)     [this file]

UpdateRules:
  * ``DCELMRule``   — the paper's preconditioned step (Omega_i metric).
  * ``AverageRule`` — identity metric: plain consensus averaging
    (gossip.neighbor_avg semantics) and D-PSGD parameter mixing
    (core/dsgd.py) over arbitrary pytrees.

On top of the round driver, ``stream_chunk`` implements **Algorithm 2**
end-to-end — Woodbury remove/add of a data chunk, beta re-seed at the
new local optimum, K consensus rounds — and runs on *both* mixers, so
the sharded production path gets online learning from the same code
the simulated fidelity path is tested with. See DESIGN.md.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import gossip, online
from repro.core.consensus import Graph
from repro.core.mixers import DenseMixer, PpermuteMixer


# ---------------------------------------------------------------------------
# Update rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DCELMRule:
    """Paper eq. (20): beta += (gamma/VC) * Omega @ lap.

    ``aux`` carries the stacked frozen preconditioners Omega_i with the
    same leading node axis as the state ((V, L, L) dense, (1, L, L) per
    shard) — the einsum below is identical in both layouts. This is the
    only implementation of the DC-ELM round body in the codebase.
    """

    num_nodes: int
    C: float

    def __call__(self, x, lap, aux, gamma):
        V, C = self.num_nodes, self.C
        update = jnp.einsum("vlk,vkm->vlm", aux, lap)
        return x + (gamma / (V * C)) * update


@dataclasses.dataclass(frozen=True)
class AverageRule:
    """Identity-metric mixing x += gamma * lap, per pytree leaf.

    The paper's rule with Omega_i = I: plain consensus averaging, and —
    applied to parameter pytrees after a local optimizer step — the
    D-PSGD mixing used by the deep-net trainer (core/dsgd.py), where the
    non-quadratic objective has no closed-form ELM preconditioner.
    """

    def __call__(self, x, lap, aux, gamma):
        del aux
        return jax.tree.map(lambda v, d: v + gamma * d.astype(v.dtype), x, lap)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConsensusEngine:
    """One consensus iteration = rule(state, mixer.laplacian(state))."""

    mixer: Any
    rule: Callable

    def step(self, x, aux=None, gamma=None, k=0):
        """A single consensus round, in the mixer's execution context.

        For ``PpermuteMixer`` this must run inside a caller-managed
        shard_map (distributed/steps.py and core/elm_head.py do this to
        mix replicas whose leaves are further model-sharded); for
        ``DenseMixer`` it is directly callable/jittable.
        """
        return self.rule(x, self.mixer.laplacian(x, k), aux, gamma)

    def run(
        self,
        x,
        aux,
        gamma,
        num_iters: int,
        *,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        """num_iters rounds under the mixer's scan driver.

        trace_fn: optional per-round metric over the stacked state
        (DenseMixer only). state_spec/aux_spec: PartitionSpec overrides
        for states whose trailing dims are also sharded (PpermuteMixer
        only). Returns (final_state, traces or None).
        """
        return self.mixer.run(
            self.rule, x, aux, gamma, num_iters, trace_fn, state_spec,
            aux_spec,
        )

    # -- streaming (paper Algorithm 2) ------------------------------------

    def stream_init(self, H_nodes, T_nodes) -> "StreamState":
        """Per-node sufficient statistics + local ridge seed from stacked
        warm-up data H:(V,Ni,L), T:(V,Ni,M). Requires a DCELMRule."""
        C, V = self._ridge_constants()
        states = jax.vmap(lambda h, t: online.init_state(h, t, C, V))(
            H_nodes, T_nodes
        )
        return StreamState(
            omegas=states.omega, Qs=states.Q, betas=online.reseed_betas(states)
        )

    def stream_chunk(
        self,
        state: "StreamState",
        added=None,
        removed=None,
        *,
        gamma,
        num_iters: int,
        trace_fn=None,
        state_spec=None,
        aux_spec=None,
    ):
        """One Algorithm 2 event on every node, end-to-end.

        added/removed: optional (dH, dT) pairs with stacked shapes
        (V, dN, L)/(V, dN, M). Steps 5-12: Woodbury remove-then-add in
        O(L^2 dN) per node; step 13: re-seed beta_i = Omega_i Q_i (which
        restores the zero-gradient-sum invariant); then ``num_iters``
        consensus rounds toward the new centralized solution. Works on
        both mixers — on PpermuteMixer the stat updates are node-local
        batched ops and only the rounds touch the ICI.

        Returns (StreamState, traces or None).
        """
        self._ridge_constants()  # assert a DCELMRule before any work
        ostate = online.OnlineNodeState(omega=state.omegas, Q=state.Qs)
        if removed is not None:
            ostate = online.batched_remove_chunk(ostate, *removed)
        if added is not None:
            ostate = online.batched_add_chunk(ostate, *added)
        betas = online.reseed_betas(ostate)
        final, traces = self.run(
            betas,
            ostate.omega,
            gamma,
            num_iters,
            trace_fn=trace_fn,
            state_spec=state_spec,
            aux_spec=aux_spec,
        )
        return (
            StreamState(omegas=ostate.omega, Qs=ostate.Q, betas=final),
            traces,
        )

    def _ridge_constants(self) -> tuple[float, int]:
        if not isinstance(self.rule, DCELMRule):
            raise TypeError(
                "streaming (Algorithm 2) re-seeds beta = Omega @ Q and so "
                f"requires a DCELMRule, got {type(self.rule).__name__}"
            )
        return self.rule.C, self.rule.num_nodes


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Stacked per-node streaming state (Algorithm 2 carry).

    omegas: (V, L, L) current (I/(VC) + P_i)^{-1}
    Qs:     (V, L, M) current H_i^T T_i
    betas:  (V, L, M) node estimates after the last consensus rounds
    """

    omegas: jax.Array
    Qs: jax.Array
    betas: jax.Array


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------


def simulated_dc_elm(
    graphs: Graph | list[Graph] | jax.Array,
    C: float,
    *,
    dtype=jnp.float32,
    compress: str | None = None,
) -> ConsensusEngine:
    """DC-ELM over arbitrary dense graphs (the fidelity/simulation path)."""
    if isinstance(graphs, (Graph, list)):
        mixer = DenseMixer.from_graphs(graphs, dtype=dtype, compress=compress)
    else:
        mixer = DenseMixer(graphs, compress=compress)
    return ConsensusEngine(mixer, DCELMRule(mixer.num_nodes, C))


def sharded_dc_elm(
    mesh: jax.sharding.Mesh,
    spec: gossip.GossipSpec,
    C: float,
    *,
    compress: str | None = None,
) -> ConsensusEngine:
    """DC-ELM over mesh neighbors (the ppermute production path)."""
    mixer = PpermuteMixer.for_mesh(mesh, spec, compress=compress)
    return ConsensusEngine(mixer, DCELMRule(mixer.num_nodes, C))


def simulated_averaging(
    adjacency, *, compress: str | None = None
) -> ConsensusEngine:
    """Plain consensus averaging / D-PSGD mixing on a dense adjacency."""
    return ConsensusEngine(
        DenseMixer(adjacency, compress=compress), AverageRule()
    )


def sharded_averaging(
    spec: gossip.GossipSpec,
    axis_sizes: dict,
    *,
    mesh: jax.sharding.Mesh | None = None,
    compress: str | None = None,
) -> ConsensusEngine:
    """Plain consensus averaging / D-PSGD mixing via ppermute gossip."""
    return ConsensusEngine(
        PpermuteMixer(
            spec=spec, axis_sizes=dict(axis_sizes), mesh=mesh,
            compress=compress,
        ),
        AverageRule(),
    )
