"""ELM random feature maps (the paper's hidden layer h(x)).

The ELM hidden layer is a *frozen random* map
    h(x) = [g(w_1, b_1, x), ..., g(w_L, b_L, x)],  h: R^D -> R^L
with g a nonlinear piecewise-continuous activation (paper Sec. II-A).
All nodes share the same (W, b) (paper Algorithm 1, step 1).

``ACTIVATIONS`` is the one activation registry in the codebase: the
fused feature->moment Pallas kernel (kernels/elm_stats.py) applies the
same callables inside its VMEM tiles that ``FeatureMap.__call__``
applies on materialized arrays, so the two paths cannot drift.

``FeatureMap`` is also the integration point for the "beyond paper"
deep-backbone features (paper Sec. V future work: unknown feature
mappings): models/ provides a FeatureMap whose ``__call__`` runs a
frozen transformer trunk.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Activation = Callable[[jax.Array], jax.Array]

# The shared activation registry (name -> elementwise g). "rbf" is not
# listed here because it is not an affine-then-nonlinearity map — it has
# its own FeatureMap class and kernel branch (see `rbf_squared_dists`).
ACTIVATIONS: dict[str, Activation] = {
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "relu": jax.nn.relu,
    "sin": jnp.sin,
    "identity": lambda x: x,
}

# historical private alias (pre-stats-plane consumers imported this)
_ACTIVATIONS = ACTIVATIONS


def valid_activations() -> tuple[str, ...]:
    """All activation names accepted by make_random_features."""
    return tuple(ACTIVATIONS) + ("rbf",)


@dataclasses.dataclass(frozen=True)
class RandomFeatureMap:
    """Affine-then-nonlinearity random feature map.

    Attributes:
      weights: (D, L) input-to-hidden weights w_l (columns).
      bias: (L,) hidden biases b_l.
      activation: name of g (a key of ``ACTIVATIONS``).
    """

    weights: jax.Array
    bias: jax.Array
    activation: str = "sigmoid"

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"unknown activation {self.activation!r}; "
                f"valid: {sorted(ACTIVATIONS)} "
                "(gaussian hidden nodes are RBFFeatureMap, not a "
                "RandomFeatureMap activation)"
            )

    @property
    def in_dim(self) -> int:
        return self.weights.shape[0]

    @property
    def num_features(self) -> int:
        return self.weights.shape[1]

    def __call__(self, x: jax.Array) -> jax.Array:
        """x: (..., D) -> H: (..., L)."""
        g = ACTIVATIONS[self.activation]
        return g(x @ self.weights + self.bias)


def rbf_squared_dists(
    x: jax.Array, centers: jax.Array, centers_sq: jax.Array | None = None
) -> jax.Array:
    """||x - c||^2 for all centers via ||x||^2 - 2 x.c^T + ||c||^2.

    One (..., L) result from a single (..., D) x (D, L) matmul — never
    the (..., L, D) broadcast intermediate (an HBM blowup at large L*D).
    Clamped at zero: the expansion can go slightly negative in floating
    point when x is near a center. Shared by ``RBFFeatureMap.__call__``
    and the fused kernel's oracle (kernels/elm_stats_ref.py).
    """
    if centers_sq is None:
        centers_sq = jnp.sum(jnp.square(centers), axis=-1)
    x_sq = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    cross = x @ centers.T
    return jnp.maximum(x_sq - 2.0 * cross + centers_sq, 0.0)


@dataclasses.dataclass(frozen=True)
class RBFFeatureMap:
    """Gaussian / RBF hidden nodes g(w, b, x) = exp(-b ||x - w||^2)."""

    centers: jax.Array  # (L, D)
    gamma: jax.Array  # (L,), positive

    @property
    def in_dim(self) -> int:
        return self.centers.shape[1]

    @property
    def num_features(self) -> int:
        return self.centers.shape[0]

    def __call__(self, x: jax.Array) -> jax.Array:
        return jnp.exp(-self.gamma * rbf_squared_dists(x, self.centers))


def make_random_features(
    key: jax.Array,
    in_dim: int,
    num_features: int,
    activation: str = "sigmoid",
    *,
    scale: float = 1.0,
    dtype=jnp.float32,
):
    """Sample the paper's uniform random hidden layer.

    The paper samples (w, b) uniformly; we use U(-scale, scale) for weights
    and U(0, scale) for biases (matching common ELM practice, e.g. Huang
    et al. 2006).
    """
    if activation == "rbf":
        kc, kg = jax.random.split(key)
        centers = jax.random.uniform(
            kc, (num_features, in_dim), minval=-scale, maxval=scale, dtype=dtype
        )
        gamma = jax.random.uniform(
            kg, (num_features,), minval=0.05, maxval=1.0, dtype=dtype
        )
        return RBFFeatureMap(centers=centers, gamma=gamma)
    if activation not in ACTIVATIONS:
        raise ValueError(
            f"unknown activation {activation!r}; valid: {sorted(valid_activations())}"
        )
    kw, kb = jax.random.split(key)
    w = jax.random.uniform(
        kw, (in_dim, num_features), minval=-scale, maxval=scale, dtype=dtype
    )
    b = jax.random.uniform(kb, (num_features,), minval=0.0, maxval=scale, dtype=dtype)
    return RandomFeatureMap(weights=w, bias=b, activation=activation)
