"""Vertically partitioned DC-ELM (arXiv 1602.02899's workload).

The paper splits data by *samples*: node i holds rows (X_i, T_i) and
the stats plane reduces per-node moments (P_i, Q_i). The SMC
privacy-preserving ELM setting splits by *features*: every node holds
the same N rows but only a disjoint column slice X[:, lo_i:hi_i]
(a bank sees balances, a bureau sees scores — same customers). Because
the random feature map is affine before its nonlinearity,

    H = g(X W + b) = g(sum_i X[:, lo_i:hi_i] W[lo_i:hi_i, :] + b),

each node can compute its partial preactivation Z_i = X_i W_i locally
and the network only needs the *sum* of the Z_i before the
nonlinearity — exactly the reduction shape that pairwise-mask secure
aggregation (core/secure.py) protects. After assembly the existing
fused moment kernel (kernels/elm_stats — the ``preact`` variant)
produces (P, Q) and every downstream consumer (finalize, DC-ELM
consensus, online Woodbury streaming, serving) works unchanged.

Bitwise reproducibility: blocked float matmul partial sums are NOT
associative — ``X @ W`` and ``sum_i X_i @ W_i`` differ in the last ulp.
``VerticalFeatureMap`` therefore owns the canonical contraction (a
left fold over node-order partials), so "centralized" and
"distributed" compute the same float sequence and the assembled (P, Q)
match the centralized stats plane bit-for-bit in f64 (pinned in
tests/test_vertical.py). The clear reduction ships *per-origin*
contributions up a BFS spanning tree of the gossip graph so the root
can fold in node order; the secure reduction ships masked fixed-point
partial sums instead — constant message size and exact modular
summation, at the cost of the fixed-point grid (2^-frac_bits).

Crash semantics ride ``consensus.FaultModel``: nodes crashed at the
reduction's start round are excluded from the cohort entirely; a node
(or link) dying mid-reduction drops every origin whose up-tree path is
broken, and in secure mode the aggregator reconstructs exactly the
dropped pairs' mask streams (``SecureAggregator.residual_mask``) so
the surviving sum is still exact — the masked-sum == unmasked-sum
property tests/test_secure.py pins for arbitrary surviving subsets.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats as stats_lib
from repro.core.compression import WireStats
from repro.core.consensus import FaultModel, Graph
from repro.core.features import ACTIVATIONS, RandomFeatureMap
from repro.core.secure import SecureAggregationSpec, SecureAggregator


# ---------------------------------------------------------------------------
# Column partition + the canonical feature map
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColumnPartition:
    """Disjoint, covering column slices: node i owns [bounds[i], bounds[i+1])."""

    bounds: tuple[int, ...]

    def __post_init__(self):
        b = tuple(int(x) for x in self.bounds)
        if len(b) < 2 or b[0] != 0:
            raise ValueError(
                f"bounds must start at 0 and delimit >= 1 slice, got {b}"
            )
        if any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"bounds must be strictly increasing (empty column "
                f"slices are not allowed), got {b}"
            )
        object.__setattr__(self, "bounds", b)

    @property
    def num_nodes(self) -> int:
        return len(self.bounds) - 1

    @property
    def in_dim(self) -> int:
        return self.bounds[-1]

    def cols(self, i: int) -> tuple[int, int]:
        return self.bounds[i], self.bounds[i + 1]

    @property
    def widths(self) -> tuple[int, ...]:
        return tuple(
            self.bounds[i + 1] - self.bounds[i]
            for i in range(self.num_nodes)
        )

    @classmethod
    def even(cls, in_dim: int, num_nodes: int) -> "ColumnPartition":
        """Split D columns as evenly as possible over V nodes."""
        if not 1 <= num_nodes <= in_dim:
            raise ValueError(
                f"need 1 <= num_nodes <= in_dim, got V={num_nodes} "
                f"over D={in_dim} columns"
            )
        base, extra = divmod(in_dim, num_nodes)
        bounds, at = [0], 0
        for i in range(num_nodes):
            at += base + (1 if i < extra else 0)
            bounds.append(at)
        return cls(tuple(bounds))

    @classmethod
    def from_widths(cls, widths) -> "ColumnPartition":
        bounds, at = [0], 0
        for w in widths:
            at += int(w)
            bounds.append(at)
        return cls(tuple(bounds))

    def split(self, X: jax.Array) -> list[jax.Array]:
        """Row-aligned column slices [X[:, lo_i:hi_i]] for all nodes."""
        if X.shape[-1] != self.in_dim:
            raise ValueError(
                f"X has {X.shape[-1]} columns, partition covers "
                f"{self.in_dim}"
            )
        return [
            X[..., lo:hi]
            for lo, hi in (self.cols(i) for i in range(self.num_nodes))
        ]


@dataclasses.dataclass(frozen=True)
class VerticalFeatureMap:
    """A ``RandomFeatureMap`` whose contraction is column-blocked.

    Owns the *canonical* preactivation order: a left fold over the
    node-order partials Z_i = X_i W_i. Distributed assembly replays
    exactly this fold at the reduction root, so centralized-vs-
    distributed parity is bitwise rather than "up to float
    reassociation". Implements the feature-map interface
    (``in_dim``/``num_features``/``__call__``), so the serving plane
    and the stats plane's materialize path consume it unchanged;
    ``stats.fusable_params`` returns None for it by design — fusing
    X @ W in one pass is precisely what vertical mode cannot do.
    """

    base: RandomFeatureMap
    partition: ColumnPartition

    def __post_init__(self):
        if not isinstance(self.base, RandomFeatureMap):
            raise ValueError(
                "vertical mode needs an affine feature map (g(XW + b)); "
                f"got {type(self.base).__name__} — RBF/gaussian nodes "
                "have no additive preactivation to assemble"
            )
        if self.partition.in_dim != self.base.in_dim:
            raise ValueError(
                f"partition covers {self.partition.in_dim} columns, "
                f"feature map expects {self.base.in_dim}"
            )

    @property
    def in_dim(self) -> int:
        return self.base.in_dim

    @property
    def num_features(self) -> int:
        return self.base.num_features

    @property
    def activation(self) -> str:
        return self.base.activation

    @property
    def num_nodes(self) -> int:
        return self.partition.num_nodes

    @property
    def bias(self) -> jax.Array:
        return self.base.bias

    def weight_shard(self, i: int) -> jax.Array:
        """Node i's (hi - lo, L) weight rows — all it ever needs."""
        lo, hi = self.partition.cols(i)
        return self.base.weights[lo:hi]

    def partial_preactivation(self, i: int, X_i: jax.Array) -> jax.Array:
        """Z_i = X_i W_i, node i's local share of the preactivation."""
        lo, hi = self.partition.cols(i)
        if X_i.shape[-1] != hi - lo:
            raise ValueError(
                f"node {i} owns columns [{lo}, {hi}) ({hi - lo} wide), "
                f"got a slice with {X_i.shape[-1]} columns"
            )
        return X_i @ self.weight_shard(i)

    @staticmethod
    def assemble(partials) -> jax.Array:
        """The canonical left fold sum_i Z_i, in node order."""
        partials = list(partials)
        z = partials[0]
        for p in partials[1:]:
            z = z + p
        return z

    def preactivation(self, X: jax.Array) -> jax.Array:
        """Z for full-width rows, via the same column-blocked fold."""
        return self.assemble(
            self.partial_preactivation(i, x)
            for i, x in enumerate(self.partition.split(X))
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        g = ACTIVATIONS[self.activation]
        return g(self.preactivation(x) + self.base.bias)

    @classmethod
    def from_shards(
        cls, shards, bias: jax.Array, activation: str = "sigmoid"
    ) -> "VerticalFeatureMap":
        """Assemble the serving map from per-node weight shards.

        shards: node-order list of (d_i, L) weight slices — what each
        party holds locally. Concatenation recovers the full (D, L)
        map, so a trained vertical federation can stand up the serving
        plane (``serving.ELMServer``) on pooled shards + the consensus
        beta without any party ever having seen another's columns.
        """
        shards = [jnp.asarray(s) for s in shards]
        widths = [s.shape[0] for s in shards]
        base = RandomFeatureMap(
            weights=jnp.concatenate(shards, axis=0),
            bias=jnp.asarray(bias),
            activation=activation,
        )
        return cls(base=base, partition=ColumnPartition.from_widths(widths))


def make_vertical_map(
    key, in_dim: int, num_features: int, num_nodes: int,
    *, activation: str = "sigmoid", scale: float = 1.0, dtype=jnp.float32,
    partition: ColumnPartition | None = None,
) -> VerticalFeatureMap:
    """A partitioned random map (paper-style U(-1,1) weights).

    ``partition`` defaults to an even column split; pass a
    ``ColumnPartition.from_widths(...)`` for uneven feature ownership
    (its widths must sum to ``in_dim`` and cover ``num_nodes`` nodes).
    """
    if partition is None:
        partition = ColumnPartition.even(in_dim, num_nodes)
    if partition.in_dim != in_dim or partition.num_nodes != num_nodes:
        raise ValueError(
            f"partition covers {partition.num_nodes} node(s) over "
            f"{partition.in_dim} column(s); expected {num_nodes} over "
            f"{in_dim}"
        )
    from repro.core.features import make_random_features

    base = make_random_features(
        key, in_dim, num_features, activation=activation, scale=scale,
        dtype=dtype,
    )
    return VerticalFeatureMap(base=base, partition=partition)


# ---------------------------------------------------------------------------
# Spanning-tree reduction over the gossip graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpanningTree:
    """BFS tree of the gossip graph, rooted at the aggregator node."""

    root: int
    parent: tuple[int, ...]  # parent[v], -1 for the root
    depth: tuple[int, ...]

    @property
    def num_nodes(self) -> int:
        return len(self.parent)

    @property
    def max_depth(self) -> int:
        return max(self.depth)

    def children(self, v: int) -> list[int]:
        return [u for u, p in enumerate(self.parent) if p == v]

    @classmethod
    def bfs(cls, graph: Graph, root: int = 0) -> "SpanningTree":
        V = graph.num_nodes
        parent = [-1] * V
        depth = [-1] * V
        depth[root] = 0
        q = deque([root])
        while q:
            v = q.popleft()
            for u in sorted(int(x) for x in graph.neighbors(v)):
                if depth[u] < 0:
                    depth[u] = depth[v] + 1
                    parent[u] = v
                    q.append(u)
        if min(depth) < 0:
            missing = [v for v in range(V) if depth[v] < 0]
            raise ValueError(
                f"graph is disconnected: nodes {missing} unreachable "
                f"from root {root}; vertical assembly needs every "
                "column slice"
            )
        return cls(root=root, parent=tuple(parent), depth=tuple(depth))


@dataclasses.dataclass(frozen=True)
class ReduceReport:
    """What one vertical reduction did on the wire.

    delivered: origins whose partial reached the root (root included).
    dropped:   cohort members whose path was broken mid-reduction.
    excluded:  nodes crashed before the reduction started (never in
               the mask cohort).
    wire:      exact byte accounting (convergecast + broadcast).
    payloads:  captured wire messages {(src, dst): array} when
               ``capture_payloads=True`` — what an eavesdropper on
               every link sees; the privacy tests grep these.
    """

    delivered: tuple[int, ...]
    dropped: tuple[int, ...]
    excluded: tuple[int, ...]
    wire: WireStats
    payloads: dict | None = None


def _crashed_at(faults: FaultModel | None, node: int, rnd: int) -> bool:
    if faults is None:
        return False
    return any(
        c.node == node and c.start <= rnd < c.start + c.duration
        for c in faults.crashes
    )


def reduce_partials(
    partials,
    graph: Graph,
    *,
    secure: SecureAggregator | SecureAggregationSpec | None = None,
    faults: FaultModel | None = None,
    start_round: int = 0,
    root: int = 0,
    capture_payloads: bool = False,
) -> tuple[jax.Array, ReduceReport]:
    """Sum per-node partials over a BFS tree of ``graph``; broadcast back.

    partials: node-order list of (N, L) arrays (one per graph node).

    Clear mode forwards *per-origin* contributions so the root can
    left-fold in node order — bitwise reproducible, message size grows
    toward the root. Secure mode forwards one masked fixed-point
    partial sum per hop — constant message size, exact modular
    summation, payloads indistinguishable from noise (core/secure.py).

    Scheduling: a node at tree depth d sends at round
    ``start_round + (max_depth - d)``, i.e. after all its children. An
    origin is delivered iff every hop node on its path is alive and
    every hop edge is kept (``FaultModel``) at that hop's send round.
    Dropped origins simply do not contribute; in secure mode the
    aggregator additionally reconstructs and subtracts the dropped
    pairs' mask residue (crash recovery). The down-tree broadcast of
    the assembled sum is accounted on the wire but assumed retried to
    success (one extra ``max_depth`` rounds).
    """
    partials = [jnp.asarray(p) for p in partials]
    V = graph.num_nodes
    if len(partials) != V:
        raise ValueError(
            f"{len(partials)} partials for a {V}-node graph"
        )
    shape = partials[0].shape
    if any(p.shape != shape for p in partials):
        raise ValueError(
            f"partials disagree on shape: {[p.shape for p in partials]}"
        )
    tree = SpanningTree.bfs(graph, root=root)
    depth_rounds = max(tree.max_depth, 1)

    if _crashed_at(faults, root, start_round):
        raise ValueError(
            f"aggregator node {root} is crashed at round {start_round}; "
            "re-root the reduction on a live node"
        )
    excluded = tuple(
        v for v in range(V) if _crashed_at(faults, v, start_round)
    )
    cohort = [v for v in range(V) if v not in excluded]

    # per-node send round: children strictly before parents
    send_round = {
        v: start_round + (tree.max_depth - tree.depth[v])
        for v in range(V)
    }
    keep = None
    if faults is not None:
        keep = faults.edge_keep(start_round + depth_rounds + 1)

    def hop_ok(v: int) -> bool:
        """Can v push its buffer one hop up at its send round?"""
        p = tree.parent[v]
        if v in excluded or p in excluded:
            return False
        r = send_round[v]
        if _crashed_at(faults, v, r) or _crashed_at(faults, p, r):
            return False
        return keep is None or bool(keep[r, v, p] > 0)

    delivered = []
    for v in cohort:
        path_ok, at = True, v
        while at != root:
            if not hop_ok(at):
                path_ok = False
                break
            at = tree.parent[at]
        if path_ok:
            delivered.append(v)
    dropped = tuple(v for v in cohort if v not in delivered)

    agg = None
    if secure is not None and len(cohort) >= 2:
        if isinstance(secure, SecureAggregator):
            agg = SecureAggregator(secure.spec, tuple(cohort))
        else:
            agg = SecureAggregator(
                SecureAggregationSpec.parse(secure), tuple(cohort)
            )

    num_vals = int(np.prod(shape))
    captured: dict | None = {} if capture_payloads else None

    # ---- convergecast (simulated per-edge, leaves first) --------------
    links_live = links_sent = bytes_up = 0
    per_round = np.zeros(depth_rounds + tree.max_depth, np.int64)
    by_depth = sorted(
        (v for v in cohort if v != root),
        key=lambda v: -tree.depth[v],
    )

    def edge_live(v: int) -> bool:
        p = tree.parent[v]
        if v in excluded or p in excluded:
            return False
        r = send_round[v]
        return keep is None or bool(keep[r, v, p] > 0)

    if agg is None:
        # buffers hold {origin: partial}; root folds in node order
        buffers = {v: {v: partials[v]} for v in cohort}
        for v in by_depth:
            links_live += edge_live(v)
            if hop_ok(v) and buffers[v]:
                msg = buffers[v]
                links_sent += 1
                nbytes = len(msg) * num_vals * partials[v].dtype.itemsize
                bytes_up += nbytes
                per_round[send_round[v] - start_round] += nbytes
                if captured is not None:
                    captured[(v, tree.parent[v])] = {
                        o: np.asarray(z) for o, z in msg.items()
                    }
                buffers[tree.parent[v]].update(msg)
                buffers[v] = {}
        root_buf = buffers[root]
        Z = VerticalFeatureMap.assemble(
            root_buf[o] for o in sorted(root_buf)
        )
    else:
        tag = start_round
        codes = {
            v: agg.mask(v, np.asarray(partials[v], np.float64), tag=tag)
            for v in cohort
        }
        buffers = {v: [codes[v]] for v in cohort}
        for v in by_depth:
            links_live += edge_live(v)
            if hop_ok(v) and buffers[v]:
                msg = SecureAggregator.masked_partial_sum(buffers[v])
                links_sent += 1
                nbytes = agg.payload_bytes(num_vals)
                bytes_up += nbytes
                per_round[send_round[v] - start_round] += nbytes
                if captured is not None:
                    captured[(v, tree.parent[v])] = msg
                buffers[tree.parent[v]].append(msg)
                buffers[v] = []
        total = SecureAggregator.masked_partial_sum(buffers[root])
        if dropped:
            total = total - agg.residual_mask(
                delivered, dropped, num_vals, tag=tag
            ).reshape(shape)
        from repro.core.secure import decode_fixed

        Z = jnp.asarray(
            decode_fixed(total, agg.spec.frac_bits), partials[0].dtype
        )

    # ---- broadcast of the assembled Z back down the tree --------------
    down_bytes = 0
    zbytes = num_vals * Z.dtype.itemsize
    for v in cohort:
        if v == root:
            continue
        down_bytes += zbytes
        links_live += 1
        links_sent += 1
        per_round[depth_rounds + tree.depth[v] - 1] += zbytes

    # the uncompressed baseline: what the same live links would have
    # moved under the clear per-origin scheme at f64
    clear_item = np.dtype(np.float64).itemsize
    uncompressed = 0
    for v in cohort:
        if v == root:
            continue
        # one per-origin message carrying its delivered subtree
        sub = sum(
            1
            for o in delivered
            if o != root and _on_path(tree, o, v)
        )
        uncompressed += sub * num_vals * clear_item
    uncompressed += (len(cohort) - 1) * zbytes

    wire = WireStats(
        rounds=depth_rounds + tree.max_depth,
        links_live=links_live,
        links_sent=links_sent,
        bytes_on_wire=bytes_up + down_bytes,
        bytes_uncompressed=uncompressed,
        per_round_bytes=per_round,
    )
    report = ReduceReport(
        delivered=tuple(sorted(set(delivered) | {root})),
        dropped=dropped,
        excluded=excluded,
        wire=wire,
        payloads=captured,
    )
    return Z, report


def _on_path(tree: SpanningTree, origin: int, via: int) -> bool:
    """True if origin's up-tree path passes through (or starts at) via."""
    at = origin
    while at != tree.root:
        if at == via:
            return True
        at = tree.parent[at]
    return False


# ---------------------------------------------------------------------------
# The vertical stats plane
# ---------------------------------------------------------------------------


def _check_slices(X_slices, fmap: VerticalFeatureMap):
    if len(X_slices) != fmap.num_nodes:
        raise ValueError(
            f"{len(X_slices)} column slices for a {fmap.num_nodes}-node "
            "partition"
        )
    rows = {int(x.shape[0]) for x in X_slices}
    if len(rows) > 1:
        raise ValueError(
            f"column slices must be row-aligned (same samples on every "
            f"node); got row counts {sorted(rows)}"
        )


def vertical_stats(
    X_slices,
    T: jax.Array,
    fmap: VerticalFeatureMap,
    *,
    graph: Graph | None = None,
    secure=None,
    faults: FaultModel | None = None,
    start_round: int = 0,
    root: int = 0,
    dtype=None,
    use_kernel: bool | None = None,
    capture_payloads: bool = False,
    **kw,
) -> tuple[stats_lib.SufficientStats, ReduceReport]:
    """(P, Q, ||T||^2) from column-sliced nodes — the vertical plane.

    Each node contributes Z_i = X_i W_i; the spanning-tree reduction
    assembles Z = sum_i Z_i (masked fixed-point when ``secure`` is
    set), and the fused preactivation->moment kernel
    (``kernels.elm_stats_ops.fused_preact_moments``) produces the
    moments without materializing H — the f64 fidelity path
    materializes H = g(Z + b) instead, matching ``stats.raw_moments``'s
    dtype policy so clear-mode vertical equals the centralized
    horizontal plane on the same ``VerticalFeatureMap`` bit-for-bit.
    """
    _check_slices(X_slices, fmap)
    if T.ndim == 1:
        T = T[:, None]
    if graph is None:
        from repro.core.consensus import complete

        graph = complete(fmap.num_nodes)
    partials = [
        fmap.partial_preactivation(i, x) for i, x in enumerate(X_slices)
    ]
    Z, report = reduce_partials(
        partials, graph, secure=secure, faults=faults,
        start_round=start_round, root=root,
        capture_payloads=capture_payloads,
    )
    dtype = (
        stats_lib.accum_dtype(Z, T) if dtype is None else jnp.dtype(dtype)
    )
    if dtype == jnp.float32:
        from repro.kernels import elm_stats_ops

        P, Q = elm_stats_ops.fused_preact_moments(
            Z, fmap.bias, T, activation=fmap.activation,
            use_kernel=use_kernel, **kw,
        )
    else:
        H = ACTIVATIONS[fmap.activation](Z + fmap.bias)
        P, Q = stats_lib.hidden_moments(H, T, dtype=dtype)
    Tf = T.astype(dtype)
    s = stats_lib.SufficientStats(
        P=P.astype(dtype),
        Q=Q.astype(dtype),
        t_sq=jnp.sum(Tf * Tf),
        count=jnp.asarray(T.shape[0], dtype),
    )
    return s, report


def vertical_train(
    X_slices,
    T: jax.Array,
    fmap: VerticalFeatureMap,
    C: float,
    **kw,
) -> tuple[jax.Array, stats_lib.SufficientStats, ReduceReport]:
    """Centralized-equivalent ridge readout from column-sliced nodes.

    Returns (beta, stats, report): beta = (I/C + P)^{-1} Q via the
    stats plane's Cholesky solve — the solution every DC-ELM node
    converges to (Thm. 2), computed here in one shot at the root.
    """
    s, report = vertical_stats(X_slices, T, fmap, **kw)
    beta = stats_lib.ridge_solve_moments(s.P, s.Q, C)
    return beta, s, report


def _scaled_node_stats(
    s: stats_lib.SufficientStats, C: float, V: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stacked (omegas, Qs, betas) giving every node 1/V of the stats.

    With P_i = P/V and Q_i = Q/V the node init (paper eq. 21) yields
    Omega_i Q_i = (I/(VC) + P/V)^{-1} (Q/V) = (I/C + P)^{-1} Q = beta*
    — every node seeds *at* the centralized optimum, so the consensus
    phase only has to hold it there (and absorb streaming updates).
    """
    Pn = s.P / V
    Qn = s.Q / V
    omega = stats_lib.omega_from_moments(Pn, C, V)
    beta = omega @ Qn
    tile = lambda a: jnp.broadcast_to(a, (V,) + a.shape)  # noqa: E731
    return tile(omega), tile(Qn), tile(beta)


def simulate_init(
    X_slices,
    T: jax.Array,
    fmap: VerticalFeatureMap,
    C: float,
    graph: Graph,
    *,
    secure=None,
    faults: FaultModel | None = None,
    **kw,
):
    """Vertical DC-ELM node init — Algorithm 1 steps 1-3, columns-split.

    Returns (DCELMState, SufficientStats, ReduceReport). The stats are
    assembled once over the spanning tree (masked when ``secure``),
    then every node is seeded with the 1/V-scaled moments so the
    existing consensus machinery (``engine.simulated_dc_elm``,
    streaming, faults, compression) composes unchanged on top.
    """
    from repro.core import dc_elm

    s, report = vertical_stats(
        X_slices, T, fmap, graph=graph, secure=secure, faults=faults, **kw
    )
    V = graph.num_nodes
    omegas, Qs, betas = _scaled_node_stats(s, C, V)
    del Qs
    state = dc_elm.DCELMState(
        betas=betas, omegas=omegas, k=jnp.zeros((), jnp.int32)
    )
    return state, s, report


def stream_init(
    eng,
    X_slices,
    T: jax.Array,
    fmap: VerticalFeatureMap,
    *,
    graph: Graph | None = None,
    secure=None,
    faults: FaultModel | None = None,
    **kw,
):
    """Vertical twin of ``ConsensusEngine.stream_init``.

    Returns (StreamState, SufficientStats, ReduceReport). The engine's
    ``secure`` field (``engine.with_secure_aggregation``) is picked up
    when the ``secure=`` argument is not given explicitly.
    """
    from repro.core.engine import StreamState

    C, V = eng._ridge_constants()
    if secure is None:
        secure = getattr(eng, "secure", None)
    s, report = vertical_stats(
        X_slices, T, fmap, graph=graph, secure=secure, faults=faults, **kw
    )
    omegas, Qs, betas = _scaled_node_stats(s, C, V)
    return StreamState(omegas=omegas, Qs=Qs, betas=betas), s, report


def stream_chunk(
    eng,
    state,
    X_new_slices,
    T_new: jax.Array,
    fmap: VerticalFeatureMap,
    *,
    gamma,
    num_iters: int,
    graph: Graph | None = None,
    secure=None,
    faults: FaultModel | None = None,
    start_round: int = 0,
    remove: bool = False,
    publish_to=None,
    dtype=None,
    **kw,
):
    """Online vertical chunk — Algorithm 2 over column-sliced rows.

    New rows arrive at *every* node simultaneously (the same samples,
    each node seeing only its columns). The chunk's preactivation is
    assembled over the tree (masked when ``secure``), then the update
    rides the horizontal machinery exactly: every node folds the
    1/sqrt(V)-scaled hidden chunk into its Woodbury state, which keeps
    the per-node stats at 1/V of the network totals — so the re-seeded
    betas stay at the centralized optimum of the *updated* data.
    ``remove=True`` retires the rows instead (eq. 26).

    Returns ((StreamState, traces), ReduceReport).
    """
    _check_slices(X_new_slices, fmap)
    if T_new.ndim == 1:
        T_new = T_new[:, None]
    if graph is None:
        from repro.core.consensus import complete

        graph = complete(fmap.num_nodes)
    if secure is None:
        secure = getattr(eng, "secure", None)
    partials = [
        fmap.partial_preactivation(i, x)
        for i, x in enumerate(X_new_slices)
    ]
    dZ, report = reduce_partials(
        partials, graph, secure=secure, faults=faults,
        start_round=start_round, **kw,
    )
    dH = ACTIVATIONS[fmap.activation](dZ + fmap.bias)
    if dtype is not None:
        dH = dH.astype(dtype)
    V = graph.num_nodes
    scale = 1.0 / jnp.sqrt(jnp.asarray(float(V), dH.dtype))
    tile = lambda a: jnp.broadcast_to(a, (V,) + a.shape)  # noqa: E731
    chunk = (tile(dH * scale), tile(T_new.astype(dH.dtype) * scale))
    out = eng.stream_chunk(
        state,
        added=None if remove else chunk,
        removed=chunk if remove else None,
        gamma=gamma,
        num_iters=num_iters,
        publish_to=publish_to,
    )
    return out, report
