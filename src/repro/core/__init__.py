"""Core: the paper's contribution (DC-ELM and friends) in JAX.

Modules:
  features    random ELM feature maps h(x) (+ the activation registry)
  async_engine event-driven push-sum gossip runtime (no round barrier)
  push_sum    ratio-consensus mass algebra + conservation accounting
  stats       the statistics plane: (P, Q, ||T||^2, Omega) for every
              path — fused feature->moment kernels, chunked
              SufficientStats, Cholesky solves
  elm         centralized ELM (paper Sec. II-A)
  consensus   communication graphs, Laplacians, rates (Sec. III-A)
  dc_elm      DC-ELM Algorithm 1 (simulated + ppermute-sharded)
  online      Online DC-ELM Algorithm 2 (Woodbury updates)
  gossip      ppermute neighbor-exchange primitives
  compression quantized/sparsified gossip payloads + wire accounting
  dsgd        beyond-paper decentralized deep training (paper rule on pytrees)
  incremental Hamiltonian-cycle baseline (Sec. II-B1)
  fusion_elm  fusion-center / MapReduce baseline (refs [17][18])
"""

from repro.core import (  # noqa: F401
    async_engine,
    compression,
    consensus,
    dc_elm,
    dsgd,
    elm,
    features,
    fusion_elm,
    gossip,
    incremental,
    online,
    push_sum,
    stats,
)
