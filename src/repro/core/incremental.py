"""Incremental (Hamiltonian-cycle) baseline — paper Sec. II-B1.

The comparison method the paper argues *against*: a single estimate is
passed node-by-node along a Hamiltonian cycle; each node applies one
(sub)gradient step of its own objective:

    z_{i,k+1} = z_{i-1,k+1} - alpha * grad u_i(z_{i-1,k+1})

For the ELM quadratic u_i(beta) = 1/2||beta||^2 + VC/2||H_i beta - T_i||^2,
grad u_i(beta) = beta + VC (P_i beta - Q_i).

Implemented for completeness so benchmarks can quantify the paper's
claims: one full cycle = V sequential hops (latency V * hop), versus one
DC-ELM round = 1 parallel neighbor exchange.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def node_grad(beta: jax.Array, P_: jax.Array, Q_: jax.Array, VC: float):
    return beta + VC * (P_ @ beta - Q_)


@functools.partial(jax.jit, static_argnames=("num_cycles", "C"))
def run(
    P_: jax.Array,  # (V, L, L)
    Q_: jax.Array,  # (V, L, M)
    alpha: float,
    C: float,
    num_cycles: int,
    beta0: jax.Array | None = None,
    decay: float = 0.0,
):
    """Run num_cycles Hamiltonian cycles; returns the estimate trace.

    The cycle order is node 0, 1, ..., V-1 (identity Hamiltonian path on
    the stacked representation — finding one in a general graph is the
    NP-hard step the paper criticizes; here we simply assume it).

    Cycle k uses the step alpha / (1 + decay * k). The default
    decay=0.0 is the paper's constant-step baseline, which stalls at an
    O(alpha) bias around the optimum; pass decay > 0 (harmonic
    diminishing schedule, the standard incremental-gradient convergence
    condition) when exact convergence is wanted.
    """
    V, L, M = Q_.shape
    VC = V * C
    z0 = jnp.zeros((L, M), P_.dtype) if beta0 is None else beta0

    def cycle(z, k):
        a = alpha / (1.0 + decay * k)

        def hop(z, pq):
            p, q = pq
            return z - a * node_grad(z, p, q, VC), None

        z, _ = lax.scan(hop, z, (P_, Q_))
        return z, z

    zf, trace = lax.scan(cycle, z0, jnp.arange(num_cycles))
    return zf, trace
