"""DC-ELM head training on deep-backbone features, at production scale.

This is the paper's algorithm applied verbatim with h(x) = the frozen
transformer trunk (the paper's §V "unknown feature mapping" future-work
case): every consensus node streams its local token shard through the
shared frozen backbone, accumulates the ELM sufficient statistics

    P_i += h^T h      (the Pallas gram kernel's job on TPU)
    Q_i += h^T onehot(labels)   (segment-sum — no materialized one-hot)

then solves its local ridge system (Omega_i, beta_i(0) = Omega_i Q_i)
and runs the paper's gossip iterations on beta_i over the mesh's
consensus axes. The result is a vocab readout equivalent to training on
the pooled corpus — with no raw token leaving its node.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import engine as engine_lib
from repro.core import mixers
from repro.core import stats as stats_lib
from repro.distributed import sharding as shd
from repro.models import Model


class ELMHeadStats(NamedTuple):
    P: jax.Array  # (V, d, d) f32
    Q: jax.Array  # (V, d, vocab) f32
    count: jax.Array  # (V,) samples seen per node


@dataclasses.dataclass(frozen=True)
class ELMHeadBundle:
    init_stats: object
    accumulate_fn: object  # (stats, backbone_params, batch) -> stats
    solve_fn: object  # (stats, C) -> (omegas, betas)
    gossip_fn: object  # (betas, omegas, gamma, iters, C) -> betas
    stats_shardings: object
    node_count: int
    gamma_bound: float


def make_elm_head_bundle(
    cfg: ArchConfig, mesh: jax.sharding.Mesh, *, use_kernel: bool | None = None
) -> ELMHeadBundle:
    model = Model(cfg)
    axes = shd.resolve_axes(cfg, mesh)
    V = max(axes.node_count, 1)
    spec = shd.consensus_gossip_spec(cfg, axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d, vocab = cfg.d_model, cfg.vocab_size
    node_spec = (
        axes.node if len(axes.node) > 1 else (axes.node[0] if axes.node else None)
    )
    mspec = axes.model if vocab % axes.model_size() == 0 else None

    stats_pspecs = ELMHeadStats(
        P=P(node_spec, None, axes.model if d % axes.model_size() == 0 else None),
        Q=P(node_spec, None, mspec),
        count=P(node_spec),
    )
    stats_sh = shd.shardings(mesh, stats_pspecs)

    def init_stats():
        return ELMHeadStats(
            P=jnp.zeros((V, d, d), jnp.float32),
            Q=jnp.zeros((V, d, vocab), jnp.float32),
            count=jnp.zeros((V,), jnp.float32),
        )

    def node_stats(backbone_params, node_batch):
        h = model.features(backbone_params, node_batch)  # (b, S, d)
        s = stats_lib.classification_moments(
            h.reshape(-1, d), node_batch["labels"].reshape(-1), vocab,
            use_kernel=use_kernel,
        )
        return s.P, s.Q, s.count

    def accumulate(stats: ELMHeadStats, backbone_params, batch):
        dP, dQ, dc = jax.vmap(node_stats, in_axes=(None, 0))(
            backbone_params, batch
        )
        return ELMHeadStats(
            P=stats.P + dP, Q=stats.Q + dQ, count=stats.count + dc
        )

    def solve(stats: ELMHeadStats, C: float):
        # paper eq. 21 per node, via the statistics plane's Cholesky
        return jax.vmap(
            lambda Pm, Qm: stats_lib.finalize_moments(Pm, Qm, C, V)
        )(stats.P, stats.Q)

    # one mixer for the bundle's lifetime: its _programs cache keys on
    # (rule, iters, specs), so repeated gossip_rounds calls compile once
    mixer = (
        mixers.PpermuteMixer(spec=spec, axis_sizes=sizes, mesh=mesh)
        if spec is not None
        else None
    )

    def gossip_rounds(betas, omegas, gamma, iters: int, C: float):
        """Paper eq. (20) on the mesh consensus axes, via the engine.

        The vocab readout's trailing dim is model-sharded, so the
        engine's sharded scan gets explicit state/aux specs instead of
        the default node-only placement.
        """
        if mixer is None:
            return betas
        eng = engine_lib.ConsensusEngine(mixer, engine_lib.DCELMRule(V, C))
        final, _ = eng.run(
            betas,
            omegas,
            gamma,
            iters,
            state_spec=P(node_spec, None, mspec),
            # Omega contracts over its full (d, d) block inside the
            # shard_map, so it must enter replicated over "model" even
            # when its at-rest storage (stats_pspecs.P) is model-sharded
            aux_spec=P(node_spec, None, None),
        )
        return final

    return ELMHeadBundle(
        init_stats=init_stats,
        accumulate_fn=accumulate,
        solve_fn=solve,
        gossip_fn=gossip_rounds,
        stats_shardings=stats_sh,
        node_count=V,
        gamma_bound=(
            spec.gamma_upper_bound(sizes) if spec is not None else float("inf")
        ),
    )
