"""Decentralized consensus training for deep networks (beyond-paper).

The paper's consensus rule, applied to arbitrary parameter pytrees:
after each local optimizer step, every node mixes its parameters with
its graph neighbors,

    theta_i <- theta_i + gamma * sum_{j in N_i} a_ij (theta_j - theta_i)

(the paper's eq. 20 with identity metric in place of Omega_i — for deep
nets the objective is non-quadratic so the exact ELM preconditioner has
no closed form; this recovers D-PSGD-style decentralized SGD, the
modern descendant of the paper's scheme). gamma < 1/d_max still governs
stability of the mixing step.

Both paths run through the ConsensusEngine (core/engine.py) with the
``AverageRule`` — the same driver DC-ELM uses, with the identity metric
in place of Omega_i:
  * simulated — stacked leading node axis + ``DenseMixer`` (tests,
    small experiments);
  * sharded — ``PpermuteMixer`` inside shard_map; this is what
    launch/train.py lowers for the assigned architectures, with each
    consensus node's replica further sharded over the "model" axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.consensus import Graph
from repro.core.engine import (
    ConsensusEngine,
    simulated_averaging,
    sharded_averaging,
)
from repro.optim.optimizers import Optimizer, apply_updates


class DSGDState(NamedTuple):
    params: object  # pytree, each leaf (V, ...) in the simulated path
    opt_state: object


def mix_simulated(stacked, adjacency: jax.Array, gamma, compress=None) -> object:
    """Paper mixing rule on a stacked pytree (leading axis = node)."""
    return simulated_averaging(adjacency, compress=compress).step(
        stacked, None, gamma
    )


def mix_sharded(
    params, gamma, spec: gossip.GossipSpec, axis_sizes, compress=None
) -> object:
    """Paper mixing rule inside shard_map (one replica per consensus node)."""
    return sharded_averaging(spec, axis_sizes, compress=compress).step(
        params, None, gamma
    )


def make_simulated_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    graph: Graph | None = None,
    gamma: float | None = None,
    *,
    engine: ConsensusEngine | None = None,
):
    """Build a jitted decentralized train step for the simulated path.

    loss_fn(params, batch) -> scalar; params is one node's pytree.
    State params/opt_state carry a leading V axis; batches are (V, ...).
    Pass either a ``graph`` (an AverageRule engine is built for it) or a
    ready-made ``engine`` (e.g. with gossip compression).
    """
    if engine is None:
        if graph is None:
            raise ValueError("need a graph or an engine")
        engine = simulated_averaging(
            jnp.asarray(graph.adjacency, jnp.float32)
        )
    if gamma is None:
        if graph is not None:
            gamma = graph.default_gamma()
        else:
            gamma = engine.mixer.default_gamma()

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))
    v_update = jax.vmap(optimizer.update)

    @jax.jit
    def step(state: DSGDState, batch):
        losses, grads = grad_fn(state.params, batch)
        updates, opt_state = v_update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        params = engine.step(params, None, gamma)
        return DSGDState(params, opt_state), losses

    return step


def init_simulated(key, init_fn: Callable, optimizer: Optimizer, V: int):
    """Identical initial replicas on every node (consensus start).

    init_fn(key) -> params pytree for one node.
    """
    params = init_fn(key)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (V,) + x.shape), params)
    opt_state = jax.vmap(optimizer.init)(stacked)
    return DSGDState(stacked, opt_state)


def consensus_distance(stacked_params) -> jax.Array:
    """Max relative distance of node replicas from the mean replica."""
    num = 0.0
    den = 0.0
    for x in jax.tree.leaves(stacked_params):
        x2 = x.reshape(x.shape[0], -1).astype(jnp.float32)
        mean = jnp.mean(x2, 0, keepdims=True)
        num = num + jnp.sum((x2 - mean) ** 2, axis=1)
        den = den + jnp.sum(mean**2)
    return jnp.sqrt(jnp.max(num)) / (1.0 + jnp.sqrt(den))


@dataclasses.dataclass(frozen=True)
class DSGDConfig:
    """Knobs for the sharded decentralized trainer (launch/train.py)."""

    gossip_axes: tuple[str, ...] = ("data",)
    gossip_kinds: tuple[str, ...] = ("ring",)
    gamma: float | None = None  # None -> 0.9 / d_max
    mix_every: int = 1  # mix every k optimizer steps (beyond-paper knob)
    compress: str | None = None  # gossip payload compression ("bf16")

    def spec(self) -> gossip.GossipSpec:
        return gossip.GossipSpec(axes=self.gossip_axes, kinds=self.gossip_kinds)

    def resolved_gamma(self, axis_sizes) -> float:
        if self.gamma is not None:
            return self.gamma
        return 0.9 * self.spec().gamma_upper_bound(axis_sizes)
