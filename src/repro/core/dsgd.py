"""Decentralized consensus training for deep networks (beyond-paper).

The paper's consensus rule, applied to arbitrary parameter pytrees:
after each local optimizer step, every node mixes its parameters with
its graph neighbors,

    theta_i <- theta_i + gamma * sum_{j in N_i} a_ij (theta_j - theta_i)

(the paper's eq. 20 with identity metric in place of Omega_i — for deep
nets the objective is non-quadratic so the exact ELM preconditioner has
no closed form; this recovers D-PSGD-style decentralized SGD, the
modern descendant of the paper's scheme). gamma < 1/d_max still governs
stability of the mixing step.

Two paths again:
  * simulated — stacked leading node axis + dense adjacency (tests,
    small experiments);
  * sharded — gossip.neighbor_laplacian under shard_map; this is what
    launch/train.py lowers for the assigned architectures, with each
    consensus node's replica further sharded over the "model" axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.consensus import Graph
from repro.optim.optimizers import Optimizer, apply_updates


class DSGDState(NamedTuple):
    params: object  # pytree, each leaf (V, ...) in the simulated path
    opt_state: object


def _compress(x, mode):
    """Gossip payload compression (paper Sec. V future work: 'reduction
    of the amount of information exchanging'). 'bf16' halves every
    neighbor message; the Laplacian delta is applied back in the
    original dtype, so quantization error enters only through the
    (bounded, gamma-scaled) mixing term."""
    if mode is None:
        return x
    if mode == "bf16":
        return x.astype(jnp.bfloat16)
    raise ValueError(f"unknown gossip compression {mode!r}")


def mix_simulated(stacked, adjacency: jax.Array, gamma, compress=None) -> object:
    """Paper mixing rule on a stacked pytree (leading axis = node)."""

    def leaf(x):
        x2 = _compress(x.reshape(x.shape[0], -1), compress)
        mixed = (
            adjacency @ x2.astype(jnp.float32)
            - jnp.sum(adjacency, 1)[:, None] * x2.astype(jnp.float32)
        )
        out = x.reshape(x.shape[0], -1) + gamma * mixed.astype(x.dtype)
        return out.reshape(x.shape)

    return jax.tree.map(leaf, stacked)


def mix_sharded(
    params, gamma, spec: gossip.GossipSpec, axis_sizes, compress=None
) -> object:
    """Paper mixing rule inside shard_map (one replica per consensus node)."""
    payload = jax.tree.map(lambda p: _compress(p, compress), params)
    lap = gossip.neighbor_laplacian(payload, spec, axis_sizes)
    return jax.tree.map(
        lambda p, d: p + gamma * d.astype(p.dtype), params, lap
    )


def make_simulated_train_step(
    loss_fn: Callable,
    optimizer: Optimizer,
    graph: Graph,
    gamma: float | None = None,
):
    """Build a jitted decentralized train step for the simulated path.

    loss_fn(params, batch) -> scalar; params is one node's pytree.
    State params/opt_state carry a leading V axis; batches are (V, ...).
    """
    if gamma is None:
        gamma = graph.default_gamma()
    adjacency = jnp.asarray(graph.adjacency, jnp.float32)

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))
    v_update = jax.vmap(optimizer.update)

    @jax.jit
    def step(state: DSGDState, batch):
        losses, grads = grad_fn(state.params, batch)
        updates, opt_state = v_update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        params = mix_simulated(params, adjacency, gamma)
        return DSGDState(params, opt_state), losses

    return step


def init_simulated(key, init_fn: Callable, optimizer: Optimizer, V: int):
    """Identical initial replicas on every node (consensus start).

    init_fn(key) -> params pytree for one node.
    """
    params = init_fn(key)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (V,) + x.shape), params)
    opt_state = jax.vmap(optimizer.init)(stacked)
    return DSGDState(stacked, opt_state)


def consensus_distance(stacked_params) -> jax.Array:
    """Max relative distance of node replicas from the mean replica."""
    num = 0.0
    den = 0.0
    for x in jax.tree.leaves(stacked_params):
        x2 = x.reshape(x.shape[0], -1).astype(jnp.float32)
        mean = jnp.mean(x2, 0, keepdims=True)
        num = num + jnp.sum((x2 - mean) ** 2, axis=1)
        den = den + jnp.sum(mean**2)
    return jnp.sqrt(jnp.max(num)) / (1.0 + jnp.sqrt(den))


@dataclasses.dataclass(frozen=True)
class DSGDConfig:
    """Knobs for the sharded decentralized trainer (launch/train.py)."""

    gossip_axes: tuple[str, ...] = ("data",)
    gossip_kinds: tuple[str, ...] = ("ring",)
    gamma: float | None = None  # None -> 0.9 / d_max
    mix_every: int = 1  # mix every k optimizer steps (beyond-paper knob)
    compress: str | None = None  # gossip payload compression ("bf16")

    def spec(self) -> gossip.GossipSpec:
        return gossip.GossipSpec(axes=self.gossip_axes, kinds=self.gossip_kinds)

    def resolved_gamma(self, axis_sizes) -> float:
        if self.gamma is not None:
            return self.gamma
        return 0.9 * self.spec().gamma_upper_bound(axis_sizes)
