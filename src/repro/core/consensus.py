"""Communication graphs and consensus machinery (paper Sec. III-A).

The network is an undirected, connected, static V-node graph G(V, E)
with adjacency A (a_ii = 0, a_ij > 0 iff (i,j) in E), degree matrix
D = diag(d_i), Laplacian Lap = D - A. Connectivity <=> lambda_2(Lap) > 0
(algebraic connectivity). The DC-ELM step size must satisfy
0 < gamma < 1/d_max (paper Thm. 2).

Two families of graphs:
  * "simulation" graphs — anything, incl. the paper's random geometric
    graphs (Fig. 6); used by the vmap-simulated DC-ELM and fidelity
    benchmarks.
  * "ICI-realizable" graphs — ring / 2-D torus / hypercube / complete —
    whose edge sets decompose into a handful of device permutations, so
    the sharded path lowers to jax.lax.ppermute schedules (see
    core/gossip.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected weighted communication graph."""

    adjacency: np.ndarray  # (V, V), symmetric, zero diagonal
    name: str = "graph"

    def __post_init__(self):
        a = self.adjacency
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError("adjacency must be square")
        if not np.allclose(a, a.T):
            raise ValueError("graph must be undirected (A symmetric)")
        if np.any(np.diag(a) != 0):
            raise ValueError("a_ii must be 0")
        if np.any(a < 0):
            raise ValueError("edge weights must be nonnegative")

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    @property
    def d_max(self) -> float:
        return float(self.degrees.max())

    @property
    def laplacian(self) -> np.ndarray:
        return np.diag(self.degrees) - self.adjacency

    @property
    def algebraic_connectivity(self) -> float:
        """lambda_2 of the Laplacian; > 0 iff connected."""
        eig = np.linalg.eigvalsh(self.laplacian)
        return float(eig[1])

    @property
    def is_connected(self) -> bool:
        return self.algebraic_connectivity > 1e-9

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    def gamma_upper_bound(self) -> float:
        """Paper Thm. 2: 0 < gamma < 1/d_max."""
        return 1.0 / self.d_max

    def default_gamma(self, safety: float = 0.9) -> float:
        return safety * self.gamma_upper_bound()

    def metropolis_weights(self) -> np.ndarray:
        """Doubly-stochastic Metropolis–Hastings mixing weights.

        Not used by the paper's algorithm (which mixes with the raw
        Laplacian), but used by the beyond-paper D-PSGD trainer where a
        doubly-stochastic W gives the standard decentralized-SGD
        guarantees.
        """
        a = (self.adjacency > 0).astype(np.float64)
        deg = a.sum(1)
        W = np.zeros_like(a)
        V = self.num_nodes
        for i in range(V):
            for j in range(V):
                if i != j and a[i, j] > 0:
                    W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        for i in range(V):
            W[i, i] = 1.0 - W[i].sum()
        return W


# ---------------------------------------------------------------------------
# Graph constructors
# ---------------------------------------------------------------------------


def line(V: int) -> Graph:
    a = np.zeros((V, V))
    for i in range(V - 1):
        a[i, i + 1] = a[i + 1, i] = 1.0
    return Graph(a, name=f"line{V}")


def ring(V: int) -> Graph:
    if V < 3:
        return line(V)
    a = np.zeros((V, V))
    for i in range(V):
        j = (i + 1) % V
        a[i, j] = a[j, i] = 1.0
    return Graph(a, name=f"ring{V}")


def complete(V: int) -> Graph:
    a = np.ones((V, V)) - np.eye(V)
    return Graph(a, name=f"complete{V}")


def star(V: int) -> Graph:
    """Fusion-center-like topology (for contrast experiments)."""
    a = np.zeros((V, V))
    a[0, 1:] = a[1:, 0] = 1.0
    return Graph(a, name=f"star{V}")


def torus2d(rows: int, cols: int) -> Graph:
    """2-D torus — matches TPU ICI physical topology."""
    V = rows * cols
    a = np.zeros((V, V))

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for j in (idx(r + 1, c), idx(r, c + 1)):
                if i != j:
                    a[i, j] = a[j, i] = 1.0
    return Graph(a, name=f"torus{rows}x{cols}")


def hypercube(dim: int) -> Graph:
    """2^dim-node hypercube: log-diameter, great algebraic connectivity."""
    V = 1 << dim
    a = np.zeros((V, V))
    for i in range(V):
        for b in range(dim):
            j = i ^ (1 << b)
            a[i, j] = a[j, i] = 1.0
    return Graph(a, name=f"hypercube{dim}")


def paper_fig2() -> Graph:
    """The paper's Fig. 2 network: V=4, d_max=2 (a 4-cycle)."""
    return Graph(ring(4).adjacency, name="paper_fig2")


def alternating_halves(V: int) -> list[Graph]:
    """A jointly-connected time-varying sequence whose snapshots are each
    DISCONNECTED: round 0 links even pairs (0-1)(2-3)..., round 1 links
    odd pairs (1-2)(3-4)... plus the wrap edge. The union is the V-ring.
    Exercises the paper's Sec. V time-varying-topology future work."""
    a0 = np.zeros((V, V))
    a1 = np.zeros((V, V))
    for i in range(0, V - 1, 2):
        a0[i, i + 1] = a0[i + 1, i] = 1.0
    for i in range(1, V - 1, 2):
        a1[i, i + 1] = a1[i + 1, i] = 1.0
    if V % 2 == 0 and V > 2:
        a1[0, V - 1] = a1[V - 1, 0] = 1.0
    return [Graph(a0, name=f"even_pairs{V}"), Graph(a1, name=f"odd_pairs{V}")]


def random_geometric(
    V: int, radius: float, seed: int = 0, max_tries: int = 200
) -> Graph:
    """Random geometric graph on the unit square (paper Fig. 6 style).

    Nodes connect iff closer than `radius`. Resamples until connected.
    """
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        pts = rng.uniform(size=(V, 2))
        d = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
        a = ((d < radius) & ~np.eye(V, dtype=bool)).astype(np.float64)
        g = Graph(a, name=f"rgg{V}")
        if g.is_connected:
            return g
    raise RuntimeError(f"no connected RGG after {max_tries} tries; grow radius")


_BUILDERS = {
    "line": line,
    "ring": ring,
    "complete": complete,
    "star": star,
    "hypercube": hypercube,
}


def build(kind: str, V: int) -> Graph:
    """Build a named topology with V nodes (used by config files)."""
    if kind == "hypercube":
        dim = int(np.log2(V))
        if 1 << dim != V:
            raise ValueError(f"hypercube needs power-of-two V, got {V}")
        return hypercube(dim)
    if kind == "torus":
        r = int(np.sqrt(V))
        while V % r:
            r -= 1
        return torus2d(r, V // r)
    if kind in _BUILDERS:
        return _BUILDERS[kind](V)
    raise ValueError(f"unknown graph kind {kind!r}")


# ---------------------------------------------------------------------------
# Fault injection (paper Sec. V: time-varying / unreliable links)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkOutage:
    """A correlated burst outage: edge (i, j) is down for rounds
    [start, start + duration)."""

    edge: tuple[int, int]
    start: int
    duration: int


@dataclasses.dataclass(frozen=True)
class NodeCrash:
    """Node crash/rejoin: every edge incident to ``node`` is down for
    rounds [start, start + duration); the node rejoins afterwards.

    The node's *process* stays up (it keeps its state and local data);
    only its links die — the paper's communication-failure model. Data
    level churn (the node's shard leaving the problem) is
    ``ConsensusEngine.stream_leave``/``stream_join``.
    """

    node: int
    start: int
    duration: int


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Generates per-round edge keep-masks over a base graph.

    Three composable failure processes (all applied on top of each
    other, worst wins):

    * ``edge_drop_prob`` — i.i.d. per-round Bernoulli loss of each
      undirected edge (packet loss / flaky link).
    * ``outages`` — scheduled correlated bursts: a link down for a
      contiguous round interval.
    * ``crashes`` — scheduled node crash/rejoin: all of a node's links
      down for a contiguous round interval.

    ``edge_keep(R)`` is deterministic in ``seed``, so the simulated
    (DenseMixer) and sharded (PpermuteMixer) execution paths of a
    ``FaultyMixer`` can replay the *same* fault trace and be compared
    bit-for-bit-level close. Consumers index round k with mask k % R.

    Theorem 2's convergence survives faults as long as the masked graph
    sequence stays *jointly connected* — every window of W consecutive
    rounds has a connected union graph. ``certify_jointly_connected``
    checks that (cyclically, matching the k % R replay), and
    ``sample_certified`` searches seeds until it holds.
    """

    graph: Graph
    edge_drop_prob: float = 0.0
    outages: tuple[LinkOutage, ...] = ()
    crashes: tuple[NodeCrash, ...] = ()
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.edge_drop_prob < 1.0:
            raise ValueError("edge_drop_prob must be in [0, 1)")
        V = self.graph.num_nodes
        for o in self.outages:
            i, j = o.edge
            if not (0 <= i < V and 0 <= j < V) or i == j:
                raise ValueError(f"bad outage edge {o.edge}")
            if self.graph.adjacency[i, j] == 0:
                # silently accepted before, then erased by the
                # `keep * edges` mask — the outage could never fire
                raise ValueError(
                    f"outage edge {o.edge} is not an edge of "
                    f"{self.graph.name}: the keep-mask is applied over "
                    "the base edge set, so this outage could never fire"
                )
            if o.start < 0 or o.duration < 0:
                raise ValueError(
                    f"outage on {o.edge} has negative start/duration "
                    f"({o.start}, {o.duration}); intervals are "
                    "[start, start + duration) in rounds >= 0"
                )
        for c in self.crashes:
            if not 0 <= c.node < V:
                raise ValueError(f"bad crash node {c.node}")
            if c.start < 0 or c.duration < 0:
                raise ValueError(
                    f"crash of node {c.node} has negative start/duration "
                    f"({c.start}, {c.duration}); intervals are "
                    "[start, start + duration) in rounds >= 0"
                )

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    def edge_keep(self, num_rounds: int) -> np.ndarray:
        """(R, V, V) symmetric 0/1 keep-masks over the base edge set."""
        V = self.num_nodes
        R = int(num_rounds)
        edges = (self.graph.adjacency > 0).astype(np.float64)
        keep = np.ones((R, V, V))
        if self.edge_drop_prob > 0.0:
            rng = np.random.default_rng(self.seed)
            u = rng.random((R, V, V))
            u = np.triu(u, 1)
            u = u + np.transpose(u, (0, 2, 1))  # symmetric per-edge draws
            keep *= (u >= self.edge_drop_prob).astype(np.float64)
        for o in self.outages:
            i, j = o.edge
            lo, hi = max(o.start, 0), min(o.start + o.duration, R)
            keep[lo:hi, i, j] = keep[lo:hi, j, i] = 0.0
        for c in self.crashes:
            lo, hi = max(c.start, 0), min(c.start + c.duration, R)
            keep[lo:hi, c.node, :] = 0.0
            keep[lo:hi, :, c.node] = 0.0
        return keep * edges[None]

    def adjacency_stream(self, num_rounds: int) -> np.ndarray:
        """(R, V, V) masked adjacency snapshots A_k = A * keep_k."""
        return self.edge_keep(num_rounds) * np.asarray(self.graph.adjacency)[None]

    def graphs(self, num_rounds: int) -> list[Graph]:
        return [
            Graph(a, name=f"{self.graph.name}_fault{k}")
            for k, a in enumerate(self.adjacency_stream(num_rounds))
        ]

    def gamma_upper_bound(self) -> float:
        """Faults only *remove* edges, so d_max never grows: the base
        graph's Thm. 2 bound stays valid for every masked snapshot."""
        return self.graph.gamma_upper_bound()

    def certify_jointly_connected(
        self, num_rounds: int, window: int
    ) -> bool:
        """True iff every (cyclic) window of ``window`` consecutive
        masked snapshots has a connected union graph.

        Cyclic because consumers replay mask k % R forever; the fault
        trace is effectively periodic.
        """
        stream = self.adjacency_stream(num_rounds)
        R = stream.shape[0]
        if window <= 0:
            raise ValueError("window must be positive")
        if window >= R:
            union = stream.max(axis=0)
            return Graph(union, name="union").is_connected
        for s in range(R):
            idx = [(s + t) % R for t in range(window)]
            union = stream[idx].max(axis=0)
            if not Graph(union, name="union").is_connected:
                return False
        return True

    @classmethod
    def sample_certified(
        cls,
        graph: Graph,
        edge_drop_prob: float,
        num_rounds: int,
        window: int,
        *,
        outages: tuple[LinkOutage, ...] = (),
        crashes: tuple[NodeCrash, ...] = (),
        seed: int = 0,
        max_tries: int = 50,
    ) -> "FaultModel":
        """Search seeds until the fault trace is jointly connected."""
        for s in range(seed, seed + max_tries):
            fm = cls(
                graph=graph,
                edge_drop_prob=edge_drop_prob,
                outages=outages,
                crashes=crashes,
                seed=s,
            )
            if fm.certify_jointly_connected(num_rounds, window):
                return fm
        raise RuntimeError(
            f"no jointly connected fault trace in {max_tries} seeds "
            f"(p={edge_drop_prob}, window={window}); grow the window or "
            "lower the failure rate"
        )


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Per-message link-latency distribution for the async runtime.

    A message put on edge (i, j) at virtual time t is delivered at

        t + scale(i, j) * (base + jitter * U),   U ~ Uniform[0, 1)

    with U drawn from the scheduler's seeded stream, so a whole async
    run replays bit-for-bit from its seed. ``edge_scale`` entries model
    slow *links* (undirected: (i, j) covers both directions); a slow
    *node* is a firing-period concern and lives in
    ``async_engine.AsyncEngine(fire_periods=...)``. ``base=0`` with no
    jitter is the synchronous limit: delivery at the send instant,
    consumed at the receiver's next fire.

    Complementary to ``FaultModel``: FaultModel decides whether a
    message survives the link at all, DelayModel decides when the
    survivors arrive.
    """

    base: float = 0.1
    jitter: float = 0.0
    edge_scale: tuple[tuple[tuple[int, int], float], ...] = ()

    def __post_init__(self):
        if not np.isfinite(self.base) or self.base < 0.0:
            raise ValueError(f"base delay must be finite >= 0, got {self.base}")
        if not np.isfinite(self.jitter) or self.jitter < 0.0:
            raise ValueError(f"jitter must be finite >= 0, got {self.jitter}")
        for (i, j), s in self.edge_scale:
            if i == j:
                raise ValueError(f"edge_scale on a self-loop ({i}, {j})")
            if not np.isfinite(s) or s <= 0.0:
                raise ValueError(
                    f"edge_scale for ({i}, {j}) must be finite > 0, got {s}"
                )

    def scale(self, i: int, j: int) -> float:
        """Per-edge latency multiplier, symmetric in (i, j)."""
        for (a, b), s in self.edge_scale:
            if (a, b) == (i, j) or (a, b) == (j, i):
                return s
        return 1.0

    def sample(self, rng: np.random.Generator, i: int, j: int) -> float:
        """One message's latency on edge (i, j); consumes one uniform
        from ``rng`` iff the model has jitter (stream-stable in config)."""
        d = self.base
        if self.jitter > 0.0:
            d += self.jitter * float(rng.random())
        return self.scale(i, j) * d


# ---------------------------------------------------------------------------
# Convergence-rate analysis (paper Appendix C)
# ---------------------------------------------------------------------------


def dc_elm_iteration_matrix(
    graph: Graph, omegas: np.ndarray, gamma: float, VC: float
) -> np.ndarray:
    """W = I_{LV} - (gamma/VC) * Omega * (Lap kron I_L)  (paper eq. 48).

    omegas: (V, L, L) per-node Omega_i matrices.
    Only for analysis/tests (dense LV x LV).
    """
    V = graph.num_nodes
    L = omegas.shape[-1]
    lap = graph.laplacian
    big = np.kron(lap, np.eye(L))
    omega_blk = np.zeros((V * L, V * L))
    for i in range(V):
        omega_blk[i * L : (i + 1) * L, i * L : (i + 1) * L] = omegas[i]
    return np.eye(V * L) - (gamma / VC) * omega_blk @ big


def essential_spectral_radius(W: np.ndarray, L: int) -> float:
    """Second-largest eigenvalue modulus — the exponential consensus rate.

    For the DC-ELM iteration matrix the eigenvalue 1 has multiplicity L
    (one per output-weight coordinate); the rate is the largest of the
    remaining moduli.
    """
    ev = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
    return float(ev[L])
