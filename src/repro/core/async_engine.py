"""Event-driven asynchronous gossip runtime (no global round barrier).

Every mixer in core/mixers.py advances the network in lockstep rounds:
one straggler stalls all V nodes, and a dropped message must be dropped
symmetrically to keep the Laplacian well-posed. This module removes the
barrier. Each node lives on its own clock: when its local event fires
it (1) absorbs whatever messages have arrived in its per-peer inboxes,
(2) applies its update rule, (3) pushes messages to its out-neighbors,
each independently subject to the message-loss process (a
``consensus.FaultModel`` trace, indexed by *send time* instead of round
number) and the per-edge latency distribution
(``consensus.DelayModel``). Nothing anywhere waits for anything.

Two update rules:

* ``PushSumRule`` — ratio consensus over the moment masses
  (core/push_sum.py). Converges to the *centralized* beta* under
  drops, delays, reordering, and arbitrary relative timing; this is
  the default and the point of the subsystem.
* ``LaplacianRule`` — the paper's eq. (20) applied to the messages at
  hand. Under the barrier schedule (unit fire periods, zero delay) it
  replays ``FaultyMixer(DenseMixer)`` *exactly* — message present iff
  the round mask kept the edge — which is what pins the synchronous
  engines as the zero-delay/zero-loss special case of this runtime.

Everything runs on a deterministic virtual clock: events live in a
heap keyed (time, seq), all randomness (drop draws via the fault
trace, delay jitter) comes from one seeded generator, and the engine
records an event log — so the same seed replays the same run
bit-for-bit (the nightly seed-sweep stress job asserts exactly this,
plus the push-sum conservation law, across >= 20 seeds). This is the
injectable-clock idiom of ``serving.ContinuousELMServer`` applied to
the training plane.

``AsyncEngine.run_until(residual_tol=..., t_max=...)`` is the drop-in
alternative to ``ConsensusEngine.run``: instead of "mix K rounds" you
say "gossip until the network disagrees by less than tol (or virtual
time runs out)". Wire traffic is billed through the exact
``compression.WireStats`` accounting every synchronous mixer uses.

See DESIGN.md §13 and the README async quickstart.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any

import numpy as np

from repro.core import push_sum
from repro.core.consensus import DelayModel, FaultModel, Graph

# event kinds, ordered within a timestamp by scheduling seq
_FIRE = "fire"
_DELIVER = "deliver"


# ---------------------------------------------------------------------------
# Update rules
# ---------------------------------------------------------------------------


class PushSumRule:
    """Robust ratio consensus over the DC-ELM moment masses.

    Gossips cumulative (running-sum) mass counters of the pair
    (A_i = I/(VC) + P_i, Q_i) plus the scalar rho; each node's
    estimate solve(sigma_A, sigma_Q) converges to the centralized
    beta* on any jointly-connected directed/lossy/async sequence.
    State, counters, and the conservation law live in
    core/push_sum.py.
    """

    def __init__(self, graph: Graph, P, Q, C: float):
        self.graph = graph
        self.C = float(C)
        self.sigmas = push_sum.init_masses(P, Q, C)
        self.total0 = push_sum.total_mass(self.sigmas)
        V = graph.num_nodes
        self.out_neighbors = [
            [int(j) for j in graph.neighbors(i)] for i in range(V)
        ]
        L, M = self.sigmas[0].A.shape[0], self.sigmas[0].Q.shape[1]
        self._shape = (L, M)
        # cumulative counters: mu = mass ever *sent* on (i, j),
        # nu = mass ever *processed* from (i, j); a message carries a
        # snapshot of mu, so any delivery catches the receiver up past
        # every drop before it
        self.mu = {
            (i, j): push_sum.Mass.zeros(L, M)
            for i in range(V)
            for j in self.out_neighbors[i]
        }
        self.nu = {k: push_sum.Mass.zeros(L, M) for k in self.mu}
        self._last_seq = dict.fromkeys(self.mu, -1)

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    def payload_floats(self) -> int:
        """Floats per message: the cumulative (A, Q, rho) counter."""
        L, M = self._shape
        return L * L + L * M + 1

    def fire(self, i: int, inbox: dict) -> dict:
        """One local event: absorb counters, split mass, emit counters.

        inbox: {sender j: (seq, Mass cumulative)} — newest per sender.
        Returns {out-neighbor j: (payload to put on the wire)}.
        """
        for j, (seq, latest) in inbox.items():
            key = (j, i)
            if seq <= self._last_seq[key]:
                continue  # stale reordering: newer counter already in
            self.sigmas[i].add_diff(latest, self.nu[key])
            self.nu[key] = latest.copy()
            self._last_seq[key] = seq
        out = self.out_neighbors[i]
        w = push_sum.split_share(len(out))
        sends = {}
        for j in out:
            self.mu[(i, j)].add_scaled(self.sigmas[i], w)
            sends[j] = self.mu[(i, j)].copy()
        self.sigmas[i].scale(w)
        return sends

    def estimate(self, i: int) -> np.ndarray:
        return push_sum.estimate(self.sigmas[i])

    def betas(self) -> np.ndarray:
        return np.stack([self.estimate(i) for i in range(self.num_nodes)])

    def conservation_residual(self) -> float:
        """Relative violation of the mass-conservation invariant —
        roundoff-sized at *every* instant, by construction."""
        return push_sum.conservation_residual(
            self.sigmas, self.mu, self.nu, self.total0
        )


class LaplacianRule:
    """Paper eq. (20) on whatever messages have arrived.

    lap_i = sum over senders j of a_ij (beta_j^msg - beta_i), i.e. a
    neighbor contributes this fire iff a message from it survived the
    wire since the last fire (newest wins). Under the barrier schedule
    this is *exactly* the ``FaultyMixer(DenseMixer)`` masked Laplacian;
    under genuinely async timing it has no exactness guarantee (stale
    betas bias the fixed point) — use ``PushSumRule`` there. Static
    adjacency only (the sync engines' time-varying snapshots have no
    canonical async analogue).
    """

    def __init__(self, graph: Graph, betas, omegas, gamma: float, C: float,
                 *, dtype=np.float64):
        self.graph = graph
        self.gamma = float(gamma)
        self.C = float(C)
        self._betas = np.array(betas, dtype=dtype)
        self._omegas = np.array(omegas, dtype=dtype)
        self._adj = np.asarray(graph.adjacency, dtype=dtype)
        V = graph.num_nodes
        self.out_neighbors = [
            [int(j) for j in graph.neighbors(i)] for i in range(V)
        ]
        self._last_seq = {
            (i, j): -1 for i in range(V) for j in self.out_neighbors[i]
        }

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    def payload_floats(self) -> int:
        L, M = self._betas.shape[1], self._betas.shape[2]
        return L * M

    def fire(self, i: int, inbox: dict) -> dict:
        lap = np.zeros_like(self._betas[i])
        for j, (seq, beta_j) in inbox.items():
            if seq <= self._last_seq[(j, i)]:
                continue
            self._last_seq[(j, i)] = seq
            lap += self._adj[i, j] * (beta_j - self._betas[i])
        V, C = self.num_nodes, self.C
        self._betas[i] = self._betas[i] + (
            self.gamma / (V * C)
        ) * (self._omegas[i] @ lap)
        payload = self._betas[i].copy()
        return {j: payload for j in self.out_neighbors[i]}

    def estimate(self, i: int) -> np.ndarray:
        return self._betas[i]

    def betas(self) -> np.ndarray:
        return self._betas.copy()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncResult:
    """Outcome of one ``run_until`` leg (the engine keeps running state;
    successive calls continue the same virtual timeline)."""

    betas: np.ndarray  # (V, L, M) node estimates at stop time
    t: float  # virtual time at stop
    fires: int  # local events processed (this leg)
    sends: int  # messages put on a live link (this leg)
    drops: int  # of those, lost to the fault trace (this leg)
    residual: float  # last residual measured
    converged: bool  # residual <= residual_tol at stop


class AsyncEngine:
    """Deterministic virtual-clock scheduler driving an update rule.

    graph: the communication topology (message routes).
    rule: ``PushSumRule`` (default choice) or ``LaplacianRule``.
    faults: optional ``consensus.FaultModel`` whose ``edge_keep`` trace
        becomes the per-message drop process — the mask row is indexed
        by floor(send time) % fault_rounds, so the barrier schedule
        replays mask k at round k exactly like ``FaultyMixer``, and a
        certified trace stays certified here.
    delays: optional ``consensus.DelayModel``; None = zero latency
        (messages arrive at the send instant, consumed at the
        receiver's next fire — the synchronous limit).
    fire_periods: per-node firing periods (virtual-time units between
        local events), default all 1.0. A straggling node is a large
        entry here; nobody else slows down.
    seed: one generator for delay jitter (drop draws are already
        deterministic inside the FaultModel trace). Same seed + same
        config => identical event log, asserted nightly.

    Events are (time, seq)-ordered: seq is the scheduling order, so
    same-instant events process in the order they were created — fires
    scheduled last round before deliveries sent this instant — which is
    what makes the zero-delay limit well-defined instead of racy.
    """

    def __init__(
        self,
        graph: Graph,
        rule: Any,
        *,
        faults: FaultModel | None = None,
        delays: DelayModel | None = None,
        fire_periods=None,
        fault_rounds: int = 1024,
        seed: int = 0,
        log_events: bool = True,
    ):
        V = graph.num_nodes
        if rule.num_nodes != V:
            raise ValueError(
                f"rule is sized for {rule.num_nodes} nodes, graph has {V}"
            )
        if faults is not None and faults.num_nodes != V:
            raise ValueError(
                f"fault model is over {faults.num_nodes} nodes, graph has {V}"
            )
        self.graph = graph
        self.rule = rule
        self.delays = delays
        self._keep = (
            None if faults is None else faults.edge_keep(int(fault_rounds))
        )
        periods = (
            np.ones(V) if fire_periods is None
            else np.asarray(fire_periods, dtype=np.float64)
        )
        if periods.shape != (V,) or np.any(periods <= 0):
            raise ValueError(
                f"fire_periods must be (V,) positive, got {periods!r}"
            )
        self.fire_periods = periods
        self.rng = np.random.default_rng(seed)
        self.log_events = bool(log_events)
        self.event_log: list[tuple] = []
        self.t = 0.0
        self.last_wire_stats = None
        self.total_bytes_on_wire = 0
        self._heap: list[tuple] = []
        self._seq = 0
        self._send_seq = dict.fromkeys(
            ((i, j) for i in range(V) for j in graph.neighbors(i)), -1
        )
        self._inbox: list[dict] = [{} for _ in range(V)]
        self._fires_total = 0
        # every node's first local event is at t = 0 (node order seeds
        # the seq tie-break, so the barrier schedule is deterministic)
        for i in range(V):
            self._push(0.0, _FIRE, i)

    # ------------------------------------------------------------- internals

    def _push(self, t: float, kind: str, *payload) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _dropped(self, i: int, j: int, t_send: float) -> bool:
        if self._keep is None:
            return False
        R = self._keep.shape[0]
        return self._keep[int(np.floor(t_send)) % R, i, j] == 0.0

    def _delay(self, i: int, j: int) -> float:
        if self.delays is None:
            return 0.0
        return self.delays.sample(self.rng, i, j)

    def _log(self, *rec) -> None:
        if self.log_events:
            self.event_log.append(rec)

    def _process_fire(self, t: float, i: int) -> tuple[int, int]:
        """Run node i's local event; returns (#sends, #drops)."""
        inbox, self._inbox[i] = self._inbox[i], {}
        sends = self.rule.fire(i, inbox)
        self._log(_FIRE, t, i)
        n_sent = n_drop = 0
        for j, payload in sends.items():
            seq = self._send_seq[(i, j)] = self._send_seq[(i, j)] + 1
            n_sent += 1
            if self._dropped(i, j, t):
                n_drop += 1
                self._log("drop", t, i, j, seq)
                continue
            self._push(t + self._delay(i, j), _DELIVER, i, j, seq, payload)
            self._log("send", t, i, j, seq)
        self._push(t + self.fire_periods[i], _FIRE, i)
        self._fires_total += 1
        return n_sent, n_drop

    def _process_deliver(self, t, i, j, seq, payload) -> None:
        """Message from i lands in j's inbox (newest per sender wins —
        the rule's seq guard makes stale reorderings no-ops anyway)."""
        have = self._inbox[j].get(i)
        if have is None or seq > have[0]:
            self._inbox[j][i] = (seq, payload)
        self._log(_DELIVER, t, i, j, seq)

    def _residual(self, target) -> float:
        betas = self.rule.betas()
        if target is None:
            ref = betas.mean(axis=0)
        else:
            ref = np.asarray(target)
        num = np.sqrt(((betas - ref[None]) ** 2).sum(axis=(1, 2))).max()
        den = 1.0 + float(np.sqrt((ref**2).sum()))
        return float(num) / den

    def _record_wire(self, fires, sends, drops, per_fire_bytes) -> None:
        from repro.core import compression

        floats = self.rule.payload_floats()
        msg_bytes = floats * 8  # the runtime's masses are float64
        stats = compression.WireStats(
            rounds=fires,
            links_live=sends,
            links_sent=sends - drops,
            bytes_on_wire=(sends - drops) * msg_bytes,
            bytes_uncompressed=sends * msg_bytes,
            per_round_bytes=np.asarray(per_fire_bytes, dtype=np.int64),
        )
        compression.record_wire_stats(self, stats)

    # ------------------------------------------------------------------ api

    @property
    def wire_stats(self):
        """``compression.WireStats`` of the last ``run_until`` leg: one
        "round" = one fire event, a live link = an attempted send, a
        sent link = a send the fault trace did not eat."""
        return self.last_wire_stats

    def betas(self) -> np.ndarray:
        """(V, L, M) current per-node estimates."""
        return self.rule.betas()

    def run_until(
        self,
        *,
        residual_tol: float | None = None,
        t_max: float | None = None,
        target=None,
        check_every: int | None = None,
    ) -> AsyncResult:
        """Drive events until the residual is below tol or the virtual
        clock passes t_max (drop-in for ``ConsensusEngine.run``'s
        "K rounds": say how converged instead of how many).

        residual_tol: stop when max_i ||beta_i - ref|| / (1 + ||ref||)
            <= tol, with ref = the node mean (consensus residual) or
            ``target`` (e.g. the centralized beta*) when given.
        t_max: stop when the next event would pass this virtual time
            (measured from t=0 of the engine's life, not of this call).
        check_every: fires between residual evaluations (default V —
            once per average network sweep); the estimate solve is the
            expensive part of a push-sum step, so it is not done per
            event.

        Returns an ``AsyncResult``; the engine stays live, so a later
        ``run_until`` continues the same timeline (liveness probes,
        straggler sweeps, "gossip a bit more" flows).
        """
        if residual_tol is None and t_max is None:
            raise ValueError("need residual_tol and/or t_max")
        V = self.graph.num_nodes
        check_every = V if check_every is None else int(check_every)
        fires = sends = drops = 0
        per_fire_bytes: list[int] = []
        msg_bytes = self.rule.payload_floats() * 8
        residual = np.inf
        converged = False
        since_check = 0
        while self._heap:
            t_next = self._heap[0][0]
            if t_max is not None and t_next > t_max:
                break
            t, _, kind, payload = heapq.heappop(self._heap)
            self.t = t
            if kind == _DELIVER:
                self._process_deliver(t, *payload)
                continue
            n_sent, n_drop = self._process_fire(t, payload[0])
            fires += 1
            sends += n_sent
            drops += n_drop
            per_fire_bytes.append((n_sent - n_drop) * msg_bytes)
            since_check += 1
            if residual_tol is not None and since_check >= check_every:
                since_check = 0
                residual = self._residual(target)
                if residual <= residual_tol:
                    converged = True
                    break
        if residual_tol is not None and not converged:
            residual = self._residual(target)
            converged = residual <= residual_tol
        self._record_wire(fires, sends, drops, per_fire_bytes)
        return AsyncResult(
            betas=self.rule.betas(),
            t=self.t,
            fires=fires,
            sends=sends,
            drops=drops,
            residual=float(residual) if np.isfinite(residual) else residual,
            converged=converged,
        )


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------


def async_dc_elm(
    graph: Graph,
    P,
    Q,
    C: float,
    **kwargs,
) -> AsyncEngine:
    """Push-sum DC-ELM over ``graph`` from per-node statistics
    P:(V,L,L), Q:(V,L,M) — the async counterpart of
    ``engine.simulated_dc_elm`` + ``run``: every node's estimate
    converges to the centralized beta* without a round barrier.
    kwargs go to ``AsyncEngine`` (faults/delays/fire_periods/seed/...).
    """
    return AsyncEngine(graph, PushSumRule(graph, P, Q, C), **kwargs)


def sync_limit_dc_elm(
    graph: Graph,
    betas,
    omegas,
    gamma: float,
    C: float,
    *,
    faults: FaultModel | None = None,
    fault_rounds: int = 1024,
    dtype=np.float64,
    **kwargs,
) -> AsyncEngine:
    """The synchronous engines as a special case of the async runtime:
    eq. (20) under the barrier schedule (unit periods, zero delay).

    ``run_until(t_max=K)`` then reproduces
    ``engine.with_faults(simulated_dc_elm(...), ...).run(...)`` for K
    rounds *exactly* (same masked Laplacian, same update, same fault
    trace — mask row k gates the messages of round k), which is the
    parity claim tests/test_async.py pins.
    """
    rule = LaplacianRule(graph, betas, omegas, gamma, C, dtype=dtype)
    return AsyncEngine(
        graph, rule, faults=faults, fault_rounds=fault_rounds,
        delays=None, fire_periods=None, **kwargs,
    )
