"""Fusion-center parallel ELM baseline (paper refs [17][18], MapReduce).

The master-slave scheme the paper contrasts with: every worker computes
P_i = H_i^T H_i and Q_i = H_i^T T_i (the "map"), a fusion center reduces
them and solves beta = (I/C + sum P_i)^{-1} sum Q_i.

On a TPU mesh the "fusion center" is an all-reduce: exact, one global
collective, but architecturally centralized (a single reduction root in
spirit; any chip failure stalls the barrier, and the reduce moves
sufficient statistics — not raw data — so privacy matches DC-ELM but
robustness does not; see DESIGN.md).

Used as: (a) the exactness reference in tests, (b) the throughput
baseline in benchmarks, (c) the 'fusion' mode of launch/elm_head.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import stats as stats_lib
from repro.utils import compat


def solve(P_sum: jax.Array, Q_sum: jax.Array, C: float) -> jax.Array:
    """Fusion-center ridge solve, via the statistics plane's Cholesky."""
    return stats_lib.ridge_solve_moments(P_sum, Q_sum, C)


def simulate(H_nodes: jax.Array, T_nodes: jax.Array, C: float) -> jax.Array:
    """Single-device reference: stack nodes, reduce, solve."""
    P_ = jnp.einsum("vnl,vnk->lk", H_nodes, H_nodes)
    Q_ = jnp.einsum("vnl,vnm->lm", H_nodes, T_nodes)
    return solve(P_, Q_, C)


def sharded_fn(mesh: jax.sharding.Mesh, reduce_axes, C: float):
    """Build the jitted fusion-center ELM over data sharded on reduce_axes.

    H: (N, L) sharded on rows across reduce_axes; T: (N, M) likewise.
    Lowers to one all-reduce (psum) of (L,L)+(L,M) stats.
    """

    def body(H, T):
        P_, Q_ = stats_lib.hidden_moments(H, T)
        P_ = lax.psum(P_, reduce_axes)
        Q_ = lax.psum(Q_, reduce_axes)
        return solve(P_, Q_, C)

    shard = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(reduce_axes), P(reduce_axes)),
        out_specs=P(),
    )
    return jax.jit(shard)
