"""Centralized ELM (paper Sec. II-A) — the fusion-center baseline.

Solves   min_beta  1/2 ||beta||^2 + C/2 ||H beta - T||^2       (paper eq. 5)
closed form (paper eq. 3):
  beta* = (I_L/C + H^T H)^{-1} H^T T      when L <= N   ("primal")
  beta* = H^T (I_N/C + H H^T)^{-1} T      when N <= L   ("dual")

Both branches are implemented and tested to agree; the primal branch is
the one the distributed algorithm decomposes (P_i = H_i^T H_i are
additive across nodes).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import stats as stats_lib
from repro.core.features import make_random_features


def ridge_primal(H: jax.Array, T: jax.Array, C: float) -> jax.Array:
    """beta = (I_L/C + H^T H)^{-1} H^T T. Cost O(N L^2 + L^3).

    Moments and the SPD solve go through the statistics plane
    (`core/stats.py`): f32-floor accumulation (f64 inputs stay f64),
    Cholesky factorization.
    """
    P, Q = stats_lib.hidden_moments(H, T)
    return stats_lib.ridge_solve_moments(P, Q, C)


def ridge_dual(H: jax.Array, T: jax.Array, C: float) -> jax.Array:
    """beta = H^T (I_N/C + H H^T)^{-1} T. Cost O(N^2 L + N^3)."""
    N = H.shape[0]
    G = H @ H.T
    A = jnp.eye(N, dtype=H.dtype) / C + G
    return H.T @ stats_lib.spd_solve(A, T.astype(A.dtype))


def ridge_solve(
    H: jax.Array,
    T: jax.Array,
    C: float,
    mode: Literal["auto", "primal", "dual"] = "auto",
) -> jax.Array:
    """Paper eq. (3): pick the branch by which Gram matrix is smaller."""
    if mode == "auto":
        mode = "primal" if H.shape[-1] <= H.shape[0] else "dual"
    if mode == "primal":
        return ridge_primal(H, T, C)
    return ridge_dual(H, T, C)


def solve_from_stats(P: jax.Array, Q: jax.Array, C: float) -> jax.Array:
    """beta from sufficient statistics P = H^T H, Q = H^T T (primal)."""
    return stats_lib.ridge_solve_moments(P, Q, C)


@dataclasses.dataclass(frozen=True)
class ELM:
    """A trained ELM: frozen random feature map + learned output weights."""

    feature_map: object  # RandomFeatureMap | RBFFeatureMap | backbone adapter
    beta: jax.Array  # (L, M)

    def __call__(self, x: jax.Array) -> jax.Array:
        """f(x) = h(x) beta (paper eq. 2), through the fused predict path.

        On fusable maps the (N, L) hidden matrix never materializes —
        kernels/elm_predict.py streams g(XW+b) @ beta tile-by-tile
        (Pallas on TPU, lax.scan elsewhere); deep-backbone adapters and
        the f64 fidelity path fall back to h(x) @ beta.
        """
        from repro.kernels import elm_predict_ops

        return elm_predict_ops.predict_map(x, self.feature_map, self.beta)

    predict = __call__


def train_centralized(
    key: jax.Array,
    X: jax.Array,
    T: jax.Array,
    *,
    num_features: int,
    C: float,
    activation: str = "sigmoid",
    mode: Literal["auto", "primal", "dual"] = "auto",
) -> ELM:
    """End-to-end centralized ELM training (paper Sec. II-A).

    The primal branch runs through the statistics plane's fused
    feature->moment pipeline, so the (N, L) hidden matrix is never
    materialized; the dual branch (N < L) needs H H^T and builds H.
    """
    if T.ndim == 1:
        T = T[:, None]
    fmap = make_random_features(key, X.shape[-1], num_features, activation)
    if mode == "auto":
        mode = "primal" if num_features <= X.shape[0] else "dual"
    if mode == "primal":
        s = stats_lib.from_raw(X, T, fmap)
        beta = stats_lib.ridge_solve_moments(s.P, s.Q, C)
    else:
        beta = ridge_dual(fmap(X), T, C)
    return ELM(feature_map=fmap, beta=beta)


def mse(elm: ELM, X: jax.Array, T: jax.Array) -> jax.Array:
    if T.ndim == 1:
        T = T[:, None]
    pred = elm(X)
    return jnp.mean(jnp.square(pred - T))


def empirical_risk(pred: jax.Array, T: jax.Array) -> jax.Array:
    """Paper eq. (31): R = (1/N_t) sum 1/2 |y - yhat| (mean absolute /2)."""
    return jnp.mean(0.5 * jnp.abs(pred - T))


def accuracy(pred: jax.Array, T: jax.Array) -> jax.Array:
    """Binary/multiclass accuracy with +-1 or one-hot targets."""
    if T.ndim == 1 or T.shape[-1] == 1:
        return jnp.mean(jnp.sign(pred.reshape(-1)) == jnp.sign(T.reshape(-1)))
    return jnp.mean(jnp.argmax(pred, -1) == jnp.argmax(T, -1))
