"""Gossip primitives: neighbor exchange via ``jax.lax.ppermute``.

This is the TPU-native realization of the paper's message-passing step
"Send beta_i to N_i, and receive beta_j, j in N_i" (Algorithm 1, step 8).
Instead of point-to-point sockets, each consensus round lowers to a
handful of ``collective-permute`` ops on the device mesh — neighbor-only
ICI traffic, **no all-reduce / no fusion center**, exactly matching the
paper's communication model.

A topology on a mesh axis is a set of edge *permutations*; applying all
permutations and summing ``(ppermute(x) - x)`` computes the Laplacian
term  sum_{j in N_i} a_ij (x_j - x_i)  with unit weights.

Supported ICI-realizable topology kinds per axis:
  ring       2 perms (+1 / -1 shifts); degree 2 (1 when axis size == 2)
  hypercube  log2(n) perms (bit flips); degree log2(n)
  complete   n-1 perms (all shifts); degree n-1
  none       no mixing on this axis

Multi-axis specs compose as a Cartesian-product (torus-like) graph:
e.g. ring on "pod" x ring on "data" = the 2 x 16 torus over 32 consensus
nodes on the multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax import lax

Perm = list[tuple[int, int]]


def ring_perms(n: int) -> list[Perm]:
    if n == 1:
        return []
    fwd: Perm = [(i, (i + 1) % n) for i in range(n)]
    if n == 2:
        return [fwd]  # +1 and -1 coincide; avoid double-counting the edge
    bwd: Perm = [(i, (i - 1) % n) for i in range(n)]
    return [fwd, bwd]


def hypercube_perms(n: int) -> list[Perm]:
    dim = int(math.log2(n))
    if 1 << dim != n:
        raise ValueError(f"hypercube axis needs power-of-two size, got {n}")
    return [[(i, i ^ (1 << b)) for i in range(n)] for b in range(dim)]


def complete_perms(n: int) -> list[Perm]:
    return [[(i, (i + s) % n) for i in range(n)] for s in range(1, n)]


_PERM_BUILDERS = {
    "ring": ring_perms,
    "hypercube": hypercube_perms,
    "complete": complete_perms,
    "none": lambda n: [],
}


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Which mesh axes gossip, and with which topology kind.

    axes:  mesh axis names carrying consensus nodes, e.g. ("data",) or
           ("pod", "data").
    kinds: per-axis topology kind.
    """

    axes: tuple[str, ...]
    kinds: tuple[str, ...]

    def __post_init__(self):
        if len(self.axes) != len(self.kinds):
            raise ValueError("axes and kinds must have equal length")
        for k in self.kinds:
            if k not in _PERM_BUILDERS:
                raise ValueError(f"unknown topology kind {k!r}")

    def degree(self, axis_sizes: dict[str, int]) -> int:
        """Graph degree d_i (regular graphs => d_max) of the product graph."""
        deg = 0
        for ax, kind in zip(self.axes, self.kinds):
            deg += len(_PERM_BUILDERS[kind](axis_sizes[ax]))
        return deg

    def num_nodes(self, axis_sizes: dict[str, int]) -> int:
        n = 1
        for ax in self.axes:
            n *= axis_sizes[ax]
        return n

    def gamma_upper_bound(self, axis_sizes: dict[str, int]) -> float:
        """Paper Thm. 2 step-size bound 1/d_max for this product graph."""
        d = self.degree(axis_sizes)
        return 1.0 / d if d else float("inf")

    def to_graph(self, axis_sizes: dict[str, int]):
        """Dense `consensus.Graph` of the product topology (for analysis)."""
        from repro.core import consensus

        adj = np.zeros((1, 1))
        adj_graphs = []
        for ax, kind in zip(self.axes, self.kinds):
            n = axis_sizes[ax]
            a = np.zeros((n, n))
            for perm in _PERM_BUILDERS[kind](n):
                for s, d in perm:
                    if s != d:
                        a[s, d] += 1.0
            # undirected: perms come in +/- pairs (or are involutions)
            a = np.maximum(a, a.T)
            adj_graphs.append(a)
        # Cartesian product: L(G1 x G2) = L1 kron I + I kron L2
        total = adj_graphs[0]
        for a in adj_graphs[1:]:
            n1, n2 = total.shape[0], a.shape[0]
            new = np.kron(total, np.eye(n2)) + np.kron(np.eye(n1), a)
            total = new
        _ = adj
        return consensus.Graph(total, name="x".join(self.kinds))


def _axis_perms(spec: GossipSpec, axis_sizes: dict[str, int]):
    for ax, kind in zip(spec.axes, spec.kinds):
        for perm in _PERM_BUILDERS[kind](axis_sizes[ax]):
            yield ax, perm


def neighbor_laplacian(x, spec: GossipSpec, axis_sizes: dict[str, int]):
    """sum_{j in N_i} (x_j - x_i) for a pytree x, inside shard_map.

    One ppermute per edge-permutation per leaf; XLA fuses the subtract/
    accumulate. Unit edge weights (a_ij = 1), matching the paper's
    simulations.
    """

    def leaf(v):
        acc = None
        for ax, perm in _axis_perms(spec, axis_sizes):
            recv = lax.ppermute(v, ax, perm)
            d = recv - v
            acc = d if acc is None else acc + d
        if acc is None:
            return jax.numpy.zeros_like(v)
        return acc

    return jax.tree.map(leaf, x)


def masked_neighbor_laplacian(
    x, spec: GossipSpec, axis_sizes: dict[str, int], keep
):
    """Laplacian term with per-permutation keep-weights for THIS node.

    ``keep`` is a (num_perms,) vector — entry p multiplies the
    contribution node i receives through edge-permutation p this round
    (0 = link down, 1 = link up). Every ppermute still executes, so the
    collective schedule (and any compiled program built over it) is
    identical to the fault-free one; a dropped link just contributes
    zero to the Laplacian. Call inside shard_map.
    """

    def leaf(v):
        acc = None
        p = 0
        for ax, perm in _axis_perms(spec, axis_sizes):
            recv = lax.ppermute(v, ax, perm)
            d = (recv - v) * keep[p].astype(v.dtype)
            acc = d if acc is None else acc + d
            p += 1
        if acc is None:
            return jax.numpy.zeros_like(v)
        return acc

    return jax.tree.map(leaf, x)


def global_node_index(spec: GossipSpec, axis_sizes: dict[str, int]):
    """This shard's product-graph node index, row-major over spec.axes.

    Matches both ``GossipSpec.to_graph`` node numbering and the layout
    of a leading array axis sharded with PartitionSpec(spec.axes).
    Call inside shard_map.
    """
    idx = None
    for ax in spec.axes:
        i = lax.axis_index(ax)
        idx = i if idx is None else idx * axis_sizes[ax] + i
    if idx is None:
        raise ValueError("spec has no axes")
    return idx


def perm_sources(spec: GossipSpec, axis_sizes: dict[str, int]) -> np.ndarray:
    """(num_perms, V) table: src[p, i] = the node whose value node i
    receives through edge-permutation p (global product-graph indices,
    same order as ``_axis_perms``)."""
    sizes = [axis_sizes[ax] for ax in spec.axes]
    V = int(np.prod(sizes))
    coords = np.stack(np.unravel_index(np.arange(V), sizes), axis=-1)
    rows = []
    for a, (ax, kind) in enumerate(zip(spec.axes, spec.kinds)):
        n = axis_sizes[ax]
        for perm in _PERM_BUILDERS[kind](n):
            inv = np.empty(n, dtype=np.int64)  # dst -> src along axis a
            for s, d in perm:
                inv[d] = s
            c = coords.copy()
            c[:, a] = inv[c[:, a]]
            rows.append(np.ravel_multi_index(tuple(c.T), sizes))
    if not rows:
        return np.zeros((0, V), dtype=np.int64)
    return np.stack(rows).astype(np.int64)


def fold_edge_keep(
    spec: GossipSpec, axis_sizes: dict[str, int], edge_keep: np.ndarray
) -> np.ndarray:
    """Fold (R, V, V) symmetric edge keep-masks onto the ppermute
    schedule: returns (R, num_perms, V) with out[r, p, i] =
    edge_keep[r, src[p, i], i] — the weight of the in-edge node i uses
    from permutation p in round r."""
    edge_keep = np.asarray(edge_keep)
    V = spec.num_nodes(axis_sizes)
    if edge_keep.ndim != 3 or edge_keep.shape[-2:] != (V, V):
        raise ValueError(
            f"edge_keep must be (R, {V}, {V}), got {edge_keep.shape}"
        )
    src = perm_sources(spec, axis_sizes)  # (P, V)
    dst = np.arange(V)[None, :]
    return edge_keep[:, src, dst]


def neighbor_avg(x, spec: GossipSpec, axis_sizes: dict[str, int], gamma: float):
    """One plain-consensus averaging step x <- x + gamma * Lap-term."""
    lap = neighbor_laplacian(x, spec, axis_sizes)
    return jax.tree.map(lambda v, d: v + gamma * d, x, lap)


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def validate_spec(spec: GossipSpec, mesh: jax.sharding.Mesh) -> None:
    sizes = mesh_axis_sizes(mesh)
    for ax in spec.axes:
        if ax not in sizes:
            raise ValueError(f"gossip axis {ax!r} not in mesh {mesh.axis_names}")


def collective_bytes_per_round(
    spec: GossipSpec, axis_sizes: dict[str, int], payload_bytes: int
) -> int:
    """Per-node ICI bytes sent per consensus round (for roofline napkin math)."""
    return spec.degree(axis_sizes) * payload_bytes
