"""Pairwise-mask secure aggregation for sum-reduction payloads.

The SMC privacy-preserving ELM construction (arXiv 1602.02899) and the
federated secure-aggregation protocol it anticipates share one idea:
when the network only ever needs a *sum* of per-node values, each pair
of participants (i, j) can agree on a random mask stream r_ij and node
i can publish ``x_i + sum_{j>i} r_ij - sum_{j<i} r_ij`` instead of x_i.
Every mask appears exactly once with each sign, so the masks cancel in
the total while every individual payload is indistinguishable from
noise.

Exact cancellation is impossible in floating point ((x + r) - r == x
does not hold), so masking happens *after quantization*: values are
encoded to two's-complement fixed point (``frac_bits`` fractional
bits) and masks are added modulo 2^64, where addition is associative
and the cancellation is exact. The masked sum therefore equals the
unmasked sum bit-for-bit — the invariant the property tests pin.

Mask lifecycle (DESIGN.md §16):

* **Agreement** — the pair stream for edge {i, j} at reduction ``tag``
  is seeded from ``SeedSequence([seed, lo, hi, tag])`` (lo < hi the
  sorted pair), modeling a Diffie-Hellman-style per-edge key exchange.
  Streams are never transmitted; both endpoints (and, at recovery
  time, the aggregator acting for the survivors) regenerate them.
* **Use** — each participant's payload carries the signed sum of its
  pair masks against every *other* participant of the reduction. Masks
  are single-use: a new ``tag`` (round index) yields independent
  streams, so replaying a payload from an earlier round reveals
  nothing.
* **Recovery** — if a node's payload never reaches the aggregator
  (crash mid-round, dead link), the masks it shared with the survivors
  no longer cancel. The survivors jointly reconstruct exactly those
  pair streams (here: the aggregator re-derives them from the shared
  seeds, standing in for the secret-share reconstruction) and the
  aggregator subtracts the residue. The dropped node's *data* stays
  masked forever: its payload was never sent, and only streams paired
  with the dropped node — never the node's values — are reconstructed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# one uint64 codeword per masked value on the wire
MASK_BYTES = 8


@dataclasses.dataclass(frozen=True)
class SecureAggregationSpec:
    """Parameters of the pairwise-mask protocol.

    seed:      the shared PRNG key-exchange seed; all pair streams
               derive from it (per edge, per reduction tag).
    frac_bits: fixed-point fractional bits. Values are encoded as
               round(x * 2^frac_bits) in two's complement; the
               quantization error per value is <= 2^-(frac_bits+1).
    """

    seed: int = 0
    frac_bits: int = 32

    def __post_init__(self):
        if not 0 < int(self.frac_bits) < 62:
            raise ValueError(
                f"frac_bits must be in (0, 62), got {self.frac_bits}: "
                "the encoded magnitude 2^frac_bits * |x| must leave "
                "headroom inside int64"
            )

    def payload_bytes(self, num_values: int) -> int:
        """Wire size of one masked payload (uint64 codewords)."""
        return int(num_values) * MASK_BYTES

    @property
    def resolution(self) -> float:
        """The fixed-point grid spacing 2^-frac_bits."""
        return 1.0 / float(1 << self.frac_bits)

    @classmethod
    def parse(cls, spec) -> "SecureAggregationSpec":
        if isinstance(spec, cls):
            return spec
        if spec is None or spec is True:
            return cls()
        if isinstance(spec, int):
            return cls(seed=spec)
        raise ValueError(
            f"cannot parse secure-aggregation spec {spec!r}: expected a "
            "SecureAggregationSpec, an int seed, True, or None"
        )


# ---------------------------------------------------------------------------
# Fixed-point codec (exact modular arithmetic lives in uint64)
# ---------------------------------------------------------------------------


def encode_fixed(values, frac_bits: int) -> np.ndarray:
    """float -> uint64 two's-complement fixed point codes.

    Raises if any scaled value leaves the +-2^62 headroom band (the
    remaining bit of slack absorbs the network-sum growth before a
    genuine wraparound could alias).
    """
    x = np.asarray(values, np.float64)
    scaled = np.round(x * float(1 << frac_bits))
    limit = float(1 << 62)
    if not np.all(np.isfinite(scaled)) or np.any(np.abs(scaled) >= limit):
        raise ValueError(
            f"value out of fixed-point range: |x| * 2^{frac_bits} must "
            f"stay below 2^62 (max scaled magnitude "
            f"{np.max(np.abs(scaled)):.3g}); lower frac_bits or "
            "pre-scale the payload"
        )
    return scaled.astype(np.int64).astype(np.uint64)


def decode_fixed(codes, frac_bits: int) -> np.ndarray:
    """uint64 codes -> float64, inverting ``encode_fixed``.

    Sums of codes decode to sums of values exactly as long as the true
    sum stays inside the int64 band — modular wraparound through
    uint64 is what makes the masked arithmetic associative.
    """
    u = np.asarray(codes, np.uint64)
    return u.astype(np.int64).astype(np.float64) / float(1 << frac_bits)


# ---------------------------------------------------------------------------
# Pair mask streams
# ---------------------------------------------------------------------------


def pair_mask(
    spec: SecureAggregationSpec, i: int, j: int, num_values: int,
    *, tag: int = 0,
) -> np.ndarray:
    """The shared mask stream for edge {i, j} at reduction ``tag``.

    Symmetric in (i, j): both endpoints derive the identical stream
    from the sorted pair, as a real key exchange would.
    """
    if i == j:
        raise ValueError("a node holds no pair mask with itself")
    lo, hi = (i, j) if i < j else (j, i)
    ss = np.random.SeedSequence([int(spec.seed), int(lo), int(hi), int(tag)])
    rng = np.random.Generator(np.random.PCG64(ss))
    return rng.integers(
        0, np.iinfo(np.uint64).max, size=int(num_values),
        dtype=np.uint64, endpoint=True,
    )


def node_mask(
    spec: SecureAggregationSpec, i: int, participants, num_values: int,
    *, tag: int = 0,
) -> np.ndarray:
    """Node i's total mask: sum of +-r_ij over the other participants.

    Sign convention: the lower-indexed endpoint adds the stream, the
    higher-indexed one subtracts it — so every pair's contribution to
    the participant-wide sum is r_ij - r_ij = 0 exactly (mod 2^64).
    """
    m = np.zeros(int(num_values), np.uint64)
    for j in participants:
        if j == i:
            continue
        r = pair_mask(spec, i, j, num_values, tag=tag)
        m = m + r if i < j else m - r
    return m


# ---------------------------------------------------------------------------
# The aggregator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SecureAggregator:
    """Masks payloads for sum-reductions over a fixed participant set.

    One instance covers one cohort of participants; each reduction
    (one ``tag``) draws fresh single-use pair streams. ``mask`` is the
    node-side operation, ``aggregate`` the collector side (including
    dropout recovery), and ``masked_partial_sum`` models what an
    interior relay of a reduction tree forwards — still fully masked.
    """

    spec: SecureAggregationSpec
    participants: tuple[int, ...]

    def __post_init__(self):
        part = tuple(sorted(int(p) for p in self.participants))
        if len(part) != len(set(part)):
            raise ValueError(f"duplicate participants: {self.participants}")
        if len(part) < 2:
            raise ValueError(
                "secure aggregation needs >= 2 participants: a single "
                "node's mask would be empty and its payload clear"
            )
        object.__setattr__(self, "participants", part)

    @property
    def num_participants(self) -> int:
        return len(self.participants)

    def mask(self, i: int, values, *, tag: int = 0) -> np.ndarray:
        """Node i's wire payload: fixed-point codes + its total mask."""
        if i not in self.participants:
            raise ValueError(f"node {i} is not in {self.participants}")
        codes = encode_fixed(values, self.spec.frac_bits)
        shaped = node_mask(
            self.spec, i, self.participants, codes.size, tag=tag
        ).reshape(codes.shape)
        return codes + shaped

    def residual_mask(
        self, survivors, dropped, num_values: int, *, tag: int = 0
    ) -> np.ndarray:
        """Uncancelled mask residue left in a survivors-only sum.

        Every (survivor s, dropped d) pair contributes its stream once
        with s's sign and never with d's — the reconstruction step of
        crash recovery re-derives exactly these streams.
        """
        res = np.zeros(int(num_values), np.uint64)
        for s in survivors:
            for d in dropped:
                r = pair_mask(self.spec, s, d, num_values, tag=tag)
                res = res + r if s < d else res - r
        return res

    def aggregate(
        self, payloads: dict[int, np.ndarray], *, tag: int = 0
    ) -> np.ndarray:
        """Sum of delivered payloads, unmasked, back in float.

        payloads: {node -> masked codes} for the nodes whose payloads
        actually arrived. Pairs of delivered nodes cancel by
        construction; for pairs broken by a dropout the residue is
        reconstructed and subtracted (mask recovery). Equals the
        unmasked fixed-point sum of the delivered values exactly.
        """
        if not payloads:
            raise ValueError("no payloads delivered")
        survivors = sorted(payloads)
        unknown = [s for s in survivors if s not in self.participants]
        if unknown:
            raise ValueError(
                f"payload from non-participant(s) {unknown}; "
                f"cohort is {self.participants}"
            )
        total = np.zeros_like(next(iter(payloads.values())))
        for s in survivors:
            total = total + np.asarray(payloads[s], np.uint64)
        dropped = [p for p in self.participants if p not in payloads]
        if dropped:
            total = total - self.residual_mask(
                survivors, dropped, total.size, tag=tag
            ).reshape(total.shape)
        return decode_fixed(total, self.spec.frac_bits)

    @staticmethod
    def masked_partial_sum(payloads) -> np.ndarray:
        """What a relay forwards: a mod-2^64 sum of masked payloads.

        Until the cohort is complete the pair masks do not cancel, so
        interior partial sums stay as opaque as the leaves — constant
        message size is what buys the tree reduction its privacy.
        """
        payloads = list(payloads)
        total = np.zeros_like(np.asarray(payloads[0], np.uint64))
        for p in payloads:
            total = total + np.asarray(p, np.uint64)
        return total

    def payload_bytes(self, num_values: int) -> int:
        return self.spec.payload_bytes(num_values)
