"""PartitionSpec assignment for every parameter / batch / cache leaf.

Axis roles (DESIGN.md §5):
  node axes  — carry the consensus graph (the paper's network nodes);
               the leading V dim of training state lives here.
               cfg.consensus_axis == "data": ("data",), or ("pod","data")
               on the multi-pod mesh (a 2x16 torus of 32 nodes).
               cfg.consensus_axis == "pod": ("pod",) — the two-site
               privacy scenario — with "data" freed up for FSDP.
  fsdp axis  — shards weight d_model/d_ff rows (ZeRO-3 style) when the
               node axes don't occupy "data" (giant archs) or in serve
               mode (no node dim at all).
  model axis — tensor parallelism: attention heads, MLP hidden, MoE
               experts (when E divides), SSM heads, vocab.

Every rule checks divisibility against the actual mesh axis size and
falls back to replication — e.g. starcoder2's 24 heads don't divide a
16-way model axis, so its attention weights replicate (recorded in the
roofline analysis; the MLP still shards).
"""

from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    node: tuple[str, ...]  # consensus axes (may be empty)
    fsdp: tuple[str, ...]  # axes usable for weight sharding
    model: str
    sizes: dict[str, int]

    @property
    def node_count(self) -> int:
        n = 1
        for a in self.node:
            n *= self.sizes[a]
        return n

    def model_size(self) -> int:
        return self.sizes[self.model]

    def fsdp_size(self) -> int:
        n = 1
        for a in self.fsdp:
            n *= self.sizes[a]
        return n


def resolve_axes(cfg: ArchConfig, mesh: jax.sharding.Mesh, *, serve: bool = False) -> MeshAxes:
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    names = mesh.axis_names
    multi_pod = "pod" in names
    if serve:
        # no consensus dim; everything non-model is FSDP/batch territory
        fsdp = tuple(a for a in names if a != "model")
        return MeshAxes(node=(), fsdp=fsdp, model="model", sizes=sizes)
    if cfg.consensus_axis == "pod":
        node = ("pod",) if multi_pod else ()
        fsdp = ("data",)
    else:
        node = ("pod", "data") if multi_pod else ("data",)
        fsdp = ()
    return MeshAxes(node=node, fsdp=fsdp, model="model", sizes=sizes)


def consensus_gossip_spec(cfg: ArchConfig, axes: MeshAxes):
    """GossipSpec over the node axes (None if V <= 1: no graph, no mixing)."""
    from repro.core.gossip import GossipSpec

    if not axes.node or axes.node_count <= 1:
        return None
    spec = GossipSpec(
        axes=axes.node, kinds=tuple(cfg.gossip_kind for _ in axes.node)
    )
    if spec.degree(axes.sizes) == 0:
        return None
    return spec


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _fsdp_axis(axes: MeshAxes, dim: int):
    """Pick the fsdp axes tuple if the dim divides their product."""
    if not axes.fsdp:
        return None
    if _div(dim, axes.fsdp_size()):
        return axes.fsdp if len(axes.fsdp) > 1 else axes.fsdp[0]
    return None


def _model_axis(axes: MeshAxes, dim: int):
    return axes.model if _div(dim, axes.model_size()) else None


def _leaf_spec(path: str, shape: tuple[int, ...], axes: MeshAxes, cfg: ArchConfig):
    """Spec for the *trailing* semantic dims; leading dims padded None."""
    m = axes.model_size()

    def pad(spec_tail: list):
        return [None] * (len(shape) - len(spec_tail)) + spec_tail

    tail = None
    if re.search(r"(embed|unembed)$", path):
        vocab, d = shape[-2:]
        tail = [_model_axis(axes, vocab), _fsdp_axis(axes, d)]
    elif re.search(r"attn/w[qkv]$", path) or re.search(r"attn/wq$", path):
        d, h, hd = shape[-3:]
        tail = [_fsdp_axis(axes, d), _model_axis(axes, h), None]
    elif re.search(r"attn/wo$", path):
        h, hd, d = shape[-3:]
        tail = [_model_axis(axes, h), None, _fsdp_axis(axes, d)]
    elif re.search(r"attn/b[qkv]$", path):
        h, hd = shape[-2:]
        tail = [_model_axis(axes, h), None]
    elif re.search(r"mlp/w_(gate|up)$", path):
        d, f = shape[-2:]
        tail = [_fsdp_axis(axes, d), _model_axis(axes, f)]
    elif re.search(r"mlp/w_down$", path):
        f, d = shape[-2:]
        tail = [_model_axis(axes, f), _fsdp_axis(axes, d)]
    elif re.search(r"moe/router$", path):
        tail = [None, None]
    elif re.search(r"moe/w_(gate|up)$", path):
        e, d, f = shape[-3:]
        if _div(e, m):
            tail = [axes.model, _fsdp_axis(axes, d), None]
        else:
            tail = [None, _fsdp_axis(axes, d), _model_axis(axes, f)]
    elif re.search(r"moe/w_down$", path):
        e, f, d = shape[-3:]
        if _div(e, m):
            tail = [axes.model, None, _fsdp_axis(axes, d)]
        else:
            tail = [None, _model_axis(axes, f), _fsdp_axis(axes, d)]
    elif re.search(r"w_[zx]$", path):  # mamba: head-major inner projections
        d, di = shape[-2:]
        ok = _div(cfg.ssm_heads, m) and _div(di, m)
        tail = [_fsdp_axis(axes, d), axes.model if ok else None]
    elif re.search(r"w_dt$", path):
        d, nh = shape[-2:]
        tail = [_fsdp_axis(axes, d), _model_axis(axes, nh)]
    elif re.search(r"w_[BC]$", path):
        d, ds = shape[-2:]
        tail = [_fsdp_axis(axes, d), None]
    elif re.search(r"conv_x$", path):
        w, di = shape[-2:]
        ok = _div(cfg.ssm_heads, m) and _div(di, m)
        tail = [None, axes.model if ok else None]
    elif re.search(r"conv_bx$", path) or re.search(r"gate_norm$", path):
        (di,) = shape[-1:]
        ok = _div(cfg.ssm_heads, m) and _div(di, m)
        tail = [axes.model if ok else None]
    elif re.search(r"(dt_bias|A_log|^D$|/D$)", path):
        (nh,) = shape[-1:]
        tail = [_model_axis(axes, nh)]
    elif re.search(r"out_proj$", path):
        di, d = shape[-2:]
        ok = _div(cfg.ssm_heads, m) and _div(di, m)
        tail = [axes.model if ok else None, _fsdp_axis(axes, d)]
    if tail is None:
        # norms, conv B/C, misc small: replicate
        tail = [None] * len(shape)
    return pad(tail)


def _path_str(key_path) -> str:
    segs = []
    for k in key_path:
        if isinstance(k, jax.tree_util.DictKey):
            segs.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            segs.append(str(k.idx))
        else:
            segs.append(str(k))
    return "/".join(segs)


def param_pspecs(cfg: ArchConfig, axes: MeshAxes, params_shape, *, node_dim: bool):
    """PartitionSpec pytree for a params template (from jax.eval_shape).

    node_dim: True for training state with the leading (V, ...) node dim.

    (§Perf note: sharding the stacked-layer L dim over fsdp axes in
    serve mode was tried and REFUTED — GSPMD gathers the entire stack
    for the scan's dynamic-slice, 4.2 TB of all-gather on grok. The
    d-dim fsdp layout below remains the best measured serve policy.)
    """
    node_spec = (
        axes.node if len(axes.node) > 1 else (axes.node[0] if axes.node else None)
    )

    def leaf(key_path, leaf_shape):
        path = _path_str(key_path)
        shape = leaf_shape.shape
        if node_dim:
            inner = _leaf_spec(path, shape[1:], axes, cfg)
            return P(node_spec, *inner)
        return P(*_leaf_spec(path, shape, axes, cfg))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ArchConfig, axes: MeshAxes, batch_shape, *, node_dim: bool):
    """Token batches: (V, b, S) node+optional-fsdp sharded, or (B, S)."""
    node_spec = (
        axes.node if len(axes.node) > 1 else (axes.node[0] if axes.node else None)
    )

    def leaf(key_path, leaf_shape):
        shape = leaf_shape.shape
        if node_dim:
            b = shape[1]
            bshard = _fsdp_axis(axes, b)
            return P(node_spec, bshard, *([None] * (len(shape) - 2)))
        b = shape[0]
        bshard = _fsdp_axis(axes, b)
        return P(bshard, *([None] * (len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def cache_pspecs(cfg: ArchConfig, axes: MeshAxes, cache_shape):
    """Decode caches (serve mode, no node dim).

    Prefer sharding batch over the fsdp axes; if the batch doesn't
    divide (long_500k, B=1), shard the sequence dim of attention caches
    instead (flash-decode style distributed KV).
    """

    def leaf(key_path, leaf_shape):
        path = _path_str(key_path)
        shape = leaf_shape.shape
        if path.endswith("pos"):
            return P()
        if re.search(r"(k|v)(_local|_global|_shared)?$", path):
            L, B, S, K, hd = shape
            bshard = _fsdp_axis(axes, B)
            sshard = None if bshard else _fsdp_axis(axes, S)
            return P(None, bshard, sshard, _model_axis(axes, K), None)
        if path.endswith("state"):
            L, B, nh, hd, ds = shape
            return P(None, _fsdp_axis(axes, B), _model_axis(axes, nh), None, None)
        if re.search(r"conv/x$", path):
            L, B, W, di = shape
            ok = _div(cfg.ssm_heads, axes.model_size()) and _div(
                di, axes.model_size()
            )
            return P(
                None, _fsdp_axis(axes, B), None, axes.model if ok else None
            )
        if re.search(r"conv/[BC]$", path):
            L, B, W, ds = shape
            return P(None, _fsdp_axis(axes, B), None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def shardings(mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
