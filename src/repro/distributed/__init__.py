from repro.distributed.sharding import (
    MeshAxes,
    resolve_axes,
    param_pspecs,
    batch_pspecs,
    cache_pspecs,
    consensus_gossip_spec,
)

__all__ = [
    "MeshAxes",
    "resolve_axes",
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "consensus_gossip_spec",
]
