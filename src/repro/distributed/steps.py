"""Jitted step factories: consensus training, prefill, decode, ELM head.

These bind (config x mesh x optimizer) into the concrete computations
that launch/{train,serve,elm_head,dryrun}.py lower. Training state
carries a leading node dim V (the consensus graph); each node's replica
is vmapped through the model and mixed with its mesh neighbors using the
paper's rule after every optimizer step (core/dsgd.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import dsgd
from repro.distributed import sharding as shd
from repro.models import Model
from repro.optim.optimizers import Optimizer, apply_updates
from repro.utils import compat


class TrainState(NamedTuple):
    params: dict  # leaves (V, ...)
    opt_state: object
    step: jax.Array


# ---------------------------------------------------------------------------
# Consensus training (train_4k)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainBundle:
    """Everything launch code needs: jitted fns + shardings."""

    init_fn: object  # (key) -> TrainState (jitted, sharded out)
    step_fn: object  # (TrainState, batch) -> (TrainState, metrics)
    state_shardings: object
    batch_shardings: object
    node_count: int
    gamma: float


def _state_pspecs(cfg, axes, state_shape):
    pp = shd.param_pspecs(cfg, axes, state_shape.params, node_dim=True)

    def opt_leaf(path, leaf):
        # mu/nu mirror params; per-node scalars get P(node)
        del path
        shape = leaf.shape
        if len(shape) <= 1:  # (V,) step counters
            node_spec = (
                axes.node
                if len(axes.node) > 1
                else (axes.node[0] if axes.node else None)
            )
            return P(*([node_spec] + [None] * (len(shape) - 1)))
        return None  # filled below by structural match

    # opt_state: same structure as params for moment trees; use params
    # specs where shapes match, replicate-node-scalars otherwise.
    flat_p, _ = jax.tree_util.tree_flatten(pp)

    def match(leaf):
        shape = leaf.shape
        node_spec = (
            axes.node
            if len(axes.node) > 1
            else (axes.node[0] if axes.node else None)
        )
        if len(shape) <= 1:
            return P(*([node_spec][: len(shape)]))
        return None

    # moments have identical treedef to params within mu/nu subtrees;
    # simplest robust approach: spec by shape lookup from params template.
    shape_to_spec = {}
    for spec, leaf in zip(
        jax.tree_util.tree_leaves(pp),
        jax.tree_util.tree_leaves(state_shape.params),
    ):
        shape_to_spec.setdefault((leaf.shape, str(leaf.dtype)), spec)

    def opt_spec(leaf):
        key = (leaf.shape, str(leaf.dtype))
        if key in shape_to_spec:
            return shape_to_spec[key]
        return match(leaf)

    po = jax.tree.map(opt_spec, state_shape.opt_state)
    return TrainState(params=pp, opt_state=po, step=P())


def make_train_bundle(
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh,
    optimizer: Optimizer,
    *,
    gamma: float | None = None,
    gossip_compress: str | None = None,
    microbatches: int = 1,
    seed: int = 0,
) -> TrainBundle:
    model = Model(cfg)
    axes = shd.resolve_axes(cfg, mesh)
    V = max(axes.node_count, 1)
    spec = shd.consensus_gossip_spec(cfg, axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if gamma is None:
        gamma = (
            0.9 * spec.gamma_upper_bound(sizes) if spec is not None else 0.0
        )

    def init_state(key):
        params = model.init(key)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (V,) + x.shape), params
        )
        opt_state = jax.vmap(optimizer.init)(stacked)
        return TrainState(stacked, opt_state, jnp.zeros((), jnp.int32))

    state_shape = jax.eval_shape(init_state, jax.random.key(seed))
    state_specs = _state_pspecs(cfg, axes, state_shape)
    state_sh = shd.shardings(mesh, state_specs)

    def node_loss(p, b):
        loss, metrics = model.loss(p, b)
        return loss, metrics

    grad_fn = jax.vmap(jax.value_and_grad(node_loss, has_aux=True))

    def _accumulate_grads(params, batch):
        """Gradient accumulation over `microbatches` splits of the
        per-node batch (activation memory / microbatches)."""
        if microbatches == 1:
            return grad_fn(params, batch)

        def split(x):  # (V, b, ...) -> (m, V, b/m, ...)
            V, b = x.shape[0], x.shape[1]
            if b % microbatches:
                raise ValueError(
                    f"per-node batch {b} % microbatches {microbatches}"
                )
            return x.reshape(
                V, microbatches, b // microbatches, *x.shape[2:]
            ).swapaxes(0, 1)

        mb = jax.tree.map(split, batch)

        def body(carry, mb_slice):
            (losses, metrics), grads = grad_fn(params, mb_slice)
            acc_l, acc_m, acc_g = carry
            acc_l = acc_l + losses
            acc_m = jax.tree.map(jnp.add, acc_m, metrics)
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            return (acc_l, acc_m, acc_g), None

        zeros = lambda t: jax.tree.map(  # noqa: E731
            lambda s: jnp.zeros(s.shape, s.dtype), t
        )
        shapes = jax.eval_shape(
            grad_fn, params,
            jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), mb
            ),
        )
        (l_s, m_s), g_s = shapes
        carry0 = (zeros(l_s), zeros(m_s), zeros(g_s))
        (losses, metrics, grads), _ = jax.lax.scan(body, carry0, mb)
        inv = 1.0 / microbatches
        return (
            (losses * inv, jax.tree.map(lambda x: x * inv, metrics)),
            jax.tree.map(lambda g: g * inv, grads),
        )

    def step(state: TrainState, batch):
        (losses, metrics), grads = _accumulate_grads(state.params, batch)
        updates, opt_state = jax.vmap(optimizer.update)(
            grads, state.opt_state, state.params
        )
        params = apply_updates(state.params, updates)
        if spec is not None:
            pspecs = state_specs.params

            def mix(p):
                return dsgd.mix_sharded(
                    p, gamma, spec, sizes, compress=gossip_compress
                )

            params = compat.shard_map(
                mix, mesh=mesh, in_specs=(pspecs,), out_specs=pspecs,
            )(params)
        metrics = dict(metrics, loss=losses)
        return TrainState(params, opt_state, state.step + 1), metrics

    # batch template: (V, b, S) int32 tokens/labels (+ vlm embeds)
    def batch_specs(batch_shape):
        return shd.batch_pspecs(cfg, axes, batch_shape, node_dim=True)

    init_jit = jax.jit(init_state, out_shardings=state_sh)

    return TrainBundle(
        init_fn=init_jit,
        step_fn=step,
        state_shardings=state_sh,
        batch_shardings=batch_specs,
        node_count=V,
        gamma=gamma,
    )


def jit_train_step(bundle: TrainBundle, mesh, batch_shape):
    """jit the step with explicit in/out shardings for a batch template."""
    bspecs = bundle.batch_shardings(batch_shape)
    bsh = shd.shardings(mesh, bspecs)
    return jax.jit(
        bundle.step_fn,
        in_shardings=(bundle.state_shardings, bsh),
        out_shardings=(bundle.state_shardings, None),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# Serving (prefill_32k / decode_32k / long_500k)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeBundle:
    prefill_fn: object
    decode_fn: object
    param_shardings: object
    cache_shardings: object
    batch_pspec_fn: object


def make_serve_bundle(
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh,
    *,
    batch: int,
    max_seq: int,
    seed: int = 0,
) -> ServeBundle:
    model = Model(cfg)
    axes = shd.resolve_axes(cfg, mesh, serve=True)

    params_shape = jax.eval_shape(model.init, jax.random.key(seed))
    pspecs = shd.param_pspecs(cfg, axes, params_shape, node_dim=False)
    psh = shd.shardings(mesh, pspecs)

    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, batch, max_seq)
    )
    cspecs = shd.cache_pspecs(cfg, axes, cache_shape)
    csh = shd.shardings(mesh, cspecs)

    tok_shard = shd.shardings(
        mesh, P(None if batch % axes.fsdp_size() else None)
    )
    del tok_shard

    def batch_pspec(shape_tree):
        return shd.batch_pspecs(cfg, axes, shape_tree, node_dim=False)

    def prefill(params, batch_):
        return model.prefill(params, batch_, max_seq=max_seq)

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return ServeBundle(
        prefill_fn=prefill,
        decode_fn=decode,
        param_shardings=psh,
        cache_shardings=csh,
        batch_pspec_fn=batch_pspec,
    )
