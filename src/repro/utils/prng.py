"""PRNG helpers.

The paper (Algorithm 1, step 1) requires every network node to share the
*same* random hidden-layer weights ``{w_l, b_l}``; ``shared_key`` makes
that contract explicit at call sites.
"""

from __future__ import annotations

import jax


def shared_key(seed: int) -> jax.Array:
    """A PRNG key that is broadcast to (identical on) every node."""
    return jax.random.key(seed)


def key_iter(seed: int):
    """Infinite stream of fresh keys."""
    key = jax.random.key(seed)
    while True:
        key, sub = jax.random.split(key)
        yield sub
