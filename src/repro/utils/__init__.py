from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_global_norm,
)
from repro.utils.prng import key_iter, shared_key

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
    "tree_global_norm",
    "key_iter",
    "shared_key",
]
