"""Small pytree utilities (no optax / flax in this environment)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_global_norm(a):
    leaves = jax.tree.leaves(a)
    if not leaves:
        return jnp.asarray(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
