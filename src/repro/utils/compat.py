"""JAX version compatibility shims.

The codebase targets the modern public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``); older installs (<= 0.4.x) only
ship ``jax.experimental.shard_map`` and a ``make_mesh`` without
``axis_types``. Everything that builds meshes or shard_maps goes through
this module so the rest of the code stays version-agnostic.
"""

from __future__ import annotations

from typing import Sequence

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` on new JAX, experimental fallback on old.

    The fallback disables the replication checker: it predates the
    rewrite rules for ``ppermute``-heavy programs like the gossip
    schedules and rejects them spuriously.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def get_abstract_mesh():
    """The mesh currently in context, or None.

    New JAX exposes ``jax.sharding.get_abstract_mesh``; on old JAX the
    nearest equivalent is the thread-local physical mesh set by a
    ``with mesh:`` block. Callers treat None / no-axes as "no mesh in
    context" and skip sharding hints, which keeps semantics identical.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - very old/changed internals
        return None


def set_mesh(mesh):
    """Context manager putting ``mesh`` in scope for sharding hints.

    ``jax.set_mesh`` on new JAX; on old JAX a ``Mesh`` is itself the
    (thread-local) context manager.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(axis_shapes),
            tuple(axis_names),
            axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
