"""Versioned multi-tenant model registry over one shared feature map.

The ROADMAP's "millions of users" story (open item 2): decentralized
multi-task ELM (arXiv 1904.11366) shows many related tasks sharing ONE
hidden layer while learning per-task readouts, and subnetwork theory
(arXiv 1610.09608) justifies restricting per-tenant learning to the
shared feature subspace. Operationally that means thousands of
``(tenant_id, version) -> beta`` readouts over a single
``RandomFeatureMap``, hot-swapped independently, and served together:
a micro-batch mixing many tenants is answered by ONE stacked-beta
kernel launch (kernels/elm_predict.py, ``elm_predict_stacked_*``).

``TenantRegistry`` generalizes ``serving.BetaStore`` from "V node
replicas of one model" to "T independent tenant models":

* **Versioning.** Every ``publish(tenant, beta)`` bumps that tenant's
  own version (1-based, monotonic across retire/re-register) AND the
  registry's global version. ``retire(tenant)`` removes the tenant;
  subsequent lookups raise the *named* ``RetiredTenantError`` (vs
  ``UnknownTenantError`` for ids never seen) so the serving plane can
  reject retired traffic distinguishably.
* **Atomic snapshots.** Readers call ``snapshot()`` and get an
  immutable ``TenantSnapshot``: the stacked (T, L, M) beta tensor plus
  tenant -> (slot, version) maps, published under one atomic reference
  swap exactly like ``BetaSnapshot``. Publishers mutate a host-side
  buffer under the registry lock; the stacked device tensor is
  (re)built lazily on the first snapshot after a mutation, so a burst
  of publishes costs one stack, not one per publish.
* **Staleness bounds.** Snapshots carry per-tenant versions;
  ``stale_tenants(snapshot, max_staleness)`` lists tenants whose
  snapshot version trails their latest publish by more than the bound
  — the serving plane's per-tenant refresh rule (a tenant that keeps
  publishing cannot pin every OTHER tenant's snapshot fresh).
* **int8 beta tiles.** ``beta_mode="int8"`` round-trips every
  published beta through the compression plane's per-tile stochastic
  quantizer (core/compression.int8_roundtrip, keyed deterministically
  by tenant uid and version); ``metrics["beta_bytes"]`` accounts the
  quantized wire/storage bytes via ``CompressionSpec.message_bytes``.
* **Consensus hook.** ``registry.publisher(tenant, reduce=...)`` is a
  ``publish_to=`` adapter for ``ConsensusEngine.stream_chunk``: the
  post-consensus stacked (V, L, M) betas are reduced (mean over nodes,
  or one node's estimate) into that tenant's next version, so per-user
  training streams publish straight into the serving plane.

Thread-safety contract: any number of publisher threads may
``publish``/``retire`` concurrently with reader ``snapshot`` calls;
a snapshot is immutable and internally consistent (its stacked tensor
and maps describe one global version).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

BETA_MODES = ("fp32", "int8")


class UnknownTenantError(KeyError):
    """A tenant id that was never registered with the registry."""


class RetiredTenantError(KeyError):
    """A tenant id that was registered and has since been retired."""


@dataclasses.dataclass(frozen=True)
class TenantSnapshot:
    """An immutable multi-tenant model: stacked betas + tenant maps.

    ``betas`` is the (T, L, M) stacked tensor the fused stacked-beta
    kernel contracts against; ``slots`` maps tenant_id -> row in it;
    ``versions`` maps tenant_id -> the per-tenant version this
    snapshot holds. ``version`` is the registry's global version at
    capture (bumped by every publish/retire on any tenant).
    """

    version: int
    betas: jax.Array  # (T, L, M)
    slots: Mapping  # tenant_id -> row index into betas
    versions: Mapping  # tenant_id -> per-tenant version
    retired: frozenset = frozenset()  # ids retired as of this snapshot

    @property
    def num_tenants(self) -> int:
        return self.betas.shape[0]

    @property
    def tenant_ids(self) -> tuple:
        return tuple(self.slots)

    def _check(self, tenant):
        if tenant not in self.slots:
            if tenant in self.retired:
                raise RetiredTenantError(
                    f"tenant {tenant!r} is retired; re-register it with "
                    f"publish() before serving"
                )
            raise UnknownTenantError(
                f"unknown tenant {tenant!r}; registered tenants: "
                f"{sorted(map(repr, self.slots))}"
            )

    def slot(self, tenant) -> int:
        """Row of ``tenant`` in the stacked tensor (named errors)."""
        self._check(tenant)
        return self.slots[tenant]

    def tenant_version(self, tenant) -> int:
        self._check(tenant)
        return self.versions[tenant]

    def beta(self, tenant) -> jax.Array:
        """One tenant's (L, M) readout out of the stacked tensor."""
        return self.betas[self.slot(tenant)]


class TenantPublisher:
    """``publish_to=`` adapter: consensus betas -> one tenant's slot.

    ``ConsensusEngine.stream_chunk(publish_to=...)`` hands over the
    post-consensus stacked (V, L, M) node betas; this reduces them to
    the tenant's single (L, M) readout — ``reduce="mean"`` averages
    the node estimates (they agree at consensus; the mean is the
    natural serving model mid-consensus too), an int picks that node's
    estimate — and publishes it as the tenant's next version.
    """

    def __init__(self, registry: "TenantRegistry", tenant, reduce="mean"):
        if reduce != "mean" and not isinstance(reduce, int):
            raise ValueError(
                f'reduce must be "mean" or a node index, got {reduce!r}'
            )
        self.registry = registry
        self.tenant = tenant
        self.reduce = reduce

    def publish(self, betas) -> int:
        b = jnp.asarray(betas)
        if b.ndim == 2:  # already a single (L, M) readout
            beta = b
        elif b.ndim == 3:
            beta = (
                jnp.mean(b, axis=0) if self.reduce == "mean"
                else b[self.reduce]
            )
        else:
            raise ValueError(
                f"betas must be (L, M) or stacked (V, L, M), got {b.shape}"
            )
        return self.registry.publish(self.tenant, beta)


class TenantRegistry:
    """Thread-safe versioned registry of per-tenant betas.

    betas: optional initial {tenant_id: (L, M) beta} mapping.
    beta_mode: "fp32" stores published betas as-is; "int8" round-trips
      each through the compression plane's per-tile stochastic int8
      quantizer at publish time (deterministic in tenant uid and
      version) and accounts the quantized bytes.
    int8_tile: quantization tile width for beta_mode="int8".
    """

    def __init__(self, betas=None, *, beta_mode: str = "fp32",
                 int8_tile: int = 128):
        if beta_mode not in BETA_MODES:
            raise ValueError(
                f"beta_mode must be one of {BETA_MODES}, got {beta_mode!r}"
            )
        if int(int8_tile) <= 0:
            raise ValueError(
                f"int8_tile must be a positive int, got {int8_tile}"
            )
        self.beta_mode = beta_mode
        self.int8_tile = int(int8_tile)
        self._lock = threading.Lock()
        self._betas: dict = {}  # tenant -> np.ndarray (L, M)
        self._versions: dict = {}  # tenant -> live per-tenant version
        self._uids: dict = {}  # tenant -> stable registration uid
        self._retired: dict = {}  # tenant -> last version before retire
        self._version = 0  # global version (any mutation bumps)
        self._next_uid = 0
        self._shape = None  # (L, M) pinned by the first publish
        self._snap: TenantSnapshot | None = None
        self.metrics = {"publishes": 0, "retires": 0, "beta_bytes": 0}
        if betas is not None:
            for tenant, beta in dict(betas).items():
                self.publish(tenant, beta)

    # -------------------------------------------------------------- write

    def _coerce(self, tenant, beta) -> np.ndarray:
        b = np.asarray(jnp.asarray(beta), np.float32)
        if b.ndim != 2:
            raise ValueError(
                f"beta must be a (L, M) readout matrix, got shape {b.shape}"
            )
        if self._shape is None:
            self._shape = b.shape
        elif b.shape != self._shape:
            raise ValueError(
                f"beta for tenant {tenant!r} has shape {b.shape}; this "
                f"registry serves {self._shape} readouts"
            )
        return b

    def _quantize(self, beta: np.ndarray, uid: int, version: int):
        from repro.core.compression import CompressionSpec, int8_roundtrip

        key = jax.random.fold_in(jax.random.key(version), uid)
        flat = int8_roundtrip(
            jnp.asarray(beta).reshape(-1), self.int8_tile, key
        )
        nbytes = CompressionSpec(
            mode="int8", tile=self.int8_tile
        ).message_bytes(int(beta.size))
        return np.asarray(flat, np.float32).reshape(beta.shape), nbytes

    def publish(self, tenant, beta) -> int:
        """Register or hot-swap one tenant's readout; returns its new
        per-tenant version (1-based, monotonic across retirement)."""
        b = self._coerce(tenant, beta)
        with self._lock:
            prev = self._versions.get(
                tenant, self._retired.pop(tenant, 0)
            )
            version = prev + 1
            if tenant not in self._uids:
                self._uids[tenant] = self._next_uid
                self._next_uid += 1
            if self.beta_mode == "int8":
                b, nbytes = self._quantize(b, self._uids[tenant], version)
                self.metrics["beta_bytes"] += nbytes
            self._betas[tenant] = b
            self._versions[tenant] = version
            self._version += 1
            self.metrics["publishes"] += 1
            return version

    def retire(self, tenant) -> None:
        """Remove a tenant; later lookups raise RetiredTenantError."""
        with self._lock:
            if tenant not in self._versions:
                if tenant in self._retired:
                    raise RetiredTenantError(
                        f"tenant {tenant!r} is already retired"
                    )
                raise UnknownTenantError(
                    f"unknown tenant {tenant!r}; registered tenants: "
                    f"{sorted(map(repr, self._versions))}"
                )
            self._retired[tenant] = self._versions.pop(tenant)
            del self._betas[tenant]
            self._version += 1
            self.metrics["retires"] += 1

    def publisher(self, tenant, *, reduce="mean") -> TenantPublisher:
        """A ``stream_chunk(publish_to=...)`` hook for one tenant."""
        return TenantPublisher(self, tenant, reduce)

    # --------------------------------------------------------------- read

    def snapshot(self) -> TenantSnapshot:
        """The current immutable snapshot (stacked lazily per version)."""
        snap = self._snap  # atomic reference read
        if snap is not None and snap.version == self._version:
            return snap
        with self._lock:
            if self._snap is None or self._snap.version != self._version:
                if not self._betas:
                    raise RuntimeError(
                        "TenantRegistry has no live tenants; publish() "
                        "at least one before snapshot()"
                    )
                tenants = list(self._betas)
                stacked = jnp.asarray(
                    np.stack([self._betas[t] for t in tenants])
                )
                self._snap = TenantSnapshot(
                    version=self._version,
                    betas=stacked,
                    slots={t: i for i, t in enumerate(tenants)},
                    versions=dict(self._versions),
                    retired=frozenset(self._retired),
                )
            return self._snap

    @property
    def version(self) -> int:
        """Global registry version (any publish/retire bumps it)."""
        return self._version

    def tenant_version(self, tenant) -> int:
        """A tenant's latest published version (named errors)."""
        with self._lock:
            if tenant in self._versions:
                return self._versions[tenant]
            if tenant in self._retired:
                raise RetiredTenantError(
                    f"tenant {tenant!r} is retired; re-register it with "
                    f"publish() before serving"
                )
            raise UnknownTenantError(
                f"unknown tenant {tenant!r}; registered tenants: "
                f"{sorted(map(repr, self._versions))}"
            )

    @property
    def tenant_ids(self) -> tuple:
        with self._lock:
            return tuple(self._versions)

    def stale_tenants(
        self, snapshot: TenantSnapshot, max_staleness: int
    ) -> list:
        """Tenants whose snapshot version trails their latest publish
        by more than ``max_staleness`` versions — plus any live tenant
        the snapshot does not know yet. The serving plane refreshes
        when this is non-empty for the tenants it is about to serve."""
        with self._lock:
            live = dict(self._versions)
        out = []
        for t, latest in live.items():
            held = snapshot.versions.get(t)
            if held is None or latest - held > max_staleness:
                out.append(t)
        return out
