"""Continuous-batching serving engine (slot-based, ragged positions).

Requests of different lengths share one decode batch: each of the B
slots advances at its own position (the ragged (B,) cache built by
``Model.init_cache(ragged=True)``). When a request finishes, its slot
is immediately refilled from the queue — a single-sequence prefill is
spliced into the batch cache at that slot, so the other slots never
stall. This is the vLLM-style scheduling loop adapted to static JAX
shapes (fixed slot count and cache width; no paging).

Supports the attention-cache families (dense / moe / vlm / audio and
gemma2's mixed local/global stacks). SSM/hybrid caches also splice (the
recurrent state is position-free), handled generically by scattering
every cache leaf with a batch dimension.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (len,) int32
    max_new: int


@dataclasses.dataclass
class _Slot:
    req: Request
    generated: list


class ContinuousBatchingEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        slots: int,
        max_seq: int,
        sample_fn: Callable | None = None,
    ):
        self.model = model
        self.params = params
        self.B = slots
        self.max_seq = max_seq
        self.cache = model.init_cache(slots, max_seq, ragged=True)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.slots: list[_Slot | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.completed: list[tuple[int, list]] = []
        self.sample_fn = sample_fn or (lambda logits: jnp.argmax(logits, -1))
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq=max_seq)
        )

    # ------------------------------------------------------------------ api
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq:
            raise ValueError("prompt exceeds cache width")
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000):
        """Drain the queue; returns {uid: generated tokens}."""
        ticks = 0
        while (any(self.slots) or self.queue) and ticks < max_ticks:
            self._fill_slots()
            self._tick()
            ticks += 1
        return dict(self.completed)

    # ------------------------------------------------------------- internals
    def _fill_slots(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self._insert(i, self.queue.popleft())

    def _insert(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": prompt}
        if self.model.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, self.model.cfg.frontend_tokens, self.model.cfg.d_model),
                jnp.dtype(self.model.cfg.dtype),
            )
        logits, c1 = self._prefill(self.params, batch)
        # splice the single-sequence cache into this slot
        new_cache = {}
        for key, val in self.cache.items():
            if key == "pos":
                new_cache[key] = val.at[slot].set(int(c1["pos"]))
                continue
            new_cache[key] = jax.tree.map(
                lambda big, small: big.at[:, slot : slot + 1].set(small),
                val, c1[key],
            )
        self.cache = new_cache
        first = self.sample_fn(logits)[0].astype(jnp.int32)  # (1,vocab)->()
        self.tokens = self.tokens.at[slot, 0].set(first)
        self.slots[slot] = _Slot(req=req, generated=[int(first)])
        self._maybe_finish(slot)

    def _tick(self):
        logits, self.cache = self._decode(self.params, self.cache, self.tokens)
        nxt = self.sample_fn(logits).astype(jnp.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.generated.append(int(nxt[i]))
            self.tokens = self.tokens.at[i, 0].set(nxt[i])
            self._maybe_finish(i)

    def _maybe_finish(self, i: int):
        s = self.slots[i]
        if s is None:
            return
        done = len(s.generated) >= s.req.max_new
        pos = int(self.cache["pos"][i]) if hasattr(
            self.cache["pos"], "__getitem__"
        ) else 0
        if pos >= self.max_seq - 1:
            done = True
        if done:
            self.completed.append((s.req.uid, s.generated[: s.req.max_new]))
            self.slots[i] = None
