"""ELM serving plane: micro-batching request server with hot-swap beta.

The paper's whole premise is that every node holds a *usable* model at
every consensus round — Algorithm 2 keeps learning chunk-by-chunk while
the per-node estimates beta_i stay valid predictors. This module is the
query side of that loop:

* ``BetaStore`` — a versioned, thread-safe publication point for beta
  snapshots. ``ConsensusEngine.stream_chunk(..., publish_to=store)``
  publishes the post-consensus stacked betas after every streaming
  event; readers get an immutable ``BetaSnapshot`` (version + arrays)
  with one atomic reference read, so a publish can never be observed
  half-applied.

* ``ELMServer`` — a micro-batching front-end over the fused predict
  kernel (kernels/elm_predict.py). Requests of varying row counts are
  packed FIFO into a small set of padded batch shapes (``buckets``) so
  every launch hits a compile-once jitted program; each packed batch is
  answered by one node replica's beta (round-robin across the V node
  models, or pinned per request — the paper's "any node answers
  locally"). Oversized requests are split into max-bucket chunks and
  reassembled.

Hot-swap protocol (bounded staleness):

1. ``flush()`` re-reads the store **at most once, at flush start**; all
   batches in one flush share that snapshot. Per-request atomicity is
   therefore structural: a request (even a split oversized one) is
   answered by exactly one version, never a mix.
2. The cached snapshot is refreshed whenever the store has advanced by
   more than ``max_staleness`` versions (0 = always serve the latest
   published beta at flush time). Every response carries the version
   that produced it, and the serve-time guarantee is
   ``store.version_at_flush - response.version <= max_staleness``.
3. ``freeze()`` pins the current snapshot (publishes keep landing in
   the store but are not picked up) — the ablation arm of
   ``benchmarks/serving_bench.py``; ``thaw()`` resumes hot-swapping.

* ``ContinuousELMServer`` — the continuous-batching mode (the idiom of
  ``examples/continuous_batching.py``, adapted to row-parallel ELM
  inference). Instead of FIFO buckets flushed on a tick, the server
  keeps one in-flight padded batch of ``slots`` rows per answering
  node: every ``step()`` admits pending rows into free slots (rows
  freed by completed requests are refilled mid-flight, and a request
  larger than the free slots is admitted *partially*, its remaining
  rows flowing into the next step), launches the compile-once fused
  predict on the padded batch, and completes whatever requests have
  all their rows served. Scheduling is deadline-aware: each request
  may carry a deadline, the packer orders pending rows by slack
  (earliest deadline first, FIFO among deadline-free requests), and a
  step whose head request would miss its deadline launches immediately
  even when the batch-fill gate (``min_fill``) says to wait.

Both servers share the int8-beta serving arm: ``beta_mode="int8"``
round-trips each served beta through the compression plane's per-tile
stochastic int8 quantizer (core/compression.int8_roundtrip, keyed
deterministically by snapshot version and node) — the bytes/latency
tradeoff row of benchmarks/serving_bench.py.

Multi-tenant mode: constructing either server over a
``serving.TenantRegistry`` (instead of a ``BetaStore``) switches it to
per-tenant serving — requests carry ``tenant=`` instead of ``node=``,
packing freely mixes tenants in one padded bucket, and each launch is
ONE stacked-beta fused predict (``kernels.elm_predict_ops.
predict_stacked``): the shared g(XW+b) row tile is computed once and
contracted against per-row gathered beta tiles from the snapshot's
(T, L, M) stacked tensor. The flush-level snapshot capture pins every
request's *per-tenant* version for the whole flush (split chunks
included), the staleness bound is per tenant
(``registry.stale_tenants``), and requests whose tenant was retired
mid-queue are rejected into ``server.rejections`` with the named
``RetiredTenantError`` instead of poisoning the flush.

The server itself is a single-dispatcher object (submit/flush from one
thread); the store is safe to publish into from another thread — the
serve-while-train loop in ``examples/elm_serving.py`` runs training
events and query traffic against the same store, and
``TenantRegistry.publisher(tenant)`` is the per-tenant
``stream_chunk(publish_to=...)`` hook.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.tenants import TenantRegistry, TenantSnapshot


# ---------------------------------------------------------------------------
# Versioned beta publication
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BetaSnapshot:
    """An immutable published model: stacked per-node betas + version."""

    version: int
    betas: jax.Array  # (V, L, M)

    @property
    def num_nodes(self) -> int:
        return self.betas.shape[0]


class BetaStore:
    """Atomic versioned publication point for consensus beta snapshots.

    ``publish`` bumps the version and swaps in a new immutable
    ``BetaSnapshot`` under a lock; ``snapshot`` is a single reference
    read (atomic in CPython), so readers never block publishers and can
    never observe a half-written update.
    """

    def __init__(self, betas=None):
        self._lock = threading.Lock()
        self._snap: BetaSnapshot | None = None
        if betas is not None:
            self.publish(betas)

    @staticmethod
    def _stack(betas) -> jax.Array:
        b = jnp.asarray(betas)
        if b.ndim == 2:  # single-model serving: V = 1
            b = b[None]
        if b.ndim != 3:
            raise ValueError(
                f"betas must be (L, M) or stacked (V, L, M), got {b.shape}"
            )
        return b

    def publish(self, betas) -> int:
        """Publish a new snapshot; returns its version (1-based)."""
        b = self._stack(betas)
        with self._lock:
            version = (self._snap.version if self._snap else 0) + 1
            self._snap = BetaSnapshot(version=version, betas=b)
            return version

    def snapshot(self) -> BetaSnapshot:
        snap = self._snap
        if snap is None:
            raise RuntimeError("BetaStore has no published betas yet")
        return snap

    @property
    def version(self) -> int:
        snap = self._snap
        return 0 if snap is None else snap.version


# ---------------------------------------------------------------------------
# Requests / responses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PredictRequest:
    uid: int
    x: np.ndarray  # (n, D) query rows
    node: int  # which node replica answers (0 in multi-tenant mode)
    v_submit: int  # store version when the request was accepted
    t_submit: float
    tenant: object = None  # multi-tenant mode: which model answers


@dataclasses.dataclass(frozen=True)
class PredictResponse:
    uid: int
    y: np.ndarray  # (n, M)
    version: int  # beta snapshot that produced y (whole response);
    # in multi-tenant mode this is the *per-tenant* version
    node: int
    latency_s: float
    tenant: object = None


def latency_percentiles(latencies_s) -> dict:
    """{p50, p99, mean} in milliseconds from a latency list."""
    if not len(latencies_s):
        return {"p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
    arr = np.asarray(latencies_s, np.float64) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p99_ms": float(np.percentile(arr, 99)),
        "mean_ms": float(np.mean(arr)),
    }


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------


class ELMServer:
    """Bucketed micro-batching ELM inference over a hot-swappable store.

    feature_map: a fusable map (RandomFeatureMap / RBFFeatureMap — the
      fused predict kernel runs g and the readout in one pass), any
      callable FeatureMap (materialized per batch), or None when
      requests already carry feature rows (deep-backbone heads).
    store: a ``BetaStore`` (hot-swap path) or a bare betas array
      (wrapped in a private store; still versioned).
    buckets: ascending padded row counts; each gets one compiled
      program. Requests longer than the largest bucket are split.
    max_staleness: how many published versions the served snapshot may
      trail the store by at flush time (0 = always re-read).
    beta_mode: "fp32" serves the published beta as-is; "int8"
      round-trips it through the compression plane's per-tile
      stochastic quantizer (deterministic in version and node) — the
      bytes/latency tradeoff arm.
    """

    #: p50/p99 are computed over a sliding window of this many requests
    LATENCY_WINDOW = 10_000

    BETA_MODES = ("fp32", "int8")

    def __init__(
        self,
        feature_map,
        store,
        *,
        buckets: tuple = (16, 64, 256, 1024),
        max_staleness: int = 0,
        use_kernel: bool | None = None,
        sample_fn: Callable | None = None,
        row_dtype=np.float32,
        beta_mode: str = "fp32",
        int8_tile: int = 128,
    ):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending unique, got {buckets}")
        if beta_mode not in self.BETA_MODES:
            raise ValueError(
                f"beta_mode must be one of {self.BETA_MODES}, got "
                f"{beta_mode!r}"
            )
        if int(max_staleness) < 0:
            raise ValueError(
                f"max_staleness must be >= 0 (trailing versions allowed "
                f"at flush time), got {max_staleness}"
            )
        if int(int8_tile) <= 0:
            raise ValueError(
                f"int8_tile must be a positive tile width, got {int8_tile}"
            )
        self.feature_map = feature_map
        self.registry = store if isinstance(store, TenantRegistry) else None
        if self.registry is not None:
            self.store = store  # multi-tenant mode: stacked-beta launches
        else:
            self.store = (
                store if isinstance(store, BetaStore) else BetaStore(store)
            )
        self.buckets = tuple(int(b) for b in buckets)
        self.max_staleness = int(max_staleness)
        self.use_kernel = use_kernel
        self.sample_fn = sample_fn  # optional post-map (e.g. argmax)
        self.row_dtype = np.dtype(row_dtype)  # every batch packs to this
        self.beta_mode = beta_mode
        self.int8_tile = int(int8_tile)
        self._row_dim = getattr(feature_map, "in_dim", None)  # else 1st req
        self._snap: BetaSnapshot | None = None
        self._frozen = False
        self._queue: deque[PredictRequest] = deque()
        self._leftover: list[PredictResponse] = []  # unclaimed by predict()
        self._uid = 0
        self._rr_node = 0
        self._fns: dict[int, Callable] = {}  # bucket rows -> compiled fn
        self._parts: dict[int, list] = {}  # uid -> chunks of a split req
        self._beta_q: dict[tuple, jax.Array] = {}  # (version, node) -> deq
        #: multi-tenant mode: (uid, tenant, error) for requests whose
        #: tenant left the pinned snapshot between submit and flush
        self.rejections: list[tuple] = []
        self.metrics = {
            "requests": 0, "responses": 0, "batches": 0,
            "rows": 0, "padded_rows": 0, "swaps": 0,
            "beta_bytes": 0, "rejected": 0, "latencies_s": [],
        }

    # ------------------------------------------------------------------ api

    def _coerce_rows(self, x) -> np.ndarray:
        """Validate one request's rows: (n>0, D) at the serving dtype."""
        x = np.asarray(x, dtype=self.row_dtype)
        if x.ndim == 1:
            x = x[None]
        if x.ndim != 2 or x.shape[0] == 0:
            raise ValueError(f"request must be (n>0, D) rows, got {x.shape}")
        if self._row_dim is None:
            self._row_dim = x.shape[1]
        elif x.shape[1] != self._row_dim:
            raise ValueError(
                f"request width {x.shape[1]} != serving width "
                f"{self._row_dim}"
            )
        return x

    def _next_node(self, node: int | None) -> int:
        """Round-robin over the *served* snapshot's node models.

        Uses the cached snapshot (refreshed by the bounded-staleness
        rule) rather than a fresh ``store.snapshot()`` per submit —
        the old per-request read was a lock-path hot-spot that also
        bypassed the ``max_staleness`` contract — and the rotation
        counter only ever advances by one, so a V change between
        submits re-wraps cleanly instead of skipping/repeating nodes
        under a shifting modulo base.
        """
        if node is not None:
            return node
        self._refresh_snapshot()
        node = self._rr_node % max(1, self._snap.num_nodes)
        self._rr_node = node + 1
        return node

    def _admit(self, node: int | None, tenant) -> int:
        """Validate the request's addressing mode; returns the node.

        Single-tenant (BetaStore) serving addresses a node replica
        (``node=``); multi-tenant (TenantRegistry) serving addresses a
        tenant model (``tenant=``). Mixing them raises a named error,
        and unknown/retired tenants are rejected here at submit time.
        """
        if self.registry is not None:
            if tenant is None:
                raise ValueError(
                    "tenant= is required when serving a TenantRegistry; "
                    "registered tenants: "
                    f"{sorted(map(repr, self.registry.tenant_ids))}"
                )
            if node is not None:
                raise ValueError(
                    "node= applies to single-tenant (BetaStore) serving; "
                    "this server serves a TenantRegistry — pin the model "
                    "with tenant= instead"
                )
            # raises the named Unknown/RetiredTenantError for bad ids
            self.registry.tenant_version(tenant)
            return 0
        if tenant is not None:
            raise ValueError(
                "tenant= applies to multi-tenant (TenantRegistry) "
                "serving; this server serves a BetaStore — pin the node "
                "replica with node= instead"
            )
        return self._next_node(node)

    def submit(self, x, *, node: int | None = None, tenant=None) -> int:
        """Queue one request of shape (n, D) (or (D,)); returns its uid.

        Rows are coerced to the server's ``row_dtype`` (one packed batch
        = one dtype, by contract) and D must match the feature map's
        input width (or the first request's, when the map doesn't say).
        node pins the answering replica; default round-robin across the
        store's V node models. Over a ``TenantRegistry`` pass ``tenant=``
        instead — packing freely mixes tenants in one stacked launch.
        Oversized requests are split into max-bucket chunks here and
        reassembled at flush.
        """
        node = self._admit(node, tenant)
        x = self._coerce_rows(x)
        uid = self._uid
        self._uid += 1
        self.metrics["requests"] += 1
        self.metrics["rows"] += x.shape[0]
        cap = self.buckets[-1]
        chunks = [x[s:s + cap] for s in range(0, x.shape[0], cap)]
        if len(chunks) > 1:
            self._parts[uid] = [None] * len(chunks)
        now = time.perf_counter()
        for part, chunk in enumerate(chunks):
            self._queue.append(PredictRequest(
                uid=uid if len(chunks) == 1 else (uid, part),
                x=chunk, node=node, tenant=tenant,
                v_submit=self.store.version, t_submit=now,
            ))
        return uid

    def flush(self) -> list[PredictResponse]:
        """Serve everything pending; returns responses in uid order.

        One store read for the whole flush (hot-swap point); FIFO
        packing per node into the smallest bucket that fits — in
        multi-tenant mode one "node" group mixes every tenant, so each
        packed batch is one stacked-beta launch. Includes any responses
        a ``predict()`` call served but did not claim.
        """
        queued = {r.tenant for r in self._queue if r.tenant is not None}
        self._refresh_snapshot(queued or None)
        responses = self._leftover
        self._leftover = []
        by_node: dict[int, list[PredictRequest]] = {}
        rejected: set = set()
        while self._queue:
            r = self._queue.popleft()
            if (
                self.registry is not None
                and r.tenant not in self._snap.slots
            ):
                uid = r.uid[0] if isinstance(r.uid, tuple) else r.uid
                if uid not in rejected:
                    rejected.add(uid)
                    self._reject(uid, r.tenant)
                self._parts.pop(uid, None)
                continue
            by_node.setdefault(r.node, []).append(r)
        served: list[PredictResponse] = []
        for node, reqs in by_node.items():
            for batch in self._pack(reqs):
                served.extend(self._launch(node, batch))
        served = self._reassemble(served)
        self._record_served(served)
        return sorted(responses + served, key=lambda r: r.uid)

    def _record_served(self, served: list) -> None:
        self.metrics["responses"] += len(served)
        lat = self.metrics["latencies_s"]
        lat.extend(r.latency_s for r in served)
        if len(lat) > self.LATENCY_WINDOW:  # long-running servers: bound it
            del lat[: len(lat) - self.LATENCY_WINDOW]

    def predict(self, x, *, node: int | None = None,
                tenant=None) -> np.ndarray:
        """Synchronous single-request convenience: submit + flush.

        Other requests pending at call time are served by the same
        flush; their responses are retained and returned by the next
        ``flush()`` rather than dropped.
        """
        uid = self.submit(x, node=node, tenant=tenant)
        mine = None
        for r in self.flush():
            if r.uid == uid:
                mine = r
            else:
                self._leftover.append(r)
        assert mine is not None
        return mine.y

    def freeze(self):
        """Pin the current snapshot; publishes are no longer picked up."""
        self._refresh_snapshot()
        self._frozen = True

    def thaw(self):
        self._frozen = False

    @property
    def served_version(self) -> int:
        return 0 if self._snap is None else self._snap.version

    def stats(self) -> dict:
        """Aggregate serving metrics incl. p50/p99 latency + padding."""
        m = dict(self.metrics)
        lat = m.pop("latencies_s")
        m.update(latency_percentiles(lat))
        total = m["rows"] + m["padded_rows"]
        m["padding_frac"] = m["padded_rows"] / total if total else 0.0
        return m

    # ------------------------------------------------------------- internals

    def _refresh_snapshot(self, tenants=None):
        """Bounded-staleness hot-swap point.

        Single-tenant: refresh when the store's global version trails by
        more than ``max_staleness``. Multi-tenant: per-tenant rule — a
        tenant that keeps publishing cannot pin everyone else's snapshot
        fresh, so refresh only when a *served* tenant (``tenants``, or
        any when None) is stale or missing from the snapshot.
        """
        if self._snap is None:
            self._snap = self.store.snapshot()
            return
        if self._frozen:
            return
        if self.registry is not None:
            stale = set(self.registry.stale_tenants(
                self._snap, self.max_staleness
            ))
            if tenants is not None:
                stale &= set(tenants)
            if stale:
                self._snap = self.store.snapshot()
                self.metrics["swaps"] += 1
            return
        latest = self.store.version
        if latest - self._snap.version > self.max_staleness:
            self._snap = self.store.snapshot()
            self.metrics["swaps"] += 1

    def _reject(self, uid, tenant) -> None:
        """Record a request whose tenant left the pinned snapshot
        between submit and flush: the named error lands in
        ``self.rejections`` instead of poisoning the whole flush
        (submit() already rejects unknown/retired tenants eagerly)."""
        try:
            self._snap._check(tenant)
        except KeyError as err:  # Unknown/RetiredTenantError
            self.rejections.append((uid, tenant, err))
            self.metrics["rejected"] += 1
            return
        raise AssertionError("rejected a servable tenant")

    def _pack(self, reqs: list) -> list[list]:
        """FIFO-pack requests into batches of <= max-bucket total rows."""
        batches, cur, rows = [], [], 0
        cap = self.buckets[-1]
        for r in reqs:
            if cur and rows + r.x.shape[0] > cap:
                batches.append(cur)
                cur, rows = [], 0
            cur.append(r)
            rows += r.x.shape[0]
        if cur:
            batches.append(cur)
        return batches

    def _bucket_for(self, rows: int) -> int:
        for b in self.buckets:
            if rows <= b:
                return b
        raise AssertionError("packing exceeded the largest bucket")

    def _compiled(self, bucket: int) -> Callable:
        fn = self._fns.get(bucket)
        if fn is None:
            fmap, use_kernel, sample = (
                self.feature_map, self.use_kernel, self.sample_fn,
            )

            def run(xpad, beta):
                from repro.kernels import elm_predict_ops

                y = elm_predict_ops.predict_map(
                    xpad, fmap, beta, use_kernel=use_kernel
                )
                return sample(y) if sample is not None else y

            fn = self._fns[bucket] = jax.jit(run)
        return fn

    def _compiled_stacked(self, bucket: int) -> Callable:
        """Compile-once stacked-beta program for one bucket: a batch
        mixing many tenants is ONE fused launch, no per-tenant
        recompilation (re-traced only when the snapshot's tenant count
        changes the stacked tensor's shape)."""
        key = ("stacked", bucket)
        fn = self._fns.get(key)
        if fn is None:
            fmap, use_kernel, sample = (
                self.feature_map, self.use_kernel, self.sample_fn,
            )

            def run(xpad, betas, tids):
                from repro.kernels import elm_predict_ops

                y = elm_predict_ops.predict_stacked(
                    xpad, fmap, betas, tids, use_kernel=use_kernel
                )
                return sample(y) if sample is not None else y

            fn = self._fns[key] = jax.jit(run)
        return fn

    def _stacked_for(self, snap: TenantSnapshot) -> jax.Array:
        """The served stacked (T, L, M) tensor: published, or its int8
        round-trip (deterministic in the snapshot version; cached so
        repeated launches pay quantization once per snapshot)."""
        if self.beta_mode == "fp32":
            return snap.betas
        key = (snap.version, "stacked")
        deq = self._beta_q.get(key)
        if deq is None:
            from repro.core.compression import (
                CompressionSpec, int8_roundtrip,
            )

            betas = snap.betas.astype(jnp.float32)
            flat = int8_roundtrip(
                betas.reshape(-1), self.int8_tile,
                jax.random.key(snap.version),
            )
            deq = flat.reshape(betas.shape)
            self._beta_q = {
                k: v for k, v in self._beta_q.items()
                if k[0] == snap.version
            }
            self._beta_q[key] = deq
            self.metrics["beta_bytes"] += CompressionSpec(
                mode="int8", tile=self.int8_tile
            ).message_bytes(int(betas.size))
        return deq

    def _beta_for(self, snap: BetaSnapshot, node: int) -> jax.Array:
        """The served beta for one node: published, or its int8
        round-trip (deterministic in version and node; cached per
        snapshot so repeated launches pay quantization once)."""
        idx = node % snap.num_nodes
        if self.beta_mode == "fp32":
            return snap.betas[idx]
        key = (snap.version, idx)
        deq = self._beta_q.get(key)
        if deq is None:
            from repro.core.compression import (
                CompressionSpec, int8_roundtrip,
            )

            beta = snap.betas[idx].astype(jnp.float32)
            flat = int8_roundtrip(
                beta.reshape(-1), self.int8_tile,
                jax.random.fold_in(jax.random.key(snap.version), idx),
            )
            deq = flat.reshape(beta.shape)
            # hold only the live snapshot's quantized betas
            self._beta_q = {
                k: v for k, v in self._beta_q.items()
                if k[0] == snap.version
            }
            self._beta_q[key] = deq
            self.metrics["beta_bytes"] += CompressionSpec(
                mode="int8", tile=self.int8_tile
            ).message_bytes(int(beta.size))
        return deq

    def _launch(self, node: int, batch: list) -> list[PredictResponse]:
        snap = self._snap
        rows = sum(r.x.shape[0] for r in batch)
        bucket = self._bucket_for(rows)
        X = np.zeros((bucket, batch[0].x.shape[1]), batch[0].x.dtype)
        off = 0
        for r in batch:
            X[off:off + r.x.shape[0]] = r.x
            off += r.x.shape[0]
        if self.registry is not None:
            # one stacked launch mixes every tenant in the batch; the
            # padded tail rows carry slot 0 (their hidden rows are
            # masked to zero, so the gathered beta contributes nothing)
            tids = np.zeros((bucket,), np.int32)
            off = 0
            for r in batch:
                tids[off:off + r.x.shape[0]] = snap.slot(r.tenant)
                off += r.x.shape[0]
            Y = np.asarray(self._compiled_stacked(bucket)(
                jnp.asarray(X), self._stacked_for(snap), jnp.asarray(tids)
            ))
        else:
            beta = self._beta_for(snap, node)
            Y = np.asarray(self._compiled(bucket)(jnp.asarray(X), beta))
        self.metrics["batches"] += 1
        self.metrics["padded_rows"] += bucket - rows
        now = time.perf_counter()
        out, off = [], 0
        for r in batch:
            n = r.x.shape[0]
            if self.registry is not None:
                # the flush-level snapshot pins every request's
                # per-tenant version for the whole flush
                version, rnode = snap.tenant_version(r.tenant), 0
            else:
                version, rnode = snap.version, node % snap.num_nodes
            out.append(PredictResponse(
                uid=r.uid, y=Y[off:off + n], version=version,
                node=rnode, tenant=r.tenant, latency_s=now - r.t_submit,
            ))
            off += n
        return out

    def _reassemble(self, responses: list) -> list[PredictResponse]:
        """Merge split-request chunk responses back into whole ones."""
        whole, pending = [], {}
        for r in responses:
            if isinstance(r.uid, tuple):
                uid, part = r.uid
                self._parts[uid][part] = r
                pending[uid] = True
            else:
                whole.append(r)
        for uid in pending:
            parts = self._parts.pop(uid)
            assert all(p is not None for p in parts)
            versions = {p.version for p in parts}
            # structural guarantee: one snapshot per flush, split chunks
            # are always flushed together
            assert len(versions) == 1, "split request straddled versions"
            whole.append(PredictResponse(
                uid=uid,
                y=np.concatenate([p.y for p in parts], axis=0),
                version=parts[0].version,
                node=parts[0].node,
                tenant=parts[0].tenant,
                latency_s=max(p.latency_s for p in parts),
            ))
        return whole


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Pending:
    """One admitted-but-unfinished request in the continuous server."""

    uid: int
    x: np.ndarray
    node: int
    deadline: float | None
    t_submit: float
    tenant: object = None  # multi-tenant mode: which model answers
    served: list = dataclasses.field(default_factory=list)
    offset: int = 0  # rows already served (mid-flight when 0 < offset < n)
    version: int | None = None  # pinned at the request's first launch

    @property
    def remaining(self) -> int:
        return self.x.shape[0] - self.offset

    @property
    def slack_key(self) -> tuple:
        """EDF order: earliest deadline first, FIFO among deadline-free."""
        return (
            self.deadline if self.deadline is not None else float("inf"),
            self.uid,
        )


class ContinuousELMServer(ELMServer):
    """Continuous-batching ELM inference: admit every step, refill
    freed slots mid-flight, schedule by deadline slack.

    Where ``ELMServer`` packs FIFO into padded buckets and serves only
    on ``flush()``, this server keeps one in-flight padded batch of
    ``slots`` rows per answering node and advances it with ``step()``:

    1. **Admission.** Pending requests are ordered by slack (earliest
       deadline first; deadline-free requests FIFO behind them) and
       their rows admitted into free slots. A request larger than the
       free slots is admitted *partially* — its remaining rows flow
       into the next step's batch, occupying slots freed by requests
       that completed (the mid-flight refill of
       ``examples/continuous_batching.py``, at row granularity).
    2. **Launch gate.** A step launches when ``force=True``, when any
       request is already mid-flight (never stall started work), when
       at least ``min_fill * slots`` rows are ready, or when the head
       request's slack has run out (``deadline - now <=
       deadline_slack_s``) — the deadline-aware force flush. An
       ungated step with too few rows returns [] and waits for more
       traffic. ``min_fill=0`` (default) always launches.
    3. **Completion.** Requests whose rows are all served complete
       immediately; their slots are free for the next step.

    Hot-swap protocol: the snapshot is re-read (same bounded-staleness
    rule as ``ELMServer``) only at steps where *no* request is
    mid-flight, and each request pins the version of its first launch —
    so a request is answered by exactly one beta version even when its
    rows span steps and publishes land in between.

    ``flush()`` force-steps until drained (same contract as
    ``ELMServer.flush``: responses in uid order, leftovers included),
    so ``predict()`` works unchanged. ``clock`` injects a time source
    for deterministic deadline tests.
    """

    def __init__(
        self,
        feature_map,
        store,
        *,
        slots: int = 256,
        min_fill: float = 0.0,
        deadline_slack_s: float = 0.0,
        clock: Callable[[], float] = time.perf_counter,
        **kw,
    ):
        if int(slots) <= 0:
            raise ValueError(
                f"slots must be a positive in-flight row count, got "
                f"{slots}"
            )
        if float(deadline_slack_s) < 0.0:
            raise ValueError(
                f"deadline_slack_s must be >= 0 seconds, got "
                f"{deadline_slack_s}"
            )
        super().__init__(feature_map, store, buckets=(int(slots),), **kw)
        if not 0.0 <= float(min_fill) <= 1.0:
            raise ValueError(f"min_fill must be in [0, 1], got {min_fill}")
        self.slots = int(slots)
        self.min_fill = float(min_fill)
        self.deadline_slack_s = float(deadline_slack_s)
        self.clock = clock
        self._pending: list[_Pending] = []
        self.metrics["steps"] = 0
        self.metrics["deadline_flushes"] = 0

    # ------------------------------------------------------------------ api

    def submit(self, x, *, node: int | None = None, tenant=None,
               deadline: float | None = None) -> int:
        """Queue one request; rows are admitted continuously by step().

        deadline: absolute time (on the server's ``clock``) by which
        the request should be served; orders admission (EDF) and
        force-launches partial batches about to miss. None = FIFO
        behind all deadlined requests. Over a ``TenantRegistry`` pass
        ``tenant=`` instead of ``node=``.
        """
        node = self._admit(node, tenant)
        x = self._coerce_rows(x)
        uid = self._uid
        self._uid += 1
        self.metrics["requests"] += 1
        self.metrics["rows"] += x.shape[0]
        self._pending.append(_Pending(
            uid=uid, x=x, node=node, tenant=tenant,
            deadline=None if deadline is None else float(deadline),
            t_submit=self.clock(),
        ))
        return uid

    def step(self, *, force: bool = False) -> list[PredictResponse]:
        """One admission + launch cycle; returns completed responses."""
        if not self._pending:
            return []
        now = self.clock()
        mid_flight = any(p.offset > 0 for p in self._pending)
        if not mid_flight:
            # refresh only between requests: every row of a request is
            # served by the version pinned at its first launch
            self._refresh_snapshot(
                {p.tenant for p in self._pending}
                if self.registry is not None else None
            )
            if self.registry is not None:
                # nothing is mid-flight, so every pending request is
                # still unstarted: reject the ones whose tenant left
                # the fresh snapshot (named error in self.rejections)
                keep = []
                for p in self._pending:
                    if p.tenant in self._snap.slots:
                        keep.append(p)
                    else:
                        self._reject(p.uid, p.tenant)
                self._pending = keep
                if not self._pending:
                    return []
        self._pending.sort(key=lambda p: p.slack_key)
        head = self._pending[0]
        ready = sum(p.remaining for p in self._pending)
        head_would_miss = (
            head.deadline is not None
            and head.deadline - now <= self.deadline_slack_s
        )
        launch = (
            force
            or mid_flight
            or ready >= self.min_fill * self.slots
            or head_would_miss
        )
        if not launch:
            return []
        if head_would_miss and ready < self.min_fill * self.slots:
            self.metrics["deadline_flushes"] += 1
        # admit rows (EDF order) into per-node batches of <= slots rows
        batches: dict[int, list[tuple[_Pending, int, int]]] = {}
        fill: dict[int, int] = {}
        snap = self._snap
        for p in self._pending:
            if (
                self.registry is not None
                and p.tenant not in snap.slots
            ):
                # admitted while another request was mid-flight and the
                # pinned snapshot predates this tenant: wait for the
                # next refresh point (retired tenants are rejected
                # there instead)
                continue
            free = self.slots - fill.get(p.node, 0)
            take = min(free, p.remaining)
            if take <= 0:
                continue
            batches.setdefault(p.node, []).append((p, p.offset, take))
            fill[p.node] = fill.get(p.node, 0) + take
            p.offset += take
        for node, parts in batches.items():
            X = np.zeros((self.slots, parts[0][0].x.shape[1]),
                         self.row_dtype)
            tids = np.zeros((self.slots,), np.int32)
            off = 0
            for p, start, take in parts:
                X[off:off + take] = p.x[start:start + take]
                if self.registry is not None:
                    tids[off:off + take] = snap.slot(p.tenant)
                off += take
            if self.registry is not None:
                Y = np.asarray(self._compiled_stacked(self.slots)(
                    jnp.asarray(X), self._stacked_for(snap),
                    jnp.asarray(tids),
                ))
            else:
                Y = np.asarray(self._compiled(self.slots)(
                    jnp.asarray(X), self._beta_for(snap, node)
                ))
            self.metrics["batches"] += 1
            self.metrics["padded_rows"] += self.slots - off
            off = 0
            for p, _, take in parts:
                if p.version is None:
                    # multi-tenant mode pins the *per-tenant* version
                    # of the request's first launch
                    p.version = (
                        snap.tenant_version(p.tenant)
                        if self.registry is not None else snap.version
                    )
                p.served.append(Y[off:off + take])
                off += take
        self.metrics["steps"] += 1
        done_at = self.clock()
        completed = []
        still = []
        for p in self._pending:
            if p.remaining == 0:
                completed.append(PredictResponse(
                    uid=p.uid,
                    y=np.concatenate(p.served, axis=0),
                    version=p.version,
                    node=(
                        0 if self.registry is not None
                        else p.node % snap.num_nodes
                    ),
                    tenant=p.tenant,
                    latency_s=done_at - p.t_submit,
                ))
            else:
                still.append(p)
        self._pending = still
        completed.sort(key=lambda r: r.uid)
        self._record_served(completed)
        return completed

    def flush(self) -> list[PredictResponse]:
        """Force-step until drained; responses in uid order (plus any
        leftovers a ``predict()`` call served but did not claim)."""
        responses = self._leftover
        self._leftover = []
        while self._pending:
            responses.extend(self.step(force=True))
        return sorted(responses, key=lambda r: r.uid)

    def stats(self) -> dict:
        m = super().stats()
        m["pending_rows"] = sum(p.remaining for p in self._pending)
        return m
