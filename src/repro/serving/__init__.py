from repro.serving.elm_server import (
    BetaSnapshot,
    BetaStore,
    ContinuousELMServer,
    ELMServer,
    PredictRequest,
    PredictResponse,
    latency_percentiles,
)
from repro.serving.engine import ContinuousBatchingEngine, Request
from repro.serving.tenants import (
    RetiredTenantError,
    TenantPublisher,
    TenantRegistry,
    TenantSnapshot,
    UnknownTenantError,
)

__all__ = [
    "BetaSnapshot",
    "BetaStore",
    "ContinuousBatchingEngine",
    "ContinuousELMServer",
    "ELMServer",
    "PredictRequest",
    "PredictResponse",
    "Request",
    "RetiredTenantError",
    "TenantPublisher",
    "TenantRegistry",
    "TenantSnapshot",
    "UnknownTenantError",
    "latency_percentiles",
]
