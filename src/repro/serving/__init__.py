from repro.serving.elm_server import (
    BetaSnapshot,
    BetaStore,
    ContinuousELMServer,
    ELMServer,
    PredictRequest,
    PredictResponse,
    latency_percentiles,
)
from repro.serving.engine import ContinuousBatchingEngine, Request

__all__ = [
    "BetaSnapshot",
    "BetaStore",
    "ContinuousBatchingEngine",
    "ContinuousELMServer",
    "ELMServer",
    "PredictRequest",
    "PredictResponse",
    "Request",
    "latency_percentiles",
]
