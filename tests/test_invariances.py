"""Model-level invariance tests: causality, MoE exactness, VLM masking."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.lm import make_lm_batches
from repro.models import Model
from repro.models.moe import moe_ffn, init_moe


@pytest.mark.parametrize("arch", ["gemma2-2b", "dbrx-132b", "zamba2-1.2b",
                                  "mamba2-780m"])
def test_causality(arch):
    """Perturbing a future token must not change past outputs."""
    cfg = registry()[arch].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 1, 64
    batch = next(make_lm_batches(cfg.vocab_size, B, S, 1))
    t1 = batch["tokens"]
    t2 = t1.at[:, -1].set((t1[:, -1] + 13) % cfg.vocab_size)

    def hidden(tokens):
        h, _ = m._embed_inputs(params, {"tokens": tokens})
        out, _, _ = m._trunk(params, h, jnp.arange(S), want_cache=False)
        return out

    h1, h2 = hidden(t1), hidden(t2)
    # every position strictly before the perturbed one is unchanged
    np.testing.assert_allclose(h1[:, :-1], h2[:, :-1], atol=1e-5)
    # ...and the perturbed position itself IS affected (non-degenerate)
    assert float(jnp.max(jnp.abs(h1[:, -1] - h2[:, -1]))) > 1e-4


def test_moe_no_drop_equals_dense_mixture():
    """With capacity ample, the capacity-scatter MoE must equal the
    explicit dense top-k mixture."""
    key = jax.random.key(0)
    B, S, d, f, E, k = 2, 16, 8, 16, 4, 2
    p = init_moe(key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (B, S, d))
    out, metrics = moe_ffn(p, x, top_k=k, capacity_factor=float(E))
    assert float(metrics["moe_drop_frac"]) == 0.0

    # dense reference: run every expert on every token, combine top-k
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, k)
    gv = gv / jnp.sum(gv, -1, keepdims=True)
    gate = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, p["w_gate"]))
    up = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    every = jnp.einsum("besf,efd->besd", gate * up, p["w_down"])  # (B,E,S,d)
    # gather per-token selected experts
    ref = jnp.zeros_like(x)
    for slot in range(k):
        idx = gi[..., slot]  # (B, S)
        picked = jnp.take_along_axis(
            every, idx[:, None, :, None], axis=1
        )[:, 0]  # (B, S, d)
        ref = ref + gv[..., slot][..., None] * picked
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_moe_drop_frac_increases_when_capacity_tight():
    key = jax.random.key(2)
    B, S, d, f, E, k = 2, 32, 8, 16, 4, 2
    p = init_moe(key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (B, S, d))
    _, loose = moe_ffn(p, x, top_k=k, capacity_factor=4.0)
    _, tight = moe_ffn(p, x, top_k=k, capacity_factor=0.5)
    assert float(tight["moe_drop_frac"]) > float(loose["moe_drop_frac"])


def test_vlm_image_positions_excluded_from_loss():
    """Loss must be computed over text labels only (image prefix sliced)."""
    cfg = registry()["internvl2-2b"].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 1, 32
    batch = next(make_lm_batches(cfg.vocab_size, B, S, 1))
    img1 = 0.1 * jax.random.normal(
        jax.random.key(1), (B, cfg.frontend_tokens, cfg.d_model)
    )
    l1, _ = m.loss(params, dict(batch, image_embeds=img1))
    # masking a LABEL to ignore changes the loss denominator
    lab = batch["labels"].at[:, 0].set(-1)
    l2, _ = m.loss(params, dict(batch, labels=lab, image_embeds=img1))
    assert not np.isclose(float(l1), float(l2))


def test_act_shard_config_is_semantics_preserving():
    """act_shard must not change the computed loss (sharding hint only)."""
    cfg = registry()["gemma2-2b"].reduced()
    m1 = Model(cfg)
    m2 = Model(dataclasses.replace(cfg, act_shard="batch"))
    params = m1.init(jax.random.key(0))
    batch = next(make_lm_batches(cfg.vocab_size, 2, 32, 1))
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
