"""Continuous-batching engine: ragged decode == sequential generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import Model
from repro.serving import ContinuousBatchingEngine, Request


def _sequential_greedy(model, params, prompt, max_new, max_seq):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = model.prefill(params, batch, max_seq=max_seq)
    toks = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(max_new - 1):
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        toks.append(int(tok[0, 0]))
    return toks


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "gemma2-2b"])
def test_engine_matches_sequential(arch):
    cfg = registry()[arch].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    max_seq = 96
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 17), max_new=6),
        Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 9), max_new=9),
        Request(uid=2, prompt=rng.integers(0, cfg.vocab_size, 25), max_new=4),
    ]
    # reference: each request generated alone
    expected = {
        r.uid: _sequential_greedy(m, params, r.prompt, r.max_new, max_seq)
        for r in reqs
    }
    # engine: 2 slots, 3 requests -> slot reuse mid-flight
    eng = ContinuousBatchingEngine(m, params, slots=2, max_seq=max_seq)
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    assert set(out) == {0, 1, 2}
    for uid in out:
        assert out[uid] == expected[uid], (
            uid, out[uid], expected[uid]
        )


def test_engine_ragged_positions_advance_independently():
    cfg = registry()["h2o-danube-1.8b"].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(1))
    eng = ContinuousBatchingEngine(m, params, slots=2, max_seq=64)
    rng = np.random.default_rng(1)
    eng.submit(Request(uid=0, prompt=rng.integers(0, 64, 5), max_new=3))
    eng.submit(Request(uid=1, prompt=rng.integers(0, 64, 20), max_new=3))
    eng._fill_slots()
    pos = np.asarray(eng.cache["pos"])
    assert pos[0] == 5 and pos[1] == 20  # per-slot positions
    out = eng.run()
    assert len(out[0]) == 3 and len(out[1]) == 3
