"""Trip-count-weighted HLO analyzer (analysis/hlo.py)."""

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.hlo import analyze_module, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = lax.scan(body, x, None, length=10)
        return c

    x = jnp.zeros((64, 64))
    s = analyze_module(_compile_text(f, x, x))
    assert abs(s.flops - 10 * 2 * 64**3) / (10 * 2 * 64**3) < 0.05


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = lax.scan(inner, c, None, length=4)
            return ci, None
        c, _ = lax.scan(outer, x, None, length=3)
        return c

    x = jnp.zeros((32, 32))
    s = analyze_module(_compile_text(f, x, x))
    expect = 12 * 2 * 32**3
    assert abs(s.flops - expect) / expect < 0.05


def test_grad_with_remat_counts_recompute():
    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = lax.scan(jax.checkpoint(body), x, None, length=10)
        return jnp.sum(c)

    x = jnp.zeros((64, 64))
    s = analyze_module(_compile_text(jax.grad(g, argnums=(0, 1)), x, x))
    expect = 40 * 2 * 64**3  # fwd + recompute + 2 bwd matmuls per layer
    assert abs(s.flops - expect) / expect < 0.05


def test_parse_handles_index_comments():
    txt = """
HloModule m

ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %t = (f32[4]{0}, /*index=5*/f32[4]{0}) tuple(%p, %p)
  ROOT %g = f32[4]{0} get-tuple-element(%t), index=0
}
"""
    mod = parse_module(txt)
    assert mod["entry"] == "main"
    kinds = [op.kind for op in mod["computations"]["main"].ops]
    assert "tuple" in kinds


def test_dot_flops_formula():
    def f(a, b):
        return jnp.einsum("ij,jk->ik", a, b)

    a = jnp.zeros((16, 32))
    b = jnp.zeros((32, 8))
    s = analyze_module(_compile_text(f, a, b))
    assert s.flops == 2 * 16 * 32 * 8


def test_no_collectives_single_device():
    def f(a):
        return a * 2

    s = analyze_module(_compile_text(f, jnp.zeros((4,))))
    assert s.collective_bytes_total == 0
