"""Graphs and consensus machinery (paper Sec. III-A, Appendix C)."""

import numpy as np
import pytest

from repro.core import consensus


@pytest.mark.parametrize("builder,V", [
    (consensus.ring, 8), (consensus.line, 5), (consensus.complete, 6),
    (consensus.star, 7),
])
def test_connected_graphs(builder, V):
    g = builder(V)
    assert g.num_nodes == V
    assert g.is_connected
    assert g.algebraic_connectivity > 0


def test_hypercube_properties():
    g = consensus.hypercube(4)
    assert g.num_nodes == 16
    assert np.all(g.degrees == 4)
    assert g.d_max == 4


def test_torus_degrees():
    g = consensus.torus2d(4, 4)
    assert np.all(g.degrees == 4)


def test_paper_fig2_network():
    """Paper Fig. 2: V=4 nodes, d_max=2."""
    g = consensus.paper_fig2()
    assert g.num_nodes == 4
    assert g.d_max == 2
    assert g.gamma_upper_bound() == pytest.approx(0.5)
    # the paper's gamma=1/2.1 is admissible, gamma=1/1.9 is not
    assert 1 / 2.1 < g.gamma_upper_bound() < 1 / 1.9


def test_random_geometric_connected():
    g = consensus.random_geometric(25, radius=0.35, seed=1)
    assert g.num_nodes == 25
    assert g.is_connected


def test_disconnected_detection():
    a = np.zeros((4, 4))
    a[0, 1] = a[1, 0] = 1.0
    a[2, 3] = a[3, 2] = 1.0
    g = consensus.Graph(a)
    assert not g.is_connected


def test_metropolis_doubly_stochastic():
    g = consensus.random_geometric(12, radius=0.5, seed=3)
    W = g.metropolis_weights()
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    assert np.all(W >= -1e-12)


def test_dc_elm_iteration_matrix_spectrum():
    """W has eigenvalue 1 with multiplicity L; rest < 1 (=> convergence)."""
    rng = np.random.default_rng(0)
    V, L = 4, 3
    g = consensus.ring(V)
    omegas = []
    for _ in range(V):
        H = rng.normal(size=(20, L))
        omegas.append(np.linalg.inv(np.eye(L) / (V * 4.0) + H.T @ H))
    W = consensus.dc_elm_iteration_matrix(
        g, np.stack(omegas), gamma=0.9 / g.d_max, VC=V * 4.0
    )
    ev = np.sort(np.abs(np.linalg.eigvals(W)))[::-1]
    np.testing.assert_allclose(ev[:L], 1.0, atol=1e-9)
    rho = consensus.essential_spectral_radius(W, L)
    assert rho < 1.0


def test_build_dispatcher():
    assert consensus.build("ring", 6).name == "ring6"
    assert consensus.build("hypercube", 8).num_nodes == 8
    assert consensus.build("torus", 12).num_nodes == 12
    with pytest.raises(ValueError):
        consensus.build("hypercube", 6)
    with pytest.raises(ValueError):
        consensus.build("nope", 4)
