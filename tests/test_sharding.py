"""PartitionSpec assignment rules (distributed/sharding.py)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed.sharding import MeshAxes, param_pspecs
from repro.models import Model

AXES_TRAIN = MeshAxes(
    node=("data",), fsdp=(), model="model",
    sizes={"data": 16, "model": 16},
)
AXES_POD = MeshAxes(
    node=("pod",), fsdp=("data",), model="model",
    sizes={"pod": 2, "data": 16, "model": 16},
)
AXES_SERVE = MeshAxes(
    node=(), fsdp=("data",), model="model",
    sizes={"data": 16, "model": 16},
)


def _specs(arch, axes, node_dim):
    cfg = registry()[arch]
    shapes = jax.eval_shape(Model(cfg).init, jax.random.key(0))
    if node_dim:
        V = max(axes.node_count, 1)
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((V,) + s.shape, s.dtype), shapes
        )
    return cfg, shapes, param_pspecs(cfg, axes, shapes, node_dim=node_dim)


def test_dense_divisible_heads_sharded():
    cfg, shapes, specs = _specs("qwen2-72b", AXES_POD, node_dim=True)
    wq = specs["layers"]["attn"]["wq"]
    # (V, L, d, H, hd): node, -, fsdp, model, -
    assert wq == P("pod", None, "data", "model", None)
    # vocab divisible: embedding sharded on vocab + fsdp on d
    assert specs["embed"] == P("pod", "model", "data")


def test_nondivisible_heads_replicated():
    cfg, shapes, specs = _specs("starcoder2-3b", AXES_TRAIN, node_dim=True)
    # 24 heads % 16 != 0 -> attention replicated over model
    assert specs["layers"]["attn"]["wq"] == P("data", None, None, None, None)
    # but MLP f=12288 divides -> sharded
    assert specs["layers"]["mlp"]["w_gate"] == P("data", None, None, "model")


def test_moe_expert_parallel_when_divisible():
    _, _, specs = _specs("dbrx-132b", AXES_POD, node_dim=True)
    # 16 experts over 16 chips: expert-parallel
    assert specs["layers"]["moe"]["w_gate"] == P("pod", None, "model", "data", None)


def test_moe_tensor_parallel_fallback():
    _, _, specs = _specs("grok-1-314b", AXES_POD, node_dim=True)
    # 8 experts < 16 chips: fall back to d_ff sharding
    assert specs["layers"]["moe"]["w_gate"] == P("pod", None, None, "data", "model")


def test_ssm_head_sharding():
    _, _, specs = _specs("mamba2-780m", AXES_TRAIN, node_dim=True)
    # 48 ssm heads % 16 == 0 -> inner projections shard over model
    assert specs["layers"]["mamba"]["w_z"] == P("data", None, None, "model")
    assert specs["layers"]["mamba"]["out_proj"] == P("data", None, "model", None)
    # shared B/C projections stay replicated
    assert specs["layers"]["mamba"]["w_B"] == P("data", None, None, None)


def test_vocab_not_divisible_replicated():
    _, _, specs = _specs("internvl2-2b", AXES_TRAIN, node_dim=True)
    # 92553 % 16 != 0 -> vocab dim replicated, d sharded only under fsdp
    assert specs["embed"] == P("data", None, None)


def test_serve_mode_no_node_dim():
    _, shapes, specs = _specs("gemma2-2b", AXES_SERVE, node_dim=False)
    # embed (vocab, d): vocab 256000 % 16 == 0
    assert specs["embed"] == P("model", "data")
    for spec, shape in zip(jax.tree.leaves(specs), jax.tree.leaves(shapes)):
        assert len(spec) == len(shape.shape)


def test_all_specs_rank_match():
    for arch in registry():
        for axes, nd in [(AXES_TRAIN, True), (AXES_POD, True), (AXES_SERVE, False)]:
            _, shapes, specs = _specs(arch, axes, node_dim=nd)
            for spec, shape in zip(
                jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)
                ),
                jax.tree.leaves(shapes),
            ):
                assert len(spec) <= len(shape.shape), (arch, spec, shape.shape)
                # every dim sharded by an axis must divide
                for dim, ax in zip(shape.shape, list(spec)):
                    if ax is None:
                        continue
                    axs = ax if isinstance(ax, tuple) else (ax,)
                    size = 1
                    for a in axs:
                        size *= axes.sizes[a]
                    assert dim % size == 0, (arch, spec, shape.shape)
