"""Continuous-batching server: bitwise scheduler parity with FIFO
bucketing, deadline ordering under a scripted clock, mid-flight slot
refill, hot-swap atomicity across admissions, and the int8-beta arm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.features import make_random_features
from repro.serving import BetaStore, ContinuousELMServer, ELMServer

D, L, M, V = 6, 32, 4, 3
SLOTS = 16


@pytest.fixture
def fmap():
    return make_random_features(jax.random.key(0), D, L)


@pytest.fixture
def betas():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.standard_normal((V, L, M)), jnp.float32)


def _stream(sizes, seed=2):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, D)).astype(np.float32) for n in sizes]


class Clock:
    """A scripted time source for deterministic deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# Scheduler parity
# ---------------------------------------------------------------------------


def test_bitwise_parity_with_fifo_bucketing(fmap, betas):
    """Same pinned stream through continuous and FIFO at the same
    compiled padded shape -> bitwise-identical responses, including a
    request larger than the slot count (partial admission)."""
    store = BetaStore(betas)
    reqs = _stream([3, 7, 16, 1, 40, 5, 2, 12])
    ref = ELMServer(fmap, store, buckets=(SLOTS,))
    for i, x in enumerate(reqs):
        ref.submit(x, node=i % V)
    ref_out = {r.uid: r for r in ref.flush()}

    cont = ContinuousELMServer(fmap, store, slots=SLOTS)
    for i, x in enumerate(reqs):
        cont.submit(x, node=i % V)
    out = {r.uid: r for r in cont.flush()}

    assert set(out) == set(ref_out)
    for uid in out:
        assert np.array_equal(out[uid].y, ref_out[uid].y)
        assert out[uid].version == ref_out[uid].version
        assert out[uid].node == ref_out[uid].node


def test_parity_under_interleaved_steps(fmap, betas):
    """Stepping between submits (different batch compositions) still
    matches the all-at-once FIFO flush bitwise."""
    store = BetaStore(betas)
    reqs = _stream([5, 9, 2, 14, 4, 30], seed=5)
    ref = ELMServer(fmap, store, buckets=(SLOTS,))
    for i, x in enumerate(reqs):
        ref.submit(x, node=i % V)
    ref_out = {r.uid: r.y for r in ref.flush()}

    cont = ContinuousELMServer(fmap, store, slots=SLOTS)
    got = {}
    for i, x in enumerate(reqs):
        cont.submit(x, node=i % V)
        for r in cont.step():
            got[r.uid] = r.y
    for r in cont.flush():
        got[r.uid] = r.y
    assert set(got) == set(ref_out)
    for uid in got:
        assert np.array_equal(got[uid], ref_out[uid])


def test_predict_roundtrip(fmap, betas):
    srv = ContinuousELMServer(fmap, BetaStore(betas), slots=8)
    x = _stream([5])[0]
    y = srv.predict(x, node=1)
    ref = np.asarray(fmap(jnp.asarray(x)) @ betas[1])
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Deadline scheduling (scripted clock)
# ---------------------------------------------------------------------------


def test_edf_admission_order(fmap, betas):
    """Earlier deadlines are admitted first; deadline-free go FIFO
    behind all deadlined requests."""
    clock = Clock()
    srv = ContinuousELMServer(
        fmap, BetaStore(betas), slots=4, min_fill=1.0, clock=clock
    )
    xs = _stream([2, 2, 1])
    u_late = srv.submit(xs[0], node=0, deadline=10.0)
    u_soon = srv.submit(xs[1], node=0, deadline=1.0)
    u_none = srv.submit(xs[2], node=0)
    done = srv.step()  # 5 rows ready >= 4: launches; EDF fills 4 slots
    assert sorted(r.uid for r in done) == sorted([u_soon, u_late])
    done = srv.step(force=True)
    assert [r.uid for r in done] == [u_none]


def test_min_fill_gate_waits_then_deadline_forces(fmap, betas):
    clock = Clock()
    srv = ContinuousELMServer(
        fmap, BetaStore(betas), slots=8, min_fill=1.0,
        deadline_slack_s=0.5, clock=clock,
    )
    uid = srv.submit(_stream([2])[0], node=0, deadline=5.0)
    clock.t = 0.0
    assert srv.step() == []  # 2/8 rows, slack 4.5s: wait
    assert srv.metrics["batches"] == 0
    clock.t = 4.8  # slack 0.2 <= 0.5: the head would miss -> force
    done = srv.step()
    assert [r.uid for r in done] == [uid]
    assert srv.metrics["deadline_flushes"] == 1


def test_deadline_free_traffic_respects_min_fill(fmap, betas):
    clock = Clock()
    srv = ContinuousELMServer(
        fmap, BetaStore(betas), slots=8, min_fill=0.5, clock=clock,
    )
    srv.submit(_stream([3])[0], node=0)
    assert srv.step() == []  # 3 < 4 = min_fill * slots
    srv.submit(_stream([2], seed=3)[0], node=0)
    done = srv.step()  # 5 >= 4: launches
    assert len(done) == 2


def test_latency_measured_on_injected_clock(fmap, betas):
    clock = Clock()
    srv = ContinuousELMServer(fmap, BetaStore(betas), slots=4, clock=clock)
    uid = srv.submit(_stream([2])[0], node=0)
    clock.t = 1.5
    (r,) = srv.step()
    assert r.uid == uid
    assert r.latency_s == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# Mid-flight slot refill
# ---------------------------------------------------------------------------


def test_mid_flight_refill(fmap, betas):
    """A request larger than slots spans steps; freed slots take new
    requests alongside its remaining rows."""
    srv = ContinuousELMServer(fmap, BetaStore(betas), slots=4)
    big_x = _stream([10])[0]
    big = srv.submit(big_x, node=0)
    assert srv.step() == []  # rows 0-3 in flight
    assert srv.stats()["pending_rows"] == 6
    small = srv.submit(_stream([1], seed=7)[0], node=0)
    assert srv.step() == []  # rows 4-7 (big is EDF-first: lower uid)
    done = srv.step()  # rows 8-9 + the small request share the batch
    assert sorted(r.uid for r in done) == sorted([big, small])
    assert srv.metrics["steps"] == 3
    big_y = next(r for r in done if r.uid == big).y
    ref = np.asarray(fmap(jnp.asarray(big_x)) @ betas[0])
    np.testing.assert_allclose(big_y, ref, rtol=1e-4, atol=1e-5)
    assert srv.stats()["pending_rows"] == 0


def test_started_request_never_stalls(fmap, betas):
    """The launch gate ignores min_fill while any request is mid-flight."""
    srv = ContinuousELMServer(
        fmap, BetaStore(betas), slots=4, min_fill=1.0
    )
    uid = srv.submit(_stream([6])[0], node=0)
    assert srv.step(force=True) == []  # 4 rows launched, 2 remain
    # remaining 2 rows < min_fill * 4, but the request is mid-flight
    done = srv.step()
    assert [r.uid for r in done] == [uid]


# ---------------------------------------------------------------------------
# Hot-swap atomicity across admissions
# ---------------------------------------------------------------------------


def test_version_pinned_across_straddling_publish(fmap, betas):
    """A publish landing between the steps of one request does not
    split it across versions; the next request sees the new beta."""
    store = BetaStore(betas)
    srv = ContinuousELMServer(fmap, store, slots=4)
    x = _stream([10])[0]
    uid = srv.submit(x, node=0)
    srv.step()  # first 4 rows under v1
    v2 = store.publish(betas * 2.0)
    (r,) = srv.flush()
    assert r.uid == uid and r.version == v2 - 1
    # every row was served by v1's beta
    ref = np.asarray(fmap(jnp.asarray(x)) @ betas[0])
    np.testing.assert_allclose(r.y, ref, rtol=1e-4, atol=1e-5)
    # a fresh request is served by v2
    uid2 = srv.submit(x, node=0)
    (r2,) = srv.flush()
    assert r2.uid == uid2 and r2.version == v2
    ref2 = np.asarray(fmap(jnp.asarray(x)) @ (betas[0] * 2.0))
    np.testing.assert_allclose(r2.y, ref2, rtol=1e-4, atol=1e-5)


def test_no_refresh_while_any_request_mid_flight(fmap, betas):
    """Even a *new* request admitted next to a mid-flight one is served
    from the pinned snapshot (one snapshot per in-flight batch)."""
    store = BetaStore(betas)
    srv = ContinuousELMServer(fmap, store, slots=4)
    big = srv.submit(_stream([6])[0], node=0)
    srv.step()  # big mid-flight under v1
    store.publish(betas * 3.0)
    small = srv.submit(_stream([2], seed=9)[0], node=0)
    done = srv.flush()
    versions = {r.uid: r.version for r in done}
    assert versions[big] == 1
    assert versions[small] == 1  # admitted mid-flight: pinned snapshot
    # drained now: the next request picks up the publish
    u3 = srv.submit(_stream([1], seed=11)[0], node=0)
    (r3,) = srv.flush()
    assert r3.uid == u3 and r3.version == 2
    assert srv.metrics["swaps"] == 1


# ---------------------------------------------------------------------------
# int8-beta serving arm
# ---------------------------------------------------------------------------


def test_int8_arm_close_and_accounted(fmap, betas):
    store = BetaStore(betas)
    x = _stream([8])[0]
    y_fp = ELMServer(fmap, store, buckets=(8,)).predict(x, node=1)
    srv = ContinuousELMServer(
        fmap, store, slots=8, beta_mode="int8", int8_tile=32
    )
    y_q = srv.predict(x, node=1)
    rel = np.max(np.abs(y_q - y_fp)) / (np.max(np.abs(y_fp)) + 1e-9)
    assert 0.0 < rel < 0.05  # quantized: differs, but closely
    assert srv.metrics["beta_bytes"] > 0
    # per-(version, node) quantization is cached: a second request for
    # the same node adds no bytes
    before = srv.metrics["beta_bytes"]
    srv.predict(x, node=1)
    assert srv.metrics["beta_bytes"] == before
    with pytest.raises(ValueError, match="beta_mode"):
        ELMServer(fmap, store, beta_mode="int4")
