"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) host device; multi-device tests spawn subprocesses that
set --xla_force_host_platform_device_count themselves."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_py(code: str, *, devices: int = 1, timeout: int = 600):
    """Run a python snippet in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
        )
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=timeout,
    )


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.key(0)
