"""The statistics plane: fused kernel parity, chunked accumulation,
Cholesky finalization, and the feature-map satellites."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dc_elm, elm, engine, features, online, stats
from repro.kernels import gram_ops
from repro.kernels.elm_stats import elm_stats_pallas
from repro.kernels.elm_stats_ref import elm_stats_scan, hidden_reference

ALL_ACTIVATIONS = ["sigmoid", "tanh", "relu", "sin", "identity", "rbf"]


def _problem(N, D, L, M, activation="sigmoid", dtype=jnp.float32, seed=0):
    fmap = features.make_random_features(jax.random.key(seed), D, L, activation)
    ks = jax.random.split(jax.random.key(seed + 1), 2)
    X = jax.random.normal(ks[0], (N, D), dtype)
    T = jax.random.normal(ks[1], (N, M), dtype)
    return fmap, X, T


# ---------------------------------------------------------------------------
# Fused kernel vs the materialize-then-gram oracle
# ---------------------------------------------------------------------------


@pytest.mark.interpret
@pytest.mark.parametrize("activation", ALL_ACTIVATIONS)
def test_fused_kernel_matches_oracle_all_activations(activation):
    fmap, X, T = _problem(100, 5, 33, 3, activation)
    W, b, act = stats.fusable_params(fmap)
    P1, Q1 = elm_stats_pallas(
        X, W, b, T, activation=act, interpret=True, block_l=16, block_n=32
    )
    P0, Q0 = gram_ops.local_elm_stats(fmap(X), T)
    np.testing.assert_allclose(P1, P0, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(Q1, Q0, rtol=2e-3, atol=2e-3)


@pytest.mark.interpret
@pytest.mark.parametrize(
    "N,D,L,M", [(64, 4, 32, 2), (300, 7, 100, 1), (33, 3, 7, 5),
                (128, 16, 64, 8)]
)
def test_fused_kernel_shape_sweep_ragged(N, D, L, M):
    """Ragged N/L/M tails must mask, not pollute (g(0) != 0!)."""
    fmap, X, T = _problem(N, D, L, M)
    W, b, act = stats.fusable_params(fmap)
    P1, Q1 = elm_stats_pallas(
        X, W, b, T, activation=act, interpret=True, block_l=16, block_n=32
    )
    P0, Q0 = gram_ops.local_elm_stats(fmap(X), T)
    assert P1.dtype == Q1.dtype == jnp.float32
    np.testing.assert_allclose(P1, P0, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(Q1, Q0, rtol=2e-3, atol=2e-3)


@pytest.mark.interpret
@pytest.mark.parametrize("activation", ["sigmoid", "rbf"])
def test_fused_kernel_bf16_operands(activation):
    fmap, X, T = _problem(128, 6, 40, 2, activation)
    W, b, act = stats.fusable_params(fmap)
    Xb, Tb = X.astype(jnp.bfloat16), T.astype(jnp.bfloat16)
    P1, Q1 = elm_stats_pallas(
        Xb, W, b, Tb, activation=act, interpret=True, block_l=16, block_n=32
    )
    # oracle on the same bf16 operands (materialized bf16 H, f32 acc)
    Hb = hidden_reference(
        Xb, W.astype(jnp.bfloat16), b, act
    ).astype(jnp.bfloat16)
    P0, Q0 = gram_ops.local_elm_stats(Hb, Tb)
    assert P1.dtype == jnp.float32
    np.testing.assert_allclose(P1, P0, rtol=5e-2, atol=5e-2 * 128**0.5)
    np.testing.assert_allclose(Q1, Q0, rtol=5e-2, atol=5e-2 * 128**0.5)


@pytest.mark.interpret
def test_fused_kernel_keeps_f32_target_precision():
    """bf16 features + f32 targets with a large offset: the kernel must
    not quantize T down to bf16 — pinned against the scan path, which
    keeps T f32."""
    fmap, X, T = _problem(96, 5, 24, 2, seed=11)
    W, b, act = stats.fusable_params(fmap)
    Xb = X.astype(jnp.bfloat16)
    T_off = T + 1000.0  # bf16 would round this to ~4 decimal digits
    P1, Q1 = elm_stats_pallas(
        Xb, W, b, T_off, activation=act, interpret=True,
        block_l=16, block_n=32,
    )
    P2, Q2 = elm_stats_scan(
        Xb, W.astype(jnp.bfloat16), b, T_off, activation=act, chunk=32
    )
    np.testing.assert_allclose(Q1, Q2, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(P1, P2, rtol=1e-5, atol=1e-4)


@pytest.mark.interpret
def test_fused_kernel_symmetric_matches_full():
    fmap, X, T = _problem(96, 5, 48, 2)
    W, b, act = stats.fusable_params(fmap)
    kw = dict(activation=act, interpret=True, block_l=16, block_n=32)
    P_sym, Q_sym = elm_stats_pallas(X, W, b, T, symmetric=True, **kw)
    P_full, Q_full = elm_stats_pallas(X, W, b, T, symmetric=False, **kw)
    np.testing.assert_allclose(P_sym, P_full, rtol=1e-6, atol=1e-5)
    np.testing.assert_array_equal(Q_sym, Q_full)


def test_streaming_scan_matches_oracle():
    fmap, X, T = _problem(200, 6, 31, 3)
    W, b, act = stats.fusable_params(fmap)
    P1, Q1 = elm_stats_scan(X, W, b, T, activation=act, chunk=64)
    P0, Q0 = gram_ops.local_elm_stats(fmap(X), T)
    np.testing.assert_allclose(P1, P0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(Q1, Q0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Chunked accumulation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N", [128, 150])  # exact 4x and ragged-tail stream
def test_chunked_accumulate_bitwise_matches_one_shot(N):
    """An N >= 4x chunk-size stream through SufficientStats.accumulate
    reproduces the one-shot fused result *bitwise* (same f32
    accumulation order when chunk == block_n)."""
    chunk = 32
    fmap, X, T = _problem(N, 4, 20, 2, seed=3)
    kw = dict(use_kernel=True, block_n=chunk, block_l=16)
    one = stats.from_raw(X, T, fmap, **kw)
    s = stats.SufficientStats.zero(20, 2)
    for i in range(0, N, chunk):
        s = s.accumulate(X[i:i + chunk], T[i:i + chunk], fmap, **kw)
    np.testing.assert_array_equal(np.asarray(one.P), np.asarray(s.P))
    np.testing.assert_array_equal(np.asarray(one.Q), np.asarray(s.Q))
    assert float(s.count) == N
    np.testing.assert_allclose(s.t_sq, one.t_sq, rtol=1e-6)


def test_from_hidden_matches_from_raw():
    fmap, X, T = _problem(70, 5, 14, 2, seed=9)
    via_h = stats.from_hidden(fmap(X), T)
    via_raw = stats.from_raw(X, T, fmap)
    np.testing.assert_allclose(via_h.P, via_raw.P, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(via_h.Q, via_raw.Q, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(via_h.t_sq, via_raw.t_sq, rtol=1e-6)
    assert float(via_h.count) == 70


def test_bf16_features_accumulate_f32():
    """bf16 operands must not produce bf16 moments (dtype-policy pin)."""
    fmap, X, T = _problem(60, 4, 12, 2, seed=10)
    Hb = fmap(X).astype(jnp.bfloat16)
    P_, Q_ = dc_elm.local_stats(Hb, T)
    assert P_.dtype == jnp.float32
    assert Q_.dtype == jnp.float32
    st = online.init_state(Hb, T, C=2.0, V=2)
    assert st.omega.dtype == jnp.float32
    # f32 targets are not quantized down to bf16 before the Q matmul
    ref = Hb.astype(jnp.float32).T @ T
    np.testing.assert_allclose(Q_, ref, rtol=1e-5, atol=1e-5)


def test_merge_equals_concat():
    fmap, X, T = _problem(80, 5, 16, 2, seed=4)
    a = stats.from_raw(X[:30], T[:30], fmap)
    b = stats.from_raw(X[30:], T[30:], fmap)
    both = a.merge(b)
    ref = stats.from_raw(X, T, fmap)
    np.testing.assert_allclose(both.P, ref.P, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(both.Q, ref.Q, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(both.t_sq, ref.t_sq, rtol=1e-6)


def test_from_raw_chunk_option_and_nonfusable_fallback():
    fmap, X, T = _problem(100, 5, 24, 2, seed=5)
    ref = stats.from_raw(X, T, fmap)
    chunked = stats.from_raw(X, T, fmap, chunk=17)
    np.testing.assert_allclose(chunked.P, ref.P, rtol=1e-5, atol=1e-5)

    class OpaqueMap:  # not fusable: exercises the materialize path
        num_features = fmap.num_features

        def __call__(self, x):
            return fmap(x)

    opaque = stats.from_raw(X, T, OpaqueMap(), chunk=17)
    np.testing.assert_allclose(opaque.P, ref.P, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(opaque.Q, ref.Q, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Cholesky finalization — the only Omega producer
# ---------------------------------------------------------------------------


def test_finalize_matches_explicit_inverse():
    fmap, X, T = _problem(120, 6, 24, 3, seed=6)
    s = stats.from_raw(X, T, fmap)
    omega, beta0 = s.finalize(C=8.0, V=4)
    A = np.eye(24) / (4 * 8.0) + np.asarray(s.P, np.float64)
    ref = np.linalg.inv(A)
    # f32 factorization vs f64 inverse: differences are pure f32 noise
    np.testing.assert_allclose(omega, ref, rtol=5e-2, atol=2e-3)
    np.testing.assert_allclose(beta0, omega @ s.Q, rtol=1e-6, atol=1e-6)


def test_stats_plane_feeds_all_paths_identically():
    """dc_elm.init_node, online.init_state and elm.solve_from_stats all
    sit on the same Cholesky producer."""
    fmap, X, T = _problem(90, 4, 18, 2, seed=7)
    H = fmap(X)
    P_, Q_ = dc_elm.local_stats(H, T)
    omega_dc, beta_dc = dc_elm.init_node(P_, Q_, C=4.0, V=3)
    st = online.init_state(H, T, C=4.0, V=3)
    np.testing.assert_allclose(omega_dc, st.omega, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(beta_dc, st.beta, rtol=1e-6, atol=1e-6)
    beta_c = elm.solve_from_stats(P_, Q_, C=4.0)
    ref = np.linalg.solve(np.eye(18) / 4.0 + np.asarray(P_), np.asarray(Q_))
    np.testing.assert_allclose(beta_c, ref, rtol=1e-4, atol=1e-4)


def test_stream_init_raw_matches_hidden_path():
    V, Ni, D, L, M, C = 3, 40, 2, 12, 1, 2.0
    fmap = features.make_random_features(jax.random.key(0), D, L)
    ks = jax.random.split(jax.random.key(1), 2)
    X = jax.random.normal(ks[0], (V, Ni, D))
    T = jax.random.normal(ks[1], (V, Ni, M))
    from repro.core import consensus

    eng = engine.simulated_dc_elm(consensus.ring(V), C)
    via_h = eng.stream_init(jax.vmap(fmap)(X), T)
    via_raw = eng.stream_init(X_nodes=X, T_nodes=T, feature_map=fmap)
    np.testing.assert_allclose(via_raw.omegas, via_h.omegas, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(via_raw.Qs, via_h.Qs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(via_raw.betas, via_h.betas, rtol=1e-4,
                               atol=1e-5)
    with pytest.raises(ValueError, match="feature_map"):
        eng.stream_init(X_nodes=X, T_nodes=T)


def test_simulate_init_raw_matches_hidden_path():
    V, Ni, D, L = 4, 30, 3, 10
    fmap = features.make_random_features(jax.random.key(2), D, L)
    ks = jax.random.split(jax.random.key(3), 2)
    X = jax.random.normal(ks[0], (V, Ni, D))
    T = jax.random.normal(ks[1], (V, Ni, 2))
    s_raw, P_raw, Q_raw = dc_elm.simulate_init_raw(X, T, fmap, C=1.0)
    s_h, P_h, Q_h = dc_elm.simulate_init(jax.vmap(fmap)(X), T, C=1.0)
    np.testing.assert_allclose(P_raw, P_h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(Q_raw, Q_h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_raw.betas, s_h.betas, rtol=1e-4, atol=1e-5)


def test_f64_dtype_policy():
    """x64 fidelity inputs keep f64 moments (the stiff-C paper runs)."""
    fmap, X, T = _problem(50, 3, 8, 1, seed=8)
    with jax.experimental.enable_x64():
        X64 = jnp.asarray(np.asarray(X), jnp.float64)
        T64 = jnp.asarray(np.asarray(T), jnp.float64)
        fmap64 = features.RandomFeatureMap(
            weights=jnp.asarray(np.asarray(fmap.weights), jnp.float64),
            bias=jnp.asarray(np.asarray(fmap.bias), jnp.float64),
            activation=fmap.activation,
        )
        s = stats.from_raw(X64, T64, fmap64)
        assert s.P.dtype == jnp.float64
        omega, _ = s.finalize(C=256.0, V=2)
        assert omega.dtype == jnp.float64


# ---------------------------------------------------------------------------
# Feature-map satellites
# ---------------------------------------------------------------------------


def test_random_feature_map_validates_activation_at_construction():
    w, b = jnp.zeros((3, 4)), jnp.zeros((4,))
    with pytest.raises(ValueError) as ei:
        features.RandomFeatureMap(weights=w, bias=b, activation="bogus")
    msg = str(ei.value)
    for name in features.ACTIVATIONS:
        assert name in msg  # the error names every valid activation


def test_activation_registry_is_shared():
    assert set(features.valid_activations()) == set(
        features.ACTIVATIONS
    ) | {"rbf"}
    assert features._ACTIVATIONS is features.ACTIVATIONS


def test_rbf_expansion_matches_broadcast_reference():
    """||x||^2 - 2 x.c + ||c||^2 == the (..., L, D) broadcast, without
    ever building the (..., L, D) intermediate."""
    fmap = features.make_random_features(jax.random.key(4), 6, 25, "rbf")
    x = jax.random.normal(jax.random.key(5), (40, 6))
    got = fmap(x)
    d2 = jnp.sum(jnp.square(x[:, None, :] - fmap.centers), axis=-1)
    ref = jnp.exp(-fmap.gamma * d2)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert got.shape == (40, 25)


def test_rbf_batched_shapes():
    fmap = features.make_random_features(jax.random.key(6), 3, 9, "rbf")
    x = jax.random.normal(jax.random.key(7), (2, 5, 3))
    assert fmap(x).shape == (2, 5, 9)


# ---------------------------------------------------------------------------
# Hypothesis property: any split of N == one-shot (f32 tolerance)
# ---------------------------------------------------------------------------


def test_chunked_any_split_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        n=st.integers(20, 120),
        splits=st.lists(st.integers(1, 40), min_size=0, max_size=4),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def prop(n, splits, seed):
        fmap, X, T = _problem(n, 3, 11, 2, seed=seed % 100)
        ref = stats.from_raw(X, T, fmap)
        cuts = sorted({min(s, n) for s in splits})
        bounds = [0] + cuts + [n]
        s = stats.SufficientStats.zero(11, 2)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if hi > lo:
                s = s.accumulate(X[lo:hi], T[lo:hi], fmap)
        np.testing.assert_allclose(s.P, ref.P, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s.Q, ref.Q, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s.t_sq, ref.t_sq, rtol=1e-5)

    prop()
