"""Per-arch smoke tests: reduced variants, forward + one train step on CPU.

Required contract: instantiate a REDUCED variant of each assigned
family, run one forward/train step, assert output shapes + no NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.data.lm import make_lm_batches
from repro.models import Model
from repro.optim import adamw
from repro.optim.optimizers import apply_updates

ARCHS = sorted(registry())


def _batch(cfg, B=2, S=64, seed=0):
    batch = next(make_lm_batches(cfg.vocab_size, B, S, 1, seed=seed))
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(5), (B, cfg.frontend_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = registry()[arch].reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch
        )
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    loss0, _ = m.loss(params, batch)
    assert loss0.shape == ()
    assert bool(jnp.isfinite(loss0)), f"{arch}: non-finite initial loss"
    params, opt_state, loss = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_over_steps(arch):
    """A few steps on a repeated batch must reduce loss (learnability)."""
    cfg = registry()[arch].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(1))
    batch = _batch(cfg, seed=1)
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        (loss, _), grads = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch
        )
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    losses = []
    for _ in range(8):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{arch}: {losses}"


@pytest.mark.parametrize(
    "arch",
    ["gemma2-2b", "h2o-danube-1.8b", "mamba2-780m", "zamba2-1.2b",
     "dbrx-132b", "internvl2-2b"],
)
def test_decode_matches_forward(arch):
    """Prefill + token-by-token decode == full teacher-forced forward."""
    cfg = registry()[arch].reduced()
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 64
    batch = _batch(cfg)

    h, off = m._embed_inputs(params, batch)
    pos = jnp.arange(h.shape[1])
    hh, _, _ = m._trunk(params, h, pos, want_cache=False)
    if off:
        hh = hh[:, off:]
    fl = m._logits(params, hh)

    Sp = S - 6
    pre = dict(batch, tokens=batch["tokens"][:, :Sp])
    max_seq = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    logits_last, cache = m.prefill(params, pre, max_seq=max_seq)
    assert float(jnp.max(jnp.abs(logits_last - fl[:, Sp - 1]))) < 2e-2
    for t in range(Sp, S):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, t : t + 1])
        assert float(jnp.max(jnp.abs(lg - fl[:, t]))) < 2e-2, f"pos {t}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_formula(arch):
    """Analytic param_count matches the actual initialized tree."""
    cfg = registry()[arch].reduced()
    m = Model(cfg)
    shapes = jax.eval_shape(m.init, jax.random.key(0))
    actual = sum(
        int(jnp.prod(jnp.asarray(s.shape))) for s in jax.tree.leaves(shapes)
    )
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.02, (actual, predicted)


def test_sliding_window_limits_attention():
    """SWA: moving a token far outside the window can't change the output."""
    cfg = registry()["h2o-danube-1.8b"].reduced()
    assert cfg.sliding_window == 64
    S = 160  # > 2x window
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)
    b1 = {"tokens": t1, "labels": t1}
    b2 = {"tokens": t2, "labels": t2}
    h1, _ = m._embed_inputs(params, b1)
    h2, _ = m._embed_inputs(params, b2)
    pos = jnp.arange(S)
    o1, _, _ = m._trunk(params, h1, pos, want_cache=False)
    o2, _, _ = m._trunk(params, h2, pos, want_cache=False)
    # final positions: window*num_layers reach, but token 0 beyond it for
    # 2 layers x 64 window = 128 < 159 => last position unaffected
    assert float(jnp.max(jnp.abs(o1[:, -1] - o2[:, -1]))) < 1e-4
