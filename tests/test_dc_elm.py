"""DC-ELM Algorithm 1 (paper Sec. III-D, Theorems 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, elm, fusion_elm, incremental


def _problem(V=4, Ni=64, L=16, M=2, C=0.25, seed=0):
    # modest C: the consensus rate scales ~ gamma*lambda2 / (1 + VC*lam_max(P)),
    # so small C isolates the graph dynamics from ridge stiffness (the
    # stiff-C regime is exercised in f64 by the fig4 benchmark).
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L))
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T, C


def test_converges_to_centralized():
    """Theorem 2: every node reaches the fusion-center solution."""
    H, T, C = _problem()
    g = consensus.paper_fig2()
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    d0 = float(dc_elm.distance_to(state.betas, beta_star))
    final, _ = dc_elm.simulate_run(state, g, g.default_gamma(), C, 5000)
    d1 = float(dc_elm.distance_to(final.betas, beta_star))
    assert d1 < 0.02
    assert d1 < d0 / 10


def test_centralized_equivalence_lemma1():
    """centralized_from_node_stats == solving the pooled problem (Lemma 1)."""
    H, T, C = _problem()
    _, P_, Q_ = dc_elm.simulate_init(H, T, C)
    via_stats = dc_elm.centralized_from_node_stats(P_, Q_, C)
    pooled = elm.ridge_solve(
        H.reshape(-1, H.shape[-1]), T.reshape(-1, T.shape[-1]), C
    )
    np.testing.assert_allclose(via_stats, pooled, rtol=1e-3, atol=1e-4)


def test_zero_gradient_sum_invariant():
    """Eq. (12): sum_i grad u_i(beta_i(k)) stays ~0 along the trajectory."""
    H, T, C = _problem()
    g = consensus.ring(4)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    for k in [0, 5, 50]:
        s = state
        if k:
            s, _ = dc_elm.simulate_run(state, g, 0.4, C, k)
        gs = dc_elm.gradient_sum(s, P_, Q_, C)
        scale = float(jnp.max(jnp.abs(s.betas))) * (4 * C) + 1
        assert float(jnp.max(jnp.abs(gs))) / scale < 5e-4, f"violated at k={k}"


def test_divergence_above_gamma_bound():
    """Paper Fig. 4(a): gamma = 1/1.9 > 1/d_max = 0.5 diverges on the
    Fig. 2 network in the paper's own setting (collinear sigmoid features
    of 1-D SinC inputs => ill-conditioned local Grams)."""
    from repro.core.features import make_random_features
    from repro.data.sinc import make_sinc_dataset

    X, Y, _, _ = make_sinc_dataset(jax.random.key(0), num_nodes=4,
                                   per_node=300, num_test=10)
    fmap = make_random_features(jax.random.key(1), 1, 60)
    H = jax.vmap(fmap)(X)
    C = 2.0**2
    g = consensus.paper_fig2()
    state, P_, Q_ = dc_elm.simulate_init(H, Y, C)
    bad, _ = dc_elm.simulate_run(state, g, 1 / 1.9, C, 1500,
                                 check_gamma=False)
    good, _ = dc_elm.simulate_run(state, g, 1 / 2.1, C, 1500)
    bad_norm = float(jnp.max(jnp.abs(bad.betas)))
    good_norm = float(jnp.max(jnp.abs(good.betas)))
    assert jnp.isfinite(good_norm) and good_norm < 1e3
    assert (not jnp.isfinite(bad_norm)) or bad_norm > 1e3 * good_norm


def test_unequal_node_data():
    """Convergence holds with heterogeneous N_i (robustness claim)."""
    key = jax.random.key(1)
    L, M, C = 12, 1, 0.25
    sizes = [10, 50, 100, 200]
    Hs = [jax.random.normal(jax.random.key(10 + i), (n, L)) for i, n in enumerate(sizes)]
    Ts = [jax.random.normal(jax.random.key(20 + i), (n, M)) for i, n in enumerate(sizes)]
    del key
    V = len(sizes)
    P_ = jnp.stack([h.T @ h for h in Hs])
    Q_ = jnp.stack([h.T @ t for h, t in zip(Hs, Ts)])
    state = dc_elm.simulate_init_from_stats(P_, Q_, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    g = consensus.complete(V)
    final, _ = dc_elm.simulate_run(state, g, g.default_gamma(), C, 5000)
    assert float(dc_elm.distance_to(final.betas, beta_star)) < 0.03


def test_topology_affects_rate():
    """Better-connected graphs converge faster (rho_ess ordering)."""
    H, T, C = _problem(V=8, seed=2)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    dists = {}
    for g in [consensus.ring(8), consensus.complete(8)]:
        final, _ = dc_elm.simulate_run(state, g, g.default_gamma(), C, 800)
        dists[g.name] = float(dc_elm.distance_to(final.betas, beta_star))
    assert dists["complete8"] < dists["ring8"]


def test_fusion_center_baseline_exact():
    H, T, C = _problem()
    beta = fusion_elm.simulate(H, T, C)
    pooled = elm.ridge_solve(
        H.reshape(-1, H.shape[-1]), T.reshape(-1, T.shape[-1]), C
    )
    np.testing.assert_allclose(beta, pooled, rtol=1e-3, atol=1e-4)


def test_incremental_baseline_approaches_solution():
    """Sec. II-B1 Hamiltonian-cycle baseline reaches the neighborhood.

    Uses a diminishing step (decay>0): the constant-step variant stalls
    at its O(alpha) bias just outside the 5% ball for this problem.
    """
    H, T, C = _problem(V=4, Ni=32, L=8, M=1)
    _, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    zf, _ = incremental.run(
        P_, Q_, alpha=5e-3, C=C, num_cycles=3000, decay=1e-2
    )
    rel = float(
        jnp.linalg.norm(zf - beta_star) / (1 + jnp.linalg.norm(beta_star))
    )
    assert rel < 0.05


def test_average_empirical_risk_trace_decreases():
    """Paper Fig. 4(b)(c): R_d(k) falls as consensus progresses."""
    from repro.core.features import make_random_features
    from repro.data.sinc import make_sinc_dataset

    # scarce local data (40 samples, 40 features) => local ELMs overfit
    # and consensus measurably improves the average risk
    X, Y, Xt, Yt = make_sinc_dataset(jax.random.key(0), num_nodes=4,
                                     per_node=40, num_test=400)
    fmap = make_random_features(jax.random.key(1), 1, 40)
    H = jax.vmap(fmap)(X)
    C = 2.0
    state, _, _ = dc_elm.simulate_init(H, Y, C)
    g = consensus.paper_fig2()
    trace_fn = dc_elm.average_empirical_risk_fn(fmap, Xt, Yt)
    _, risks = dc_elm.simulate_run(
        state, g, 1 / 2.1, C, 2000, trace_fn=trace_fn
    )
    assert float(risks[-1]) < float(risks[0])
