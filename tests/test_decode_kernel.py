"""Flash-decode kernel + windowed prefill kernel vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn import flash_attention_pallas
from repro.kernels.decode_attn import flash_decode_pallas
from repro.models.attention import decode_attend, flash_attention as jnp_flash


@pytest.mark.parametrize("B,S,K,G,hd,pos,win", [
    (2, 256, 2, 4, 32, 100, None),
    (1, 300, 4, 1, 16, 299, None),  # padding path (300 % 64 != 0)
    (2, 128, 1, 2, 64, 90, 64),     # sliding window
    (1, 512, 2, 2, 32, 0, None),    # first token
])
def test_flash_decode_vs_oracle(B, S, K, G, hd, pos, win):
    ks = jax.random.split(jax.random.key(S + pos), 3)
    q = jax.random.normal(ks[0], (B, 1, K, G, hd))
    ck = jax.random.normal(ks[1], (B, S, K, hd))
    cv = jax.random.normal(ks[2], (B, S, K, hd))
    p = jnp.asarray(pos, jnp.int32)
    out = flash_decode_pallas(
        q, ck, cv, p, block_k=64, window=win, interpret=True
    )
    ref = decode_attend(q, ck, cv, p, windowed=False, window=win, cap=0.0)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_decode_softcap():
    B, S, K, G, hd = 1, 128, 2, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, 1, K, G, hd))
    ck = jax.random.normal(ks[1], (B, S, K, hd))
    cv = jax.random.normal(ks[2], (B, S, K, hd))
    p = jnp.asarray(64, jnp.int32)
    out = flash_decode_pallas(
        q, ck, cv, p, block_k=32, softcap=30.0, interpret=True
    )
    ref = decode_attend(q, ck, cv, p, windowed=False, window=None, cap=30.0)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("S,win,bq", [(256, 64, 32), (128, 32, 32)])
def test_windowed_prefill_kernel_vs_jnp_banded(S, win, bq):
    B, K, G, hd = 1, 2, 2, 16
    ks = jax.random.split(jax.random.key(S), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    pos = jnp.arange(S)
    out = flash_attention_pallas(
        q, k, v, block_q=bq, block_k=bq, window=win, interpret=True
    )
    ref = jnp_flash(
        q, k, v, q_positions=pos, k_positions=pos, causal=True, window=win,
        q_chunk=bq, k_chunk=bq,
    )
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
