"""Serving plane: fused predict kernel parity, bucket padding,
hot-swap atomicity, and bounded staleness under a scripted stream."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dc_elm, engine
from repro.core import consensus
from repro.core.elm import ELM
from repro.core.features import make_random_features
from repro.kernels.elm_predict import elm_predict_pallas
from repro.kernels.elm_predict_ops import fused_predict
from repro.kernels.elm_predict_ref import (
    elm_predict_scan,
    predict_reference,
)
from repro.serving import BetaStore, ELMServer
from tests.conftest import run_py


def _relerr(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    return float(jnp.max(jnp.abs(a - b)) / (1 + jnp.max(jnp.abs(b))))


def _problem(N, D, L, M, dtype, activation, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dtype)
    W = jax.random.normal(ks[1], (D, L)).astype(dtype)
    if activation == "rbf":
        b = jax.random.uniform(ks[2], (L,), minval=0.05, maxval=1.0)
    else:
        b = jax.random.normal(ks[2], (L,))
    beta = jax.random.normal(ks[3], (L, M)).astype(jnp.float32)
    return X, W, b, beta


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.interpret
@pytest.mark.parametrize(
    "activation", ["sigmoid", "tanh", "relu", "sin", "identity", "rbf"]
)
def test_kernel_parity_activations(activation):
    """Pallas (interpret) and scan match the materialized-H oracle."""
    X, W, b, beta = _problem(300, 7, 130, 3, jnp.float32, activation)
    ref = predict_reference(X, W, b, beta, activation=activation)
    pal = elm_predict_pallas(
        X, W, b, beta, activation=activation, interpret=True,
        block_l=64, block_n=128,
    )
    scan = elm_predict_scan(X, W, b, beta, activation=activation, chunk=90)
    assert _relerr(pal, ref) < 2e-5
    assert _relerr(scan, ref) < 2e-5


@pytest.mark.interpret
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "shape",
    [(128, 8, 64, 1), (130, 7, 65, 3), (513, 9, 256, 8), (31, 3, 140, 2)],
)
def test_kernel_parity_dtypes_ragged(shape, dtype):
    """Ragged N/L/D tails and bf16 operands match the oracle.

    The ragged-N mask matters because g(0) != 0 for sigmoid — without
    it the padded rows would leak into nothing here (predict has no
    cross-row reduction) but the padded L columns WOULD leak without
    zero beta padding; both are covered by exactness below.
    """
    N, D, L, M = shape
    X, W, b, beta = _problem(N, D, L, M, dtype, "sigmoid")
    ref = predict_reference(X, W, b, beta, activation="sigmoid")
    pal = elm_predict_pallas(
        X, W, b, beta, activation="sigmoid", interpret=True,
        block_l=64, block_n=128,
    )
    tol = 1e-2 if dtype == jnp.bfloat16 else 2e-5
    assert pal.shape == (N, M)
    assert _relerr(pal, ref) < tol
    assert _relerr(
        elm_predict_scan(X, W, b, beta, activation="sigmoid", chunk=100),
        ref,
    ) < tol


def test_fused_predict_dispatch_and_dtype():
    """The ops wrapper returns the oracle's promoted result dtype."""
    X, W, b, beta = _problem(64, 4, 32, 2, jnp.bfloat16, "sigmoid")
    ref = predict_reference(X, W, b, beta, activation="sigmoid")
    # block_l is a Pallas-only knob (the scan path raises on it);
    # block_n maps onto the scan's chunk, so both paths take it
    for use_kernel, kw in [
        (False, dict(block_n=32)),
        (True, dict(block_l=16, block_n=32)),
    ]:
        out = fused_predict(X, W, b, beta, use_kernel=use_kernel, **kw)
        assert out.dtype == ref.dtype
        assert _relerr(out, ref) < 1e-2
    allb = fused_predict(X, W, b, beta.astype(jnp.bfloat16))
    assert allb.dtype == jnp.bfloat16


def test_elm_call_matches_materialized():
    """ELM.__call__ (fused path) == h(x) @ beta, incl. leading dims."""
    fmap = make_random_features(jax.random.key(1), 5, 40, "sigmoid")
    beta = jax.random.normal(jax.random.key(2), (40, 3))
    elm = ELM(feature_map=fmap, beta=beta)
    for shape in [(11, 5), (4, 7, 5), (5,)]:
        x = jax.random.normal(jax.random.key(3), shape)
        ref = fmap(x) @ beta
        out = elm(x)
        assert out.shape == ref.shape
        assert _relerr(out, ref) < 2e-6
    # rbf maps fuse through the squared-distance expansion
    rbf = make_random_features(jax.random.key(4), 5, 40, "rbf")
    elm = ELM(feature_map=rbf, beta=beta)
    x = jax.random.normal(jax.random.key(5), (23, 5))
    assert _relerr(elm(x), rbf(x) @ beta) < 2e-6


def test_predict_map_f64_fidelity_preserved():
    """The f64 fidelity path must not be squeezed through f32 fusion."""
    code = """
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.features import make_random_features
from repro.kernels.elm_predict_ops import predict_map

fmap = make_random_features(jax.random.key(1), 3, 20)
x = jax.random.normal(jax.random.key(2), (9, 3), dtype=jnp.float64)
beta = jax.random.normal(jax.random.key(3), (20, 2), dtype=jnp.float64)
out = predict_map(x, fmap, beta)
assert out.dtype == jnp.float64, out.dtype
ref = fmap(x) @ beta
assert float(jnp.max(jnp.abs(out - ref))) < 1e-12
print("OK")
"""
    r = run_py(code)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_node_predict_matches_per_node():
    """node_predict == each node's fmap(X) @ beta_i."""
    fmap = make_random_features(jax.random.key(1), 2, 30)
    betas = jax.random.normal(jax.random.key(2), (4, 30, 2))
    X = jax.random.normal(jax.random.key(3), (17, 2))
    out = dc_elm.node_predict(fmap, betas, X)
    ref = jnp.stack([fmap(X) @ betas[i] for i in range(4)])
    assert out.shape == (4, 17, 2)
    assert _relerr(out, ref) < 2e-6


# ---------------------------------------------------------------------------
# Micro-batching server
# ---------------------------------------------------------------------------


def _server(V=3, D=2, L=24, M=2, buckets=(4, 16, 64), seed=0, **kw):
    fmap = make_random_features(jax.random.key(seed), D, L)
    betas = jax.random.normal(jax.random.key(seed + 1), (V, L, M))
    store = BetaStore(betas)
    return ELMServer(fmap, store, buckets=buckets, **kw), fmap, store


@pytest.mark.parametrize("n", [1, 3, 4, 5, 15, 16, 17, 63, 64, 65, 200])
def test_bucket_padding_boundary_sizes(n):
    """Exact parity at and around every bucket boundary, incl. splits."""
    srv, fmap, store = _server()
    rng = np.random.default_rng(n)
    x = rng.standard_normal((n, 2)).astype(np.float32)
    y = srv.predict(x, node=1)
    assert y.shape == (n, 2)
    ref = np.asarray(fmap(jnp.asarray(x)) @ store.snapshot().betas[1])
    np.testing.assert_allclose(y, ref, rtol=2e-5, atol=2e-5)


def test_packing_multiple_requests_one_batch():
    """Small requests pack into one padded launch, answers stay exact."""
    srv, fmap, store = _server()
    rng = np.random.default_rng(0)
    qs = {}
    for k in (3, 5, 2, 4):
        q = rng.standard_normal((k, 2)).astype(np.float32)
        qs[srv.submit(q, node=0)] = q
    out = {r.uid: r for r in srv.flush()}
    assert srv.metrics["batches"] == 1  # 14 rows -> one 16-bucket launch
    for uid, q in qs.items():
        ref = np.asarray(fmap(jnp.asarray(q)) @ store.snapshot().betas[0])
        np.testing.assert_allclose(out[uid].y, ref, rtol=2e-5, atol=2e-5)


def test_oversized_request_split_and_reassembled():
    srv, fmap, store = _server(buckets=(4, 8))
    rng = np.random.default_rng(1)
    x = rng.standard_normal((21, 2)).astype(np.float32)  # 3 chunks of <=8
    uid = srv.submit(x, node=2)
    (resp,) = srv.flush()
    assert resp.uid == uid and resp.y.shape == (21, 2)
    ref = np.asarray(fmap(jnp.asarray(x)) @ store.snapshot().betas[2])
    np.testing.assert_allclose(resp.y, ref, rtol=2e-5, atol=2e-5)


def test_round_robin_across_node_replicas():
    srv, fmap, store = _server(V=3)
    x = np.ones((2, 2), np.float32)
    nodes = []
    for _ in range(6):
        srv.submit(x)
        nodes.append(srv.flush()[0].node)
    assert nodes == [0, 1, 2, 0, 1, 2]


def test_hot_swap_atomicity_never_mixes_versions():
    """Every response equals exactly one published beta's output —
    never a blend — even with publishes interleaved mid-traffic."""
    srv, fmap, store = _server(V=1, buckets=(4, 8))
    rng = np.random.default_rng(2)
    # distinguishable versions: beta scaled by 1, 10, 100
    base = np.asarray(store.snapshot().betas[0])
    refs = {}
    x = rng.standard_normal((21, 2)).astype(np.float32)  # splits into 3
    for scale in (10.0, 100.0):
        srv.submit(x, node=0)
        version = store.publish(jnp.asarray(base * scale)[None])
        refs[version] = np.asarray(fmap(jnp.asarray(x))) @ (base * scale)
        (resp,) = srv.flush()
        # served from exactly one version (the latest at flush time)
        assert resp.version == version
        np.testing.assert_allclose(
            resp.y, refs[version], rtol=2e-5, atol=2e-5
        )


def test_bounded_staleness_scripted_stream():
    """latest_at_flush - served_version <= max_staleness, and with the
    bound at 0 the server always serves the newest published beta."""
    for max_staleness in (0, 2):
        srv, fmap, store = _server(V=1, max_staleness=max_staleness)
        x = np.ones((2, 2), np.float32)
        served = []
        for step in range(6):
            store.publish(store.snapshot().betas * 1.5)
            srv.submit(x, node=0)
            latest = store.version
            (resp,) = srv.flush()
            served.append(resp.version)
            assert latest - resp.version <= max_staleness
        # versions never regress
        assert served == sorted(served)
        if max_staleness == 0:
            assert served[-1] == store.version


def test_freeze_pins_snapshot_until_thaw():
    srv, fmap, store = _server(V=1)
    x = np.ones((3, 2), np.float32)
    srv.predict(x, node=0)
    srv.freeze()
    v_frozen = srv.served_version
    store.publish(store.snapshot().betas * 2.0)
    store.publish(store.snapshot().betas * 2.0)
    srv.submit(x, node=0)
    (resp,) = srv.flush()
    assert resp.version == v_frozen and store.version == v_frozen + 2
    srv.thaw()
    srv.submit(x, node=0)
    (resp,) = srv.flush()
    assert resp.version == store.version


def test_beta_store_concurrent_publishes_are_ordered():
    """Version numbers stay dense/unique under concurrent publishers."""
    store = BetaStore(jnp.zeros((1, 4, 1)))
    versions = []
    lock = threading.Lock()

    def pub():
        for _ in range(20):
            v = store.publish(jnp.ones((1, 4, 1)))
            with lock:
                versions.append(v)

    threads = [threading.Thread(target=pub) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(versions) == list(range(2, 82))
    assert store.version == 81


def test_server_input_validation():
    srv, _, _ = _server()
    with pytest.raises(ValueError, match="buckets"):
        ELMServer(None, BetaStore(jnp.zeros((1, 4, 1))), buckets=(8, 4))
    with pytest.raises(ValueError, match="rows"):
        srv.submit(np.zeros((0, 2), np.float32))
    with pytest.raises(ValueError, match="betas"):
        BetaStore(jnp.zeros((4,)))
    with pytest.raises(RuntimeError, match="no published"):
        BetaStore().snapshot()


def test_serve_while_train_stream_chunk_publishes():
    """stream_chunk(publish_to=store) hot-swaps a live server and the
    served test error falls as Algorithm 2 keeps learning."""
    from repro.data.sinc import make_sinc_dataset, sinc

    V, L, C = 4, 60, 2.0**6
    fmap = make_random_features(jax.random.key(1), 1, L)
    eng = engine.simulated_dc_elm(consensus.paper_fig2(), C)
    X, Y, X_test, Y_test = make_sinc_dataset(
        jax.random.key(0), num_nodes=V, per_node=80, num_test=400
    )
    state = eng.stream_init(X_nodes=X, T_nodes=Y, feature_map=fmap)
    store = BetaStore()
    state, _ = eng.stream_chunk(
        state, gamma=1 / 2.1, num_iters=150, publish_to=store
    )
    assert store.version == 1
    srv = ELMServer(fmap, store, buckets=(64, 512))
    mses, versions = [], []
    key = jax.random.key(7)
    for _ in range(3):
        key, k1, k2 = jax.random.split(key, 3)
        Xn = jax.random.uniform(k1, (V, 40, 1), minval=-10, maxval=10)
        Yn = sinc(Xn) + jax.random.uniform(
            k2, (V, 40, 1), minval=-0.2, maxval=0.2
        )
        state, _ = eng.stream_chunk(
            state, added=(jax.vmap(fmap)(Xn), Yn), gamma=1 / 2.1,
            num_iters=150, publish_to=store,
        )
        pred = srv.predict(np.asarray(X_test, np.float32))
        versions.append(srv.served_version)
        mses.append(float(np.mean((pred - np.asarray(Y_test)) ** 2)))
    assert versions == [2, 3, 4]  # hot-swapped onto every publish
    assert mses[-1] < mses[0] * 1.5 and mses[-1] < 5e-3


def test_predict_retains_other_pending_responses():
    """predict() must not drop responses of other queued requests."""
    srv, fmap, store = _server()
    rng = np.random.default_rng(3)
    q = rng.standard_normal((3, 2)).astype(np.float32)
    uid_a = srv.submit(q, node=0)
    y = srv.predict(np.ones((2, 2), np.float32), node=1)
    assert y.shape == (2, 2)
    later = srv.flush()  # a's response was retained, not dropped
    assert [r.uid for r in later] == [uid_a]
    ref = np.asarray(fmap(jnp.asarray(q)) @ store.snapshot().betas[0])
    np.testing.assert_allclose(later[0].y, ref, rtol=2e-5, atol=2e-5)


def test_submit_enforces_row_width_and_coerces_dtype():
    srv, fmap, store = _server()  # fmap.in_dim == 2
    with pytest.raises(ValueError, match="width"):
        srv.submit(np.zeros((3, 5), np.float32))
    # f64 rows are coerced to the serving dtype, not silently packed
    y = srv.predict(np.zeros((2, 2), np.float64), node=0)
    assert y.dtype == np.float32
