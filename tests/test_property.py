"""Hypothesis property-based tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import consensus, dc_elm, elm, gossip, online
from repro.models.layers import chunked_cross_entropy, cross_entropy

_SMALL = dict(max_examples=20, deadline=None)


@given(
    n=st.integers(5, 40),
    l=st.integers(2, 12),
    m=st.integers(1, 3),
    c=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_ridge_primal_dual_equivalence(n, l, m, c, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(k1, (n, l), jnp.float32)
    T = jax.random.normal(k2, (n, m), jnp.float32)
    b1 = elm.ridge_primal(H, T, c)
    b2 = elm.ridge_dual(H, T, c)
    np.testing.assert_allclose(b1, b2, rtol=2e-2, atol=2e-3)


@given(
    v=st.integers(2, 10),
    gamma=st.floats(0.01, 0.45),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_mixing_preserves_network_average(v, gamma, seed):
    """The consensus step conserves sum_i beta_i on any symmetric graph."""
    g = consensus.ring(v)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    betas = jax.random.normal(jax.random.key(seed), (v, 3, 2))
    # identity-metric mixing (Omega = I): paper rule conserves the mean
    omegas = jnp.broadcast_to(jnp.eye(3), (v, 3, 3))
    state = dc_elm.DCELMState(betas=betas, omegas=omegas,
                              k=jnp.zeros((), jnp.int32))
    out = dc_elm.simulate_step(state, adj, jnp.asarray(gamma), C=1.0 / v)
    np.testing.assert_allclose(
        jnp.sum(out.betas, 0), jnp.sum(betas, 0), rtol=1e-4, atol=1e-4
    )


@given(
    n=st.integers(10, 60),
    dn=st.integers(1, 8),
    l=st.integers(2, 10),
    m=st.integers(1, 3),
    c=st.floats(0.2, 50.0),
    v=st.integers(1, 12),
    dtype=st.sampled_from(["float32", "float64"]),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_woodbury_add_then_remove_roundtrips_state(
    n, dn, l, m, c, v, dtype, seed
):
    """add(S, d) then remove(..., d) round-trips the FULL state (omega
    AND Q) to the original, across random shapes/dtypes/constants."""
    with _dtype_ctx(dtype):
        dt = jnp.dtype(dtype)
        ks = jax.random.split(jax.random.key(seed), 4)
        H = jax.random.normal(ks[0], (n, l), dt) / np.sqrt(l)
        T = jax.random.normal(ks[1], (n, m), dt)
        dH = jax.random.normal(ks[2], (dn, l), dt) / np.sqrt(l)
        dT = jax.random.normal(ks[3], (dn, m), dt)
        s0 = online.init_state(H, T, C=c, V=v)
        s1 = online.remove_chunk(online.add_chunk(s0, dH, dT), dH, dT)
        tol = dict(rtol=1e-2, atol=1e-3) if dtype == "float32" else dict(
            rtol=1e-8, atol=1e-10
        )
        np.testing.assert_allclose(s1.omega, s0.omega, **tol)
        np.testing.assert_allclose(s1.Q, s0.Q, **tol)
        # and the reverse ordering: remove a real chunk, then re-add it
        s2 = online.add_chunk(
            online.remove_chunk(s0, H[:dn], T[:dn]), H[:dn], T[:dn]
        )
        np.testing.assert_allclose(s2.omega, s0.omega, **tol)
        np.testing.assert_allclose(s2.Q, s0.Q, **tol)


@given(
    n=st.integers(10, 60),
    dn=st.integers(1, 8),
    l=st.integers(2, 10),
    m=st.integers(1, 3),
    c=st.floats(0.2, 50.0),
    v=st.integers(1, 12),
    dtype=st.sampled_from(["float32", "float64"]),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_woodbury_matches_direct_state(n, dn, l, m, c, v, dtype, seed):
    """Woodbury add/remove == online.direct_state (the O(L^3)
    recompute-from-scratch reference) on the surviving data."""
    with _dtype_ctx(dtype):
        dt = jnp.dtype(dtype)
        ks = jax.random.split(jax.random.key(seed), 4)
        H = jax.random.normal(ks[0], (n, l), dt) / np.sqrt(l)
        T = jax.random.normal(ks[1], (n, m), dt)
        dH = jax.random.normal(ks[2], (dn, l), dt) / np.sqrt(l)
        dT = jax.random.normal(ks[3], (dn, m), dt)
        s = online.init_state(H, T, C=c, V=v)
        s = online.add_chunk(s, dH, dT)
        s = online.remove_chunk(s, H[:dn], T[:dn])
        H2 = jnp.concatenate([H[dn:], dH])
        T2 = jnp.concatenate([T[dn:], dT])
        ref = online.direct_state(H2, T2, C=c, V=v)
        tol = dict(rtol=2e-2, atol=2e-3) if dtype == "float32" else dict(
            rtol=1e-7, atol=1e-9
        )
        np.testing.assert_allclose(s.omega, ref.omega, **tol)
        np.testing.assert_allclose(s.Q, ref.Q, **tol)
        np.testing.assert_allclose(s.beta, ref.beta, **tol)


def _dtype_ctx(dtype: str):
    """x64 mode for float64 draws; a no-op context for float32."""
    import contextlib

    if dtype == "float64":
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


@given(
    v=st.sampled_from([2, 4, 8, 16]),
    kind=st.sampled_from(["ring", "hypercube", "complete"]),
)
@settings(**_SMALL)
def test_gossip_spec_consistent_with_graph(v, kind):
    spec = gossip.GossipSpec(axes=("data",), kinds=(kind,))
    sizes = {"data": v}
    g = spec.to_graph(sizes)
    assert g.num_nodes == spec.num_nodes(sizes)
    assert g.d_max == spec.degree(sizes)
    assert g.is_connected
    assert spec.gamma_upper_bound(sizes) == 1.0 / g.d_max


@given(
    b=st.integers(1, 3),
    s=st.integers(2, 33),
    v=st.integers(5, 40),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_chunked_ce_equals_full(b, s, v, chunk, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    d = 8
    h = jax.random.normal(ks[0], (b, s, d))
    table = jax.random.normal(ks[1], (v, d))
    labels = jax.random.randint(ks[2], (b, s), -1, v)
    full = cross_entropy(jnp.einsum("bsd,vd->bsv", h, table), labels)
    chunked = chunked_cross_entropy(h, table, labels, chunk=chunk)
    np.testing.assert_allclose(full, chunked, rtol=1e-4, atol=1e-5)


@given(
    n=st.integers(2, 50),
    l=st.integers(1, 8),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_gram_kernel_property(n, l, seed):
    from repro.kernels.gram import gram_pallas

    H = jax.random.normal(jax.random.key(seed), (n, l))
    P = gram_pallas(H, interpret=True, block_l=8, block_n=16)
    np.testing.assert_allclose(P, H.T @ H, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(P, P.T, atol=1e-4)


@given(
    s=st.integers(3, 40),
    q=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**30),
)
@settings(**_SMALL)
def test_ssd_chunk_invariance(s, q, seed):
    """SSD output must not depend on the chunk size."""
    from repro.kernels.ssd_ref import ssd_naive_reference, ssd_reference

    b, nh, hd, ds = 1, 2, 4, 4
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    y1, h1 = ssd_reference(x, dt, A, B, C, chunk=q)
    y2, h2 = ssd_naive_reference(x, dt, A, B, C)
    np.testing.assert_allclose(y1, y2, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(h1, h2, rtol=5e-3, atol=5e-3)


@given(
    v=st.integers(2, 8),
    iters=st.integers(1, 30),
    seed=st.integers(0, 2**30),
)
@settings(max_examples=10, deadline=None)
def test_dc_elm_monotone_lyapunov(v, iters, seed):
    """Thm 1's Lyapunov argument: disagreement never increases."""
    ks = jax.random.split(jax.random.key(seed), 2)
    H = jax.random.normal(ks[0], (v, 20, 6))
    T = jax.random.normal(ks[1], (v, 20, 1))
    g = consensus.complete(v)
    state, _, _ = dc_elm.simulate_init(H, T, C=8.0)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    gamma = jnp.asarray(g.default_gamma())
    prev = float(dc_elm.consensus_error(state.betas))
    for _ in range(iters):
        state = dc_elm.simulate_step(state, adj, gamma, C=8.0)
        cur = float(dc_elm.consensus_error(state.betas))
        assert cur <= prev * 1.01 + 1e-7
        prev = cur
