"""Secure aggregation: mask algebra, crash recovery, leakage.

The pairwise additive masks live in uint64 mod-2^64 arithmetic over a
fixed-point encoding, so full-cohort cancellation is *exact* and every
assertion here about mask algebra is bitwise, not approximate. The
only approximation in the whole protocol is the fixed-point grid
(2^-frac_bits), pinned explicitly where it appears.
"""

import numpy as np
import pytest

from repro.core import consensus, vertical
from repro.core.consensus import FaultModel, NodeCrash
from repro.core.secure import (
    SecureAggregationSpec,
    SecureAggregator,
    decode_fixed,
    encode_fixed,
    node_mask,
    pair_mask,
)


def _values(rng, n=64, scale=10.0):
    return (rng.standard_normal(n) * scale).astype(np.float64)


# ---------------------------------------------------------------------------
# Fixed-point codec
# ---------------------------------------------------------------------------


def test_fixed_point_roundtrip_on_grid():
    spec = SecureAggregationSpec(seed=0)
    rng = np.random.default_rng(0)
    x = _values(rng)
    back = decode_fixed(encode_fixed(x, spec.frac_bits), spec.frac_bits)
    assert np.abs(back - x).max() <= spec.resolution
    # values already on the grid roundtrip bitwise
    grid = np.round(x * 2.0**spec.frac_bits) / 2.0**spec.frac_bits
    again = decode_fixed(encode_fixed(grid, spec.frac_bits), spec.frac_bits)
    np.testing.assert_array_equal(again, grid)


def test_fixed_point_headroom_check():
    with pytest.raises(ValueError, match="fixed-point range"):
        encode_fixed(np.array([2.0e12]), 32)


def test_spec_parse_forms():
    assert SecureAggregationSpec.parse(True).seed == 0
    assert SecureAggregationSpec.parse(7).seed == 7
    spec = SecureAggregationSpec(seed=3, frac_bits=20)
    assert SecureAggregationSpec.parse(spec) is spec
    # None means "secure, default spec" at the parse layer; the off/on
    # decision (secure=None disables) lives in reduce_partials
    assert SecureAggregationSpec.parse(None).seed == 0


# ---------------------------------------------------------------------------
# Mask algebra
# ---------------------------------------------------------------------------


def test_pair_masks_are_symmetric_and_seeded():
    spec = SecureAggregationSpec(seed=5)
    a = pair_mask(spec, 2, 7, 32, tag=1)
    b = pair_mask(spec, 7, 2, 32, tag=1)
    np.testing.assert_array_equal(a, b)  # shared edge PRNG
    c = pair_mask(spec, 2, 7, 32, tag=2)
    assert not np.array_equal(a, c)  # fresh masks per round tag


def test_full_cohort_masks_cancel_bitwise():
    spec = SecureAggregationSpec(seed=9)
    parts = list(range(5))
    total = np.zeros(48, np.uint64)
    for i in parts:
        total = total + node_mask(spec, i, parts, 48, tag=3)
    np.testing.assert_array_equal(total, np.zeros(48, np.uint64))


def test_aggregate_matches_plain_sum_full_cohort():
    spec = SecureAggregationSpec(seed=1)
    rng = np.random.default_rng(1)
    vals = {i: _values(rng) for i in range(4)}
    agg = SecureAggregator(spec, tuple(range(4)))
    payloads = {i: agg.mask(i, v, tag=0) for i, v in vals.items()}
    got = agg.aggregate(payloads, tag=0)
    want = sum(vals.values())
    # exact up to the fixed-point grid (one rounding per node)
    assert np.abs(got - want).max() <= 4 * spec.resolution


# ---------------------------------------------------------------------------
# Leakage: payloads never expose raw partials
# ---------------------------------------------------------------------------


def test_masked_payload_never_equals_raw_encoding():
    """No gossip payload may equal any node's raw (encoded) partials."""
    spec = SecureAggregationSpec(seed=2)
    rng = np.random.default_rng(2)
    vals = {i: _values(rng) for i in range(4)}
    raw = {i: encode_fixed(v, spec.frac_bits) for i, v in vals.items()}
    agg = SecureAggregator(spec, tuple(range(4)))
    payloads = {i: agg.mask(i, v, tag=0) for i, v in vals.items()}
    for i, p in payloads.items():
        for j, r in raw.items():
            assert not np.array_equal(p, r), (i, j)


def test_tree_reduction_payloads_stay_masked():
    """Every captured wire payload differs from every raw partial."""
    rng = np.random.default_rng(3)
    V, N, L = 5, 20, 8
    partials = [rng.standard_normal((N, L)) for _ in range(V)]
    spec = SecureAggregationSpec(seed=4)
    g = consensus.ring(V)
    Z, rep = vertical.reduce_partials(
        partials, g, secure=spec, capture_payloads=True
    )
    want = np.sum(np.stack(partials), axis=0)
    # grid error plus one f32 rounding (Z lands in the default jnp dtype)
    np.testing.assert_allclose(np.asarray(Z), want, rtol=1e-5, atol=1e-5)
    raw = [
        encode_fixed(p.reshape(-1).astype(np.float64), spec.frac_bits)
        for p in partials
    ]
    assert rep.payloads  # capture actually recorded wire traffic
    for (src, dst), payload in rep.payloads.items():
        for j, r in enumerate(raw):
            assert not np.array_equal(payload, r), (src, dst, j)


# ---------------------------------------------------------------------------
# Crash-time mask recovery (FaultModel interaction)
# ---------------------------------------------------------------------------


def test_crash_mid_round_recovers_survivor_sum():
    """Deterministic regression: node 3 crashes mid-reduction.

    The recovered aggregate must equal the sum over *delivered* nodes
    (no mask residue, no corruption from the dropped node's partial).
    """
    rng = np.random.default_rng(7)
    V, N, L = 5, 16, 6
    partials = [rng.standard_normal((N, L)) for _ in range(V)]
    spec = SecureAggregationSpec(seed=11)
    g = consensus.line(V)  # line graph: deep tree, mid-path crash hurts
    fm = FaultModel(
        graph=g, crashes=(NodeCrash(node=3, start=1, duration=10),)
    )
    Z, rep = vertical.reduce_partials(
        partials, g, secure=spec, faults=fm, start_round=0
    )
    assert 3 not in rep.delivered
    want = np.sum(np.stack([partials[i] for i in rep.delivered]), axis=0)
    np.testing.assert_allclose(np.asarray(Z), want, rtol=1e-5, atol=1e-5)
    # clear-mode reduction under the same faults agrees (same cohort)
    Zc, repc = vertical.reduce_partials(partials, g, faults=fm)
    assert repc.delivered == rep.delivered
    np.testing.assert_allclose(np.asarray(Z), np.asarray(Zc), atol=1e-5,
                               rtol=1e-5)


def test_crashed_at_start_is_excluded_not_dropped():
    """Nodes dead before the round never agree masks: exact bitwise
    parity with the survivor-only clear reduction (no grid error from
    a recovery step, because no recovery is needed)."""
    rng = np.random.default_rng(8)
    V = 4
    partials = [rng.standard_normal((8, 4)) for _ in range(V)]
    spec = SecureAggregationSpec(seed=12)
    g = consensus.ring(V)
    fm = FaultModel(
        graph=g, crashes=(NodeCrash(node=2, start=0, duration=99),)
    )
    Z, rep = vertical.reduce_partials(
        partials, g, secure=spec, faults=fm, start_round=0
    )
    assert rep.excluded == (2,) and rep.dropped == ()
    survivors = [p for i, p in enumerate(partials) if i != 2]
    want = np.sum(np.stack(survivors), axis=0)
    np.testing.assert_allclose(np.asarray(Z), want, rtol=1e-5, atol=1e-5)


def test_residual_mask_closes_the_books():
    """residual_mask(survivors, dropped) is exactly the sum of the
    dropped nodes' mask contributions toward the survivors."""
    spec = SecureAggregationSpec(seed=13)
    parts = (0, 1, 2, 3, 4)
    agg = SecureAggregator(spec, parts)
    dropped, survivors = (1, 4), (0, 2, 3)
    n = 16
    resid = agg.residual_mask(survivors, dropped, n, tag=5)
    want = np.zeros(n, np.uint64)
    for d in dropped:
        for s in survivors:
            r = pair_mask(spec, d, s, n, tag=5)
            # sign as seen from the *survivor* side
            want = want + r if s < d else want - r
    np.testing.assert_array_equal(resid, want)


def test_masked_sum_equals_unmasked_sum_over_surviving_subsets():
    """Property (hypothesis): for any surviving subset handled by
    recovery, decode(sum(masked) - residual) == sum(unmasked)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        v=st.integers(2, 7),
        seed=st.integers(0, 2**30),
        tag=st.integers(0, 5),
        data=st.data(),
    )
    @settings(max_examples=25, deadline=None)
    def prop(v, seed, tag, data):
        rng = np.random.default_rng(seed)
        vals = {i: _values(rng, n=12) for i in range(v)}
        spec = SecureAggregationSpec(seed=seed % 997)
        agg = SecureAggregator(spec, tuple(range(v)))
        survivors = data.draw(
            st.sets(st.integers(0, v - 1), min_size=1, max_size=v)
        )
        payloads = {
            i: agg.mask(i, vals[i], tag=tag) for i in sorted(survivors)
        }
        got = agg.aggregate(payloads, tag=tag)
        want = sum(vals[i] for i in survivors)
        assert np.abs(got - want).max() <= v * spec.resolution

    prop()


def test_payload_byte_accounting():
    spec = SecureAggregationSpec(seed=0)
    assert spec.payload_bytes(100) == 800  # uint64 per value
    agg = SecureAggregator(spec, (0, 1, 2))
    assert agg.payload_bytes(100) == 800
