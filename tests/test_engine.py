"""ConsensusEngine: one update rule, pluggable mixers, streaming driver.

Covers the acceptance contract of the engine refactor:
  * engine-vs-legacy equivalence for ``simulate_run`` (dense) and
    ``sharded_run`` (ppermute) on ring/hypercube topologies;
  * Algorithm 2 streaming: sharded-streaming == simulated-streaming ==
    the O(L^3) recompute reference after a mixed add+remove sequence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, dc_elm, engine, online
from tests.conftest import run_py


def _problem(V=8, Ni=32, L=12, M=2, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T


def _legacy_rounds(betas, omegas, adj, gamma, C, iters):
    """Paper eq. (20) hand-rolled — the pre-engine reference body."""
    V = betas.shape[0]
    deg = adj.sum(1)
    for _ in range(iters):
        lap = jnp.einsum("ij,jlm->ilm", adj, betas) - deg[:, None, None] * betas
        betas = betas + (gamma / (V * C)) * jnp.einsum(
            "vlk,vkm->vlm", omegas, lap
        )
    return betas


@pytest.mark.parametrize("kind", ["ring", "hypercube"])
def test_engine_matches_legacy_simulate_run(kind):
    H, T = _problem()
    C = 0.5
    g = consensus.build(kind, 8)
    state, _, _ = dc_elm.simulate_init(H, T, C)
    gamma = g.default_gamma()

    ref = _legacy_rounds(
        state.betas, state.omegas,
        jnp.asarray(g.adjacency, jnp.float32), gamma, C, 40,
    )
    wrapped, _ = dc_elm.simulate_run(state, g, gamma, C, 40)
    eng = engine.simulated_dc_elm(g, C)
    direct, _ = eng.run(state.betas, state.omegas, gamma, 40)

    np.testing.assert_allclose(wrapped.betas, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(direct, ref, rtol=1e-5, atol=1e-5)


def test_engine_time_varying_matches_legacy():
    H, T = _problem(V=6)
    C = 0.5
    graphs = consensus.alternating_halves(6)
    state, _, _ = dc_elm.simulate_init(H, T, C)
    gamma = 0.9 * dc_elm.joint_gamma_bound(graphs)

    betas = state.betas
    for k in range(30):
        adj = jnp.asarray(graphs[k % 2].adjacency, jnp.float32)
        betas = _legacy_rounds(betas, state.omegas, adj, gamma, C, 1)
    final, _ = dc_elm.simulate_run_time_varying(state, graphs, gamma, C, 30)
    np.testing.assert_allclose(final.betas, betas, rtol=1e-5, atol=1e-5)


def test_average_rule_preserves_mean():
    """Identity-metric engine == plain consensus averaging: the network
    mean is conserved and disagreement contracts."""
    g = consensus.ring(6)
    eng = engine.simulated_averaging(jnp.asarray(g.adjacency, jnp.float32))
    x = {"w": jax.random.normal(jax.random.key(0), (6, 4, 3))}
    out, _ = eng.run(x, None, g.default_gamma(), 50)
    np.testing.assert_allclose(
        jnp.mean(out["w"], 0), jnp.mean(x["w"], 0), atol=1e-5
    )
    spread = lambda v: float(jnp.max(jnp.abs(v - jnp.mean(v, 0))))  # noqa: E731
    assert spread(out["w"]) < spread(x["w"]) / 5


def test_stream_requires_dcelm_rule():
    g = consensus.ring(4)
    eng = engine.simulated_averaging(jnp.asarray(g.adjacency, jnp.float32))
    with pytest.raises(TypeError):
        eng.stream_init(jnp.zeros((4, 8, 3)), jnp.zeros((4, 8, 1)))


def test_streaming_simulated_matches_direct():
    """Algorithm 2 via the engine == O(L^3) recompute after a mixed
    add+remove chunk sequence, and the consensus rounds approach the new
    centralized solution."""
    V, L, M, C = 4, 10, 2, 4.0
    H, T = _problem(V=V, Ni=50, L=L, M=M)
    ks = jax.random.split(jax.random.key(3), 4)
    c1 = (jax.random.normal(ks[0], (V, 8, L)) / np.sqrt(L),
          jax.random.normal(ks[1], (V, 8, M)))
    c2 = (jax.random.normal(ks[2], (V, 6, L)) / np.sqrt(L),
          jax.random.normal(ks[3], (V, 6, M)))

    g = consensus.complete(V)
    eng = engine.simulated_dc_elm(g, C)
    s = eng.stream_init(H, T)
    gamma = g.default_gamma()
    s, _ = eng.stream_chunk(s, added=c1, gamma=gamma, num_iters=50)
    # mixed event: c1 expires while c2 arrives
    s, _ = eng.stream_chunk(
        s, added=c2, removed=c1, gamma=gamma, num_iters=1500
    )

    # surviving data = warm-up + c2
    H2 = jnp.concatenate([H, c2[0]], axis=1)
    T2 = jnp.concatenate([T, c2[1]], axis=1)
    ref = jax.vmap(lambda h, t: online.direct_state(h, t, C, V))(H2, T2)
    np.testing.assert_allclose(s.omegas, ref.omega, rtol=5e-3, atol=5e-5)
    np.testing.assert_allclose(s.Qs, ref.Q, rtol=1e-4, atol=1e-4)

    P2 = jnp.einsum("vnl,vnk->vlk", H2, H2)
    Q2 = jnp.einsum("vnl,vnm->vlm", H2, T2)
    beta_star = dc_elm.centralized_from_node_stats(P2, Q2, C)
    assert float(dc_elm.distance_to(s.betas, beta_star)) < 0.05


def test_sharded_run_matches_dense_engine():
    """sharded_run (ppermute engine) == simulate_run (dense engine) on
    the matching product graph, ring and hypercube."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import dc_elm, gossip
V, Ni, L, M, C = 8, 32, 12, 2, 0.5
from repro.utils import compat
mesh = compat.make_mesh((8,), ('data',))
kx, kt = jax.random.split(jax.random.key(0))
H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(kt, (V, Ni, M))
state, _, _ = dc_elm.simulate_init(H, T, C)
for kind in ['ring', 'hypercube']:
    spec = gossip.GossipSpec(axes=('data',), kinds=(kind,))
    g = spec.to_graph({'data': V})
    gamma = g.default_gamma()
    out = dc_elm.sharded_run(mesh, spec, state.betas, state.omegas, gamma, C, 300)
    ref, _ = dc_elm.simulate_run(state, g, gamma, C, 300)
    assert np.allclose(out, ref.betas, atol=2e-5), (kind, np.abs(out - ref.betas).max())
    step = dc_elm.sharded_step_fn(mesh, spec, C)
    one = step(state.betas, state.omegas, jnp.float32(gamma))
    sim = dc_elm.simulate_step(state, jnp.asarray(g.adjacency, jnp.float32),
                               jnp.float32(gamma), C)
    assert np.allclose(one, sim.betas, atol=1e-5), kind
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_elm_head_bundle_gossip_matches_dense():
    """core/elm_head's engine-backed gossip_fn (model-sharded vocab
    readout, Omega replicated at shard_map entry) == dense engine on
    the matching product graph; repeat calls hit the program cache."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.core import dc_elm
from repro.core.elm_head import make_elm_head_bundle
from repro.distributed import sharding as shd
from repro.utils import compat
mesh = compat.make_mesh((4, 2), ('data', 'model'))
cfg = registry()['gemma2-2b'].reduced()
bundle = make_elm_head_bundle(cfg, mesh)
stats = bundle.init_stats()
rng = np.random.default_rng(0)
d = stats.P.shape[-1]
assert d % 2 == 0  # exercises the model-sharded-Omega storage case
P_ = jnp.asarray(rng.normal(size=stats.P.shape) * 0.01 + np.eye(d), jnp.float32)
Q_ = jnp.asarray(rng.normal(size=stats.Q.shape) * 0.01, jnp.float32)
stats = type(stats)(P=P_, Q=Q_, count=stats.count + 10)
omegas, betas = bundle.solve_fn(stats, 1.0)
out = bundle.gossip_fn(betas, omegas, 0.2, 20, 1.0)
out2 = bundle.gossip_fn(betas, omegas, 0.2, 20, 1.0)  # cached program
axes = shd.resolve_axes(cfg, mesh)
spec = shd.consensus_gossip_spec(cfg, axes)
g = spec.to_graph({'data': 4, 'model': 2})
state = dc_elm.DCELMState(betas=betas, omegas=omegas, k=jnp.zeros((), jnp.int32))
ref, _ = dc_elm.simulate_run(state, g, 0.2, 1.0, 20)
assert np.allclose(out, ref.betas, atol=1e-5), np.abs(out - ref.betas).max()
assert np.allclose(out, out2, atol=0)
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_streaming_sharded_matches_simulated():
    """The same stream_chunk driver on the PpermuteMixer == DenseMixer ==
    direct O(L^3) recompute, after a mixed add+remove event."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine, gossip, online
from repro.utils import compat
V, L, M, C = 8, 10, 2, 4.0
ks = jax.random.split(jax.random.key(0), 6)
H = jax.random.normal(ks[0], (V, 40, L)) / np.sqrt(L)
T = jax.random.normal(ks[1], (V, 40, M))
dH = jax.random.normal(ks[2], (V, 6, L)) / np.sqrt(L)
dT = jax.random.normal(ks[3], (V, 6, M))
spec = gossip.GossipSpec(axes=('data',), kinds=('hypercube',))
g = spec.to_graph({'data': V})
gamma = g.default_gamma()
sim = engine.simulated_dc_elm(g, C)
s = sim.stream_init(H, T)
s, _ = sim.stream_chunk(s, added=(dH, dT), removed=(H[:, :5], T[:, :5]),
                        gamma=gamma, num_iters=400)
mesh = compat.make_mesh((8,), ('data',))
shd = engine.sharded_dc_elm(mesh, spec, C)
t = shd.stream_init(H, T)
t, _ = shd.stream_chunk(t, added=(dH, dT), removed=(H[:, :5], T[:, :5]),
                        gamma=gamma, num_iters=400)
assert np.allclose(s.betas, t.betas, atol=1e-4), np.abs(s.betas - t.betas).max()
assert np.allclose(s.omegas, t.omegas, atol=1e-5)
H2 = jnp.concatenate([H[:, 5:], dH], axis=1)
T2 = jnp.concatenate([T[:, 5:], dT], axis=1)
ref = jax.vmap(lambda h, t_: online.direct_state(h, t_, C, V))(H2, T2)
assert np.allclose(t.omegas, ref.omega, atol=1e-4), np.abs(t.omegas - ref.omega).max()
assert np.allclose(t.Qs, ref.Q, atol=1e-3)
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
