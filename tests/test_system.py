"""End-to-end behaviour tests: the paper's claims + system integration.

Multi-device paths run in subprocesses with forced host device counts so
the rest of the suite keeps the real single-device backend.
"""

import json

import pytest

from tests.conftest import run_py


def test_sinc_experiment_end_to_end():
    """Paper Test Case 1: DC-ELM ~= centralized ELM on noisy SinC."""
    code = """
import jax
jax.config.update('jax_enable_x64', True)  # stiff C=2^8 ridge solves
import jax.numpy as jnp
from repro.core import consensus, dc_elm, elm
from repro.data.sinc import make_sinc_dataset
X, Y, Xt, Yt = make_sinc_dataset(jax.random.key(0), num_nodes=4, per_node=500, num_test=1000)
X, Y = X.astype(jnp.float64), Y.astype(jnp.float64)
fmap, final, _ = dc_elm.simulate_train(
    jax.random.key(1), X, Y, num_features=100, C=2**8,
    graph=consensus.paper_fig2(), gamma=1/2.1, num_iters=2000)
H = jax.vmap(fmap)(X)
beta_c = elm.ridge_solve(H.reshape(-1, 100), Y.reshape(-1, 1), 2**8)
cent = elm.ELM(feature_map=fmap, beta=beta_c)
mse_c = float(elm.mse(cent, Xt, Yt))
mses = [float(elm.mse(elm.ELM(feature_map=fmap, beta=final.betas[i]), Xt, Yt)) for i in range(4)]
assert mse_c < 5e-3, mse_c
assert max(mses) < mse_c * 1.6 + 2e-3, (mses, mse_c)
print('OK', mse_c, max(mses))
"""
    r = run_py(code)
    assert r.returncode == 0, r.stderr


def test_consensus_training_on_devices():
    """Sharded consensus trainer: loss falls, replicas agree."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.distributed.steps import make_train_bundle, jit_train_step
from repro.core import dsgd
from repro.optim import adamw
from repro.data.lm import TokenStream
from repro.utils import compat
mesh = compat.make_mesh((4, 2), ('data', 'model'))
cfg = registry()['starcoder2-3b'].reduced()
bundle = make_train_bundle(cfg, mesh, adamw(3e-3), seed=0)
V = bundle.node_count
state = bundle.init_fn(jax.random.key(0))
stream = TokenStream(cfg.vocab_size, 0)
rng = np.random.default_rng(0)
def nb():
    t = stream.sample(rng, V*2, 32).reshape(V, 2, 33)
    return {'tokens': jnp.asarray(t[..., :-1], jnp.int32),
            'labels': jnp.asarray(t[..., 1:], jnp.int32)}
b = nb()
shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b)
step = jit_train_step(bundle, mesh, shape)
losses = []
for i in range(25):
    state, m = step(state, b)
    losses.append(float(jnp.mean(m['loss'])))
    b = nb()
assert losses[-1] < losses[0], losses
cd = float(dsgd.consensus_distance(state.params))
assert cd < 0.05, cd
print('OK', losses[0], losses[-1], cd)
"""
    r = run_py(code, devices=8, timeout=900)
    assert r.returncode == 0, r.stderr


def test_elm_head_integration():
    """Paper algorithm on frozen backbone features reaches fusion answer."""
    code = """
from repro.launch.elm_head import main
d1 = main(['--arch', 'gemma2-2b', '--reduced', '--nodes', '4',
           '--batches', '2', '--iters', '3000', '--C', '1e-4'])
assert d1 < 0.05, d1
print('OK', d1)
"""
    r = run_py(code, timeout=900)
    assert r.returncode == 0, r.stderr


def test_train_cli_reduced():
    code = """
from repro.launch.train import main
loss = main(['--arch', 'mamba2-780m', '--reduced', '--steps', '15',
             '--batch', '2', '--seq', '32', '--devices', '1x1',
             '--log-every', '0'])
assert loss < 7.0, loss
print('OK', loss)
"""
    r = run_py(code, timeout=900)
    assert r.returncode == 0, r.stderr


def test_serve_cli_reduced():
    code = """
from repro.launch.serve import main
gen = main(['--arch', 'h2o-danube-1.8b', '--reduced', '--batch', '2',
            '--prompt-len', '24', '--gen', '8'])
assert gen.shape == (2, 8)
print('OK')
"""
    r = run_py(code, timeout=900)
    assert r.returncode == 0, r.stderr


@pytest.mark.slow
def test_dryrun_one_combo():
    """Dry-run contract: 512-device lower+compile for one combo."""
    out = "/tmp/test_dryrun_combo.json"
    code = f"""
import runpy, sys
sys.argv = ['dryrun', '--arch', 'h2o-danube-1.8b', '--shape', 'decode_32k',
            '--out', '{out}', '--quiet']
runpy.run_module('repro.launch.dryrun', run_name='__main__')
"""
    r = run_py(code, timeout=1200)
    assert r.returncode == 0, r.stderr
    with open(out) as f:
        rec = json.load(f)
    assert rec["ok"], rec.get("reason")
    assert rec["roofline"]["chips"] == 256
