"""Data pipeline: sinc, synthetic MNIST 3v6, LM streams, partitioning."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elm
from repro.data.lm import TokenStream, make_lm_batches
from repro.data.partition import partition_equal, partition_sizes
from repro.data.sinc import make_sinc_dataset, sinc
from repro.data.synthetic_mnist import make_mnist36_dataset


def test_sinc_function():
    assert float(sinc(jnp.asarray(0.0))) == 1.0
    x = jnp.asarray([1.0, -2.0])
    np.testing.assert_allclose(sinc(x), np.sin(x) / x, rtol=1e-6)


def test_sinc_dataset_shapes_and_noise():
    X, Y, Xt, Yt = make_sinc_dataset(jax.random.key(0))
    assert X.shape == (4, 1250, 1) and Y.shape == (4, 1250, 1)
    assert Xt.shape == (5000, 1)
    # train targets noisy, test noise-free
    train_resid = np.abs(np.asarray(Y - sinc(X)))
    assert train_resid.max() <= 0.2 + 1e-6
    assert train_resid.mean() > 0.05
    np.testing.assert_allclose(Yt, sinc(Xt), atol=1e-6)


def test_mnist36_separable_by_elm():
    """The surrogate 3-vs-6 task is learnable (sanity for Fig. 7 repro)."""
    X, T, Xt, Tt = make_mnist36_dataset(seed=0, num_train=1200, num_test=400)
    assert X.shape == (1200, 784)
    model = elm.train_centralized(
        jax.random.key(0), jnp.asarray(X), jnp.asarray(T),
        num_features=50, C=0.25,
    )
    acc = float(elm.accuracy(model(jnp.asarray(Xt)), jnp.asarray(Tt)))
    assert acc > 0.85, f"3v6 accuracy {acc}"


def test_mnist36_determinism():
    a = make_mnist36_dataset(seed=3, num_train=10, num_test=4)
    b = make_mnist36_dataset(seed=3, num_train=10, num_test=4)
    np.testing.assert_array_equal(a[0], b[0])


def test_partition_equal():
    X = np.arange(103 * 2, dtype=np.float32).reshape(103, 2)
    T = np.arange(103, dtype=np.float32)[:, None]
    Xn, Tn = partition_equal(X, T, V=4, seed=0)
    assert Xn.shape == (4, 25, 2)
    # partition preserves (x, t) pairing
    assert np.allclose(Xn[..., 0], Tn[..., 0] * 2)


def test_partition_sizes():
    assert partition_sizes(100, 4) == [25, 25, 25, 25]
    assert sum(partition_sizes(103, 4)) == 103
    skewed = partition_sizes(1000, 5, skew=2.0, seed=1)
    assert sum(skewed) == 1000
    assert min(skewed) >= 1


def test_token_stream_learnable_structure():
    """Order-2 Markov stream: same history hash => limited branching."""
    ts = TokenStream(vocab_size=100, seed=0, branching=4)
    rng = np.random.default_rng(0)
    toks = ts.sample(rng, 64, 50)
    assert toks.shape == (64, 51)
    assert toks.max() < 100
    # successors of a given (prev2, prev1) pair come from <= 4 values
    succ = {}
    for row in toks:
        for t in range(2, 51):
            h = (row[t - 1] * 31 + row[t - 2]) % 4096
            succ.setdefault(h, set()).add(row[t])
    assert max(len(v) for v in succ.values()) <= 4


def test_make_lm_batches():
    batches = list(make_lm_batches(64, 2, 16, 3))
    assert len(batches) == 3
    b = batches[0]
    assert b["tokens"].shape == (2, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
