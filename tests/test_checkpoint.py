"""Checkpoint save/restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6.0).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3)},
        "lst": [jnp.zeros((2,)), jnp.full((1,), 7.0)],
    }
    path = ckpt.save_pytree(str(tmp_path), 5, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = ckpt.load_pytree(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save_pytree(str(tmp_path), 3, {"x": jnp.zeros(1)})
    ckpt.save_pytree(str(tmp_path), 11, {"x": jnp.zeros(1)})
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_shape_mismatch_raises(tmp_path):
    path = ckpt.save_pytree(str(tmp_path), 0, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.load_pytree(path, {"x": jnp.zeros((3,))})


def test_missing_key_raises(tmp_path):
    path = ckpt.save_pytree(str(tmp_path), 0, {"x": jnp.zeros(1)})
    with pytest.raises(KeyError):
        ckpt.load_pytree(path, {"y": jnp.zeros(1)})
