"""Multi-tenant model plane: stacked-beta kernel parity, the versioned
TenantRegistry, mixed-tenant serving (one fused launch), differential
bitwise packing-independence, and publisher-thread concurrency."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core.features import make_random_features
from repro.kernels import autotune, elm_predict_ops
from repro.kernels.elm_predict import elm_predict_stacked_pallas
from repro.kernels.elm_predict_ops import (
    fused_predict_stacked,
    predict_map,
    predict_stacked,
)
from repro.kernels.elm_predict_ref import (
    elm_predict_stacked_scan,
    predict_reference,
    predict_stacked_reference,
)
from repro.serving import (
    ContinuousELMServer,
    ELMServer,
    RetiredTenantError,
    TenantRegistry,
    UnknownTenantError,
)


def _relerr(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    return float(jnp.max(jnp.abs(a - b)) / (1 + jnp.max(jnp.abs(b))))


def _stacked_problem(N, D, L, M, T, dtype=jnp.float32,
                     activation="sigmoid", seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    X = jax.random.normal(ks[0], (N, D)).astype(dtype)
    W = jax.random.normal(ks[1], (D, L)).astype(dtype)
    if activation == "rbf":
        b = jax.random.uniform(ks[2], (L,), minval=0.05, maxval=1.0)
    else:
        b = jax.random.normal(ks[2], (L,))
    betas = jax.random.normal(ks[3], (T, L, M)).astype(jnp.float32)
    tids = jax.random.randint(ks[4], (N,), 0, T, jnp.int32)
    return X, W, b, betas, tids


def _loop_oracle(X, W, b, betas, tids, activation):
    """Per-tenant loop over the single-beta oracle: the semantics the
    stacked path must reproduce."""
    rows = [
        predict_reference(
            X[n:n + 1], W, b, betas[int(t)], activation=activation
        )
        for n, t in enumerate(np.asarray(tids))
    ]
    return jnp.concatenate(rows, axis=0)


# ---------------------------------------------------------------------------
# Stacked kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "activation", ["sigmoid", "tanh", "relu", "sin", "identity", "rbf"]
)
def test_stacked_parity_activations(activation):
    """Reference == per-tenant loop; scan and Pallas match it."""
    X, W, b, betas, tids = _stacked_problem(
        70, 5, 66, 3, 7, activation=activation
    )
    ref = predict_stacked_reference(
        X, W, b, betas, tids, activation=activation
    )
    loop = _loop_oracle(X, W, b, betas, tids, activation)
    assert _relerr(ref, loop) < 2e-5
    scan = elm_predict_stacked_scan(
        X, W, b, betas, tids, activation=activation, chunk=32
    )
    assert _relerr(scan, ref) < 2e-5
    pal = elm_predict_stacked_pallas(
        X, W, b, betas, tids, activation=activation, interpret=True,
        block_l=32, block_n=32,
    )
    assert _relerr(pal, ref) < 2e-5


@pytest.mark.parametrize("N", [1, 5, 127, 256])
def test_stacked_parity_ragged_rows(N):
    """Row counts off the block grid: padded rows contribute nothing."""
    X, W, b, betas, tids = _stacked_problem(N, 4, 40, 2, 5, seed=N)
    ref = predict_stacked_reference(X, W, b, betas, tids)
    pal = elm_predict_stacked_pallas(
        X, W, b, betas, tids, interpret=True, block_l=16, block_n=64,
    )
    assert _relerr(pal, ref) < 2e-5
    scan = elm_predict_stacked_scan(X, W, b, betas, tids, chunk=33)
    assert _relerr(scan, ref) < 2e-5


def test_stacked_parity_bf16():
    X, W, b, betas, tids = _stacked_problem(
        64, 6, 48, 3, 4, dtype=jnp.bfloat16
    )
    ref = predict_stacked_reference(X, W, b, betas, tids)
    assert ref.dtype == jnp.float32  # f32 betas win the promotion
    pal = elm_predict_stacked_pallas(
        X, W, b, betas, tids, interpret=True, block_l=16, block_n=32,
    )
    assert _relerr(pal, ref) < 1e-2
    scan = elm_predict_stacked_scan(X, W, b, betas, tids, chunk=17)
    assert _relerr(scan, ref) < 1e-2


def test_stacked_single_tenant_matches_plain_predict():
    """T=1, all ids 0: the stacked path degenerates to plain predict."""
    X, W, b, betas, _ = _stacked_problem(50, 4, 30, 2, 1)
    tids = jnp.zeros((50,), jnp.int32)
    ref = predict_reference(X, W, b, betas[0])
    out = predict_stacked_reference(X, W, b, betas, tids)
    assert _relerr(out, ref) < 1e-6


def test_stacked_dispatcher_and_empty_batch():
    X, W, b, betas, tids = _stacked_problem(40, 4, 24, 2, 3)
    ref = predict_stacked_reference(X, W, b, betas, tids)
    for use_kernel in (False, True):
        out = fused_predict_stacked(
            X, W, b, betas, tids, use_kernel=use_kernel, tuning="off"
        )
        assert _relerr(out, ref) < 2e-5
    fmap = make_random_features(jax.random.key(3), 4, 24)
    y0 = predict_stacked(X[:0], fmap, betas, tids[:0])
    assert y0.shape == (0, 2)


def test_predict_stacked_map_level_parity():
    """FeatureMap-level stacked predict == per-tenant predict_map."""
    fmap = make_random_features(jax.random.key(5), 6, 33)
    X, _, _, betas, tids = _stacked_problem(45, 6, 33, 3, 4, seed=5)
    out = predict_stacked(X, fmap, betas, tids)
    for n, t in enumerate(np.asarray(tids)):
        ref = predict_map(X[n:n + 1], fmap, betas[int(t)])
        assert _relerr(out[n:n + 1], ref) < 2e-5


def test_predict_stacked_feature_map_none():
    """feature_map=None: x already IS the feature matrix."""
    H = jax.random.normal(jax.random.key(0), (20, 16))
    betas = jax.random.normal(jax.random.key(1), (3, 16, 2))
    tids = jax.random.randint(jax.random.key(2), (20,), 0, 3, jnp.int32)
    out = predict_stacked(H, None, betas, tids)
    ref = jnp.stack([
        H[n] @ betas[int(t)] for n, t in enumerate(np.asarray(tids))
    ])
    assert _relerr(out, ref) < 1e-6


# ---------------------------------------------------------------------------
# Property test: stacked == per-tenant loop (hypothesis)
# ---------------------------------------------------------------------------


def _property_case(N, T, L, act, dtype_name, seed):
    dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[dtype_name]
    X, W, b, betas, tids = _stacked_problem(
        N, 3, L, 2, T, dtype=dtype, activation=act, seed=seed
    )
    tol = 2e-5 if dtype == jnp.float32 else 1e-2
    ref = _loop_oracle(X, W, b, betas, tids, act)
    scan = elm_predict_stacked_scan(
        X, W, b, betas, tids, activation=act, chunk=max(1, N // 2)
    )
    assert _relerr(scan, ref) < tol
    pal = elm_predict_stacked_pallas(
        X, W, b, betas, tids, activation=act, interpret=True,
        block_l=16, block_n=16,
    )
    assert _relerr(pal, ref) < tol


def test_property_stacked_equals_loop():
    """Hypothesis sweep: random tenant mixes, ragged row counts, every
    activation, f32 and bf16 — stacked predict == per-tenant loop
    within the pinned tolerance on BOTH fallbacks."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None)
    @hyp.given(
        N=st.integers(1, 40),
        T=st.integers(1, 6),
        L=st.integers(1, 48),
        act=st.sampled_from(
            ["sigmoid", "tanh", "relu", "sin", "identity", "rbf"]
        ),
        dtype_name=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 2**16),
    )
    def prop(N, T, L, act, dtype_name, seed):
        _property_case(N, T, L, act, dtype_name, seed)

    prop()


# ---------------------------------------------------------------------------
# TenantRegistry
# ---------------------------------------------------------------------------


def _betas(L=16, M=2, seed=0, n=1):
    rng = np.random.default_rng(seed)
    out = [rng.normal(size=(L, M)).astype(np.float32) for _ in range(n)]
    return out[0] if n == 1 else out


def test_registry_versioning_and_snapshot():
    b1, b2 = _betas(n=2)
    reg = TenantRegistry()
    assert reg.publish("a", b1) == 1
    assert reg.publish("b", b1) == 1
    assert reg.publish("a", b2) == 2  # hot-swap bumps per-tenant
    assert reg.version == 3  # every publish bumps the global version
    snap = reg.snapshot()
    assert snap.num_tenants == 2
    assert snap.tenant_version("a") == 2
    np.testing.assert_array_equal(np.asarray(snap.beta("a")), b2)
    assert reg.snapshot() is snap  # cached until the next mutation
    reg.publish("b", b2)
    assert reg.snapshot() is not snap


def test_registry_init_mapping_and_retire_cycle():
    b1, b2 = _betas(n=2)
    reg = TenantRegistry({"a": b1, "b": b2})
    assert sorted(reg.tenant_ids) == ["a", "b"]
    reg.retire("a")
    assert sorted(reg.tenant_ids) == ["b"]
    with pytest.raises(RetiredTenantError):
        reg.tenant_version("a")
    with pytest.raises(RetiredTenantError):
        reg.retire("a")  # already retired: still the named error
    with pytest.raises(UnknownTenantError):
        reg.retire("never-seen")
    # re-registration resumes the version counter (no version reuse)
    assert reg.publish("a", b2) == 2
    snap = reg.snapshot()
    assert snap.tenant_version("a") == 2


def test_registry_named_errors_name_the_argument():
    reg = TenantRegistry({"a": _betas()})
    with pytest.raises(UnknownTenantError, match="registered tenants"):
        reg.tenant_version("zz")
    snap = reg.snapshot()
    reg.retire("a")
    reg.publish("b", _betas())
    snap = reg.snapshot()
    with pytest.raises(RetiredTenantError, match="re-register"):
        snap.slot("a")
    with pytest.raises(UnknownTenantError, match="registered tenants"):
        snap.slot("zz")
    with pytest.raises(ValueError, match="beta must be"):
        reg.publish("c", np.zeros((4,)))
    with pytest.raises(ValueError, match="registry serves"):
        reg.publish("c", np.zeros((3, 3), np.float32))
    with pytest.raises(ValueError, match="beta_mode must be one of"):
        TenantRegistry(beta_mode="fp64")
    with pytest.raises(ValueError, match="int8_tile must be"):
        TenantRegistry(int8_tile=0)


def test_registry_empty_snapshot_raises():
    with pytest.raises(RuntimeError, match="no live tenants"):
        TenantRegistry().snapshot()


def test_registry_stale_tenants_rule():
    b = _betas()
    reg = TenantRegistry({"a": b, "b": b})
    snap = reg.snapshot()
    assert reg.stale_tenants(snap, 0) == []
    reg.publish("a", b)
    assert reg.stale_tenants(snap, 0) == ["a"]
    assert reg.stale_tenants(snap, 1) == []  # within the bound
    reg.publish("c", b)  # live tenant the snapshot never saw
    assert "c" in reg.stale_tenants(snap, 99)


def test_registry_int8_publish_quantizes_and_accounts():
    L, M = 32, 4
    beta = _betas(L, M)
    reg = TenantRegistry(beta_mode="int8", int8_tile=16)
    reg.publish("a", beta)
    assert reg.metrics["beta_bytes"] > 0
    got = np.asarray(reg.snapshot().beta("a"))
    assert not np.array_equal(got, beta)  # actually quantized
    assert np.max(np.abs(got - beta)) < 0.2  # but close
    # deterministic in (uid, version): republishing the same beta after
    # a retire/re-register cycle lands on a later version -> new noise
    reg2 = TenantRegistry(beta_mode="int8", int8_tile=16)
    reg2.publish("a", beta)
    np.testing.assert_array_equal(
        np.asarray(reg2.snapshot().beta("a")), got
    )


def test_publisher_reduce_modes_and_stream_chunk_hook():
    L, M = 12, 2
    stacked = np.stack(_betas(L, M, n=3))
    reg = TenantRegistry()
    reg.publisher("u", reduce="mean").publish(stacked)
    np.testing.assert_allclose(
        np.asarray(reg.snapshot().beta("u")), stacked.mean(0), rtol=1e-6
    )
    reg.publisher("v", reduce=1).publish(stacked)
    np.testing.assert_allclose(
        np.asarray(reg.snapshot().beta("v")), stacked[1], rtol=1e-6
    )
    reg.publisher("w").publish(stacked[0])  # bare (L, M) passes through
    with pytest.raises(ValueError, match='reduce must be "mean"'):
        reg.publisher("x", reduce="median")
    with pytest.raises(ValueError, match="betas must be"):
        reg.publisher("x").publish(np.zeros((2, 2, 2, 2)))


def test_stream_chunk_publishes_into_registry():
    """ConsensusEngine.stream_chunk(publish_to=registry.publisher(t))
    lands the post-consensus model in that tenant's slot."""
    from repro.core import consensus

    V, D, Lh, Mh = 4, 4, 10, 2
    fmap = make_random_features(jax.random.key(0), D, Lh)
    ks = jax.random.split(jax.random.key(1), 2)
    H = jax.vmap(fmap)(jax.random.normal(ks[0], (V, 12, D)))
    T = jax.random.normal(ks[1], (V, 12, Mh))
    eng = engine_mod.simulated_dc_elm(consensus.paper_fig2(), 2.0**6)
    state = eng.stream_init(H, T)
    reg = TenantRegistry()
    state, _ = eng.stream_chunk(
        state, gamma=1 / 2.1, num_iters=100,
        publish_to=reg.publisher("user-7"),
    )
    assert reg.tenant_version("user-7") == 1
    np.testing.assert_allclose(
        np.asarray(reg.snapshot().beta("user-7")),
        np.asarray(state.betas.mean(0)),
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# Multi-tenant serving
# ---------------------------------------------------------------------------


D, L, M = 5, 24, 2


def _mt_setup(T=4, seed=0, **kw):
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(seed)
    reg = TenantRegistry({
        f"t{i}": rng.normal(size=(L, M)).astype(np.float32)
        for i in range(T)
    })
    return fmap, reg, ELMServer(fmap, reg, **kw), rng


def test_server_mixed_tenants_one_launch():
    """A micro-batch mixing many tenants is served by ONE launch."""
    fmap, reg, srv, rng = _mt_setup(T=6, buckets=(64,))
    xs = {
        i: rng.normal(size=(4, D)).astype(np.float32) for i in range(6)
    }
    uids = {
        srv.submit(xs[i], tenant=f"t{i}"): i for i in list(range(6)) * 2
    }
    out = srv.flush()
    assert srv.metrics["batches"] == 1
    assert len(out) == 12
    snap = srv._snap
    for r in out:
        i = uids[r.uid]
        assert r.tenant == f"t{i}"
        assert r.version == snap.tenant_version(r.tenant)
        ref = predict_map(jnp.asarray(xs[i]), fmap, snap.beta(f"t{i}"))
        assert _relerr(r.y, ref) < 2e-5


def test_server_mode_mismatch_errors_name_the_argument():
    fmap, reg, srv, rng = _mt_setup()
    x = rng.normal(size=(2, D)).astype(np.float32)
    with pytest.raises(ValueError, match="tenant= is required"):
        srv.submit(x)
    with pytest.raises(ValueError, match="node= applies to single-tenant"):
        srv.submit(x, node=0, tenant="t0")
    with pytest.raises(UnknownTenantError, match="registered tenants"):
        srv.submit(x, tenant="zz")
    reg.retire("t0")
    with pytest.raises(RetiredTenantError, match="re-register"):
        srv.submit(x, tenant="t0")
    single = ELMServer(fmap, _betas(L, M))
    with pytest.raises(ValueError, match="tenant= applies to multi-tenant"):
        single.submit(x, tenant="t0")


def test_server_validation_errors_name_argument_and_values():
    fmap, reg, _, rng = _mt_setup()
    with pytest.raises(ValueError, match="max_staleness must be >= 0"):
        ELMServer(fmap, reg, max_staleness=-1)
    with pytest.raises(ValueError, match="int8_tile must be a positive"):
        ELMServer(fmap, reg, int8_tile=-8)
    with pytest.raises(ValueError, match="buckets must be ascending"):
        ELMServer(fmap, reg, buckets=(64, 16))
    with pytest.raises(ValueError, match="beta_mode must be one of"):
        ELMServer(fmap, reg, beta_mode="int4")
    with pytest.raises(ValueError, match="slots must be a positive"):
        ContinuousELMServer(fmap, reg, slots=0)
    with pytest.raises(ValueError, match="deadline_slack_s must be >= 0"):
        ContinuousELMServer(fmap, reg, deadline_slack_s=-0.5)
    with pytest.raises(ValueError, match="min_fill must be in"):
        ContinuousELMServer(fmap, reg, min_fill=1.5)
    srv = ELMServer(fmap, reg)
    with pytest.raises(ValueError, match="rows"):
        srv.submit(np.zeros((0, D), np.float32), tenant="t0")
    srv.submit(rng.normal(size=(1, D)).astype(np.float32), tenant="t0")
    with pytest.raises(ValueError, match="width"):
        srv.submit(np.zeros((1, D + 3), np.float32), tenant="t0")


def test_server_per_tenant_staleness_and_version_pinning():
    """A publish to tenant A refreshes requests *for A*; the flush
    snapshot pins every request's per-tenant version."""
    fmap, reg, srv, rng = _mt_setup(max_staleness=0)
    x = rng.normal(size=(2, D)).astype(np.float32)
    srv.predict(x, tenant="t0")  # prime the snapshot
    reg.publish("t1", rng.normal(size=(L, M)).astype(np.float32))
    srv.submit(x, tenant="t0")
    srv.submit(x, tenant="t1")
    out = srv.flush()
    by_tenant = {r.tenant: r for r in out}
    assert by_tenant["t1"].version == 2  # saw the fresh publish
    assert by_tenant["t0"].version == 1
    # a frozen server keeps serving the pinned snapshot
    srv.freeze()
    reg.publish("t0", rng.normal(size=(L, M)).astype(np.float32))
    srv.submit(x, tenant="t0")
    assert srv.flush()[0].version == 1
    srv.thaw()
    srv.submit(x, tenant="t0")
    assert srv.flush()[0].version == 2


def test_server_oversized_split_pins_one_version():
    fmap, reg, srv, rng = _mt_setup(buckets=(8,))
    x = rng.normal(size=(29, D)).astype(np.float32)  # 4 chunks
    uid = srv.submit(x, tenant="t2")
    out = srv.flush()
    (r,) = [r for r in out if r.uid == uid]
    assert r.y.shape == (29, M)
    assert r.version == srv._snap.tenant_version("t2")
    ref = predict_map(jnp.asarray(x), fmap, srv._snap.beta("t2"))
    assert _relerr(r.y, ref) < 2e-5


def test_server_int8_stacked_arm():
    fmap, reg, srv, rng = _mt_setup(beta_mode="int8", int8_tile=16)
    x = rng.normal(size=(3, D)).astype(np.float32)
    y = srv.predict(x, tenant="t1")
    assert srv.metrics["beta_bytes"] > 0
    ref = predict_map(jnp.asarray(x), fmap, srv._snap.beta("t1"))
    assert _relerr(y, ref) < 0.3  # quantized but close
    assert not np.allclose(y, np.asarray(ref))  # actually quantized


def test_server_rejects_tenant_retired_mid_queue():
    """A tenant retired between submit and flush rejects with the
    named error in server.rejections; the flush still serves others."""
    fmap, reg, srv, rng = _mt_setup(max_staleness=0)
    x = rng.normal(size=(2, D)).astype(np.float32)
    srv.predict(x, tenant="t0")  # prime
    uid_dead = srv.submit(x, tenant="t3")
    uid_live = srv.submit(x, tenant="t1")
    reg.retire("t3")
    reg.publish("t1", rng.normal(size=(L, M)))  # forces the refresh
    out = srv.flush()
    assert [r.uid for r in out] == [uid_live]
    ((uid, tenant, err),) = srv.rejections
    assert uid == uid_dead and tenant == "t3"
    assert isinstance(err, RetiredTenantError)
    assert srv.metrics["rejected"] == 1


# ---------------------------------------------------------------------------
# Differential serving: packing independence, bitwise
# ---------------------------------------------------------------------------


def _serve_requests(reqs, *, buckets, seed=0, flush_each=False):
    """Serve (tenant, x) requests on a fresh server; returns uid -> y."""
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(seed)
    reg = TenantRegistry({
        f"t{i}": rng.normal(size=(L, M)).astype(np.float32)
        for i in range(4)
    })
    srv = ELMServer(fmap, reg, buckets=buckets)
    out = {}
    for tenant, x in reqs:
        uid = srv.submit(x, tenant=tenant)
        if flush_each:
            for r in srv.flush():
                out[r.uid] = r.y
    for r in srv.flush():
        out[r.uid] = r.y
    return out, srv


def test_differential_mixed_vs_single_tenant_bitwise():
    """One mixed-tenant bucket == the same requests served in
    single-tenant buckets, BITWISE (per-row results are independent of
    launch packing)."""
    rng = np.random.default_rng(3)
    reqs = [
        (f"t{i % 4}", rng.normal(size=(3, D)).astype(np.float32))
        for i in range(8)
    ]
    mixed, srv_m = _serve_requests(reqs, buckets=(32,))
    single, srv_s = _serve_requests(reqs, buckets=(32,), flush_each=True)
    assert srv_m.metrics["batches"] == 1
    assert srv_s.metrics["batches"] == 8  # one launch per request
    assert mixed.keys() == single.keys()
    for uid in mixed:
        np.testing.assert_array_equal(mixed[uid], single[uid])


def test_differential_oversized_split_bitwise():
    """An oversized request split across stacked launches reassembles
    bitwise-identically to dedicated single-tenant service."""
    rng = np.random.default_rng(4)
    big = ("t1", rng.normal(size=(21, D)).astype(np.float32))
    small = [
        (f"t{i % 4}", rng.normal(size=(2, D)).astype(np.float32))
        for i in range(3)
    ]
    mixed, _ = _serve_requests([big] + small, buckets=(8,))
    alone, _ = _serve_requests([big] + small, buckets=(8,),
                               flush_each=True)
    for uid in mixed:
        np.testing.assert_array_equal(mixed[uid], alone[uid])


# ---------------------------------------------------------------------------
# Concurrency: publisher threads vs a flushing server
# ---------------------------------------------------------------------------


def test_concurrent_publish_swap_retire_no_version_straddle():
    """Publisher threads register/hot-swap/retire while the server
    flushes. Distinguishable betas (version-scaled) prove no response
    ever mixes two versions; retired tenants reject with the named
    error and everything else keeps serving."""
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(9)
    base = {
        f"t{i}": rng.normal(size=(L, M)).astype(np.float32)
        for i in range(4)
    }
    reg = TenantRegistry(base)
    srv = ELMServer(fmap, reg, buckets=(64,), max_staleness=0)
    stop = threading.Event()
    errors = []

    def publisher(tenant):
        v = 1
        while not stop.is_set():
            try:
                v = reg.publish(tenant, base[tenant] * (v + 1))
                if v % 7 == 0:
                    reg.retire(tenant)
                    v = reg.publish(tenant, base[tenant] * (v + 2))
            except Exception as e:  # pragma: no cover - fail loudly
                errors.append(e)
                return

    threads = [
        threading.Thread(target=publisher, args=(f"t{i}",))
        for i in range(3)  # t3 stays at its seed version
    ]
    for t in threads:
        t.start()
    x = rng.normal(size=(5, D)).astype(np.float32)
    Hx = np.asarray(fmap(jnp.asarray(x)))
    served = 0
    transient_rejects = 0
    try:
        for _ in range(60):
            for i in range(4):
                try:
                    srv.submit(x, tenant=f"t{i}")
                except RetiredTenantError:
                    # submitted inside a publisher's retire->republish
                    # window: the named rejection is the contract
                    transient_rejects += 1
            for r in srv.flush():
                served += 1
                # the served beta must be base * k for ONE integer k:
                # a straddled response would mix two scalings
                expect_unit = Hx @ base[r.tenant]
                scale = r.y / np.where(
                    np.abs(expect_unit) < 1e-9, 1.0, expect_unit
                )
                ks = scale[np.abs(expect_unit) > 1e-3]
                assert ks.size
                k = np.round(ks.flat[0])
                np.testing.assert_allclose(ks, k, rtol=1e-4, atol=1e-4)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    assert served > 0
    # mid-queue rejections (tenant retired after submit) all carry the
    # named error
    assert all(
        isinstance(e, (RetiredTenantError, UnknownTenantError))
        for _, _, e in srv.rejections
    )
    reg.retire("t3")
    with pytest.raises(RetiredTenantError):
        srv.submit(x, tenant="t3")


# ---------------------------------------------------------------------------
# Continuous batching, multi-tenant
# ---------------------------------------------------------------------------


def test_continuous_mixed_tenants_and_refill():
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(11)
    reg = TenantRegistry({
        f"t{i}": rng.normal(size=(L, M)).astype(np.float32)
        for i in range(3)
    })
    srv = ContinuousELMServer(fmap, reg, slots=8)
    xs = {i: rng.normal(size=(6, D)).astype(np.float32) for i in range(3)}
    uids = {srv.submit(xs[i], tenant=f"t{i}"): i for i in range(3)}
    done = srv.flush()  # 18 rows through 8 slots: mid-flight refill
    assert len(done) == 3
    snap = srv._snap
    for r in done:
        i = uids[r.uid]
        ref = predict_map(jnp.asarray(xs[i]), fmap, snap.beta(f"t{i}"))
        assert _relerr(r.y, ref) < 2e-5
        assert r.version == snap.tenant_version(r.tenant)


def test_continuous_pins_first_launch_version_mid_flight():
    """Rows spanning steps are all served by the version pinned at the
    request's first launch, even when the tenant republishes between
    steps."""
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(12)
    beta1 = rng.normal(size=(L, M)).astype(np.float32)
    reg = TenantRegistry({"a": beta1})
    srv = ContinuousELMServer(fmap, reg, slots=4, max_staleness=0)
    x = rng.normal(size=(10, D)).astype(np.float32)
    uid = srv.submit(x, tenant="a")
    assert srv.step() == []  # 4 of 10 rows served, mid-flight
    reg.publish("a", beta1 * 10.0)  # lands mid-request
    out = []
    while not out:
        out = srv.step()
    (r,) = out
    assert r.uid == uid and r.version == 1
    ref = predict_map(jnp.asarray(x), fmap, jnp.asarray(beta1))
    assert _relerr(r.y, ref) < 2e-5  # ALL rows from the pinned beta
    # the next request picks up the published version
    y2 = srv.predict(x[:2], tenant="a")
    assert _relerr(y2, 10.0 * np.asarray(ref[:2])) < 2e-5


def test_continuous_rejects_retired_at_refresh():
    fmap = make_random_features(jax.random.key(7), D, L)
    rng = np.random.default_rng(13)
    reg = TenantRegistry({
        "a": rng.normal(size=(L, M)).astype(np.float32),
        "b": rng.normal(size=(L, M)).astype(np.float32),
    })
    srv = ContinuousELMServer(fmap, reg, slots=8, max_staleness=0)
    srv.predict(rng.normal(size=(1, D)).astype(np.float32), tenant="a")
    uid_dead = srv.submit(
        rng.normal(size=(2, D)).astype(np.float32), tenant="b"
    )
    reg.retire("b")
    reg.publish("a", rng.normal(size=(L, M)))  # forces the refresh
    srv.submit(rng.normal(size=(2, D)).astype(np.float32), tenant="a")
    out = srv.flush()
    assert [r.tenant for r in out] == ["a"]
    ((uid, tenant, err),) = srv.rejections
    assert uid == uid_dead and tenant == "b"
    assert isinstance(err, RetiredTenantError)


# ---------------------------------------------------------------------------
# Autotuner: the stacked op
# ---------------------------------------------------------------------------


@pytest.fixture()
def _fresh_memo():
    autotune.clear_memo()
    yield
    autotune.clear_memo()


def test_stacked_tunepoint_key_carries_T(_fresh_memo):
    pt = autotune.TunePoint(
        op="stacked", impl="scan", N=1024, D=8, L=64, M=4,
        dtype="float32", backend="cpu", T=16,
    )
    assert "_T16" in pt.key
    # T=0 (the single-beta ops) keeps the committed key format stable
    pt0 = autotune.TunePoint(
        op="predict", impl="scan", N=1024, D=8, L=64, M=4,
        dtype="float32", backend="cpu",
    )
    assert "_T" not in pt0.key
    with pytest.raises(ValueError, match="T"):
        autotune.TunePoint(
            op="stacked", impl="scan", N=1024, D=8, L=64, M=4,
            dtype="float32", backend="cpu",
        )


def test_stacked_candidates_and_tune_roundtrip(tmp_path, _fresh_memo):
    path = str(tmp_path / "tuned.json")
    cfg = autotune.tune(
        "stacked", 64, 4, 16, 2, "float32", impl="scan", T=3,
        cache_path=path, repeats=1,
    )
    assert "chunk" in cfg
    hit = autotune.lookup(
        "stacked", 64, 4, 16, 2, "float32", impl="scan", T=3,
        cache_path=path,
    )
    assert hit == cfg


def test_stacked_dispatcher_consults_tuning_dict():
    X, W, b, betas, tids = _stacked_problem(32, 4, 16, 2, 3)
    ref = predict_stacked_reference(X, W, b, betas, tids)
    out = fused_predict_stacked(
        X, W, b, betas, tids, use_kernel=False, tuning={"chunk": 8}
    )
    assert _relerr(out, ref) < 2e-5
    with pytest.raises(ValueError, match="chunk is the scan-fallback"):
        fused_predict_stacked(
            X, W, b, betas, tids, use_kernel=True, tuning={"chunk": 8}
        )
