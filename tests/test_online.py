"""Online DC-ELM, Algorithm 2 (Woodbury chunk updates)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online


def _data(n, L=12, M=2, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return (
        jax.random.normal(k1, (n, L)) / np.sqrt(L),
        jax.random.normal(k2, (n, M)),
    )


C, V = 8.0, 4


def test_add_chunk_matches_direct():
    H, T = _data(100)
    dH, dT = _data(7, seed=1)
    st = online.init_state(H, T, C, V)
    st2 = online.add_chunk(st, dH, dT)
    ref = online.init_state(
        jnp.concatenate([H, dH]), jnp.concatenate([T, dT]), C, V
    )
    np.testing.assert_allclose(st2.omega, ref.omega, rtol=5e-3, atol=5e-5)
    np.testing.assert_allclose(st2.beta, ref.beta, rtol=5e-3, atol=5e-4)


def test_remove_chunk_matches_direct():
    H, T = _data(100)
    st = online.init_state(H, T, C, V)
    st2 = online.remove_chunk(st, H[-9:], T[-9:])
    ref = online.init_state(H[:-9], T[:-9], C, V)
    np.testing.assert_allclose(st2.omega, ref.omega, rtol=5e-3, atol=5e-5)
    np.testing.assert_allclose(st2.beta, ref.beta, rtol=5e-3, atol=5e-4)


def test_add_then_remove_roundtrip():
    H, T = _data(80)
    dH, dT = _data(5, seed=2)
    st = online.init_state(H, T, C, V)
    st2 = online.remove_chunk(online.add_chunk(st, dH, dT), dH, dT)
    np.testing.assert_allclose(st2.omega, st.omega, rtol=5e-3, atol=5e-5)
    np.testing.assert_allclose(st2.Q, st.Q, rtol=1e-4, atol=1e-4)


def test_streaming_chunks_equal_batch():
    """Chunk-by-chunk online learning == batch training (paper Sec. III-E)."""
    H, T = _data(128, seed=5)
    st = online.init_state(H[:32], T[:32], C, V)
    for i in range(32, 128, 16):
        st = online.add_chunk(st, H[i : i + 16], T[i : i + 16])
    ref = online.init_state(H, T, C, V)
    np.testing.assert_allclose(st.beta, ref.beta, rtol=1e-2, atol=1e-3)


def test_update_chunk_remove_then_add():
    H, T = _data(64)
    dH, dT = _data(6, seed=3)
    st = online.init_state(H, T, C, V)
    st2 = online.update_chunk(st, added=(dH, dT), removed=(H[:6], T[:6]))
    ref = online.init_state(
        jnp.concatenate([H[6:], dH]), jnp.concatenate([T[6:], dT]), C, V
    )
    np.testing.assert_allclose(st2.beta, ref.beta, rtol=5e-3, atol=5e-4)


def test_batched_variants():
    Hs = jnp.stack([_data(40, seed=i)[0] for i in range(3)])
    Ts = jnp.stack([_data(40, seed=i)[1] for i in range(3)])
    sts = jax.vmap(lambda h, t: online.init_state(h, t, C, V))(Hs, Ts)
    dH = Hs[:, :5]
    dT = Ts[:, :5]
    out = online.batched_add_chunk(sts, dH, dT)
    for i in range(3):
        ref = online.add_chunk(
            online.OnlineNodeState(sts.omega[i], sts.Q[i]), dH[i], dT[i]
        )
        # atol floor: the vmapped path lowers to a batched triangular
        # solve whose f32 reduction order differs from the single-node
        # solve by a few ULP near zero
        np.testing.assert_allclose(
            out.omega[i], ref.omega, rtol=1e-5, atol=1e-7
        )
    betas = online.reseed_betas(out)
    assert betas.shape == (3, 12, 2)
