"""Decentralized consensus SGD (beyond-paper trainer, core/dsgd.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dsgd
from repro.optim import sgd


def _quadratic_problem(V=4, D=6, seed=0):
    """Node i minimizes ||A_i x - b_i||^2; global optimum is known."""
    rng = np.random.default_rng(seed)
    As = jnp.asarray(rng.normal(size=(V, 8, D)))
    bs = jnp.asarray(rng.normal(size=(V, 8)))

    def loss_fn(params, batch):
        A, b = batch
        r = A @ params["x"] - b
        return jnp.sum(r * r)

    A_all = np.concatenate(list(np.asarray(As)), 0)
    b_all = np.concatenate(list(np.asarray(bs)), 0)
    x_star = np.linalg.lstsq(A_all, b_all, rcond=None)[0]
    return loss_fn, (As, bs), jnp.asarray(x_star)


def test_consensus_sgd_reaches_global_optimum():
    V = 4
    loss_fn, batch, x_star = _quadratic_problem(V)
    g = consensus.ring(V)
    opt = sgd(5e-3)
    step = dsgd.make_simulated_train_step(loss_fn, opt, g)
    state = dsgd.init_simulated(
        jax.random.key(0), lambda k: {"x": jnp.zeros(6)}, opt, V
    )
    for _ in range(3000):
        state, losses = step(state, batch)
    xs = state.params["x"]
    assert float(dsgd.consensus_distance(state.params)) < 1e-2
    err = float(jnp.max(jnp.linalg.norm(xs - x_star[None], axis=1)))
    assert err < 0.05, err


def test_mix_preserves_mean():
    """Laplacian mixing conserves the network average (symmetric graph)."""
    V = 6
    g = consensus.random_geometric(V, 0.6, seed=2)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    x = {"w": jax.random.normal(jax.random.key(0), (V, 3, 2))}
    mixed = dsgd.mix_simulated(x, adj, gamma=0.1)
    np.testing.assert_allclose(
        jnp.mean(mixed["w"], 0), jnp.mean(x["w"], 0), atol=1e-6
    )


def test_mix_contracts_disagreement():
    V = 8
    g = consensus.ring(V)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    x = {"w": jax.random.normal(jax.random.key(1), (V, 5))}
    d0 = float(dsgd.consensus_distance(x))
    for _ in range(50):
        x = dsgd.mix_simulated(x, adj, gamma=g.default_gamma())
    assert float(dsgd.consensus_distance(x)) < d0 / 5


def test_dsgd_config_spec():
    c = dsgd.DSGDConfig(gossip_axes=("data",), gossip_kinds=("ring",))
    assert c.resolved_gamma({"data": 8}) == 0.9 / 2
    assert c.spec().degree({"data": 8}) == 2
