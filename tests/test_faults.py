"""Fault-injection consensus: FaultModel, FaultyMixer, elastic membership.

Pins the robustness acceptance contract:
  (a) DC-ELM under per-round Bernoulli edge dropout (p <= 0.3) on a
      certified jointly connected trace still converges to the
      centralized solution on both mixers, simulated == sharded;
  (b) a node leave -> rejoin during streaming recovers the
      ``online.direct_state`` reference;
  (c) the fusion-center comparison example runs end-to-end.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, dc_elm, engine, gossip, mixers, online
from tests.conftest import REPO, run_py


def _problem(V=8, Ni=40, L=10, M=1, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T


# ---------------------------------------------------------------------------
# FaultModel
# ---------------------------------------------------------------------------


def test_fault_model_masks_symmetric_deterministic():
    g = consensus.hypercube(3)
    fm = consensus.FaultModel(graph=g, edge_drop_prob=0.3, seed=7)
    k1, k2 = fm.edge_keep(50), fm.edge_keep(50)
    np.testing.assert_array_equal(k1, k2)  # replayable by seed
    np.testing.assert_array_equal(k1, np.transpose(k1, (0, 2, 1)))
    assert set(np.unique(k1)) <= {0.0, 1.0}
    # masks live only on base edges
    assert np.all(k1[:, g.adjacency == 0] == 0)
    # p=0 keeps every edge every round
    all_up = consensus.FaultModel(graph=g).edge_keep(5)
    np.testing.assert_array_equal(all_up, np.broadcast_to(
        (g.adjacency > 0).astype(float), (5, 8, 8)))


def test_fault_model_outage_and_crash_windows():
    g = consensus.ring(6)
    fm = consensus.FaultModel(
        graph=g,
        outages=(consensus.LinkOutage(edge=(0, 1), start=5, duration=10),),
        crashes=(consensus.NodeCrash(node=3, start=2, duration=4),),
    )
    keep = fm.edge_keep(20)
    assert keep[4, 0, 1] == 1 and keep[5, 0, 1] == 0
    assert keep[14, 0, 1] == 0 and keep[15, 0, 1] == 1
    assert np.all(keep[2:6, 3, :] == 0) and np.all(keep[2:6, :, 3] == 0)
    assert keep[6, 3, 2] == 1  # rejoined


def test_certification_catches_partition():
    g = consensus.ring(4)
    # both of node 0's links permanently dead => never jointly connected
    fm = consensus.FaultModel(
        graph=g,
        outages=(
            consensus.LinkOutage(edge=(0, 1), start=0, duration=100),
            consensus.LinkOutage(edge=(0, 3), start=0, duration=100),
        ),
    )
    assert not fm.certify_jointly_connected(100, window=100)
    assert consensus.FaultModel(graph=g).certify_jointly_connected(10, 1)
    with pytest.raises(RuntimeError):
        consensus.FaultModel.sample_certified(
            g, 0.0, num_rounds=100, window=100,
            outages=fm.outages, max_tries=3,
        )


def test_certification_joint_but_not_per_round():
    """A trace whose every snapshot is disconnected but whose windowed
    unions are connected certifies (the paper's joint-connectivity
    condition, not per-round connectivity)."""
    halves = consensus.alternating_halves(6)
    union = consensus.Graph(
        np.maximum(halves[0].adjacency, halves[1].adjacency)
    )
    # drop exactly the odd-pair edges on even rounds and vice versa
    outages = []
    for i in range(6):
        for j in range(i + 1, 6):
            if halves[0].adjacency[i, j] and not halves[1].adjacency[i, j]:
                outages.append(consensus.LinkOutage((i, j), 1, 1))
            elif halves[1].adjacency[i, j] and not halves[0].adjacency[i, j]:
                outages.append(consensus.LinkOutage((i, j), 0, 1))
    fm = consensus.FaultModel(graph=union, outages=tuple(outages))
    for k, a in enumerate(fm.adjacency_stream(2)):
        assert not consensus.Graph(a).is_connected, k
    assert fm.certify_jointly_connected(2, window=2)
    assert not fm.certify_jointly_connected(2, window=1)


def test_fault_gamma_bound_delegates():
    g = consensus.hypercube(3)
    fm = consensus.FaultModel(graph=g, edge_drop_prob=0.2)
    assert fm.gamma_upper_bound() == g.gamma_upper_bound()
    base = mixers.DenseMixer.from_graphs(g)
    faulty = mixers.FaultyMixer.from_fault_model(base, fm, 16)
    assert faulty.default_gamma() == base.default_gamma()


# ---------------------------------------------------------------------------
# FaultyMixer over DenseMixer
# ---------------------------------------------------------------------------


def test_faulty_dense_laplacian_matches_masked_reference():
    g = consensus.hypercube(3)
    fm = consensus.FaultModel(graph=g, edge_drop_prob=0.4, seed=1)
    keep = fm.edge_keep(7)
    base = mixers.DenseMixer.from_graphs(g)
    faulty = mixers.FaultyMixer(base, keep)
    x = jax.random.normal(jax.random.key(2), (8, 5, 3))
    flat = np.asarray(x).reshape(8, -1)
    for k in [0, 3, 6, 9]:  # 9 wraps: mask k % R
        adj = np.asarray(g.adjacency) * keep[k % 7]
        ref = (adj @ flat - adj.sum(1)[:, None] * flat).reshape(x.shape)
        out = faulty.laplacian(x, k)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_dropout_converges_simulated():
    """Acceptance (a), simulated path: p=0.3 per-round Bernoulli dropout
    on a certified jointly connected trace still reaches beta*."""
    H, T = _problem()
    C = 0.5
    g = consensus.hypercube(3)
    fm = consensus.FaultModel.sample_certified(
        g, 0.3, num_rounds=500, window=12
    )
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    eng = engine.with_faults(engine.simulated_dc_elm(g, C), fm, 500)
    betas, _ = eng.run(state.betas, state.omegas, g.default_gamma(), 2000)
    assert float(dc_elm.distance_to(betas, beta_star)) < 0.01


def test_fold_edge_keep_covers_every_edge_once():
    """Each undirected edge's two directions land on exactly the two
    (perm, dst) slots that receive through it — for every ICI kind."""
    for kind, n in [("ring", 8), ("ring", 2), ("hypercube", 8),
                    ("complete", 5)]:
        spec = gossip.GossipSpec(axes=("data",), kinds=(kind,))
        sizes = {"data": n}
        src = gossip.perm_sources(spec, sizes)
        g = spec.to_graph(sizes)
        # summing indicator masks per edge reconstructs the adjacency
        counts = np.zeros((n, n))
        for p in range(src.shape[0]):
            for i in range(n):
                counts[src[p, i], i] += 1
        np.testing.assert_array_equal(counts, g.adjacency)


def test_dropout_sharded_matches_simulated():
    """Acceptance (a), sharded path: the same fault trace replayed
    through masked ppermute gossip == the masked dense engine, and a
    second fault trace reuses the compiled program."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import consensus, dc_elm, engine, gossip
from repro.utils import compat
V, Ni, L, M, C = 8, 32, 12, 2, 0.5
mesh = compat.make_mesh((8,), ('data',))
spec = gossip.GossipSpec(axes=('data',), kinds=('hypercube',))
g = spec.to_graph({'data': V})
fm = consensus.FaultModel.sample_certified(g, 0.3, num_rounds=300, window=10)
keep = fm.edge_keep(300)
kx, kt = jax.random.split(jax.random.key(0))
H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(kt, (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
gamma = g.default_gamma()
dense = engine.with_faults(engine.simulated_dc_elm(g, C), keep)
ref, _ = dense.run(state.betas, state.omegas, gamma, 300)
base = engine.sharded_dc_elm(mesh, spec, C)
shd = engine.with_faults(base, keep)
out, _ = shd.run(state.betas, state.omegas, gamma, 300)
assert np.allclose(out, ref, atol=2e-5), np.abs(out - ref).max()
assert float(dc_elm.distance_to(out, beta_star)) < 0.01
n_programs = len(base.mixer._programs)
keep2 = consensus.FaultModel(graph=g, edge_drop_prob=0.1, seed=9).edge_keep(300)
shd2 = engine.with_faults(base, keep2)
out2, _ = shd2.run(state.betas, state.omegas, gamma, 300)
assert len(base.mixer._programs) == n_programs, 'recompiled for new masks'
dense2 = engine.with_faults(engine.simulated_dc_elm(g, C), keep2)
ref2, _ = dense2.run(state.betas, state.omegas, gamma, 300)
assert np.allclose(out2, ref2, atol=2e-5), np.abs(out2 - ref2).max()
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_faulty_step_inside_shard_map():
    """engine.step with a faulty ppermute mixer inside a caller-managed
    shard_map picks the round's mask via its mesh position."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import consensus, dc_elm, engine, gossip
from repro.utils import compat
V, L, M, C = 8, 6, 2, 0.5
mesh = compat.make_mesh((8,), ('data',))
spec = gossip.GossipSpec(axes=('data',), kinds=('ring',))
g = spec.to_graph({'data': V})
keep = consensus.FaultModel(graph=g, edge_drop_prob=0.5, seed=3).edge_keep(11)
H, T = (jax.random.normal(k, s) for k, s in
        zip(jax.random.split(jax.random.key(1)), [(V, 20, L), (V, 20, M)]))
state, _, _ = dc_elm.simulate_init(H, T, C)
gamma = jnp.float32(g.default_gamma())
shd = engine.with_faults(engine.sharded_dc_elm(mesh, spec, C), keep)
dense = engine.with_faults(engine.simulated_dc_elm(g, C), keep)
for k in [0, 4, 13]:
    fn = compat.shard_map(lambda b, o: shd.step(b, o, gamma, k=k), mesh,
                          in_specs=(P('data'), P('data')), out_specs=P('data'))
    out = jax.jit(fn)(state.betas, state.omegas)
    ref = dense.step(state.betas, state.omegas, gamma, k=k)
    assert np.allclose(out, ref, atol=1e-5), (k, np.abs(out - ref).max())
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Elastic membership (streaming churn)
# ---------------------------------------------------------------------------


def test_stream_leave_rejoin_recovers_direct_state():
    """Acceptance (b): a node leaves mid-stream and rejoins with its
    data; the stacked statistics recover the O(L^3) recompute reference
    at every stage and consensus reaches the restored centralized
    solution."""
    V, L, M, C = 4, 10, 2, 4.0
    H, T = _problem(V=V, Ni=30, L=L, M=M, seed=5)
    g = consensus.complete(V)
    eng = engine.simulated_dc_elm(g, C)
    s = eng.stream_init(H, T)

    eng3, s3 = eng.stream_leave(s, 1)
    assert eng3.rule.num_nodes == 3
    assert eng3.mixer.num_nodes == 3
    stay = jnp.asarray([0, 2, 3])
    ref3 = jax.vmap(lambda h, t: online.direct_state(h, t, C, 3))(
        H[stay], T[stay]
    )
    np.testing.assert_allclose(s3.omegas, ref3.omega, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(s3.Qs, ref3.Q, rtol=1e-6, atol=1e-6)

    # the shrunken network keeps streaming: rounds + a data chunk
    dH, dT = _problem(V=3, Ni=6, L=L, M=M, seed=6)
    s3, _ = eng3.stream_chunk(
        s3, added=(dH, dT), gamma=eng3.mixer.default_gamma(), num_iters=40
    )

    # node 1 rejoins with its original data (appended at index 3)
    eng4, s4 = eng3.stream_join(s3, H[1], T[1])
    assert eng4.rule.num_nodes == 4
    # post-rejoin node order is [0, 2, 3, 1] (joiner appends)
    H4 = [jnp.concatenate([H[i], dH[j]]) for j, i in enumerate([0, 2, 3])]
    H4.append(H[1])
    T4 = [jnp.concatenate([T[i], dT[j]]) for j, i in enumerate([0, 2, 3])]
    T4.append(T[1])
    refs = [online.direct_state(h, t, C, 4) for h, t in zip(H4, T4)]
    np.testing.assert_allclose(
        s4.omegas, jnp.stack([r.omega for r in refs]), rtol=1e-3, atol=1e-5
    )
    np.testing.assert_allclose(
        s4.Qs, jnp.stack([r.Q for r in refs]), rtol=1e-5, atol=1e-5
    )

    # and the restored network consents to the restored beta*
    s4, _ = eng4.stream_chunk(
        s4, gamma=eng4.mixer.default_gamma(), num_iters=1500
    )
    P4 = jnp.stack([h.T @ h for h in H4])
    Q4 = jnp.stack([h.T @ t for h, t in zip(H4, T4)])
    beta_star = dc_elm.centralized_from_node_stats(P4, Q4, C)
    assert float(dc_elm.distance_to(s4.betas, beta_star)) < 0.05


def test_membership_needs_dense_or_explicit_graph():
    spec = gossip.GossipSpec(axes=("data",), kinds=("ring",))
    eng = engine.ConsensusEngine(
        mixers.PpermuteMixer(spec=spec, axis_sizes={"data": 4}),
        engine.DCELMRule(4, 1.0),
    )
    s = engine.StreamState(
        omegas=jnp.broadcast_to(jnp.eye(3), (4, 3, 3)),
        Qs=jnp.zeros((4, 3, 2)),
        betas=jnp.zeros((4, 3, 2)),
    )
    with pytest.raises(TypeError):
        eng.stream_leave(s, 0)
    # an explicit graph sidesteps the sharded-adjacency question
    eng2, s2 = eng.stream_leave(s, 0, graph=consensus.ring(3))
    assert eng2.mixer.num_nodes == 3 and s2.betas.shape[0] == 3


def test_membership_preserves_fault_layer():
    """stream_leave/stream_join on a with_faults engine carry the fault
    trace across the membership change (masks resized with the
    adjacency, joiner links all-up) instead of silently going
    fault-free."""
    V, C = 4, 4.0
    H, T = _problem(V=V, Ni=20, L=6, M=1, seed=8)
    g = consensus.complete(V)
    keep = consensus.FaultModel(
        graph=g, edge_drop_prob=0.4, seed=2
    ).edge_keep(9)
    eng = engine.with_faults(engine.simulated_dc_elm(g, C), keep)
    s = eng.stream_init(H, T)

    eng2, s2 = eng.stream_leave(s, 1)
    assert isinstance(eng2.mixer, mixers.FaultyMixer)
    stay = [0, 2, 3]
    np.testing.assert_array_equal(
        eng2.mixer.edge_keep, keep[np.ix_(range(9), stay, stay)]
    )

    eng3, _ = eng2.stream_join(s2, H[1], T[1])
    assert isinstance(eng3.mixer, mixers.FaultyMixer)
    grown = eng3.mixer.edge_keep
    np.testing.assert_array_equal(grown[:, :3, :3], eng2.mixer.edge_keep)
    assert np.all(grown[:, 3, :] == 1) and np.all(grown[:, :, 3] == 1)


def test_rescale_num_nodes_matches_direct():
    H, T = _problem(V=1, Ni=50, L=8, M=2, seed=9)
    H, T = H[0], T[0]
    for C in [0.5, 8.0]:
        for V_old, V_new in [(4, 3), (3, 4), (5, 5)]:
            s = online.init_state(H, T, C, V_old)
            out = online.rescale_num_nodes(s.omega, V_old, V_new, C)
            ref = online.init_state(H, T, C, V_new)
            np.testing.assert_allclose(
                out, ref.omega, rtol=1e-4, atol=1e-6
            )


# ---------------------------------------------------------------------------
# Example (acceptance c)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fault_tolerance_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "fault_tolerance.py")],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr
    assert "Fusion-center baseline" in r.stdout
    assert "distance to centralized" in r.stdout
