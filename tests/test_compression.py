"""Compressed gossip (paper Sec. V: "reduction of the amount of
information exchanging").

Two layers under test:

* the legacy inline ``compress="bf16"`` mixer knob — pins (1)
  compressed-vs-uncompressed drift on the dense path, (2)
  DenseMixer-vs-PpermuteMixer agreement under compression, and (3)
  that compressed runs still satisfy the Thm. 2 stability bound
  gamma < 1/d_max;

* the ``core/compression.py`` subsystem (``CompressionSpec`` +
  ``CompressedMixer``) — int8 round-trip edges (all-zero / rank-1 /
  ragged-tile payloads), CHOCO error feedback cancelling quantization
  bias over rounds (hypothesis property + a deterministic pin),
  dense == ppermute under int8 within a pinned tolerance (incl.
  composed with a fault trace), event-triggered skipping, exact
  bytes-on-wire accounting, and uniform None/"none" handling across
  mixers and engine constructors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, consensus, dc_elm, engine, mixers
from repro.core.compression import CompressionSpec
from tests.conftest import run_py


def _problem(V=8, Ni=32, L=12, M=2, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T


def test_dense_bf16_close_to_fp32():
    H, T = _problem()
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    gamma = g.default_gamma()
    full, _ = engine.simulated_dc_elm(g, C).run(
        state.betas, state.omegas, gamma, 200
    )
    comp, _ = engine.simulated_dc_elm(g, C, compress="bf16").run(
        state.betas, state.omegas, gamma, 200
    )
    # pinned: observed drift ~1.2e-3 at 200 rounds on unit-scale betas
    np.testing.assert_allclose(comp, full, atol=5e-3)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    assert float(dc_elm.distance_to(comp, beta_star)) < 0.01


def test_bf16_respects_gamma_stability_bound():
    """At gamma = 0.99/d_max (just inside the Thm. 2 bound) the
    compressed iteration still contracts: disagreement decays
    monotonically to the quantization floor instead of diverging."""
    H, T = _problem(seed=3)
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    gamma = 0.99 * g.gamma_upper_bound()
    eng = engine.simulated_dc_elm(g, C, compress="bf16")
    betas, traces = eng.run(
        state.betas, state.omegas, gamma, 1000,
        trace_fn=dc_elm.consensus_error,
    )
    traces = np.asarray(traces)
    assert traces[-1] < 5e-3  # reached the bf16 consensus floor
    # no blow-up anywhere along the run, and early rounds contract
    assert traces.max() <= traces[0] * 1.01
    assert traces[200] < traces[0] / 10
    assert float(dc_elm.distance_to(betas, beta_star)) < 0.01


def test_dense_vs_ppermute_bf16_agree():
    """Compressed rounds on the two mixers agree within a pinned
    tolerance (both quantize the payload to bf16; the dense path
    accumulates the Laplacian in f32, the gossip path in bf16)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import dc_elm, engine, gossip
from repro.utils import compat
V, Ni, L, M, C = 8, 32, 12, 2, 0.5
mesh = compat.make_mesh((8,), ('data',))
kx, kt = jax.random.split(jax.random.key(0))
H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(kt, (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
for kind in ['ring', 'hypercube']:
    spec = gossip.GossipSpec(axes=('data',), kinds=(kind,))
    g = spec.to_graph({'data': V})
    gamma = g.default_gamma()
    dense, _ = engine.simulated_dc_elm(g, C, compress='bf16').run(
        state.betas, state.omegas, gamma, 400)
    shard, _ = engine.sharded_dc_elm(mesh, spec, C, compress='bf16').run(
        state.betas, state.omegas, gamma, 400)
    # pinned: observed ~5e-4 max divergence at 400 rounds
    assert np.allclose(dense, shard, atol=2e-3), (
        kind, np.abs(np.asarray(dense) - np.asarray(shard)).max())
    assert float(dc_elm.distance_to(shard, beta_star)) < 0.01, kind
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# core/compression.py: int8 round-trip edges
# ---------------------------------------------------------------------------


def test_int8_roundtrip_all_zero_is_exact():
    """Scale-0 tiles must encode the zero code, not NaN/garbage."""
    flat = jnp.zeros((200,))
    out = compression.int8_roundtrip(flat, tile=64, key=jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(out), np.zeros(200))


def test_int8_roundtrip_rank1_error_bounded():
    """Per-element error is below one quantization step of its tile,
    including on a payload whose length is not a tile multiple."""
    u = jnp.linspace(-1.0, 1.0, 20)
    v = jnp.linspace(0.1, 2.0, 5)
    flat = jnp.outer(u, v).reshape(-1)  # 100 values, tile 64 -> ragged
    out = compression.int8_roundtrip(flat, tile=64, key=jax.random.key(1))
    err = np.abs(np.asarray(out) - np.asarray(flat))
    t = np.abs(np.asarray(jnp.pad(flat, (0, 28)).reshape(2, 64)))
    step = t.max(axis=1) / 127.0
    assert err[:64].max() <= step[0] + 1e-7
    assert err[64:].max() <= step[1] + 1e-7


def test_int8_roundtrip_unbiased():
    """Stochastic rounding is unbiased: averaging many independent
    encodes recovers the value to ~1/sqrt(n) of a step."""
    flat = jnp.full((64,), 0.3141)
    outs = jnp.stack([
        compression.int8_roundtrip(flat, 64, jax.random.key(s))
        for s in range(200)
    ])
    step = 0.3141 / 127.0
    assert abs(float(outs.mean()) - 0.3141) < 0.2 * step


def test_topk_keeps_largest_and_zeroes_rest():
    flat = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])
    out = np.asarray(compression.topk_roundtrip(flat, 2))
    np.testing.assert_allclose(out, [0.0, -5.0, 0.0, 3.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# error feedback cancels quantization bias
# ---------------------------------------------------------------------------


def _replica_residual(x, rounds, *, feedback, tile=32, seed=0):
    """||x - xhat|| after `rounds` of the replica protocol on a fixed
    target: xhat += Q(x - xhat) (feedback) vs xhat = Q(x) (ablation)."""
    xhat = jnp.zeros_like(x)
    for k in range(rounds):
        key = jax.random.fold_in(jax.random.key(seed), k)
        if feedback:
            xhat = xhat + compression.int8_roundtrip(x - xhat, tile, key)
        else:
            xhat = compression.int8_roundtrip(x, tile, key)
    return float(jnp.max(jnp.abs(x - xhat)))


def test_error_feedback_cancels_quantization_bias():
    """Deterministic pin of the hypothesis property below: the EF
    residual contracts geometrically (each round quantizes a payload
    ~127x smaller), while the memoryless ablation stays at one step."""
    x = jax.random.normal(jax.random.key(3), (96,))
    step = float(jnp.abs(x).max()) / 127.0
    ef = _replica_residual(x, 8, feedback=True)
    raw = _replica_residual(x, 8, feedback=False)
    assert ef < 1e-10  # (1/127)^8-ish of the initial scale
    assert raw > 0.01 * step  # ablation is stuck at the quant floor


try:
    from hypothesis import given, settings, strategies as st

    @given(
        n=st.integers(3, 200),
        tile=st.integers(1, 64),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**30),
    )
    @settings(max_examples=25, deadline=None)
    def test_error_feedback_contracts_property(n, tile, scale, seed):
        x = scale * jax.random.normal(jax.random.key(seed), (n,))
        r0 = float(jnp.abs(x).max())
        ef = _replica_residual(x, 6, feedback=True, tile=tile, seed=seed)
        # six EF rounds contract the residual far below one first-round
        # quantization step (the memoryless floor)
        assert ef <= r0 / 127.0 * 0.2 + 1e-12
except ImportError:  # hypothesis is an optional dev dependency
    pass


# ---------------------------------------------------------------------------
# compressed consensus: convergence, event triggering, wire accounting
# ---------------------------------------------------------------------------


def _problem_big(V=8, Ni=32, L=32, M=4, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T


def test_int8_ef_matches_fp32_convergence():
    """int8 + replica error feedback has no quantization floor: it
    reaches the fp32 run's residual class, while the memoryless
    ablation (error_feedback=False) is stuck well above it."""
    H, T = _problem_big()
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    gamma = g.default_gamma()
    runs = {}
    for name, spec in [
        ("fp32", None),
        ("int8", CompressionSpec(mode="int8", tile=128)),
        ("noef", CompressionSpec(mode="int8", tile=128,
                                 error_feedback=False)),
    ]:
        eng = engine.simulated_dc_elm(g, C, compress=spec)
        betas, _ = eng.run(state.betas, state.omegas, gamma, 400)
        runs[name] = float(dc_elm.distance_to(betas, beta_star))
    assert runs["int8"] < 10 * max(runs["fp32"], 1e-7)
    assert runs["noef"] > 100 * runs["int8"]


def test_event_triggered_skips_links_and_converges():
    H, T = _problem_big(seed=5)
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    spec = CompressionSpec(mode="int8", tile=128, event_threshold=1e-3)
    eng = engine.simulated_dc_elm(g, C, compress=spec)
    betas, _ = eng.run(state.betas, state.omegas, g.default_gamma(), 1500)
    ws = eng.wire_stats
    assert float(dc_elm.distance_to(betas, beta_star)) < 1e-2
    assert ws.links_skipped > 0.3 * ws.links_live
    assert ws.compression_ratio < 0.25
    assert ws.links_sent + ws.links_skipped == ws.links_live


def test_wire_stats_exact_accounting():
    """bytes = live directed links x per-message bytes, on all mixers."""
    H, T = _problem_big()
    C, rounds = 0.5, 17
    g = consensus.hypercube(3)
    state, _, _ = dc_elm.simulate_init(H, T, C)
    V, L, M = 8, 32, 4
    links = int((np.asarray(g.adjacency) > 0).sum())  # directed, per round

    eng = engine.simulated_dc_elm(g, C)
    eng.run(state.betas, state.omegas, g.default_gamma(), rounds)
    ws = eng.wire_stats
    assert ws.links_live == rounds * links
    assert ws.bytes_on_wire == rounds * links * L * M * 4
    assert ws.per_round_bytes.shape == (rounds,)

    spec = CompressionSpec(mode="int8", tile=128)
    eng8 = engine.simulated_dc_elm(g, C, compress=spec)
    eng8.run(state.betas, state.omegas, g.default_gamma(), rounds)
    msg = L * M + 4 * ((L * M + 127) // 128)  # codes + per-tile scales
    assert eng8.wire_stats.bytes_on_wire == rounds * links * msg
    assert eng8.wire_stats.bytes_uncompressed == ws.bytes_on_wire

    # composed with a fault trace: only live links move bytes
    keep = consensus.FaultModel(
        graph=g, crashes=(consensus.NodeCrash(node=1, start=0,
                                              duration=rounds),)
    ).edge_keep(rounds)
    engf = engine.with_faults(engine.simulated_dc_elm(g, C, compress=spec),
                              keep)
    engf.run(state.betas, state.omegas, g.default_gamma(), rounds)
    live = int((keep * np.asarray(g.adjacency)[None] > 0).sum())
    assert engf.wire_stats.links_live == live
    assert engf.wire_stats.bytes_on_wire == live * msg


def test_stream_chunk_threads_wire_stats():
    H, T = _problem_big()
    C = 0.5
    g = consensus.hypercube(3)
    spec = CompressionSpec(mode="int8", tile=128)
    eng = engine.simulated_dc_elm(g, C, compress=spec)
    st0 = eng.stream_init(H, T)
    before = eng.mixer.total_bytes_on_wire
    dH = jax.random.normal(jax.random.key(9), (8, 4, 32)) / np.sqrt(32)
    dT = jax.random.normal(jax.random.key(10), (8, 4, 4))
    eng.stream_chunk(st0, added=(dH, dT), gamma=g.default_gamma(),
                     num_iters=12)
    ws = eng.wire_stats
    assert ws is not None and ws.rounds == 12
    assert eng.mixer.total_bytes_on_wire == before + ws.bytes_on_wire


# ---------------------------------------------------------------------------
# unknown modes and None/"none" uniformity
# ---------------------------------------------------------------------------


def test_compress_payload_unknown_mode_message():
    x = jnp.ones((4, 4))
    with pytest.raises(ValueError) as ei:
        mixers.compress_payload(x, "int4")
    msg = str(ei.value)
    assert "int4" in msg and "bf16" in msg
    assert "CompressionSpec" in msg  # points at the richer subsystem


def test_unknown_modes_fail_at_construction():
    adj = jnp.asarray(np.asarray(consensus.ring(8).adjacency))
    with pytest.raises(ValueError, match="unknown gossip compression"):
        mixers.DenseMixer(adj, compress="int4")
    with pytest.raises(ValueError, match="unknown compression mode"):
        CompressionSpec(mode="int4")
    with pytest.raises(ValueError, match="unknown compression mode"):
        engine.simulated_dc_elm(consensus.ring(8), 0.5, compress="int4")
    with pytest.raises(TypeError, match="CompressionSpec"):
        CompressionSpec.parse(3.14)
    # event triggering needs the replica memory; without it every round
    # is an absolute broadcast and the threshold would silently no-op
    with pytest.raises(ValueError, match="event_threshold"):
        CompressionSpec(mode="int8", error_feedback=False,
                        event_threshold=1e-3)


def test_none_and_none_string_are_uniform():
    """None and "none" mean "no compression" everywhere."""
    g = consensus.ring(8)
    adj = jnp.asarray(np.asarray(g.adjacency))
    assert mixers.DenseMixer(adj, compress="none").compress is None
    assert mixers.DenseMixer(adj, compress=None).compress is None
    pm = mixers.PpermuteMixer(
        spec=engine.gossip.GossipSpec(axes=("data",), kinds=("ring",)),
        axis_sizes={"data": 8}, compress="none",
    )
    assert pm.compress is None
    x = jnp.ones((8, 3))
    np.testing.assert_array_equal(
        np.asarray(mixers.compress_payload(x, None)),
        np.asarray(mixers.compress_payload(x, "none")),
    )
    assert CompressionSpec.parse(None).is_identity
    assert CompressionSpec.parse("none").is_identity
    H, T = _problem_big()
    state, _, _ = dc_elm.simulate_init(H, T, 0.5)
    outs = []
    for c in (None, "none"):
        eng = engine.simulated_dc_elm(consensus.hypercube(3), 0.5,
                                      compress=c)
        betas, _ = eng.run(state.betas, state.omegas, 0.1, 20)
        outs.append(np.asarray(betas))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# dense == ppermute under int8 (plus fault composition), pinned
# ---------------------------------------------------------------------------


def test_dense_vs_ppermute_int8_agree():
    """The two substrates quantize identically (same per-(round, node)
    PRNG stream) and agree within a pinned tolerance, with and without
    a composed fault trace; wire accounting is byte-identical."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import consensus, dc_elm, engine, gossip
from repro.core.compression import CompressionSpec
from repro.utils import compat
V, Ni, L, M, C = 8, 32, 32, 4, 0.5
mesh = compat.make_mesh((8,), ('data',))
kx, kt = jax.random.split(jax.random.key(0))
H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(kt, (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
spec = gossip.GossipSpec(axes=('data',), kinds=('hypercube',))
g = spec.to_graph({'data': V})
gamma = g.default_gamma()
cs = CompressionSpec(mode='int8', tile=128)
de = engine.simulated_dc_elm(g, C, compress=cs)
dense, _ = de.run(state.betas, state.omegas, gamma, 400)
se = engine.sharded_dc_elm(mesh, spec, C, compress=cs)
shard, _ = se.run(state.betas, state.omegas, gamma, 400)
# pinned: observed ~1e-6 max divergence at 400 rounds
assert np.allclose(dense, shard, atol=1e-4), np.abs(
    np.asarray(dense) - np.asarray(shard)).max()
assert float(dc_elm.distance_to(shard, beta_star)) < 1e-3
assert de.wire_stats.bytes_on_wire == se.wire_stats.bytes_on_wire
fm = consensus.FaultModel.sample_certified(g, 0.2, num_rounds=64, window=16)
keep = fm.edge_keep(64)
df = engine.with_faults(engine.simulated_dc_elm(g, C, compress=cs), keep)
sf = engine.with_faults(engine.sharded_dc_elm(mesh, spec, C, compress=cs), keep)
assert type(df.mixer).__name__ == 'CompressedMixer'  # compression outermost
d2, _ = df.run(state.betas, state.omegas, gamma, 400)
s2, _ = sf.run(state.betas, state.omegas, gamma, 400)
assert np.allclose(d2, s2, atol=1e-4), np.abs(
    np.asarray(d2) - np.asarray(s2)).max()
assert float(dc_elm.distance_to(s2, beta_star)) < 1e-3
assert df.wire_stats.bytes_on_wire == sf.wire_stats.bytes_on_wire
assert df.wire_stats.links_live == sf.wire_stats.links_live
# program cache: a second run with new masks of the same period reuses
n0 = len(sf.mixer._programs)
sf.run(state.betas, state.omegas, gamma, 400)
assert len(sf.mixer._programs) == n0
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_topk_ties_keep_exactly_count():
    out = np.asarray(compression.topk_roundtrip(jnp.ones(6), 2))
    assert (out != 0).sum() == 2  # billing matches the kept set


def test_blocked_runs_continue_replica_state():
    """Replica memory and the absolute round counter persist across
    run() calls on one mixer: N blocked runs are bitwise-identical to
    one contiguous run (same PRNG / fault-trace / refresh streams,
    same wire bytes), and reset_replicas() cold-starts."""
    H, T = _problem_big()
    C = 0.5
    g = consensus.hypercube(3)
    state, _, _ = dc_elm.simulate_init(H, T, C)
    gamma = g.default_gamma()
    spec = CompressionSpec(mode="int8", tile=128, event_threshold=1e-3)
    keep = consensus.FaultModel.sample_certified(
        g, 0.2, num_rounds=64, window=16
    ).edge_keep(64)

    blocked = engine.with_faults(
        engine.simulated_dc_elm(g, C, compress=spec), keep
    )
    b1, tot = state.betas, 0
    for _ in range(4):
        b1, _ = blocked.run(b1, state.omegas, gamma, 50)
        tot += blocked.wire_stats.bytes_on_wire
    contiguous = engine.with_faults(
        engine.simulated_dc_elm(g, C, compress=spec), keep
    )
    b2, _ = contiguous.run(state.betas, state.omegas, gamma, 200)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert tot == contiguous.wire_stats.bytes_on_wire
    blocked.mixer.reset_replicas()
    b3, _ = blocked.run(state.betas, state.omegas, gamma, 200)
    np.testing.assert_array_equal(np.asarray(b3), np.asarray(b2))
