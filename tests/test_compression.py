"""The bf16 gossip payload-compression knob (paper Sec. V: "reduction
of the amount of information exchanging").

Pins (1) compressed-vs-uncompressed drift on the dense path, (2)
DenseMixer-vs-PpermuteMixer agreement under compression (the two paths
quantize identically but accumulate in different orders/dtypes), and
(3) that compressed runs still satisfy the Thm. 2 stability bound
gamma < 1/d_max — quantization bounds the payload error, and the
gamma-scaled delta is applied in the state dtype, so the contraction
argument survives down to the bf16 quantization floor.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, engine
from tests.conftest import run_py


def _problem(V=8, Ni=32, L=12, M=2, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T


def test_dense_bf16_close_to_fp32():
    H, T = _problem()
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    gamma = g.default_gamma()
    full, _ = engine.simulated_dc_elm(g, C).run(
        state.betas, state.omegas, gamma, 200
    )
    comp, _ = engine.simulated_dc_elm(g, C, compress="bf16").run(
        state.betas, state.omegas, gamma, 200
    )
    # pinned: observed drift ~1.2e-3 at 200 rounds on unit-scale betas
    np.testing.assert_allclose(comp, full, atol=5e-3)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    assert float(dc_elm.distance_to(comp, beta_star)) < 0.01


def test_bf16_respects_gamma_stability_bound():
    """At gamma = 0.99/d_max (just inside the Thm. 2 bound) the
    compressed iteration still contracts: disagreement decays
    monotonically to the quantization floor instead of diverging."""
    H, T = _problem(seed=3)
    C = 0.5
    g = consensus.hypercube(3)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    gamma = 0.99 * g.gamma_upper_bound()
    eng = engine.simulated_dc_elm(g, C, compress="bf16")
    betas, traces = eng.run(
        state.betas, state.omegas, gamma, 1000,
        trace_fn=dc_elm.consensus_error,
    )
    traces = np.asarray(traces)
    assert traces[-1] < 5e-3  # reached the bf16 consensus floor
    # no blow-up anywhere along the run, and early rounds contract
    assert traces.max() <= traces[0] * 1.01
    assert traces[200] < traces[0] / 10
    assert float(dc_elm.distance_to(betas, beta_star)) < 0.01


def test_dense_vs_ppermute_bf16_agree():
    """Compressed rounds on the two mixers agree within a pinned
    tolerance (both quantize the payload to bf16; the dense path
    accumulates the Laplacian in f32, the gossip path in bf16)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import dc_elm, engine, gossip
from repro.utils import compat
V, Ni, L, M, C = 8, 32, 12, 2, 0.5
mesh = compat.make_mesh((8,), ('data',))
kx, kt = jax.random.split(jax.random.key(0))
H = jax.random.normal(kx, (V, Ni, L)) / np.sqrt(L)
T = jax.random.normal(kt, (V, Ni, M))
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
for kind in ['ring', 'hypercube']:
    spec = gossip.GossipSpec(axes=('data',), kinds=(kind,))
    g = spec.to_graph({'data': V})
    gamma = g.default_gamma()
    dense, _ = engine.simulated_dc_elm(g, C, compress='bf16').run(
        state.betas, state.omegas, gamma, 400)
    shard, _ = engine.sharded_dc_elm(mesh, spec, C, compress='bf16').run(
        state.betas, state.omegas, gamma, 400)
    # pinned: observed ~5e-4 max divergence at 400 rounds
    assert np.allclose(dense, shard, atol=2e-3), (
        kind, np.abs(np.asarray(dense) - np.asarray(shard)).max())
    assert float(dc_elm.distance_to(shard, beta_star)) < 0.01, kind
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
