"""Gossip primitives: ppermute schedules == dense Laplacian mixing."""

import numpy as np
import pytest

from repro.core import consensus, gossip
from tests.conftest import run_py


def test_perm_builders():
    assert len(gossip.ring_perms(8)) == 2
    assert len(gossip.ring_perms(2)) == 1
    assert len(gossip.ring_perms(1)) == 0
    assert len(gossip.hypercube_perms(8)) == 3
    assert len(gossip.complete_perms(5)) == 4
    with pytest.raises(ValueError):
        gossip.hypercube_perms(6)


@pytest.mark.parametrize("kind,n,deg", [
    ("ring", 8, 2), ("ring", 2, 1), ("hypercube", 16, 4), ("complete", 4, 3),
])
def test_degree_matches_graph(kind, n, deg):
    spec = gossip.GossipSpec(axes=("data",), kinds=(kind,))
    sizes = {"data": n}
    assert spec.degree(sizes) == deg
    g = spec.to_graph(sizes)
    assert g.d_max == deg
    assert g.is_connected


def test_product_graph_torus():
    """ring x ring == 2-D torus Laplacian."""
    spec = gossip.GossipSpec(axes=("pod", "data"), kinds=("ring", "ring"))
    sizes = {"pod": 4, "data": 4}
    g = spec.to_graph(sizes)
    ref = consensus.torus2d(4, 4)
    np.testing.assert_allclose(
        np.sort(np.linalg.eigvalsh(g.laplacian)),
        np.sort(np.linalg.eigvalsh(ref.laplacian)),
        atol=1e-9,
    )


def test_gamma_bound_product():
    spec = gossip.GossipSpec(axes=("pod", "data"), kinds=("ring", "ring"))
    assert spec.gamma_upper_bound({"pod": 2, "data": 16}) == pytest.approx(
        1.0 / 3.0
    )  # degree 1 (pod pair) + 2 (ring16)


def test_sharded_laplacian_equals_dense():
    """ppermute gossip on 8 devices == dense adjacency mixing."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import gossip, consensus
from repro.utils import compat
from jax.sharding import PartitionSpec as P
mesh = compat.make_mesh((8,), ('data',))
spec = gossip.GossipSpec(axes=('data',), kinds=('hypercube',))
x = jnp.arange(8*3, dtype=jnp.float32).reshape(8, 3) ** 1.5
def body(v):
    return gossip.neighbor_laplacian(v, spec, {'data': 8})
out = jax.jit(compat.shard_map(body, mesh, in_specs=P('data'), out_specs=P('data')))(x)
g = spec.to_graph({'data': 8})
lap = jnp.asarray(g.adjacency @ np.array(x) - g.degrees[:, None] * np.array(x))
assert np.allclose(out, lap, atol=1e-5), (out, lap)
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


def test_collective_bytes_per_round():
    spec = gossip.GossipSpec(axes=("data",), kinds=("ring",))
    assert gossip.collective_bytes_per_round(spec, {"data": 8}, 100) == 200


@pytest.mark.parametrize("axes,kinds,sizes", [
    (("data",), ("ring",), {"data": 8}),
    (("data",), ("ring",), {"data": 2}),
    (("data",), ("hypercube",), {"data": 16}),
    (("data",), ("complete",), {"data": 6}),
    (("pod", "data"), ("ring", "ring"), {"pod": 4, "data": 4}),  # torus2d
    (("pod", "data"), ("ring", "hypercube"), {"pod": 2, "data": 8}),
])
def test_gamma_bound_implementations_agree(axes, kinds, sizes):
    """Thm. 2's 1/d_max bound has four implementations —
    ``consensus.Graph.gamma_upper_bound``,
    ``gossip.GossipSpec.gamma_upper_bound``, and the two mixers'
    ``default_gamma`` — which must agree on every ICI-realizable
    topology (drift here silently breaks the sharded/simulated
    equivalence)."""
    from repro.core import mixers

    spec = gossip.GossipSpec(axes=axes, kinds=kinds)
    g = spec.to_graph(sizes)
    bound = g.gamma_upper_bound()
    assert spec.gamma_upper_bound(sizes) == pytest.approx(bound, rel=1e-12)

    dense = mixers.DenseMixer.from_graphs(g)
    ppermute = mixers.PpermuteMixer(spec=spec, axis_sizes=dict(sizes))
    safety = 0.9
    assert dense.default_gamma(safety) == pytest.approx(
        safety * bound, rel=1e-6
    )
    assert ppermute.default_gamma(safety) == pytest.approx(
        safety * bound, rel=1e-12
    )
    # the fault wrapper must not shift the bound either (masks only
    # remove edges)
    faulty = mixers.FaultyMixer(dense, np.ones((3,) + g.adjacency.shape))
    assert faulty.default_gamma(safety) == dense.default_gamma(safety)
    # torus2d cross-check: the explicit constructor agrees with the
    # ring x ring product spec
    if kinds == ("ring", "ring"):
        ref = consensus.torus2d(sizes["pod"], sizes["data"])
        assert ref.gamma_upper_bound() == pytest.approx(bound, rel=1e-12)
