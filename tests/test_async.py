"""Async push-sum runtime: sync-limit parity against the round-based
engines, mass conservation under loss, straggler liveness, scheduler
determinism — plus regressions for the three correctness bugs this
subsystem surfaced in the synchronous plane (silent FaultModel no-ops,
unvalidated gamma after churn, the serving round-robin snapshot race).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import async_engine, consensus, dc_elm, engine, push_sum
from repro.serving import BetaStore, ELMServer


def _problem(V=4, Ni=30, L=8, M=2, C=4.0, seed=0):
    ks = jax.random.split(jax.random.key(seed), 2)
    H = jax.random.normal(ks[0], (V, Ni, L)) / np.sqrt(L)
    T = jax.random.normal(ks[1], (V, Ni, M))
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    return state, P_, Q_


def _beta_star64(P_, Q_, C):
    """Centralized beta* in f64 (the jax path is f32 under tests, whose
    ~1e-7 error would floor the async residual assertions)."""
    P = np.asarray(P_, np.float64)
    Q = np.asarray(Q_, np.float64)
    L = P.shape[1]
    return np.linalg.solve(np.eye(L) / C + P.sum(0), Q.sum(0))


def _reference_rounds(betas, omegas, adj, gamma, C, K, keep=None):
    """Hand-rolled eq. (20) in f64, optionally fault-masked (the exact
    recursion both planes must reproduce)."""
    b = np.asarray(betas, np.float64).copy()
    omegas = np.asarray(omegas, np.float64)
    adj = np.asarray(adj, np.float64)
    V = b.shape[0]
    for r in range(K):
        a = adj if keep is None else adj * keep[r]
        lap = np.einsum("ij,jlm->ilm", a, b) - a.sum(1)[:, None, None] * b
        b = b + (gamma / (V * C)) * np.einsum("vlk,vkm->vlm", omegas, lap)
    return b


# ---------------------------------------------------------------------------
# Sync-limit parity
# ---------------------------------------------------------------------------


def test_sync_limit_matches_dense_engine():
    """Barrier schedule + zero delay/loss: run_until(t_max=K) equals K
    rounds of eq. (20) exactly in f64, and matches the f32 DenseMixer
    engine to f32-roundoff ("bitwise-level close")."""
    C, K = 4.0, 60
    state, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    gamma = g.default_gamma()
    eng = async_engine.sync_limit_dc_elm(
        g, np.asarray(state.betas), np.asarray(state.omegas), gamma, C
    )
    res = eng.run_until(t_max=K)
    exact = _reference_rounds(
        state.betas, state.omegas, g.adjacency, gamma, C, K
    )
    np.testing.assert_allclose(res.betas, exact, rtol=0, atol=1e-12)
    dense, _ = dc_elm.simulate_run(state, g, gamma, C, K)
    np.testing.assert_allclose(
        res.betas, np.asarray(dense.betas, np.float64), rtol=0, atol=5e-6
    )
    assert res.fires == g.num_nodes * (K + 1)  # incl. the t=0 warm-up


def test_sync_limit_matches_faulty_engine_certified_trace():
    """Same claim under a certified lossy trace: the async runtime with
    the FaultModel as its message-drop process replays
    with_faults(DenseMixer) round for round."""
    C, K = 4.0, 60
    state, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    gamma = g.default_gamma()
    fm = consensus.FaultModel.sample_certified(
        g, 0.3, num_rounds=K, window=8
    )
    a = async_engine.sync_limit_dc_elm(
        g, np.asarray(state.betas), np.asarray(state.omegas), gamma, C,
        faults=fm, fault_rounds=K,
    )
    res = a.run_until(t_max=K)
    exact = _reference_rounds(
        state.betas, state.omegas, g.adjacency, gamma, C, K,
        keep=fm.edge_keep(K),
    )
    np.testing.assert_allclose(res.betas, exact, rtol=0, atol=1e-12)
    eng_f = engine.with_faults(engine.simulated_dc_elm(g, C), fm, K)
    ref, _ = eng_f.run(state.betas, state.omegas, gamma, K)
    np.testing.assert_allclose(
        res.betas, np.asarray(ref, np.float64), rtol=0, atol=5e-6
    )


# ---------------------------------------------------------------------------
# Push-sum: exactness, conservation, liveness
# ---------------------------------------------------------------------------


def test_push_sum_reaches_sync_tolerance_on_fig2_lossy():
    """Acceptance: on the paper's Fig. 2 graph under a certified lossy
    trace (+ delay jitter), the async engine reaches the same residual
    to beta* that DenseMixer.run reached — with no round barrier."""
    C, K = 4.0, 400
    state, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    beta_star = _beta_star64(P_, Q_, C)
    dense, _ = dc_elm.simulate_run(state, g, g.default_gamma(), C, K)
    sync_res = float(dc_elm.distance_to(
        jnp.asarray(dense.betas), jnp.asarray(beta_star, jnp.float32)
    ))
    fm = consensus.FaultModel.sample_certified(g, 0.2, num_rounds=64, window=8)
    eng = async_engine.async_dc_elm(
        g, P_, Q_, C,
        faults=fm, delays=consensus.DelayModel(base=0.3, jitter=0.4), seed=3,
    )
    res = eng.run_until(
        residual_tol=max(sync_res, 1e-6), t_max=20_000, target=beta_star
    )
    assert res.converged, (res.residual, sync_res)


def test_push_sum_mass_conservation_under_loss():
    """The conservation law holds at every probe point of a lossy,
    jittery run — dropped messages delay mass, they never destroy it."""
    C = 4.0
    _, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    fm = consensus.FaultModel(graph=g, edge_drop_prob=0.4, seed=7)
    eng = async_engine.async_dc_elm(
        g, P_, Q_, C,
        faults=fm, delays=consensus.DelayModel(base=0.5, jitter=1.0), seed=5,
    )
    for t_stop in (3, 10, 40, 160):
        eng.run_until(t_max=float(t_stop))
        assert eng.rule.conservation_residual() < 1e-9, t_stop
    # and the in-flight term is genuinely nonzero mid-run (mass rides
    # the counters, the invariant is not trivially sigma-only)
    inflight = sum(
        abs(eng.rule.mu[k].rho - eng.rule.nu[k].rho) for k in eng.rule.mu
    )
    assert inflight > 0.0


def test_straggler_liveness_10x():
    """One node firing at 10x the period: the network still converges
    to beta* (nobody waits on a barrier for the straggler)."""
    C = 4.0
    _, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    beta_star = _beta_star64(P_, Q_, C)
    eng = async_engine.async_dc_elm(
        g, P_, Q_, C,
        fire_periods=[10.0, 1.0, 1.0, 1.0],
        delays=consensus.DelayModel(base=0.2), seed=1,
    )
    res = eng.run_until(residual_tol=1e-6, t_max=8000, target=beta_star)
    assert res.converged, res.residual
    assert eng.rule.conservation_residual() < 1e-9


def test_push_sum_stale_reordered_messages_are_noops():
    """The running-sum counters make late/duplicate deliveries no-ops:
    processing a *stale* counter after a newer one changes nothing."""
    C = 4.0
    _, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    rule = async_engine.PushSumRule(g, P_, Q_, C)
    rule.fire(1, {})  # node 1 ships counters to 0 and 2
    old = rule.mu[(1, 0)].copy()
    rule.fire(1, {})  # newer cumulative counter on the same edge
    new = rule.mu[(1, 0)].copy()
    rule.fire(0, {1: (1, new)})  # newest arrives first
    sig = rule.sigmas[0].copy()
    rule.fire(0, {1: (0, old)})  # stale reordering: must be a no-op
    assert rule._last_seq[(1, 0)] == 1
    np.testing.assert_array_equal(rule.sigmas[0].A, (
        sig.A * push_sum.split_share(len(rule.out_neighbors[0]))
    ))
    assert rule.conservation_residual() < 1e-12


def test_same_seed_same_event_log():
    """Determinism: same seed => identical event log; a different seed
    (under delay jitter) diverges."""
    C = 4.0
    _, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()

    def run(seed):
        fm = consensus.FaultModel(graph=g, edge_drop_prob=0.3, seed=11)
        eng = async_engine.async_dc_elm(
            g, P_, Q_, C,
            faults=fm, delays=consensus.DelayModel(base=0.2, jitter=0.6),
            seed=seed,
        )
        eng.run_until(t_max=40.0)
        return eng.event_log, eng.betas()

    log_a, betas_a = run(0)
    log_b, betas_b = run(0)
    log_c, _ = run(1)
    assert log_a == log_b
    np.testing.assert_array_equal(betas_a, betas_b)
    assert log_a != log_c


def test_wire_stats_exact_accounting():
    """Barrier/no-loss: every fire ships deg messages, all billed; under
    a full outage the dropped messages cost zero wire bytes."""
    C, K = 4.0, 10
    state, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()  # ring4: out-degree 2 everywhere
    eng = async_engine.async_dc_elm(g, P_, Q_, C)
    res = eng.run_until(t_max=float(K))
    ws = eng.wire_stats
    msg_bytes = eng.rule.payload_floats() * 8
    assert res.fires == 4 * (K + 1)
    assert ws.rounds == res.fires
    assert ws.links_live == ws.links_sent == 2 * res.fires
    assert ws.bytes_on_wire == ws.links_sent * msg_bytes
    assert ws.per_round_bytes.sum() == ws.bytes_on_wire
    assert eng.total_bytes_on_wire == ws.bytes_on_wire

    fm = consensus.FaultModel(
        graph=g,
        outages=tuple(
            consensus.LinkOutage(edge=(i, (i + 1) % 4), start=0, duration=10**6)
            for i in range(4)
        ),
    )
    dead = async_engine.async_dc_elm(g, P_, Q_, C, faults=fm)
    r2 = dead.run_until(t_max=float(K))
    assert r2.drops == r2.sends > 0
    assert dead.wire_stats.bytes_on_wire == 0
    assert dead.wire_stats.links_live == r2.sends


def test_run_until_argument_validation():
    C = 4.0
    _, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()
    eng = async_engine.async_dc_elm(g, P_, Q_, C)
    with pytest.raises(ValueError, match="residual_tol"):
        eng.run_until()
    with pytest.raises(ValueError, match="fire_periods"):
        async_engine.async_dc_elm(g, P_, Q_, C, fire_periods=[1, 1, 0, 1])
    with pytest.raises(ValueError, match="sized for"):
        async_engine.AsyncEngine(
            consensus.ring(6), async_engine.PushSumRule(g, P_, Q_, C)
        )


# ---------------------------------------------------------------------------
# Bugfix regressions: FaultModel validation
# ---------------------------------------------------------------------------


def test_fault_model_rejects_non_edge_outage():
    """A LinkOutage on a non-edge used to be silently erased by the
    `keep * edges` mask — it must fail loudly at construction."""
    g = consensus.ring(6)  # (0, 3) is not a ring edge
    with pytest.raises(ValueError, match="not an edge"):
        consensus.FaultModel(
            graph=g,
            outages=(consensus.LinkOutage(edge=(0, 3), start=0, duration=5),),
        )


def test_fault_model_rejects_negative_intervals():
    g = consensus.ring(6)
    with pytest.raises(ValueError, match="negative start/duration"):
        consensus.FaultModel(
            graph=g,
            outages=(consensus.LinkOutage(edge=(0, 1), start=-3, duration=5),),
        )
    with pytest.raises(ValueError, match="negative start/duration"):
        consensus.FaultModel(
            graph=g,
            outages=(consensus.LinkOutage(edge=(0, 1), start=0, duration=-1),),
        )
    with pytest.raises(ValueError, match="negative start/duration"):
        consensus.FaultModel(
            graph=g,
            crashes=(consensus.NodeCrash(node=2, start=-1, duration=4),),
        )
    # valid models still construct (both orientations of an edge)
    consensus.FaultModel(
        graph=g,
        outages=(consensus.LinkOutage(edge=(1, 0), start=0, duration=5),),
        crashes=(consensus.NodeCrash(node=2, start=0, duration=4),),
    )


def test_delay_model_validation():
    with pytest.raises(ValueError, match="base delay"):
        consensus.DelayModel(base=-0.1)
    with pytest.raises(ValueError, match="jitter"):
        consensus.DelayModel(jitter=-1.0)
    with pytest.raises(ValueError, match="edge_scale"):
        consensus.DelayModel(edge_scale=(((0, 1), 0.0),))
    with pytest.raises(ValueError, match="self-loop"):
        consensus.DelayModel(edge_scale=(((2, 2), 1.0),))
    dm = consensus.DelayModel(base=0.5, edge_scale=(((0, 1), 4.0),))
    assert dm.scale(1, 0) == 4.0  # symmetric lookup
    assert dm.scale(1, 2) == 1.0
    rng = np.random.default_rng(0)
    assert dm.sample(rng, 0, 1) == 2.0  # no jitter => deterministic


# ---------------------------------------------------------------------------
# Bugfix regressions: gamma validation after churn
# ---------------------------------------------------------------------------


def test_run_rejects_gamma_above_bound():
    C = 4.0
    state, P_, Q_ = _problem(C=C)
    g = consensus.paper_fig2()  # d_max = 2 => bound 0.5
    eng = engine.simulated_dc_elm(g, C)
    with pytest.raises(ValueError, match="Thm. 2"):
        eng.run(state.betas, state.omegas, 0.6, 10)
    with pytest.raises(ValueError, match="Thm. 2"):
        eng.step(state.betas, state.omegas, -0.1)
    # escape hatch for deliberate divergence experiments
    eng.run(state.betas, state.omegas, 0.6, 2, check_gamma=False)
    # in-bound gamma passes; bound is surfaced on the engine
    eng.run(state.betas, state.omegas, 0.4, 2)
    assert eng.gamma_upper_bound() == pytest.approx(0.5)


def test_stream_join_rejects_stale_gamma():
    """stream_join's default all-incumbent topology jumps d_max to ~V;
    reusing the pre-churn gamma must fail loudly, and the post-churn
    bound is surfaced on the returned engine."""
    V, L, M, C = 6, 8, 2, 4.0
    ks = jax.random.split(jax.random.key(0), 4)
    H = jax.random.normal(ks[0], (V, 20, L)) / np.sqrt(L)
    T = jax.random.normal(ks[1], (V, 20, M))
    g = consensus.ring(V)
    eng = engine.simulated_dc_elm(g, C)
    s = eng.stream_init(H, T)
    gamma = g.default_gamma()  # 0.45, fine on the ring
    s, _ = eng.stream_chunk(s, gamma=gamma, num_iters=2)
    H_new = jax.random.normal(ks[2], (15, L)) / np.sqrt(L)
    T_new = jax.random.normal(ks[3], (15, M))
    eng2, s2 = eng.stream_join(s, H_new, T_new)
    bound2 = eng2.gamma_upper_bound()
    assert bound2 == pytest.approx(1.0 / V)  # joiner degree = V
    with pytest.raises(ValueError, match="Thm. 2"):
        eng2.run(s2.betas, s2.omegas, gamma, 2)
    eng2.run(s2.betas, s2.omegas, eng2.mixer.default_gamma(), 2)
    # leave surfaces the (relaxed) bound too
    eng3, s3 = eng2.stream_leave(s2, V)
    assert eng3.gamma_upper_bound() == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Bugfix regressions: serving round-robin snapshot protocol
# ---------------------------------------------------------------------------


def _server(V, L=6, M=2, **kw):
    from repro.core.features import make_random_features

    fmap = make_random_features(jax.random.key(0), 2, L)
    betas = jax.random.normal(jax.random.key(1), (V, L, M))
    store = BetaStore(betas)
    return ELMServer(fmap, store, **kw), store


def test_round_robin_uses_served_snapshot_not_store():
    """A frozen server keeps rotating over its pinned snapshot's V even
    after the store publishes a different-sized model (the old code
    read the live store on every submit, bypassing freeze/staleness)."""
    srv, store = _server(V=3)
    x = np.ones((2, 2), np.float32)
    srv.freeze()  # pins the V=3 snapshot
    store.publish(jnp.ones((1, 6, 2)))  # live store shrinks to V=1
    nodes = []
    for _ in range(6):
        srv.submit(x)
        nodes.append(srv.flush()[0].node)
    assert nodes == [0, 1, 2, 0, 1, 2]


def test_round_robin_rewraps_cleanly_when_V_changes():
    """Node choice re-wraps modulo the new V instead of skipping or
    repeating replicas under a shifting modulo base."""
    srv, store = _server(V=3)
    x = np.ones((2, 2), np.float32)
    picks = []
    for _ in range(2):
        srv.submit(x)
        picks.append(srv.flush()[0].node)
    assert picks == [0, 1]
    store.publish(jnp.ones((2, 6, 2)))  # V: 3 -> 2 mid-rotation
    for _ in range(4):
        srv.submit(x)
        picks.append(srv.flush()[0].node)
    # counter re-wraps into the smaller V with no replica skipped
    assert picks[2:] == [0, 1, 0, 1]


def test_round_robin_empty_store_still_raises():
    srv = ELMServer(lambda x: x, BetaStore())
    with pytest.raises(RuntimeError, match="no published betas"):
        srv.submit(np.ones((1, 2), np.float32))
