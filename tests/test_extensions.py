"""Beyond-paper extensions: compressed gossip, symmetric gram kernel.

(The paper's Sec. V names 'reduction of the amount of information
exchanging' as future work — compressed gossip implements it.)
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dsgd
from repro.kernels.gram import gram_pallas
from repro.kernels.gram_ref import gram_reference
from repro.optim import sgd


def test_symmetric_gram_kernel_matches():
    for (N, L) in [(300, 100), (64, 48), (33, 7)]:
        H = jax.random.normal(jax.random.key(N + L), (N, L))
        sym = gram_pallas(H, interpret=True, block_l=32, block_n=64,
                          symmetric=True)
        full = gram_pallas(H, interpret=True, block_l=32, block_n=64,
                           symmetric=False)
        ref = gram_reference(H)
        np.testing.assert_allclose(sym, full, atol=1e-4)
        np.testing.assert_allclose(sym, ref, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(sym, sym.T, atol=0)  # exactly symmetric


def test_compressed_mix_preserves_mean_approximately():
    V = 6
    g = consensus.ring(V)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    x = {"w": jax.random.normal(jax.random.key(0), (V, 64))}
    mixed = dsgd.mix_simulated(x, adj, 0.3, compress="bf16")
    exact = dsgd.mix_simulated(x, adj, 0.3, compress=None)
    # bf16 payload: ~3 decimal digits of mantissa
    np.testing.assert_allclose(mixed["w"], exact["w"], rtol=0, atol=2e-2)


def test_compressed_consensus_sgd_still_converges():
    """bf16 gossip halves wire bytes; convergence within quantization."""
    V = 4
    rng = np.random.default_rng(0)
    As = jnp.asarray(rng.normal(size=(V, 8, 6)))
    bs = jnp.asarray(rng.normal(size=(V, 8)))

    def loss_fn(params, batch):
        A, b = batch
        r = A @ params["x"] - b
        return jnp.sum(r * r)

    x_star = np.linalg.lstsq(
        np.concatenate(list(np.asarray(As)), 0),
        np.concatenate(list(np.asarray(bs)), 0), rcond=None,
    )[0]

    g = consensus.ring(V)
    opt = sgd(5e-3)
    adj = jnp.asarray(g.adjacency, jnp.float32)
    gamma = g.default_gamma()
    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    @jax.jit
    def step(state, batch):
        _, grads = grad_fn(state.params, batch)
        upd, opt_state = jax.vmap(opt.update)(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(jnp.add, state.params, upd)
        params = dsgd.mix_simulated(params, adj, gamma, compress="bf16")
        return dsgd.DSGDState(params, opt_state)

    state = dsgd.init_simulated(
        jax.random.key(0), lambda k: {"x": jnp.zeros(6)}, opt, V
    )
    for _ in range(3000):
        state = step(state, (As, bs))
    err = float(jnp.max(jnp.linalg.norm(
        state.params["x"] - jnp.asarray(x_star)[None], axis=1)))
    assert err < 0.1, err  # within the quantization neighborhood


def test_sharded_compressed_mix(tmp_path):
    from tests.conftest import run_py

    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import dsgd, gossip
from repro.utils import compat
from jax.sharding import PartitionSpec as P
mesh = compat.make_mesh((8,), ('data',))
spec = gossip.GossipSpec(axes=('data',), kinds=('ring',))
x = {'w': (jnp.arange(8*4, dtype=jnp.float32).reshape(8, 4) * 0.37) ** 1.5}
def body(v):
    return dsgd.mix_sharded(v, 0.25, spec, {'data': 8}, compress='bf16')
out = jax.jit(compat.shard_map(body, mesh, in_specs=(P('data'),), out_specs=P('data')))(x)
ref = dsgd.mix_simulated(x, jnp.asarray(np.roll(np.eye(8),1,0)+np.roll(np.eye(8),-1,0), jnp.float32), 0.25, compress='bf16')
assert np.allclose(out['w'], ref['w'], atol=6e-2), (out['w'], ref['w'])  # bf16 rounding-order differs between paths
print('OK')
"""
    r = run_py(code, devices=8)
    assert r.returncode == 0, r.stderr
