"""Autotuner: candidate grids, roofline pruning, cache round-trip,
lookup policy (exact / nearest-N / miss) and dispatcher integration."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, elm_predict_ops, elm_stats_ops
from repro.kernels.elm_stats_ops import scan_kwargs
from repro.kernels.elm_stats_ref import elm_stats_scan


@pytest.fixture(autouse=True)
def _fresh_memo():
    autotune.clear_memo()
    yield
    autotune.clear_memo()


def _point(**kw):
    base = dict(
        op="stats", impl="scan", N=4096, D=16, L=64, M=4,
        dtype="float32", backend=jax.default_backend(),
    )
    base.update(kw)
    return autotune.TunePoint(**base)


def _stats_problem(N=256, D=5, L=33, M=3, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    return (
        jax.random.normal(ks[0], (N, D)),
        jax.random.normal(ks[1], (D, L)),
        jax.random.normal(ks[2], (L,)),
        jax.random.normal(ks[3], (N, M)),
    )


# ---------------------------------------------------------------------------
# Candidates + pruning
# ---------------------------------------------------------------------------


def test_candidates_always_include_default():
    for impl in ("scan", "pallas"):
        pt = _point(impl=impl, N=512, L=96)
        cands = autotune.candidates(pt)
        d = autotune.DEFAULTS[("stats", impl)]
        clamped = {
            k: min(v, pt.N if k != "block_l" else pt.L)
            for k, v in d.items()
        }
        assert clamped in cands
        # clamped to the problem dims
        for c in cands:
            assert c.get("chunk", 0) <= pt.N
            assert c.get("block_n", 0) <= pt.N
            assert c.get("block_l", 0) <= pt.L


def test_roofline_prune_partitions_and_keeps_default():
    pt = _point(N=65536, L=512, dtype="bfloat16")
    cands = autotune.candidates(pt)
    kept, pruned = autotune.roofline_prune(pt, cands)
    assert kept, "pruning must leave at least one candidate"
    assert len(kept) + len(pruned) == len(cands)
    # pruning is a relative-ranking filter: everything kept is within
    # PRUNE_FACTOR of the best in-budget estimate
    budget = autotune.CACHE_BUDGET
    ests = [autotune.estimate(pt, c) for c in kept]
    assert all(e["working_set"] <= budget for e in ests)
    best = min(e["t_estimate"] for e in ests)
    assert all(
        e["t_estimate"] <= autotune.PRUNE_FACTOR * best + 1e-12
        for e in ests
    )


def test_prune_drops_over_budget_working_sets():
    pt = _point(N=1 << 20, L=4096, M=8, dtype="float32")
    cands = [{"chunk": 1 << 20}, {"chunk": 512}]
    kept, pruned = autotune.roofline_prune(pt, cands)
    assert {"chunk": 512} in kept
    assert {"chunk": 1 << 20} in pruned


# ---------------------------------------------------------------------------
# tune() + cache + lookup
# ---------------------------------------------------------------------------


def test_tune_persists_and_lookup_hits(tmp_path):
    path = str(tmp_path / "TUNED.json")
    dims = dict(N=2048, D=8, L=32, M=2, dtype="float32")
    cfg = autotune.tune(
        "stats", **dims, impl="scan", repeats=1, cache_path=path
    )
    assert "chunk" in cfg
    payload = json.loads(open(path).read())
    assert payload["schema"] == autotune.SCHEMA_VERSION
    [(key, entry)] = payload["entries"].items()
    assert key.startswith("stats/scan/N2048_")
    assert entry["config"] == cfg
    assert entry["sweep"][0]["config"] == cfg  # sorted fastest first
    assert entry["jax"] == jax.__version__
    # exact lookup
    assert autotune.lookup("stats", **dims, cache_path=path) == cfg
    # nearest-N within 4x
    near = dict(dims, N=4096)
    assert autotune.lookup("stats", **near, cache_path=path) == cfg
    # beyond 4x: miss
    far = dict(dims, N=32768)
    assert autotune.lookup("stats", **far, cache_path=path) is None
    # different dims: miss
    other = dict(dims, L=64)
    assert autotune.lookup("stats", **other, cache_path=path) is None


def test_tune_is_a_read_on_existing_entry(tmp_path):
    path = str(tmp_path / "TUNED.json")
    dims = dict(N=1024, D=4, L=16, M=2, dtype="float32")
    autotune.tune(
        "predict", **dims, impl="scan", repeats=1, cache_path=path
    )
    # poison the entry; force=False must return it without re-measuring
    payload = json.loads(open(path).read())
    key = next(iter(payload["entries"]))
    payload["entries"][key]["config"] = {"chunk": 123}
    open(path, "w").write(json.dumps(payload))
    autotune.clear_memo()
    assert autotune.tune(
        "predict", **dims, impl="scan", repeats=1, cache_path=path
    ) == {"chunk": 123}
    # force=True re-measures (123 is not even a candidate); the winner
    # is whatever measured best this run, but always from the real
    # candidate grid
    point = autotune.TunePoint(
        op="predict", impl="scan", backend=jax.default_backend(), **dims
    )
    re = autotune.tune(
        "predict", **dims, impl="scan", repeats=1, cache_path=path,
        force=True,
    )
    assert re != {"chunk": 123}
    assert re in autotune.candidates(point)


def test_unknown_schema_reads_as_empty(tmp_path):
    path = str(tmp_path / "TUNED.json")
    open(path, "w").write(json.dumps(
        {"schema": 999, "entries": {"stats/scan/N1_D1_L1_M1_float32/cpu":
                                    {"config": {"chunk": 7}}}}
    ))
    assert autotune.lookup(
        "stats", 1, 1, 1, 1, "float32", impl="scan", cache_path=path
    ) is None


def test_memo_invalidated_on_file_change(tmp_path):
    path = str(tmp_path / "TUNED.json")
    dims = dict(N=1024, D=4, L=16, M=2, dtype="float32")
    assert autotune.lookup("stats", **dims, cache_path=path) is None
    autotune.tune("stats", **dims, impl="scan", repeats=1, cache_path=path)
    # the tune() write cleared the memo: the same lookup now hits
    assert autotune.lookup("stats", **dims, cache_path=path) is not None


# ---------------------------------------------------------------------------
# Dispatcher integration
# ---------------------------------------------------------------------------


def test_resolve_config_policies(tmp_path):
    path = str(tmp_path / "TUNED.json")
    dims = dict(N=2048, D=8, L=32, M=2, dtype="float32")
    autotune.tune("stats", **dims, impl="scan", repeats=1, cache_path=path)
    cached = autotune.lookup("stats", **dims, cache_path=path)
    common = dict(op="stats", impl="scan", **dims, cache_path=path)
    # cached: applied on a miss-free point
    assert autotune.resolve_config({}, "cached", **common) == cached
    # explicit kwargs win outright
    assert autotune.resolve_config(
        {"chunk": 99}, "cached", **common
    ) == {"chunk": 99}
    # off: untouched
    assert autotune.resolve_config({}, "off", **common) == {}
    # explicit dict applied, caller kwargs still win
    assert autotune.resolve_config(
        {"chunk": 7}, {"chunk": 5}, **common
    ) == {"chunk": 7}
    assert autotune.resolve_config({}, {"chunk": 5}, **common) == {
        "chunk": 5
    }
    with pytest.raises(ValueError, match="tuning"):
        autotune.resolve_config({}, "bogus", **common)


def test_fused_moments_consults_cache(tmp_path, monkeypatch):
    """tuning='cached' resolves the tuned chunk and matches tuning='off'."""
    path = str(tmp_path / "TUNED.json")
    X, W, b, T = _stats_problem()
    dims = dict(N=X.shape[0], D=X.shape[1], L=W.shape[1], M=T.shape[1])
    autotune.tune(
        "stats", **dims, dtype="float32", impl="scan", repeats=1,
        cache_path=path,
    )
    monkeypatch.setenv("REPRO_TUNED_CACHE", path)
    autotune.clear_memo()
    P1, Q1 = elm_stats_ops.fused_moments(X, W, b, T, use_kernel=False)
    P2, Q2 = elm_stats_ops.fused_moments(
        X, W, b, T, use_kernel=False, tuning="off"
    )
    np.testing.assert_allclose(P1, P2, rtol=1e-5)
    np.testing.assert_allclose(Q1, Q2, rtol=1e-5)


def test_fused_predict_explicit_dict_tuning():
    X, W, b, T = _stats_problem()
    beta = jax.random.normal(jax.random.key(9), (W.shape[1], 3))
    y0 = elm_predict_ops.fused_predict(
        X, W, b, beta, use_kernel=False, tuning="off"
    )
    y1 = elm_predict_ops.fused_predict(
        X, W, b, beta, use_kernel=False, tuning={"chunk": 64}
    )
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5)


# ---------------------------------------------------------------------------
# scan_kwargs (block-knob mapping; the former silent-drop bug)
# ---------------------------------------------------------------------------


def test_scan_kwargs_block_n_maps_to_chunk():
    assert scan_kwargs({"block_n": 128}) == {"chunk": 128}
    assert scan_kwargs({"chunk": 64}) == {"chunk": 64}
    assert scan_kwargs({"block_l": None, "block_n": None}) == {}


def test_scan_kwargs_block_l_raises():
    with pytest.raises(ValueError, match="block_l"):
        scan_kwargs({"block_l": 64})
    with pytest.raises(ValueError, match="block_l"):
        elm_stats_ops.fused_moments(
            *_stats_problem(), use_kernel=False, block_l=64
        )


def test_scan_kwargs_conflict_raises():
    with pytest.raises(ValueError, match="both block_n"):
        scan_kwargs({"block_n": 128, "chunk": 64})


def test_block_n_honored_bitwise_by_scan_path():
    """block_n=k through the dispatcher == chunk=k directly."""
    X, W, b, T = _stats_problem()
    P1, Q1 = elm_stats_ops.fused_moments(
        X, W, b, T, use_kernel=False, tuning="off", block_n=96
    )
    P2, Q2 = elm_stats_scan(X, W, b, T, chunk=96)
    assert np.array_equal(np.asarray(P1), np.asarray(P2))
    assert np.array_equal(np.asarray(Q1), np.asarray(Q2))


def test_pallas_path_rejects_chunk():
    X, W, b, T = _stats_problem(N=64, D=4, L=32, M=2)
    with pytest.raises(ValueError, match="chunk"):
        elm_stats_ops.fused_moments(X, W, b, T, use_kernel=True, chunk=32)
