"""Centralized ELM (paper Sec. II-A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elm
from repro.core.features import make_random_features
from repro.data.sinc import make_sinc_dataset


def test_primal_dual_agree():
    key = jax.random.key(0)
    H = jax.random.normal(key, (60, 40))
    T = jax.random.normal(jax.random.key(1), (60, 3))
    b1 = elm.ridge_primal(H, T, C=8.0)
    b2 = elm.ridge_dual(H, T, C=8.0)
    np.testing.assert_allclose(b1, b2, rtol=2e-3, atol=2e-4)


def test_ridge_solve_auto_picks_branch():
    key = jax.random.key(0)
    tall = jax.random.normal(key, (100, 20))
    wide = jax.random.normal(key, (20, 100))
    T_tall = jnp.ones((100, 1))
    T_wide = jnp.ones((20, 1))
    assert elm.ridge_solve(tall, T_tall, 4.0).shape == (20, 1)
    assert elm.ridge_solve(wide, T_wide, 4.0).shape == (100, 1)


def test_solve_from_stats_matches_direct():
    key = jax.random.key(2)
    H = jax.random.normal(key, (128, 32))
    T = jax.random.normal(jax.random.key(3), (128, 2))
    direct = elm.ridge_primal(H, T, 16.0)
    via_stats = elm.solve_from_stats(H.T @ H, H.T @ T, 16.0)
    np.testing.assert_allclose(direct, via_stats, rtol=1e-4, atol=1e-5)


def test_sinc_regression_quality():
    """Paper Fig. 3/4: sigmoid ELM approximates noisy SinC well."""
    key = jax.random.key(0)
    X, Y, Xt, Yt = make_sinc_dataset(key, num_nodes=1, per_node=2000,
                                     num_test=1000)
    model = elm.train_centralized(
        jax.random.key(7), X[0], Y[0], num_features=100, C=2**8
    )
    test_mse = float(elm.mse(model, Xt, Yt))
    assert test_mse < 5e-3, f"SinC test MSE too high: {test_mse}"


def test_regularization_effect():
    """Small C = strong regularization => smaller output-weight norm."""
    key = jax.random.key(1)
    X, Y, _, _ = make_sinc_dataset(key, num_nodes=1, per_node=500)
    fmap = make_random_features(jax.random.key(2), 1, 50)
    H = fmap(X[0])
    beta_hi = elm.ridge_solve(H, Y[0], C=2**10)
    beta_lo = elm.ridge_solve(H, Y[0], C=2**-6)
    assert jnp.linalg.norm(beta_lo) < jnp.linalg.norm(beta_hi)


def test_empirical_risk_matches_paper_def():
    pred = jnp.array([1.0, 2.0])
    t = jnp.array([0.0, 4.0])
    # (1/N) sum 1/2 |y - yhat| = (0.5*1 + 0.5*2)/2
    assert float(elm.empirical_risk(pred, t)) == pytest.approx(0.75)


@pytest.mark.parametrize("activation", ["sigmoid", "tanh", "relu", "rbf", "sin"])
def test_feature_maps(activation):
    fmap = make_random_features(jax.random.key(0), 3, 17, activation)
    x = jax.random.normal(jax.random.key(1), (5, 3))
    h = fmap(x)
    assert h.shape == (5, 17)
    assert bool(jnp.all(jnp.isfinite(h)))
