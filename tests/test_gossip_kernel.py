"""Fused gossip-round kernel plane (kernels/elm_gossip*).

Pins, per DESIGN.md §15: neighbor-list construction, scan-fallback and
Pallas-interpret parity against the dense DenseMixer round (plain,
chunked, bf16, explicit-payload, time-varying, fault-masked), the
in-kernel multi-round arm, engine-level NeighborMixer composition
(FaultyMixer / CompressedMixer / membership churn), int8 bitwise
determinism, the dense-fallback heuristic, and op="gossip" autotuning.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as engine_lib
from repro.core.compression import CompressionSpec
from repro.core.consensus import (
    FaultModel,
    alternating_halves,
    build,
    random_geometric,
)
from repro.core.mixers import DenseMixer, NeighborMixer
from repro.kernels import autotune, elm_gossip_ops
from repro.kernels import elm_gossip_ref as ref
from repro.kernels.elm_gossip import (
    elm_gossip_pallas,
    elm_gossip_pallas_multiround,
    multiround_vmem_bytes,
)

TOL = dict(rtol=2e-5, atol=2e-5)


def _adj(g):
    return jnp.asarray(np.asarray(g.adjacency), jnp.float32)


def _state(V, L, M, seed=0):
    ks = jax.random.split(jax.random.key(seed), 2)
    betas = jax.random.normal(ks[0], (V, L, M), jnp.float32)
    w = jax.random.normal(ks[1], (V, L, L), jnp.float32)
    omegas = jnp.einsum("vlk,vmk->vlm", w, w) / L
    return betas, omegas


def _dense_rounds(betas, omegas, adj, scale, rounds, compress=None):
    deg = jnp.sum(adj, axis=-1)
    return ref.dense_gossip_rounds(
        betas, omegas, adj, deg, scale, num_rounds=rounds,
        compress=compress,
    )


# ---------------------------------------------------------------------------
# Neighbor lists
# ---------------------------------------------------------------------------


def test_neighbor_lists_roundtrip():
    g = random_geometric(13, 0.5, seed=4)
    adj = _adj(g)
    idx, w, deg = ref.neighbor_lists(adj)
    assert idx.shape == w.shape and idx.dtype == jnp.int32
    V, d_max = idx.shape[1:]
    assert d_max == int((np.asarray(adj) != 0).sum(axis=-1).max())
    rebuilt = np.zeros((V, V), np.float32)
    for i in range(V):
        for s in range(d_max):
            rebuilt[i, int(idx[0, i, s])] += float(w[0, i, s])
    np.testing.assert_allclose(rebuilt, np.asarray(adj), **TOL)
    np.testing.assert_allclose(deg[0], np.asarray(adj).sum(-1), **TOL)


def test_neighbor_lists_validates_shape():
    with pytest.raises(ValueError, match="adjacencies"):
        ref.neighbor_lists(jnp.ones((3, 4)))


def test_payload_mode_validation():
    betas, omegas = _state(4, 8, 2)
    adj = _adj(build("ring", 4))
    idx, w, deg = ref.neighbor_lists(adj)
    with pytest.raises(ValueError, match="core/compression.py"):
        ref.elm_gossip_scan(
            betas, omegas, idx, w, deg, 0.1, num_rounds=2, compress="int8"
        )


# ---------------------------------------------------------------------------
# Scan fallback vs the dense round (the oracle relation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kind,V", [("hypercube", 16), ("ring", 12), ("star", 9), ("complete", 7)]
)
def test_scan_matches_dense_rounds(kind, V):
    g = build(kind, V)
    adj = _adj(g)
    betas, omegas = _state(V, 12, 3, seed=V)
    idx, w, deg = ref.neighbor_lists(adj)
    scale = 0.5 * g.default_gamma() / V
    got = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, scale, num_rounds=7
    )
    want = _dense_rounds(betas, omegas, adj[None], scale, 7)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_scan_matches_dense_on_random_sparse_graphs(seed):
    g = random_geometric(11 + seed, 0.55, seed=seed)
    adj = _adj(g)
    V = g.num_nodes
    betas, omegas = _state(V, 10, 2, seed=seed)
    idx, w, deg = ref.neighbor_lists(adj)
    scale = 0.4 * g.default_gamma() / V
    got = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, scale, num_rounds=6
    )
    want = _dense_rounds(betas, omegas, adj[None], scale, 6)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("chunk", [1, 2, 3])
def test_chunked_scan_matches_full_gather(chunk):
    g = build("hypercube", 16)
    adj = _adj(g)
    betas, omegas = _state(16, 12, 3)
    idx, w, deg = ref.neighbor_lists(adj)
    full = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.02, num_rounds=5
    )
    got = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.02, num_rounds=5, chunk=chunk
    )
    np.testing.assert_allclose(got, full, **TOL)


def test_bf16_payload_matches_dense_bf16():
    g = build("hypercube", 16)
    adj = _adj(g)
    betas, omegas = _state(16, 12, 3, seed=5)
    idx, w, deg = ref.neighbor_lists(adj)
    got = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.02, num_rounds=5, compress="bf16"
    )
    want = _dense_rounds(betas, omegas, adj[None], 0.02, 5, compress="bf16")
    np.testing.assert_allclose(got, want, **TOL)


def test_time_varying_snapshots_parity():
    gs = alternating_halves(12)
    adj = jnp.stack([_adj(g) for g in gs])
    betas, omegas = _state(12, 9, 2, seed=7)
    idx, w, deg = ref.neighbor_lists(adj)
    got = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.1, num_rounds=5
    )
    want = _dense_rounds(betas, omegas, adj, 0.1, 5)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode off-TPU)
# ---------------------------------------------------------------------------


@pytest.mark.interpret
def test_pallas_single_round_matches_reference():
    g = build("hypercube", 8)
    adj = _adj(g)
    betas, omegas = _state(8, 16, 3, seed=2)
    idx, w, deg = ref.neighbor_lists(adj)
    want = ref.gossip_round_reference(
        betas, omegas, idx[0], w[0], deg[0], 0.05
    )
    got = elm_gossip_pallas(
        betas, omegas, idx, w, deg, 0.05, num_rounds=1, block_v=4,
        interpret=True,
    )
    assert got.dtype == betas.dtype
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.interpret
@pytest.mark.parametrize("compress", [None, "bf16"])
def test_pallas_scanned_rounds_match_scan(compress):
    g = build("hypercube", 8)
    adj = _adj(g)
    betas, omegas = _state(8, 16, 3, seed=3)
    idx, w, deg = ref.neighbor_lists(adj)
    want = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.05, num_rounds=4, compress=compress
    )
    got = elm_gossip_pallas(
        betas, omegas, idx, w, deg, 0.05, num_rounds=4, block_v=4,
        compress=compress, interpret=True,
    )
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.interpret
@pytest.mark.parametrize("compress", [None, "bf16"])
def test_pallas_multiround_arm_matches_scan(compress):
    gs = alternating_halves(8)
    adj = jnp.stack([_adj(g) for g in gs])
    betas, omegas = _state(8, 16, 3, seed=4)
    idx, w, deg = ref.neighbor_lists(adj)
    assert multiround_vmem_bytes(8, 16, 3, 2, int(idx.shape[-1])) < (
        autotune.VMEM_BUDGET
    )
    want = ref.elm_gossip_scan(
        betas, omegas, idx, w, deg, 0.2, num_rounds=5, compress=compress
    )
    got = elm_gossip_pallas_multiround(
        betas, omegas, idx, w, deg, 0.2, num_rounds=5, compress=compress,
        interpret=True,
    )
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.interpret
def test_pallas_explicit_payload_round():
    g = build("hypercube", 8)
    adj = _adj(g)
    betas, omegas = _state(8, 16, 3, seed=6)
    idx, w, deg = ref.neighbor_lists(adj)
    payload = betas.astype(jnp.bfloat16).astype(jnp.float32)
    want = ref.gossip_round_payload(
        betas, payload, omegas, idx[0], w[0], deg[0], 0.05
    )
    got = elm_gossip_pallas(
        betas, omegas, idx, w, deg, 0.05, num_rounds=1, payload=payload,
        block_v=4, interpret=True,
    )
    np.testing.assert_allclose(got, want, **TOL)
    with pytest.raises(ValueError, match="payload"):
        elm_gossip_pallas(
            betas, omegas, idx, w, deg, 0.05, num_rounds=2,
            payload=payload, interpret=True,
        )


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def test_dispatcher_knob_cross_errors():
    g = build("hypercube", 8)
    betas, omegas = _state(8, 8, 2)
    idx, w, deg = ref.neighbor_lists(_adj(g))
    with pytest.raises(ValueError, match="block_v"):
        elm_gossip_ops.fused_gossip_rounds(
            betas, omegas, idx, w, deg, 0.1, num_rounds=2,
            use_kernel=False, block_v=4,
        )
    with pytest.raises(ValueError, match="chunk"):
        elm_gossip_ops.fused_gossip_rounds(
            betas, omegas, idx, w, deg, 0.1, num_rounds=2,
            use_kernel=True, chunk=2,
        )


def test_dispatcher_arms_agree():
    g = build("hypercube", 8)
    betas, omegas = _state(8, 16, 3, seed=9)
    idx, w, deg = ref.neighbor_lists(_adj(g))
    scan = elm_gossip_ops.fused_gossip_rounds(
        betas, omegas, idx, w, deg, 0.05, num_rounds=3, use_kernel=False
    )
    kern = elm_gossip_ops.fused_gossip_rounds(
        betas, omegas, idx, w, deg, 0.05, num_rounds=3, use_kernel=True,
        interpret=jax.default_backend() != "tpu",
    )
    np.testing.assert_allclose(scan, kern, **TOL)


def test_prefers_dense_pins():
    # the BENCH_consensus grid's arm choices (DESIGN.md §15), pinned
    # at each backend's slack: TPU trusts the roofline ratio almost
    # directly; off-TPU the dense GEMM's efficiency edge means only
    # large V / small L points hand the round to the gather arm
    tpu = dict(slack=elm_gossip_ops.DENSE_SLACK)
    assert elm_gossip_ops.prefers_dense(16, 4, 128, 8, **tpu)
    assert not elm_gossip_ops.prefers_dense(64, 6, 128, 8, **tpu)
    assert elm_gossip_ops.prefers_dense(64, 6, 512, 8, **tpu)
    assert not elm_gossip_ops.prefers_dense(256, 8, 128, 8, **tpu)
    assert elm_gossip_ops.prefers_dense(64, 63, 128, 8, **tpu)  # complete
    cpu = dict(slack=elm_gossip_ops.DENSE_SLACK_OFF_TPU)
    assert elm_gossip_ops.prefers_dense(256, 8, 128, 8, **cpu)
    assert not elm_gossip_ops.prefers_dense(1024, 10, 128, 8, **cpu)
    assert not elm_gossip_ops.prefers_dense(256, 8, 24, 2, **cpu)
    # the default slack follows the backend
    expected = (
        elm_gossip_ops.DENSE_SLACK if jax.default_backend() == "tpu"
        else elm_gossip_ops.DENSE_SLACK_OFF_TPU
    )
    assert elm_gossip_ops.prefers_dense(
        64, 6, 128, 8
    ) == elm_gossip_ops.prefers_dense(64, 6, 128, 8, slack=expected)
    assert elm_gossip_ops.laplacian_prefers_dense(8, 7)
    assert not elm_gossip_ops.laplacian_prefers_dense(64, 6)


# ---------------------------------------------------------------------------
# NeighborMixer through the engine (composition parity)
# ---------------------------------------------------------------------------


def _engines(g, C=10.0, **kw):
    ed = engine_lib.simulated_dc_elm(g, C, **kw)
    en = engine_lib.simulated_dc_elm(g, C, mixer="neighbor", **kw)
    return ed, en


def _stream(eng, V, L, M, seed=0):
    ks = jax.random.split(jax.random.key(seed), 2)
    H = jax.random.normal(ks[0], (V, 3 * L, L), jnp.float32)
    T = jax.random.normal(ks[1], (V, 3 * L, M), jnp.float32)
    return eng.stream_init(H, T)


@pytest.mark.parametrize("compress", [None, "bf16"])
def test_neighbor_engine_matches_dense(compress):
    g = build("hypercube", 16)
    ed, en = _engines(g, compress=compress)
    st = _stream(ed, 16, 12, 2)
    gamma = en.mixer.default_gamma()
    fd, _ = ed.run(st.betas, st.omegas, gamma, 20)
    fn, _ = en.run(st.betas, st.omegas, gamma, 20)
    np.testing.assert_allclose(fn, fd, **TOL)
    assert en.wire_stats is not None
    assert en.mixer.total_bytes_on_wire > 0


@pytest.mark.parametrize("compress", [None, "bf16"])
def test_neighbor_engine_fused_arm_parity(compress):
    # V=256 hypercube at L=24: large V / small L, so every backend's
    # slack routes NeighborMixer.run through the fused gossip program
    # (the V=16 tests above exercise the dense-fallback arm off-TPU)
    g = build("hypercube", 256)
    assert not elm_gossip_ops.prefers_dense(256, 8, 24, 2)
    ed, en = _engines(g, compress=compress)
    st = _stream(ed, 256, 24, 2, seed=21)
    gamma = en.mixer.default_gamma()
    fd, _ = ed.run(st.betas, st.omegas, gamma, 12)
    fn, _ = en.run(st.betas, st.omegas, gamma, 12)
    np.testing.assert_allclose(fn, fd, **TOL)
    assert en.mixer.total_bytes_on_wire > 0


def test_neighbor_engine_fused_int8_round():
    # the explicit-payload fused round (CompressedMixer arm) at a
    # point where apply_round dispatches to the gather program
    g = build("hypercube", 256)
    spec = CompressionSpec.parse("int8")
    ed, en = _engines(g, compress=spec)
    st = _stream(engine_lib.simulated_dc_elm(g, 10.0), 256, 24, 2, seed=23)
    gamma = 0.1
    fd, _ = ed.run(st.betas, st.omegas, gamma, 6)
    fn, _ = en.run(st.betas, st.omegas, gamma, 6)
    np.testing.assert_allclose(fn, fd, **TOL)


def test_neighbor_engine_time_varying():
    gs = alternating_halves(12)
    ed, en = _engines(gs)
    st = _stream(ed, 12, 10, 2, seed=3)
    fd, _ = ed.run(st.betas, st.omegas, 0.3, 16)
    fn, _ = en.run(st.betas, st.omegas, 0.3, 16)
    np.testing.assert_allclose(fn, fd, **TOL)


def test_neighbor_engine_certified_faults():
    g = build("hypercube", 16)
    fm = FaultModel.sample_certified(g, 0.3, num_rounds=12, window=4)
    ed, en = _engines(g)
    ed = engine_lib.with_faults(ed, fm, num_rounds=12)
    en = engine_lib.with_faults(en, fm, num_rounds=12)
    # the mask fold preserved the fused mixer class on the masked period
    assert type(en.mixer._dense) is NeighborMixer
    st = _stream(engine_lib.simulated_dc_elm(g, 10.0), 16, 12, 2, seed=5)
    gamma = ed.mixer.default_gamma()
    fd, _ = ed.run(st.betas, st.omegas, gamma, 24)
    fn, _ = en.run(st.betas, st.omegas, gamma, 24)
    np.testing.assert_allclose(fn, fd, **TOL)


def test_neighbor_engine_int8_parity_and_determinism():
    g = build("hypercube", 16)
    spec = CompressionSpec.parse("int8")
    ed, en = _engines(g, compress=spec)
    st = _stream(engine_lib.simulated_dc_elm(g, 10.0), 16, 12, 2, seed=8)
    gamma = 0.2
    fd, _ = ed.run(st.betas, st.omegas, gamma, 16)
    fn, _ = en.run(st.betas, st.omegas, gamma, 16)
    np.testing.assert_allclose(fn, fd, **TOL)
    # bitwise determinism of the fused int8 arm: a fresh mixer replaying
    # the same (state, key schedule) reproduces the run exactly
    en2 = engine_lib.simulated_dc_elm(
        g, 10.0, compress=spec, mixer="neighbor"
    )
    fn2, _ = en2.run(st.betas, st.omegas, gamma, 16)
    assert bool(jnp.all(fn == fn2))


def test_churn_preserves_neighbor_mixer():
    g = build("hypercube", 16)
    en = engine_lib.simulated_dc_elm(g, 10.0, mixer="neighbor")
    st = _stream(en, 16, 10, 2, seed=11)
    e2, s2 = en.stream_leave(st, 5)
    assert type(e2.mixer) is NeighborMixer
    assert e2.mixer.num_nodes == 15
    Hn = jax.random.normal(jax.random.key(0), (30, 10), jnp.float32)
    Tn = jax.random.normal(jax.random.key(1), (30, 2), jnp.float32)
    e3, s3 = e2.stream_join(s2, Hn, Tn)
    assert type(e3.mixer) is NeighborMixer
    f3, _ = e3.run(
        s3.betas, s3.omegas, e3.mixer.default_gamma() * 0.5, 4
    )
    assert bool(jnp.all(jnp.isfinite(f3)))


def test_neighbor_mixer_generic_pytree_path():
    g = build("hypercube", 16)
    adj = _adj(g)
    nm = NeighborMixer(adj)
    dm = DenseMixer(adj)
    tree = {
        "a": jax.random.normal(jax.random.key(2), (16, 7), jnp.float32),
        "b": jax.random.normal(jax.random.key(3), (16, 3, 2), jnp.float32),
    }
    rule = engine_lib.AverageRule()
    o1, _ = nm.run(rule, tree, None, 0.1, 6)
    o2, _ = dm.run(rule, tree, None, 0.1, 6)
    for k in tree:
        np.testing.assert_allclose(o1[k], o2[k], **TOL)


def test_dense_mixer_precomputed_degrees():
    gs = alternating_halves(10)
    adj = jnp.stack([_adj(g) for g in gs])
    dm = DenseMixer(adj)
    assert dm.degrees.shape == (2, 10)
    np.testing.assert_allclose(
        dm.degrees, jnp.sum(adj, axis=-1), **TOL
    )
    np.testing.assert_allclose(dm._degree_row(3), dm.degrees[1], **TOL)


def test_compress_payload_rejects_unknown_mode():
    # satellite pin: the inline knob names the CompressionSpec escape
    # hatch for richer wire formats
    from repro.core.mixers import compress_payload

    with pytest.raises(ValueError, match="CompressionSpec"):
        compress_payload(jnp.ones((2, 2)), "int8")


# ---------------------------------------------------------------------------
# Autotune op="gossip"
# ---------------------------------------------------------------------------


def _gossip_point(**kw):
    base = dict(
        op="gossip", impl="scan", N=16, D=4, L=16, M=3,
        dtype="float32", backend=jax.default_backend(),
    )
    base.update(kw)
    return autotune.TunePoint(**base)


def test_gossip_candidates_clamped_and_include_default():
    pt = _gossip_point(D=6)
    cands = autotune.candidates(pt)
    assert {"chunk": 6} in cands  # clamped to d_max
    assert all(c["chunk"] <= 6 for c in cands)
    ptp = _gossip_point(impl="pallas", N=12)
    candsp = autotune.candidates(ptp)
    assert {"block_n": 8} in candsp  # the hard-coded default
    assert all(c["block_n"] <= 12 for c in candsp)


def test_gossip_roofline_prune_keeps_a_candidate():
    pt = _gossip_point(N=64, D=6, L=128, M=8)
    kept, _ = autotune.roofline_prune(pt, autotune.candidates(pt))
    assert kept
    est = autotune.estimate(pt, kept[0])
    assert est["t_estimate"] > 0


def test_gossip_tune_and_lookup_roundtrip(tmp_path):
    path = str(tmp_path / "tuned.json")
    cfg = autotune.tune(
        "gossip", 16, 4, 16, 3, "float32", impl="scan",
        cache_path=path, repeats=1,
    )
    assert 1 <= cfg["chunk"] <= 4
    hit = autotune.lookup(
        "gossip", 16, 4, 16, 3, "float32", impl="scan", cache_path=path
    )
    assert hit == cfg
    # nearest-N fallback within the 4x window
    near = autotune.lookup(
        "gossip", 32, 4, 16, 3, "float32", impl="scan", cache_path=path
    )
    assert near == cfg


def test_gossip_resolve_config_explicit_wins(tmp_path):
    path = str(tmp_path / "tuned.json")
    autotune.tune(
        "gossip", 16, 4, 16, 3, "float32", impl="scan",
        cache_path=path, repeats=1,
    )
    merged = autotune.resolve_config(
        {"chunk": 2}, "cached", op="gossip", impl="scan",
        N=16, D=4, L=16, M=3, dtype="float32", cache_path=path,
    )
    assert merged["chunk"] == 2


# ---------------------------------------------------------------------------
# Hypothesis property sweep (skipped when hypothesis is unavailable —
# the deterministic parametrized parity pins above always run)
# ---------------------------------------------------------------------------

_hyp = pytest.importorskip  # alias so the guard reads as intent


def test_property_fused_round_matches_dense():
    _hyp("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        v=st.integers(4, 14),
        l=st.integers(2, 10),
        m=st.integers(1, 3),
        radius=st.floats(0.45, 0.8),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=15, deadline=None)
    def prop(v, l, m, radius, seed):  # noqa: E741
        g = random_geometric(v, radius, seed=seed % 100)
        adj = _adj(g)
        betas, omegas = _state(v, l, m, seed=seed)
        idx, w, deg = ref.neighbor_lists(adj)
        scale = 0.3 * g.default_gamma() / v
        got = ref.elm_gossip_scan(
            betas, omegas, idx, w, deg, scale, num_rounds=3
        )
        want = _dense_rounds(betas, omegas, adj[None], scale, 3)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    prop()
