"""Time-varying topologies (paper Sec. V future work)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm


def _problem(V=6, Ni=48, L=10, M=1, C=0.25, seed=0):
    kx, kt = jax.random.split(jax.random.key(seed))
    H = jax.random.normal(kx, (V, Ni, L))
    T = jax.random.normal(kt, (V, Ni, M))
    return H, T, C


def test_snapshots_disconnected_union_connected():
    graphs = consensus.alternating_halves(6)
    for g in graphs:
        assert not g.is_connected  # each snapshot alone is disconnected
    union = consensus.Graph(
        np.maximum(graphs[0].adjacency, graphs[1].adjacency)
    )
    assert union.is_connected  # jointly connected (the 6-ring)


def test_time_varying_converges_to_centralized():
    H, T, C = _problem()
    graphs = consensus.alternating_halves(6)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    gamma = 0.9 * dc_elm.joint_gamma_bound(graphs)
    final, _ = dc_elm.simulate_run_time_varying(
        state, graphs, gamma, C, 6000
    )
    d = float(dc_elm.distance_to(final.betas, beta_star))
    assert d < 0.03, d


def test_static_disconnected_does_not_converge():
    """Control: staying on one disconnected snapshot never consents."""
    H, T, C = _problem()
    g0 = consensus.alternating_halves(6)[0]
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    final, _ = dc_elm.simulate_run(state, g0, 0.45, C, 6000)
    d = float(dc_elm.distance_to(final.betas, beta_star))
    assert d > 0.05, d  # pairs agree locally but the halves never meet


def test_gradient_sum_invariant_over_switching():
    H, T, C = _problem()
    graphs = consensus.alternating_halves(6)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    final, _ = dc_elm.simulate_run_time_varying(
        state, graphs, 0.4, C, 37
    )
    gs = dc_elm.gradient_sum(final, P_, Q_, C)
    scale = float(jnp.max(jnp.abs(final.betas))) * (6 * C) + 1
    assert float(jnp.max(jnp.abs(gs))) / scale < 5e-4
