"""Vertical (column-partitioned) DC-ELM: assembly, parity, serving.

The load-bearing invariant: blocked float matmul is not associative,
so ``VerticalFeatureMap`` owns the canonical contraction (left fold in
node order). Both the distributed reduction and the centralized stats
plane run that same fold, which is what makes the bitwise-in-f64
acceptance criterion well-defined.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    consensus,
    dc_elm,
    engine,
    online,
    stats as stats_lib,
    vertical,
)
from repro.core.consensus import FaultModel, NodeCrash
from repro.core.features import make_random_features
from repro.core.secure import SecureAggregationSpec
from repro.core.vertical import (
    ColumnPartition,
    SpanningTree,
    VerticalFeatureMap,
    make_vertical_map,
)
from repro.kernels import elm_stats_ops
from repro.kernels.elm_stats import elm_preact_stats_pallas
from repro.kernels.elm_stats_ref import (
    preact_stats_reference,
    preact_stats_scan,
)
from repro.serving import BetaStore, ELMServer


def _problem(N, D, L, M, V, *, seed=0, activation="tanh"):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    T = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    fmap = make_vertical_map(
        jax.random.key(seed), D, L, V, activation=activation
    )
    return X, T, fmap


# ---------------------------------------------------------------------------
# Partition / feature-map plumbing
# ---------------------------------------------------------------------------


def test_partition_even_and_from_widths():
    p = ColumnPartition.even(10, 4)
    assert p.in_dim == 10 and p.num_nodes == 4
    assert sum(p.widths) == 10 and max(p.widths) - min(p.widths) <= 1
    q = ColumnPartition.from_widths([3, 3, 2, 2])
    assert q.bounds == p.bounds


def test_partition_validation():
    with pytest.raises(ValueError):
        ColumnPartition((0, 5, 3, 8))  # not increasing
    with pytest.raises(ValueError):
        ColumnPartition((1, 5))  # must start at 0


def test_make_vertical_map_custom_partition():
    part = ColumnPartition.from_widths([5, 4, 6, 3])
    fmap = vertical.make_vertical_map(
        jax.random.key(0), 18, 8, 4, partition=part
    )
    assert fmap.partition is part
    assert [s.shape[1] for s in part.split(jnp.zeros((3, 18)))] == [
        5, 4, 6, 3,
    ]
    with pytest.raises(ValueError, match="partition covers"):
        vertical.make_vertical_map(
            jax.random.key(0), 18, 8, 3, partition=part
        )
    with pytest.raises(ValueError, match="partition covers"):
        vertical.make_vertical_map(
            jax.random.key(0), 20, 8, 4, partition=part
        )


def test_split_concat_roundtrip():
    X, _, fmap = _problem(20, 9, 8, 1, 3)
    parts = fmap.partition.split(X)
    assert len(parts) == 3
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), X)


def test_vertical_map_matches_canonical_fold():
    """__call__ == g(left-fold of partials + b), by construction."""
    X, _, fmap = _problem(40, 7, 12, 1, 3)
    parts = fmap.partition.split(X)
    Z = VerticalFeatureMap.assemble(
        [fmap.partial_preactivation(i, x) for i, x in enumerate(parts)]
    )
    np.testing.assert_array_equal(np.asarray(fmap(X)),
                                  np.asarray(jnp.tanh(Z + fmap.bias)))


def test_from_shards_roundtrip():
    X, _, fmap = _problem(16, 6, 10, 1, 2)
    shards = [fmap.weight_shard(i) for i in range(2)]
    rebuilt = VerticalFeatureMap.from_shards(
        shards, fmap.bias, fmap.activation
    )
    np.testing.assert_array_equal(np.asarray(rebuilt(X)),
                                  np.asarray(fmap(X)))


def test_rbf_rejected():
    rbf = make_random_features(jax.random.key(0), 6, 8, "rbf")
    with pytest.raises((TypeError, ValueError)):
        VerticalFeatureMap(rbf, ColumnPartition.even(6, 2))


def test_spanning_tree_bfs():
    t = SpanningTree.bfs(consensus.line(5), root=0)
    assert t.depth == (0, 1, 2, 3, 4)
    assert t.parent[4] == 3
    ring = SpanningTree.bfs(consensus.ring(6), root=0)
    assert max(ring.depth) == 3
    # disconnected graph raises
    adj = np.zeros((4, 4))
    adj[0, 1] = adj[1, 0] = 1.0
    with pytest.raises(ValueError):
        SpanningTree.bfs(consensus.Graph(adjacency=adj))


# ---------------------------------------------------------------------------
# Bitwise parity: distributed assembly == centralized stats plane
# ---------------------------------------------------------------------------


def test_vertical_stats_bitwise_f64_vs_centralized():
    """Acceptance: assembled (P, Q) from column-sliced nodes matches
    the centralized horizontal stats plane bitwise in f64."""
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(1)
        N, D, L, M, V = 150, 11, 24, 2, 4
        X = jnp.asarray(rng.standard_normal((N, D)), jnp.float64)
        T = jnp.asarray(rng.standard_normal((N, M)), jnp.float64)
        fmap = make_vertical_map(
            jax.random.key(1), D, L, V, dtype=jnp.float64
        )
        for g in (consensus.ring(V), consensus.line(V),
                  consensus.complete(V)):
            s, rep = vertical.vertical_stats(
                fmap.partition.split(X), T, fmap, graph=g,
                dtype=jnp.float64,
            )
            P0, Q0 = stats_lib.raw_moments(X, T, fmap, dtype=jnp.float64)
            assert s.P.dtype == jnp.float64
            np.testing.assert_array_equal(np.asarray(s.P), np.asarray(P0))
            np.testing.assert_array_equal(np.asarray(s.Q), np.asarray(Q0))
            assert rep.delivered == tuple(range(V))


def test_vertical_stats_f32_and_bf16_pinned_tol():
    X, T, fmap = _problem(128, 8, 20, 2, 3, seed=2)
    s, _ = vertical.vertical_stats(fmap.partition.split(X), T, fmap)
    P0, Q0 = stats_lib.raw_moments(X, T, fmap)
    np.testing.assert_allclose(s.P, P0, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(s.Q, Q0, rtol=1e-6, atol=1e-6)
    # bf16 column slices: pinned at bf16 grid tolerance
    from repro.core import features

    base16 = features.RandomFeatureMap(
        weights=fmap.base.weights.astype(jnp.bfloat16),
        bias=fmap.base.bias.astype(jnp.bfloat16),
        activation=fmap.activation,
    )
    fb = VerticalFeatureMap(base=base16, partition=fmap.partition)
    sb, _ = vertical.vertical_stats(
        fb.partition.split(X.astype(jnp.bfloat16)), T, fb
    )
    np.testing.assert_allclose(sb.P, P0, rtol=0.1, atol=0.2)


def test_vertical_stats_secure_pinned_tol():
    X, T, fmap = _problem(100, 9, 16, 1, 3, seed=3)
    spec = SecureAggregationSpec(seed=5)
    s, rep = vertical.vertical_stats(
        fmap.partition.split(X), T, fmap, secure=spec
    )
    P0, Q0 = stats_lib.raw_moments(X, T, fmap)
    # fixed-point grid on Z then one activation: small pinned tolerance
    np.testing.assert_allclose(s.P, P0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s.Q, Q0, rtol=1e-5, atol=1e-5)
    assert rep.wire.bytes_on_wire > 0


def test_wire_accounting_secure_vs_clear():
    """Secure payloads are constant-width (8 B/value): on a deep tree
    they beat clear convergecast, whose messages grow toward the root."""
    rng = np.random.default_rng(4)
    V, N, L = 6, 64, 12
    partials = [rng.standard_normal((N, L)) for _ in range(V)]
    g = consensus.line(V)
    _, clear = vertical.reduce_partials(partials, g)
    _, sec = vertical.reduce_partials(
        partials, g, secure=SecureAggregationSpec(seed=0)
    )
    assert sec.wire.bytes_on_wire < clear.wire.bytes_on_wire
    # the baseline prices every origin payload at f64 clear convergecast
    assert clear.wire.bytes_uncompressed >= clear.wire.bytes_on_wire
    assert sec.wire.bytes_uncompressed == clear.wire.bytes_uncompressed
    for rep in (clear, sec):
        assert int(np.sum(rep.wire.per_round_bytes)) == rep.wire.bytes_on_wire


def test_dropped_node_degrades_gracefully():
    X, T, fmap = _problem(80, 8, 14, 1, 4, seed=5)
    g = consensus.line(4)
    fm = FaultModel(
        graph=g, crashes=(NodeCrash(node=2, start=1, duration=9),)
    )
    s, rep = vertical.vertical_stats(
        fmap.partition.split(X), T, fmap, graph=g, faults=fm
    )
    assert set(rep.delivered) < set(range(4))
    # the assembled stats are those of the surviving columns' fold
    parts = fmap.partition.split(X)
    Z = VerticalFeatureMap.assemble(
        [fmap.partial_preactivation(i, parts[i]) for i in rep.delivered]
    )
    H = jnp.tanh(Z + fmap.bias)
    P0, Q0 = stats_lib.hidden_moments(H, T)
    np.testing.assert_allclose(s.P, P0, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Training: init at optimum, streaming, entry points
# ---------------------------------------------------------------------------


def test_vertical_train_matches_centralized_ridge():
    X, T, fmap = _problem(120, 10, 18, 2, 3, seed=6)
    beta, s, _ = vertical.vertical_train(
        fmap.partition.split(X), T, fmap, C=10.0
    )
    H = fmap(X)
    beta0 = stats_lib.ridge_solve_moments(
        *stats_lib.hidden_moments(H, T), C=10.0
    )
    np.testing.assert_allclose(beta, beta0, rtol=1e-4, atol=1e-5)


def test_simulate_init_seeds_all_nodes_at_optimum():
    X, T, fmap = _problem(90, 6, 12, 1, 3, seed=7)
    g = consensus.ring(3)
    state, s, _ = dc_elm.simulate_init_vertical(
        fmap.partition.split(X), T, fmap, 10.0, g
    )
    beta, _, _ = vertical.vertical_train(
        fmap.partition.split(X), T, fmap, C=10.0, graph=g
    )
    assert state.betas.shape[0] == 3
    np.testing.assert_allclose(
        state.betas, jnp.broadcast_to(beta, state.betas.shape),
        rtol=1e-4, atol=1e-5,
    )
    # consensus from the optimum stays at the optimum
    gamma = 0.5 * g.gamma_upper_bound()
    out, _ = dc_elm.simulate_run(state, g, gamma, 10.0, 5)
    np.testing.assert_allclose(out.betas, state.betas, rtol=1e-4, atol=1e-4)


def test_stream_chunk_matches_retrain():
    X, T, fmap = _problem(100, 9, 15, 1, 3, seed=8)
    g = consensus.ring(3)
    eng = engine.simulated_dc_elm(g, 10.0)
    eng = engine.with_secure_aggregation(eng)
    assert eng.secure is not None
    st, _, _ = vertical.stream_init(eng, fmap.partition.split(X), T, fmap,
                                    graph=g)
    rng = np.random.default_rng(9)
    Xn = jnp.asarray(rng.standard_normal((30, 9)), jnp.float32)
    Tn = jnp.asarray(rng.standard_normal((30, 1)), jnp.float32)
    (st2, _), rep = vertical.stream_chunk(
        eng, st, fmap.partition.split(Xn), Tn, fmap,
        gamma=0.1, num_iters=2, graph=g,
    )
    Xall = jnp.concatenate([X, Xn])
    Tall = jnp.concatenate([T, Tn])
    beta_all, _, _ = vertical.vertical_train(
        fmap.partition.split(Xall), Tall, fmap, C=10.0, graph=g
    )
    np.testing.assert_allclose(st2.betas[0], beta_all, rtol=1e-3, atol=1e-4)
    # removing the chunk restores the original optimum
    (st3, _), _ = vertical.stream_chunk(
        eng, st2, fmap.partition.split(Xn), Tn, fmap,
        gamma=0.1, num_iters=2, graph=g, remove=True,
    )
    beta0, _, _ = vertical.vertical_train(
        fmap.partition.split(X), T, fmap, C=10.0, graph=g
    )
    np.testing.assert_allclose(st3.betas[0], beta0, rtol=1e-3, atol=1e-4)


def test_online_vertical_chunk_node_local():
    X, T, fmap = _problem(80, 8, 10, 1, 2, seed=10)
    g = consensus.complete(2)
    state, s, _ = vertical.simulate_init(
        fmap.partition.split(X), T, fmap, 10.0, g
    )
    ns = online.OnlineNodeState(
        omega=state.omegas[0], Q=(s.Q / 2).astype(state.omegas.dtype)
    )
    rng = np.random.default_rng(11)
    Xn = jnp.asarray(rng.standard_normal((20, 8)), jnp.float32)
    Tn = jnp.asarray(rng.standard_normal((20, 1)), jnp.float32)
    ns2, rep = online.vertical_chunk(
        ns, fmap.partition.split(Xn), Tn, fmap, graph=g
    )
    beta_all, _, _ = vertical.vertical_train(
        fmap.partition.split(jnp.concatenate([X, Xn])),
        jnp.concatenate([T, Tn]), fmap, C=10.0, graph=g,
    )
    np.testing.assert_allclose(ns2.beta, beta_all, rtol=1e-3, atol=1e-4)


def test_engine_secure_field_survives_wrappers():
    g = consensus.ring(4)
    eng = engine.simulated_dc_elm(g, 10.0)
    eng = engine.with_secure_aggregation(eng, 42)
    assert eng.secure.seed == 42
    from repro.core.compression import CompressionSpec

    eng2 = engine.with_compression(eng, CompressionSpec(mode="bf16"))
    assert eng2.secure.seed == 42
    fm = FaultModel(graph=g, edge_drop_prob=0.1)
    eng3 = engine.with_faults(eng2, fm, 4)
    assert eng3.secure.seed == 42


# ---------------------------------------------------------------------------
# Kernel plane: fused preactivation moments
# ---------------------------------------------------------------------------


@pytest.mark.interpret
@pytest.mark.parametrize("activation", ["sigmoid", "tanh", "relu"])
def test_preact_kernel_matches_oracle(activation):
    rng = np.random.default_rng(12)
    Z = jnp.asarray(rng.standard_normal((100, 33)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((33,)), jnp.float32)
    T = jnp.asarray(rng.standard_normal((100, 3)), jnp.float32)
    P0, Q0 = preact_stats_reference(Z, b, T, activation=activation)
    P1, Q1 = elm_preact_stats_pallas(
        Z, b, T, activation=activation, interpret=True,
        block_l=16, block_n=32,
    )
    np.testing.assert_allclose(P1, P0, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(Q1, Q0, rtol=2e-3, atol=2e-3)
    P2, Q2 = preact_stats_scan(Z, b, T, activation=activation, chunk=32)
    np.testing.assert_allclose(P2, P0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(Q2, Q0, rtol=1e-5, atol=1e-5)


@pytest.mark.interpret
@pytest.mark.parametrize(
    "N,L,M", [(64, 32, 2), (33, 7, 5), (130, 100, 1)]
)
def test_preact_kernel_ragged_shapes(N, L, M):
    rng = np.random.default_rng(13)
    Z = jnp.asarray(rng.standard_normal((N, L)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((L,)), jnp.float32)
    T = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    P0, Q0 = preact_stats_reference(Z, b, T, activation="sigmoid")
    P1, Q1 = elm_preact_stats_pallas(
        Z, b, T, interpret=True, block_l=16, block_n=32
    )
    np.testing.assert_allclose(P1, P0, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(Q1, Q0, rtol=2e-3, atol=2e-3)


def test_preact_dispatch_and_rbf_rejection():
    rng = np.random.default_rng(14)
    Z = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    T = jnp.asarray(rng.standard_normal((64, 2)), jnp.float32)
    P0, Q0 = preact_stats_reference(Z, b, T, activation="sigmoid")
    for use_kernel in (False, True):
        P, Q = elm_stats_ops.fused_preact_moments(
            Z, b, T, use_kernel=use_kernel
        )
        np.testing.assert_allclose(P, P0, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(Q, Q0, rtol=2e-3, atol=2e-3)
    with pytest.raises(ValueError, match="rbf"):
        elm_stats_ops.fused_preact_moments(Z, b, T, activation="rbf")


def test_force_interpret_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert elm_stats_ops.force_interpret()
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert not elm_stats_ops.force_interpret()
    monkeypatch.delenv("REPRO_FORCE_INTERPRET")
    assert not elm_stats_ops.force_interpret()


# ---------------------------------------------------------------------------
# Serving a vertically assembled model
# ---------------------------------------------------------------------------


def test_elm_server_serves_vertical_map():
    """VerticalFeatureMap takes the materialize path (not fusable) and
    serves through the bucketed batcher unchanged."""
    X, T, fmap = _problem(60, 8, 12, 2, 3, seed=15)
    beta, _, _ = vertical.vertical_train(
        fmap.partition.split(X), T, fmap, C=10.0
    )
    assert stats_lib.fusable_params(fmap) is None
    srv = ELMServer(fmap, BetaStore(beta[None]), buckets=(16, 64))
    rng = np.random.default_rng(16)
    q = rng.standard_normal((10, 8)).astype(np.float32)
    y = srv.predict(q, node=0)
    ref = np.asarray(fmap(jnp.asarray(q)) @ beta)
    np.testing.assert_allclose(y, ref, rtol=2e-5, atol=2e-5)
