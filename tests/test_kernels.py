"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn import flash_attention_pallas
from repro.kernels.attn_ref import attention_reference
from repro.kernels.gram import cross_pallas, gram_pallas
from repro.kernels.gram_ref import cross_reference, gram_reference
from repro.kernels.ssd_ref import ssd_naive_reference, ssd_reference
from repro.kernels.ssd_scan import ssd_pallas


@pytest.mark.parametrize("N,L", [(64, 32), (300, 100), (512, 256), (33, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel(N, L, dtype):
    H = jax.random.normal(jax.random.key(N + L), (N, L), dtype)
    out = gram_pallas(H, interpret=True, block_l=64, block_n=128)
    ref = gram_reference(H)
    assert out.dtype == jnp.float32
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * N**0.5)


@pytest.mark.parametrize("N,L,M", [(128, 64, 8), (100, 30, 1), (256, 128, 16)])
def test_cross_kernel(N, L, M):
    H = jax.random.normal(jax.random.key(0), (N, L))
    T = jax.random.normal(jax.random.key(1), (N, M))
    out = cross_pallas(H, T, interpret=True, block_l=32, block_m=8, block_n=64)
    np.testing.assert_allclose(out, cross_reference(H, T), rtol=1e-3, atol=1e-3)


def test_gram_symmetry_psd():
    H = jax.random.normal(jax.random.key(3), (200, 48))
    P = gram_pallas(H, interpret=True, block_l=16, block_n=64)
    np.testing.assert_allclose(P, P.T, atol=1e-3)
    ev = np.linalg.eigvalsh(np.asarray(P, np.float64))
    assert ev.min() > -1e-3


@pytest.mark.parametrize("b,s,nh,hd,ds,Q", [
    (2, 64, 4, 8, 16, 16),
    (1, 100, 2, 32, 64, 32),  # padding path (100 % 32 != 0)
    (2, 128, 3, 16, 8, 64),
])
def test_ssd_kernel_vs_naive(b, s, nh, hd, ds, Q):
    ks = jax.random.split(jax.random.key(s + nh), 6)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    h0 = jax.random.normal(ks[5], (b, nh, hd, ds))
    y1, hT1 = ssd_pallas(x, dt, A, B, C, chunk=Q, initial_state=h0,
                         interpret=True)
    y2, hT2 = ssd_naive_reference(x, dt, A, B, C, initial_state=h0)
    np.testing.assert_allclose(y1, y2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(hT1, hT2, rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_vs_naive_bf16():
    b, s, nh, hd, ds = 1, 96, 2, 8, 8
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    y1, h1 = ssd_reference(x, dt, A, B, C, chunk=32)
    y2, h2 = ssd_naive_reference(x, dt, A, B, C)
    np.testing.assert_allclose(
        y1.astype(jnp.float32), y2.astype(jnp.float32), rtol=5e-2, atol=5e-2
    )
    np.testing.assert_allclose(h1, h2, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("B,S,K,G,hd,bq,cap", [
    (2, 128, 2, 2, 16, 32, 0.0),
    (1, 64, 1, 4, 32, 16, 50.0),
    (2, 96, 3, 1, 8, 32, 0.0),
    (1, 256, 2, 4, 64, 64, 0.0),
])
def test_attention_kernel(B, S, K, G, hd, bq, cap):
    ks = jax.random.split(jax.random.key(S + K), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention_pallas(
        q, k, v, block_q=bq, block_k=bq, softcap=cap, interpret=True
    )
    ref = attention_reference(q, k, v, softcap=cap)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_attention_kernel_bf16():
    B, S, K, G, hd = 1, 128, 2, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    ref = attention_reference(q, k, v)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), rtol=5e-2, atol=5e-2
    )


def test_model_chunked_attention_vs_kernel():
    """models/attention.py jnp path == Pallas kernel semantics."""
    from repro.models.attention import flash_attention as jnp_flash

    B, S, K, G, hd = 2, 128, 2, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    pos = jnp.arange(S)
    a = jnp_flash(q, k, v, q_positions=pos, k_positions=pos, causal=True,
                  q_chunk=32, k_chunk=32)
    b = flash_attention_pallas(q, k, v, block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
