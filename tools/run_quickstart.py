#!/usr/bin/env python3
"""Run every README ```python block verbatim (CI's docs job).

Extracts each ```python fenced block from README.md — the 60-second
quickstart and the serving how-to — and executes them in order, each
in a fresh namespace, with ``src/`` on the import path. If a snippet
drifts from the code, this fails, not a new user.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def main() -> int:
    text = (REPO / "README.md").read_text(encoding="utf-8")
    blocks = re.findall(r"```python\n(.*?)```", text, re.DOTALL)
    if not blocks:
        print("README.md has no ```python blocks")
        return 1
    sys.path.insert(0, str(REPO / "src"))
    for i, snippet in enumerate(blocks, 1):
        print(f"--- running README python block {i}/{len(blocks)} ---")
        print(snippet)
        print("---------------------------------")
        exec(  # noqa: S102 — executing our own documented snippets is the point
            compile(snippet, f"README.md:python-block-{i}", "exec"), {}
        )
        print(f"block {i} OK")
    print(f"quickstart OK ({len(blocks)} block(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
