#!/usr/bin/env python3
"""Run the README quickstart verbatim (CI's docs job).

Extracts the first ```python fenced block from README.md and executes
it with ``src/`` on the import path — if the quickstart drifts from the
code, this fails, not a new user.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def main() -> int:
    text = (REPO / "README.md").read_text(encoding="utf-8")
    m = re.search(r"```python\n(.*?)```", text, re.DOTALL)
    if not m:
        print("README.md has no ```python quickstart block")
        return 1
    snippet = m.group(1)
    sys.path.insert(0, str(REPO / "src"))
    print("--- running README quickstart ---")
    print(snippet)
    print("---------------------------------")
    exec(compile(snippet, "README.md:quickstart", "exec"), {})
    print("quickstart OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
