#!/usr/bin/env python3
"""Coverage floor gate (stdlib only; CI's coverage job).

Reads the Cobertura ``coverage.xml`` that pytest-cov writes and fails
when line coverage drops below the committed floor:

    PYTHONPATH=src python -m pytest --cov=repro --cov-report=xml ...
    python tools/coverage_gate.py [--xml coverage.xml]
                                  [--floor coverage_floor.txt]

The floor lives in ``coverage_floor.txt`` at the repo root — a single
number (percent). Raise it as coverage grows; never lower it to make
CI pass (fix the missing tests instead, or revert the change that
dropped it). The gate prints per-package rates so a regression is
attributable from the job log alone.

Exit code 0 = at or above the floor, 1 = below (or missing inputs).
"""

from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--xml", default=str(REPO / "coverage.xml"))
    ap.add_argument("--floor", default=str(REPO / "coverage_floor.txt"))
    args = ap.parse_args()

    floor_path = Path(args.floor)
    xml_path = Path(args.xml)
    if not floor_path.exists():
        print(f"coverage floor file missing: {floor_path}")
        return 1
    if not xml_path.exists():
        print(f"coverage report missing: {xml_path} (run pytest --cov)")
        return 1

    floor = float(floor_path.read_text().strip())
    root = ET.parse(xml_path).getroot()
    rate = float(root.get("line-rate", 0.0)) * 100.0

    for pkg in root.iter("package"):
        pr = float(pkg.get("line-rate", 0.0)) * 100.0
        print(f"  {pkg.get('name'):<40s} {pr:6.1f}%")
    print(f"total line coverage: {rate:.2f}% (floor {floor:.2f}%)")

    if rate < floor:
        print(
            f"\nCOVERAGE REGRESSION: {rate:.2f}% < floor {floor:.2f}% — "
            "add tests for the uncovered lines (or revert the change "
            "that dropped them); do not lower coverage_floor.txt"
        )
        return 1
    print("coverage gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
