#!/usr/bin/env python3
"""Benchmark regression gate (stdlib only; CI's bench-gate job).

Compares freshly produced ``BENCH_*.json`` files against the committed
baselines. The benches write to the repo root, so CI copies the
checked-out baselines aside *before* running them:

    mkdir .bench-baseline && cp BENCH_*.json .bench-baseline/
    python -m benchmarks.run --suite stats,serving --fast
    python tools/bench_gate.py --baseline .bench-baseline

Matching is by identity key (N, D, L, M, dtype) over each suite's
``rows`` records — the --fast sweeps intersect the committed full
sweeps at the acceptance point by construction, and only intersecting
points are compared. Tolerances are generous (CI runners are noisy
shared machines; the committed numbers may come from different
hardware): the gate exists to catch the 4x wall-time or 1.5x peak-temp
cliffs of a genuine fusion/megakernel regression, not 10% jitter.
Backend mismatches (a TPU baseline checked against a CPU runner) skip
wall/temp comparison but still enforce each suite's own acceptance
invariant (fused_not_slower) on the fresh run.

Two additional checks:

* **Committed-row invariant** (hard fail): every row of the *committed*
  baselines must report ``fused_speedup >= 1.0``. The ratio is a
  same-machine measurement, so it is hardware-independent and must hold
  at commit time at every swept point, not just the acceptance point —
  this is what makes "fused is never slower" a property of the repo
  rather than of one lucky shape. (Fresh CI rows are *not* held to it:
  a noisy shared runner may flip a close ratio.) The committed
  ``acceptance.fused_not_slower`` flag is held to the same standard.
* **Tuned-cache drift** (warn only): when both the baseline and fresh
  directories hold a ``TUNED_kernels.json`` (the nightly --tune job
  produces a fresh one), entries whose committed winner wall time
  drifts more than ``--drift-tol`` (1.5x) from the fresh measurement
  are printed as warnings — the signal that the committed cache was
  tuned on different hardware or a different jax and should be
  regenerated, without failing CI over it.

Exit code 0 = within tolerance, 1 = regression (each printed).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
KEY_FIELDS = ("N", "D", "L", "M", "dtype")


def _key(rec: dict):
    return tuple(rec.get(k) for k in KEY_FIELDS)


def _wall_metrics(rec: dict):
    return {k: v for k, v in rec.items() if k.endswith("wall_ms")}


def _temp_metrics(rec: dict):
    return {k: v for k, v in rec.items() if k.endswith("peak_temp_bytes")}


def compare_suite(
    base: dict, fresh: dict, name: str, wall_tol: float, mem_tol: float
) -> tuple[list[str], list[str]]:
    """Returns (failures, notes) for one suite's payload pair."""
    failures, notes = [], []

    comparable = base.get("backend") == fresh.get("backend")
    if not comparable:
        notes.append(
            f"{name}: backend {base.get('backend')} (baseline) != "
            f"{fresh.get('backend')} (fresh) — skipping wall/temp deltas"
        )

    fresh_rows = {_key(r): r for r in fresh.get("rows", [])}
    matched = 0
    for brow in base.get("rows", []):
        frow = fresh_rows.get(_key(brow))
        if frow is None or not comparable:
            continue
        matched += 1
        tag = f"{name}{_key(brow)}"
        for metric, bval in _wall_metrics(brow).items():
            fval = frow.get(metric)
            if fval is None or bval <= 0:
                continue
            if fval > wall_tol * bval:
                failures.append(
                    f"{tag}.{metric}: {fval:.1f} ms vs baseline "
                    f"{bval:.1f} ms (> {wall_tol:.1f}x)"
                )
        for metric, bval in _temp_metrics(brow).items():
            fval = frow.get(metric, -1)
            if bval is None or fval is None or bval <= 0 or fval < 0:
                continue
            if fval > mem_tol * bval:
                failures.append(
                    f"{tag}.{metric}: {fval} B vs baseline {bval} B "
                    f"(> {mem_tol:.1f}x)"
                )
    notes.append(f"{name}: {matched} intersecting point(s) compared")

    # the suite's own acceptance invariant must hold on the fresh run
    # regardless of hardware: fused must not regress past the unfused
    # path by more than the noise allowance
    acc = fresh.get("acceptance")
    if acc is not None:
        fused = acc.get("fused_wall_ms")
        unfused = acc.get("unfused_wall_ms")
        if fused is not None and unfused is not None:
            slack = 1.25  # runner noise allowance on a same-machine ratio
            if fused > slack * unfused:
                failures.append(
                    f"{name}.acceptance: fused {fused:.1f} ms vs unfused "
                    f"{unfused:.1f} ms (> {slack:.2f}x on the same run)"
                )
    return failures, notes


def committed_row_failures(base: dict, name: str) -> list[str]:
    """fused_speedup >= 1.0 must hold at EVERY committed row.

    The speedup is a same-run, same-machine ratio, so unlike wall
    times it is comparable across hardware — a committed row below
    1.0 means the repo ships a point where the fused path loses.
    """
    failures = []
    for rec in base.get("rows", []):
        sp = rec.get("fused_speedup")
        if sp is not None and sp < 1.0:
            failures.append(
                f"{name}{_key(rec)}: committed fused_speedup {sp:.3f} "
                f"< 1.0 (impl {rec.get('fused_impl')}) — retune and "
                "regenerate the baseline (benchmarks.run --tune)"
            )
    # the committed acceptance record is the same same-machine ratio:
    # a baseline shipped with fused_not_slower=false means the suite's
    # own invariant was already broken at commit time
    acc = base.get("acceptance")
    if acc is not None and acc.get("fused_not_slower") is False:
        failures.append(
            f"{name}.acceptance: committed fused_not_slower is false "
            f"(fused {acc.get('fused_wall_ms')} ms vs unfused "
            f"{acc.get('unfused_wall_ms')} ms) — regenerate the "
            "baseline (benchmarks.run --tune)"
        )
    return failures


def tuned_drift_warnings(
    base_path: Path, fresh_path: Path, drift_tol: float
) -> list[str]:
    """Committed vs fresh TUNED_kernels.json winner drift (warn only)."""
    try:
        base = json.loads(base_path.read_text()).get("entries", {})
        fresh = json.loads(fresh_path.read_text()).get("entries", {})
    except (OSError, json.JSONDecodeError) as e:
        return [f"tuned-cache comparison skipped: {e}"]
    warnings = []
    common = sorted(set(base) & set(fresh))
    for key in common:
        b, f = base[key].get("wall_ms"), fresh[key].get("wall_ms")
        if not b or not f or b <= 0 or f <= 0:
            continue
        ratio = max(b, f) / min(b, f)
        if ratio > drift_tol:
            warnings.append(
                f"tuned-cache drift {key}: committed winner "
                f"{base[key].get('config')} at {b:.1f} ms vs fresh "
                f"{fresh[key].get('config')} at {f:.1f} ms "
                f"({ratio:.2f}x > {drift_tol:.1f}x) — consider "
                "regenerating TUNED_kernels.json on this hardware"
            )
    if common:
        warnings.append(
            f"tuned-cache: {len(common)} common entries compared"
        )
    return warnings


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--baseline", required=True,
        help="directory holding the committed BENCH_*.json copies "
        "(and optionally the committed TUNED_kernels.json)",
    )
    ap.add_argument(
        "--fresh", default=str(REPO),
        help="directory holding the freshly written BENCH_*.json",
    )
    ap.add_argument("--wall-tol", type=float, default=4.0)
    ap.add_argument("--mem-tol", type=float, default=1.5)
    ap.add_argument(
        "--drift-tol", type=float, default=1.5,
        help="tuned-cache winner drift ratio above which to warn",
    )
    args = ap.parse_args()

    baseline_dir = Path(args.baseline)
    fresh_dir = Path(args.fresh)
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"no BENCH_*.json baselines under {baseline_dir}")
        return 1

    # union of both sides: a fresh suite without a committed baseline
    # still gets its own acceptance invariant enforced (a suite whose
    # baseline was deleted must not silently skip the gate)
    names = sorted(
        {p.name for p in baselines}
        | {p.name for p in fresh_dir.glob("BENCH_*.json")}
    )
    failures, notes = [], []
    for name in names:
        bpath = baseline_dir / name
        fpath = fresh_dir / name
        if not fpath.exists():
            failures.append(f"{name}: fresh run missing ({fpath})")
            continue
        fresh = json.loads(fpath.read_text())
        if bpath.exists():
            base = json.loads(bpath.read_text())
        else:
            base = {"rows": [], "backend": None}
            notes.append(
                f"{Path(name).stem}: no committed baseline — acceptance "
                "invariant only"
            )
        f, n = compare_suite(
            base, fresh, Path(name).stem, args.wall_tol, args.mem_tol
        )
        failures.extend(f)
        failures.extend(committed_row_failures(base, Path(name).stem))
        notes.extend(n)

    base_tuned = baseline_dir / "TUNED_kernels.json"
    fresh_tuned = fresh_dir / "TUNED_kernels.json"
    if base_tuned.exists() and fresh_tuned.exists():
        for w in tuned_drift_warnings(
            base_tuned, fresh_tuned, args.drift_tol
        ):
            print(f"warning: {w}")

    for n in notes:
        print(f"note: {n}")
    if failures:
        print("\nBENCH REGRESSION:")
        print("\n".join(f"  {f}" for f in failures))
        return 1
    print(f"bench gate OK ({len(names)} suite file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
