#!/usr/bin/env python3
"""Seed-sweep stress test for the async push-sum runtime (nightly CI).

For every seed it builds a randomized adversarial configuration —
loss rate, delay jitter, per-node firing periods, per-edge latency
scales, graph topology — runs the event scheduler twice, and asserts
the two properties the subsystem's docs promise unconditionally:

* **Determinism**: the same seed replays the identical event log and
  identical betas, bit for bit. (The whole scheduler runs on one
  seeded generator and a (time, seq)-keyed heap; any hidden ordering
  nondeterminism shows up here first.)
* **Mass conservation**: after every run leg,
  sum_i sigma_i + sum_edges (mu - nu) equals the initial total to
  float roundoff — dropped/delayed/reordered messages may park mass
  in flight but can never create or destroy it.

Plus a liveness floor: every configuration is certified jointly
connected, so the run must actually converge to the centralized
beta* within the virtual-time budget.

Usage:
    PYTHONPATH=src python tools/async_stress.py [--seeds 24] [--tol 1e-5]

Exit code 0 = every seed clean; 1 = any violation (each printed).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np


def _config(seed: int):
    """One randomized adversarial setup, deterministic in ``seed``."""
    from repro.core import consensus

    rng = np.random.default_rng(seed)
    graph = [
        consensus.paper_fig2(),
        consensus.ring(6),
        consensus.hypercube(3),
    ][seed % 3]
    drop = float(rng.choice([0.0, 0.15, 0.3]))
    delays = consensus.DelayModel(
        base=float(rng.uniform(0.05, 0.5)),
        jitter=float(rng.uniform(0.0, 1.0)),
    )
    V = graph.num_nodes
    periods = rng.choice([1.0, 1.0, 2.0, 5.0], size=V)
    return graph, drop, delays, periods


def _run(seed: int, tol: float):
    import jax

    from repro.core import async_engine, consensus, dc_elm

    graph, drop, delays, periods = _config(seed)
    V, Ni, L, M, C = graph.num_nodes, 24, 6, 2, 0.5
    ks = jax.random.split(jax.random.key(seed), 2)
    H = jax.random.normal(ks[0], (V, Ni, L))
    T = jax.random.normal(ks[1], (V, Ni, M))
    _, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = np.linalg.solve(
        np.eye(L) / C + np.asarray(P_, np.float64).sum(0),
        np.asarray(Q_, np.float64).sum(0),
    )
    faults = None
    if drop > 0.0:
        faults = consensus.FaultModel.sample_certified(
            graph, drop, num_rounds=64, window=16, seed=seed
        )

    def one_run():
        eng = async_engine.async_dc_elm(
            graph,
            P_,
            Q_,
            C,
            faults=faults,
            delays=delays,
            fire_periods=periods,
            seed=seed,
        )
        # two legs: conservation must hold at interior stops too
        eng.run_until(t_max=10.0 * float(periods.max()))
        mid = eng.rule.conservation_residual()
        res = eng.run_until(
            residual_tol=tol, t_max=5000.0 * float(periods.max()), target=beta_star
        )
        return eng, mid, res

    eng_a, mid_a, res_a = one_run()
    eng_b, _, _ = one_run()

    failures = []
    if mid_a > 1e-9 or eng_a.rule.conservation_residual() > 1e-9:
        failures.append(
            f"conservation violated: mid={mid_a:.3e} "
            f"end={eng_a.rule.conservation_residual():.3e}"
        )
    if eng_a.event_log != eng_b.event_log:
        failures.append(
            f"event log not reproducible ({len(eng_a.event_log)} vs "
            f"{len(eng_b.event_log)} events)"
        )
    if not np.array_equal(eng_a.betas(), eng_b.betas()):
        failures.append("betas not bitwise reproducible across replays")
    if not res_a.converged:
        failures.append(
            f"no convergence: residual {res_a.residual:.3e} > {tol:g} "
            f"at t={res_a.t:.0f}"
        )
    tag = (
        f"{graph.name} drop={drop:.2f} jitter={delays.jitter:.2f} "
        f"events={len(eng_a.event_log)} t={res_a.t:.0f} "
        f"residual={res_a.residual:.2e}"
    )
    return failures, tag


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=24)
    ap.add_argument("--tol", type=float, default=1e-5)
    args = ap.parse_args()
    bad = 0
    for seed in range(args.seeds):
        failures, tag = _run(seed, args.tol)
        status = "ok " if not failures else "FAIL"
        print(f"seed {seed:3d} {status} {tag}")
        for f in failures:
            bad += 1
            print(f"         -> {f}")
    if bad:
        print(f"\n{bad} violation(s) across {args.seeds} seeds")
        return 1
    print(f"\nall {args.seeds} seeds clean (determinism + conservation + liveness)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
