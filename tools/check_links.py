#!/usr/bin/env python3
"""Markdown link check (stdlib only; CI's docs job).

Scans every *.md in the repo for

* relative markdown links ``[text](path)`` — the target file must
  exist (http(s)/mailto and pure #anchchor links are skipped);
* backticked repo paths like ``core/compression.py:74`` or
  ``tests/test_compression.py`` — the file part must exist at the repo
  root, under ``src/`` or under ``src/repro/`` (line numbers are not
  checked; they drift, the files should not).

Exit code 0 = clean, 1 = broken references (each printed).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "results"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|json|yml))(?::\d+)?`")


def md_files():
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


#: where backticked code paths may live; markdown links get no such
#: leniency — a rendered link resolves relative to its file only
CODE_ROOTS = (
    "", "src", "src/repro", "src/repro/core", "src/repro/kernels",
    "src/repro/serving", "src/repro/launch",
)


def resolve(base: Path, target: str, *, code: bool = False) -> bool:
    target = target.split("#", 1)[0]
    if not target:
        return True
    if target.startswith("/"):
        # absolute paths point outside the repo (machine-local context
        # like the retrieval set under /root/related) — not checkable
        # portably, so out of scope rather than broken
        return True
    if (base.parent / target).exists():
        return True
    if code:
        return any((REPO / root / target).exists() for root in CODE_ROOTS)
    return False


def main() -> int:
    broken = []
    for md in md_files():
        text = md.read_text(encoding="utf-8")
        rel = md.relative_to(REPO)
        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            if not resolve(md, target):
                broken.append(f"{rel}: broken link -> {target}")
        for m in CODE_PATH.finditer(text):
            if not resolve(md, m.group(1), code=True):
                broken.append(f"{rel}: missing file -> {m.group(1)}")
    if broken:
        print("\n".join(broken))
        print(f"\n{len(broken)} broken reference(s)")
        return 1
    print(f"link check OK ({sum(1 for _ in md_files())} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
