"""Vertical-partitioning benchmark: tree reduction + assembled moments.

Measures the cost of assembling the network preactivation from
column-sliced nodes (core/vertical.reduce_partials) in clear and
secure-aggregation modes, and the fused moment pass on the assembled
Z, over a (topology, V, N, D, L) grid. Writes a machine-readable
``BENCH_vertical.json`` at the repo root.

The headline numbers are *wire costs*, which are deterministic
byte counts, not timings:

  * clear convergecast carries per-origin payloads, so messages grow
    toward the root (sum over nodes of subtree-size * N * L * itemsize);
  * secure mode carries one masked fixed-point partial sum per link —
    constant 8 bytes/value — so on any tree deeper than one hop it is
    strictly lighter, *and* interior nodes never see a neighbor's raw
    partials (core/secure.py).

The acceptance invariant at the flagship deep-tree point is
``secure_not_heavier``: masked payload bytes <= clear payload bytes.
It is a deterministic property of the protocol (not a timing), so it
must hold on every machine; the bench asserts it at run time and
records it in the JSON. Wall-time rows (``*_wall_ms``) ride along for
tools/bench_gate.py's 4x cliff check on same-backend runs; the
reduction is a host-side tree walk, so no fused/unfused race (and no
``fused_speedup``) is reported — there is no unfused subject to race.

``tune=True`` refreshes the ``preact_stats`` entries of
TUNED_kernels.json at each swept point before timing the moment pass,
like the stats/serving suites do for their ops.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, vertical
from repro.core.secure import SecureAggregationSpec
from repro.kernels import autotune, elm_stats_ops

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_vertical.json")

M = 2  # targets per sample (small: the wire cost is all Z, not T)
SPEC = SecureAggregationSpec(seed=0)


def _host_ms(fn, repeats):
    """Median wall time of a host-side (non-jittable) callable."""
    fn()  # warm-up: jit caches inside the tree walk
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def _problem(kind, V, N, D, L):
    g = consensus.build(kind, V)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    T = jnp.asarray(rng.standard_normal((N, M)), jnp.float32)
    fmap = vertical.make_vertical_map(jax.random.key(0), D, L, V)
    partials = [
        fmap.partial_preactivation(i, x)
        for i, x in enumerate(fmap.partition.split(X))
    ]
    return g, X, T, fmap, partials


def bench_vertical(fast: bool = False, tune: bool = False):
    """Reduction wall time + wire bytes + assembled moment pass.

    Emits CSV rows and writes BENCH_vertical.json at the repo root.
    """
    backend = jax.default_backend()
    impl = "pallas" if backend == "tpu" else "scan"
    reps = 2 if fast else 5
    # one N per (topology, V): tools/bench_gate.py matches rows by
    # (N, D, L, M, dtype) only, so reused shapes would alias rows
    if fast:
        grid = [
            ("line", 8, 4096, 32, 128),
            ("ring", 8, 4608, 32, 128),
        ]
    else:
        grid = [
            ("line", 8, 4096, 32, 128),
            ("line", 16, 16384, 64, 256),
            ("ring", 8, 4608, 32, 128),
            ("ring", 16, 18432, 64, 256),
            ("complete", 8, 5120, 32, 128),
        ]
    # flagship: the deepest committed tree — where constant-width
    # masked payloads beat the growing clear convergecast the hardest
    flagship = ("line", 16 if not fast else 8)

    rows, records = [], []
    acceptance = None
    for kind, V, N, D, L in grid:
        g, X, T, fmap, partials = _problem(kind, V, N, D, L)
        pt = dict(N=N, D=D, L=L, M=M, dtype="float32")

        clear_ms = _host_ms(
            lambda: vertical.reduce_partials(partials, g)[1], reps
        )
        _, clear_rep = vertical.reduce_partials(partials, g)
        secure_ms = _host_ms(
            lambda: vertical.reduce_partials(partials, g, secure=SPEC)[1],
            reps,
        )
        _, sec_rep = vertical.reduce_partials(partials, g, secure=SPEC)

        if tune:
            autotune.tune(
                "preact_stats", N=N, D=0, L=L, M=M, dtype="float32",
                impl=impl, repeats=2 if fast else 3, force=True,
            )
        Z = vertical.VerticalFeatureMap.assemble(partials)
        mom_ms = _host_ms(
            lambda: jax.block_until_ready(
                elm_stats_ops.fused_preact_moments(
                    Z, fmap.bias, T, activation=fmap.activation
                )
            ),
            reps,
        )

        cb = clear_rep.wire.bytes_on_wire
        sb = sec_rep.wire.bytes_on_wire
        rec = dict(
            pt, graph=kind, V=V, backend=backend,
            clear_reduce_wall_ms=clear_ms,
            secure_reduce_wall_ms=secure_ms,
            moments_wall_ms=mom_ms,
            clear_bytes_on_wire=cb,
            secure_bytes_on_wire=sb,
            bytes_uncompressed=clear_rep.wire.bytes_uncompressed,
            secure_payload_bytes_per_value=8,
            wire_ratio=sb / max(cb, 1),
        )
        records.append(rec)
        tag = f"vertical/{kind}_V{V}_N{N}_L{L}"
        rows.append((
            tag, secure_ms * 1e3,
            f"clear_ms={clear_ms:.1f};secure_ms={secure_ms:.1f};"
            f"moments_ms={mom_ms:.1f};clear_B={cb};secure_B={sb};"
            f"wire_ratio={sb / max(cb, 1):.2f}",
        ))

        if (kind, V) == flagship:
            ok = sb <= cb
            if not ok:
                raise AssertionError(
                    f"secure aggregation heavier than clear at the "
                    f"flagship point: {sb} B > {cb} B"
                )
            acceptance = dict(
                point=pt, graph=kind, V=V,
                secure_bytes_on_wire=sb,
                clear_bytes_on_wire=cb,
                secure_not_heavier=ok,
            )
            rows.append((
                "vertical/acceptance_flagship", 0.0,
                f"secure_not_heavier={ok};secure_B={sb};clear_B={cb}",
            ))

    payload = dict(
        suite="vertical",
        backend=backend,
        default_point=dict(
            N=grid[-1][2], D=grid[-1][3], L=grid[-1][4], M=M,
            dtype="float32",
        ),
        tuned=tune,
        rows=records,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append((
        "vertical/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"
    ))
    return rows, {"json": BENCH_JSON}
