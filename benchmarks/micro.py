"""Microbenchmarks: kernels, online updates, communication models.

Runnable standalone for a single profile:

  PYTHONPATH=src python -m benchmarks.micro --profile stats

prints the fused feature->moment pipeline's FLOP utilization next to
the existing gram numbers (``--profile`` accepts any registered name;
``benchmarks.run`` remains the multi-suite entry point).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, engine, gossip, incremental, online
from repro.kernels.gram import gram_pallas
from repro.kernels.gram_ref import gram_reference
from repro.kernels.ssd_ref import ssd_reference


def _timeit_us(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_gram():
    """Paper hot-spot P = H^T H: oracle timing + kernel flop accounting."""
    rows = []
    for (N, L) in [(2048, 128), (8192, 256), (4096, 512)]:
        H = jax.random.normal(jax.random.key(0), (N, L), jnp.float32)
        ref = jax.jit(gram_reference)
        us = _timeit_us(ref, H)
        flops = 2 * N * L * L
        rows.append((
            f"kernels/gram_ref_N{N}_L{L}", us,
            f"gflops={flops/us/1e3:.2f}",
        ))
        # interpret-mode kernel: correctness-checked, not a CPU perf path
        out = gram_pallas(H[:256], interpret=True, block_l=64, block_n=128)
        err = float(jnp.max(jnp.abs(out - gram_reference(H[:256]))))
        rows.append((f"kernels/gram_pallas_interp_N256_L{L}", 0.0,
                     f"max_err={err:.2e}"))
    return rows, {}


def bench_stats_profile():
    """Fused feature->moment FLOP utilization next to the gram numbers.

    The fused pipeline does the gram work *plus* the feature matmul and
    activation in the same streaming pass, so its gflops row is
    directly comparable to kernels/gram_ref at the same (N, L): the
    utilization the statistics plane sustains on the full Algorithm 1
    steps 1-3, not just the moment contraction. Includes an
    interpret-mode correctness row for the Pallas kernel, mirroring
    bench_gram's, and a tuned-vs-default comparison row showing what
    the autotuned cache (kernels/autotune.py) buys over the hard-coded
    block config at each point.
    """
    from repro.core import features, stats
    from repro.kernels import autotune, elm_stats_ops
    from repro.kernels.elm_stats import elm_stats_pallas

    rows = list(bench_gram()[0])  # the gram numbers, for side-by-side
    D, M = 64, 8
    # measure exactly what production dispatches on this backend
    impl = "pallas" if jax.default_backend() == "tpu" else "scan"
    fused = jax.jit(
        lambda X, W, b, T: elm_stats_ops.fused_moments(
            X, W, b, T, activation="sigmoid", block_n=2048
        )
    )
    for (N, L) in [(2048, 128), (8192, 256), (4096, 512)]:
        ks = jax.random.split(jax.random.key(0), 4)
        X = jax.random.normal(ks[0], (N, D), jnp.float32)
        W = jax.random.normal(ks[1], (D, L), jnp.float32)
        b = jax.random.normal(ks[2], (L,), jnp.float32)
        T = jax.random.normal(ks[3], (N, M), jnp.float32)
        us = _timeit_us(fused, X, W, b, T)
        flops = 2 * N * D * L + 2 * N * L * (L + M)
        rows.append((
            f"kernels/elm_stats_{impl}_N{N}_L{L}", us,
            f"gflops={flops/us/1e3:.2f};fused=feature+gram+cross",
        ))
        # tuned-vs-default: the cache's config (nearest-N fallback
        # included) against the hard-coded default at the same point
        point = autotune.TunePoint(
            op="stats", impl=impl, N=N, D=D, L=L, M=M,
            dtype="float32", backend=jax.default_backend(),
        )
        default_cfg = {
            k: min(v, N if k != "block_l" else L)
            for k, v in autotune.DEFAULTS[("stats", impl)].items()
        }
        tuned_cfg = autotune.lookup("stats", N, D, L, M, "float32", impl=impl)
        if tuned_cfg is None or tuned_cfg == default_cfg:
            rows.append((
                f"kernels/elm_stats_tuned_N{N}_L{L}", 0.0,
                "tuned=default (cache miss or same config)",
            ))
        else:
            us_d = _timeit_us(autotune.candidate_fn(point, default_cfg),
                              X, W, b, T)
            us_t = _timeit_us(autotune.candidate_fn(point, tuned_cfg),
                              X, W, b, T)
            cfg_s = ",".join(f"{k}={v}" for k, v in sorted(tuned_cfg.items()))
            rows.append((
                f"kernels/elm_stats_tuned_N{N}_L{L}", us_t,
                f"tuned({cfg_s})_speedup={us_d / max(us_t, 1e-9):.2f}x"
                f";default_us={us_d:.0f}",
            ))
    # interpret-mode kernel correctness row (vs the statistics plane)
    fmap = features.make_random_features(jax.random.key(1), D, 64)
    X = jax.random.normal(jax.random.key(2), (256, D))
    T = jax.random.normal(jax.random.key(3), (256, M))
    W, b, act = stats.fusable_params(fmap)
    P1, Q1 = elm_stats_pallas(
        X, W, b, T, activation=act, interpret=True, block_l=32, block_n=64
    )
    ref = stats.from_raw(X, T, fmap, use_kernel=False)
    err = max(
        float(jnp.max(jnp.abs(P1 - ref.P))), float(jnp.max(jnp.abs(Q1 - ref.Q)))
    )
    rows.append((
        "kernels/elm_stats_pallas_interp_N256_L64", 0.0, f"max_err={err:.2e}"
    ))
    return rows, {}


def bench_ssd():
    rows = []
    for (b, s, nh, hd, ds) in [(4, 512, 8, 64, 64), (2, 1024, 16, 64, 128)]:
        ks = jax.random.split(jax.random.key(1), 5)
        x = jax.random.normal(ks[0], (b, s, nh, hd))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
        B = jax.random.normal(ks[3], (b, s, ds))
        C = jax.random.normal(ks[4], (b, s, ds))
        fn = jax.jit(lambda *a: ssd_reference(*a, chunk=128)[0])
        us = _timeit_us(fn, x, dt, A, B, C)
        toks = b * s
        rows.append((f"kernels/ssd_ref_b{b}_s{s}", us,
                     f"tokens_per_s={toks/us*1e6:.0f}"))
    return rows, {}


def bench_attention():
    rows = []
    from repro.models.attention import flash_attention

    for (B, S, K, G, hd) in [(2, 1024, 4, 2, 64), (1, 4096, 2, 4, 64)]:
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        pos = jnp.arange(S)
        fn = jax.jit(
            lambda q, k, v: flash_attention(
                q, k, v, q_positions=pos, k_positions=pos, causal=True
            )
        )
        us = _timeit_us(fn, q, k, v)
        flops = 2 * 2 * B * K * G * S * S * hd / 2  # causal half
        rows.append((f"kernels/flash_jnp_B{B}_S{S}", us,
                     f"gflops={flops/us/1e3:.2f}"))
    return rows, {}


def bench_online_vs_direct():
    """Algorithm 2's claim: Woodbury chunk update beats O(L^3) recompute."""
    rows = []
    for L, n, dn in [(256, 4096, 64), (512, 8192, 64), (1024, 8192, 128)]:
        ks = jax.random.split(jax.random.key(3), 4)
        H = jax.random.normal(ks[0], (n, L)) / np.sqrt(L)
        T = jax.random.normal(ks[1], (n, 4))
        dH = jax.random.normal(ks[2], (dn, L)) / np.sqrt(L)
        dT = jax.random.normal(ks[3], (dn, 4))
        st = online.init_state(H, T, C=8.0, V=4)
        add = jax.jit(online.add_chunk)
        us_add = _timeit_us(add, st, dH, dT)
        direct = jax.jit(
            lambda H, T: online.init_state(H, T, 8.0, 4),
        )
        H2 = jnp.concatenate([H, dH])
        T2 = jnp.concatenate([T, dT])
        us_direct = _timeit_us(direct, H2, T2)
        rows.append((
            f"online/woodbury_L{L}_dn{dn}", us_add,
            f"direct_us={us_direct:.0f};speedup={us_direct/us_add:.1f}x",
        ))
    return rows, {}


def bench_consensus_vs_incremental():
    """Paper Sec. II-B: gossip vs Hamiltonian-cycle, latency-normalized.

    Latency model: one gossip round = 1 parallel neighbor exchange; one
    incremental cycle = V *sequential* hops. At an equal hop-latency
    budget we compare achieved distance to the centralized solution.
    The paper's structural claims (no NP-hard cycle construction, no
    single point of failure) are qualitative and noted in EXPERIMENTS.md.
    """
    rows = []
    V, Ni, L, M, C = 8, 64, 16, 2, 0.5
    ks = jax.random.split(jax.random.key(4), 2)
    H = jax.random.normal(ks[0], (V, Ni, L))
    T = jax.random.normal(ks[1], (V, Ni, M))
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    budget_hops = 2000
    g = consensus.complete(V)  # all-neighbor exchange, 1 hop latency
    eng = engine.simulated_dc_elm(g, C)
    betas, _ = eng.run(
        state.betas, state.omegas, g.default_gamma(), budget_hops
    )
    d_dc = float(dc_elm.distance_to(betas, beta_star))
    z, _ = incremental.run(
        P_, Q_, alpha=2e-4, C=C, num_cycles=budget_hops // V
    )
    den = 1 + float(jnp.linalg.norm(beta_star))
    d_inc = float(jnp.linalg.norm(z - beta_star)) / den
    rows.append((
        f"comm/dcelm_complete{V}", 0.0,
        f"hops={budget_hops};dist={d_dc:.4f};spof=none;cycle_required=no",
    ))
    rows.append((
        f"comm/incremental_cycle{V}", 0.0,
        f"hops={budget_hops};cycles={budget_hops // V};dist={d_inc:.4f};"
        f"spof=any_node;cycle_required=yes(NP-hard)",
    ))
    spec = gossip.GossipSpec(axes=("data",), kinds=("ring",))
    payload = L * M * 4
    rows.append((
        "comm/bytes_per_round", 0.0,
        f"dcelm_ring={gossip.collective_bytes_per_round(spec, {'data': V}, payload)}"
        f";incremental_per_cycle={payload * V}",
    ))
    return rows, {}


def bench_streaming_driver():
    """Algorithm 2 end-to-end through the engine: one chunk event
    (Woodbury add+remove, re-seed, K rounds) vs recompute-from-scratch
    (O(L^3) per-node re-inversion, then the same K rounds)."""
    rows = []
    K = 50
    for V, L, n, dn in [(4, 256, 4096, 64), (8, 512, 4096, 128)]:
        M, C = 4, 8.0
        g = consensus.ring(V)
        ks = jax.random.split(jax.random.key(6), 4)
        H = jax.random.normal(ks[0], (V, n, L)) / np.sqrt(L)
        T = jax.random.normal(ks[1], (V, n, M))
        dH = jax.random.normal(ks[2], (V, dn, L)) / np.sqrt(L)
        dT = jax.random.normal(ks[3], (V, dn, M))
        eng = engine.simulated_dc_elm(g, C)
        state = eng.stream_init(H, T)
        gamma = g.default_gamma()

        @jax.jit
        def chunk_event(s):
            s2, _ = eng.stream_chunk(
                s, added=(dH, dT), removed=(H[:, :dn], T[:, :dn]),
                gamma=gamma, num_iters=K,
            )
            return s2.betas

        us_stream = _timeit_us(chunk_event, state)

        H2 = jnp.concatenate([H[:, dn:], dH], axis=1)
        T2 = jnp.concatenate([T[:, dn:], dT], axis=1)

        @jax.jit
        def recompute(H2, T2):
            s = eng.stream_init(H2, T2)
            betas, _ = eng.run(s.betas, s.omegas, gamma, K)
            return betas

        us_direct = _timeit_us(recompute, H2, T2)
        rows.append((
            f"streaming/engine_V{V}_L{L}_dn{dn}_K{K}", us_stream,
            f"recompute_us={us_direct:.0f};"
            f"speedup={us_direct/us_stream:.1f}x",
        ))
    return rows, {}


def bench_fault_tolerance(rounds: int = 4000, tol: float = 1e-2):
    """Robustness: rounds-to-tolerance and ICI bytes vs link failure rate.

    DC-ELM under per-round Bernoulli edge dropout on a certified
    jointly connected trace (FaultModel + FaultyMixer). Collective
    bytes count only *live* links — a dropped link moves no payload —
    so the scheme trades rounds for bytes gracefully. The fusion-center
    baseline has no such trade: any node crash stalls its all-reduce
    for the whole outage (stall row below).
    """
    rows = []
    V, Ni, L, M, C = 16, 48, 12, 1, 0.05
    ks = jax.random.split(jax.random.key(7), 2)
    H = jax.random.normal(ks[0], (V, Ni, L))
    T = jax.random.normal(ks[1], (V, Ni, M))
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    g = consensus.build("hypercube", V)
    gamma = g.default_gamma()
    payload = L * M * 4
    trace_fn = lambda betas: dc_elm.distance_to(betas, beta_star)  # noqa: E731
    window = 16
    for p in [0.0, 0.1, 0.2, 0.3, 0.4]:
        fm = consensus.FaultModel.sample_certified(
            g, p, num_rounds=rounds, window=window
        )
        keep = fm.edge_keep(rounds)
        eng = engine.with_faults(engine.simulated_dc_elm(g, C), keep)
        _, traces = eng.run(state.betas, state.omegas, gamma, rounds,
                            trace_fn=trace_fn)
        traces = np.asarray(traces)
        hit = np.nonzero(traces < tol)[0]
        r2t = int(hit[0]) + 1 if hit.size else -1
        # bytes actually moved: one payload per live directed edge
        live = keep.sum(axis=(1, 2))  # directed live edges per round
        total_edges = float((g.adjacency > 0).sum())
        upto = r2t if r2t > 0 else rounds
        bytes_per_node = float(live[:upto].sum()) * payload / V
        rows.append((
            f"faults/bernoulli_p{p:.1f}", 0.0,
            f"rounds_to_{tol:g}={r2t};bytes_per_node={bytes_per_node:.0f};"
            f"live_edge_frac={live.mean() / total_edges:.2f};"
            f"certified_window={window}",
        ))
    # node crash/rejoin burst: DC-ELM degrades, fusion stalls outright
    crash = consensus.NodeCrash(node=3, start=200, duration=400)
    fm = consensus.FaultModel(graph=g, crashes=(crash,))
    eng = engine.with_faults(engine.simulated_dc_elm(g, C), fm.edge_keep(rounds))
    _, traces = eng.run(state.betas, state.omegas, gamma, rounds,
                        trace_fn=trace_fn)
    traces = np.asarray(traces)
    hit = np.nonzero(traces < tol)[0]
    r2t = int(hit[0]) + 1 if hit.size else -1
    stall = crash.duration
    rows.append((
        "faults/crash_rejoin_node3", 0.0,
        f"rounds_to_{tol:g}={r2t};dcelm_stalled_rounds=0;"
        f"fusion_stalled_rounds={stall}(all-reduce blocked while any "
        f"chip is down)",
    ))
    return rows, {}


def bench_gossip_topologies():
    """Consensus cost across ICI-realizable topologies at equal rounds.

    Small C so the graph term (not the ridge stiffness) dominates the
    essential spectral radius — isolates the topology effect.
    """
    rows = []
    V, Ni, L, M, C = 16, 48, 12, 1, 0.05
    ks = jax.random.split(jax.random.key(5), 2)
    H = jax.random.normal(ks[0], (V, Ni, L))
    T = jax.random.normal(ks[1], (V, Ni, M))
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    rounds = 1500
    for kind in ["ring", "torus", "hypercube", "complete"]:
        g = consensus.build(kind, V)
        eng = engine.simulated_dc_elm(g, C)
        betas, _ = eng.run(
            state.betas, state.omegas, g.default_gamma(), rounds
        )
        dist = float(dc_elm.distance_to(betas, beta_star))
        bytes_round = g.d_max * L * M * 4
        rows.append((
            f"topology/{kind}16", 0.0,
            f"rounds={rounds};dist={dist:.5f};"
            f"lambda2={g.algebraic_connectivity:.3f};"
            f"dmax={g.d_max:.0f};bytes_per_node_per_round={bytes_round:.0f}",
        ))
    return rows, {}


def bench_compression_pareto(rounds: int = 2000, tol: float = 1e-2):
    """Accuracy-vs-bytes Pareto for compressed gossip (DESIGN.md §9).

    Every scheme runs the same `rounds` window on the same problem and
    reports rounds-to-tolerance, exact bytes-on-wire up to that round,
    and total window bytes — so the table answers both "what does it
    cost to *reach* the fp32 residual" and "what does it cost to reach
    and then *hold* it" (a serving window; this is where event-
    triggered rounds go quiet and win). The acceptance rows check that
    int8 + error feedback reaches the fp32 run's tolerance residual
    within 10x the fp32 rounds at <= 25% of the fp32 window bytes, on
    both mixers, including composed with a certified FaultModel trace.

    topk ships k=10% of entries and needs a reduced consensus gain
    (gamma x0.3) to contract — the classic CHOCO delta-compression
    trade.
    """
    from repro.core.compression import CompressionSpec

    rows = []
    V, Ni, L, M, C = 8, 32, 32, 4, 0.5
    ks = jax.random.split(jax.random.key(11), 2)
    H = (jax.random.normal(ks[0], (V, Ni, L)) / np.sqrt(L)).astype(
        jnp.float32
    )
    T = jax.random.normal(ks[1], (V, Ni, M)).astype(jnp.float32)
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
    g = consensus.build("hypercube", V)
    gamma = g.default_gamma()
    trace_fn = lambda b: dc_elm.distance_to(b, beta_star)  # noqa: E731
    fm = consensus.FaultModel.sample_certified(
        g, 0.2, num_rounds=64, window=16
    )
    keep = fm.edge_keep(64)

    schemes = [
        ("fp32", None, 1.0),
        ("bf16+ef", CompressionSpec(mode="bf16"), 1.0),
        ("int8+ef", CompressionSpec(mode="int8", tile=128), 1.0),
        ("int8-noef", CompressionSpec(mode="int8", tile=128,
                                      error_feedback=False), 1.0),
        ("topk10+ef", CompressionSpec(mode="topk", k=0.1), 0.3),
        ("int8+ef+event", CompressionSpec(mode="int8", tile=128,
                                          event_threshold=1e-3), 1.0),
    ]

    def measure(eng, gscale):
        betas, tr = eng.run(
            state.betas, state.omegas, gamma * gscale, rounds,
            trace_fn=trace_fn,
        )
        tr = np.asarray(tr)
        hit = np.nonzero(tr < tol)[0]
        r2t = int(hit[0]) + 1 if hit.size else -1
        ws = eng.wire_stats
        b2t = float(ws.per_round_bytes[:r2t].sum()) if r2t > 0 else -1.0
        return r2t, b2t, float(ws.bytes_on_wire), float(tr[-1]), ws

    base = {}
    for faulted in (False, True):
        tag = "faulty/" if faulted else "dense/"
        for name, spec, gscale in schemes:
            eng = engine.simulated_dc_elm(g, C, compress=spec)
            if faulted:
                eng = engine.with_faults(eng, keep)
            r2t, b2t, bwin, final, ws = measure(eng, gscale)
            key = tag + name
            base[key] = (r2t, b2t, bwin)
            fp = base[tag + "fp32"]
            rows.append((
                f"compression/{key}", 0.0,
                f"rounds_to_{tol:g}={r2t};bytes_to_tol={b2t:.0f};"
                f"window_bytes={bwin:.0f};window_ratio={bwin/fp[2]:.3f};"
                f"final_residual={final:.2e};"
                f"skip_frac={ws.links_skipped/max(ws.links_live,1):.2f}",
            ))
        # acceptance: int8+EF (event-triggered) vs the fp32 window
        fp, ev = base[tag + "fp32"], base[tag + "int8+ef+event"]
        ok_rounds = 0 < ev[0] <= 10 * max(fp[0], 1)
        ok_bytes = ev[2] <= 0.25 * fp[2]
        rows.append((
            f"compression/{tag}acceptance", 0.0,
            f"int8_ef_within_10x_rounds={ok_rounds};"
            f"bytes_le_25pct_fp32={ok_bytes};"
            f"rounds={ev[0]}v{fp[0]};bytes_ratio={ev[2]/fp[2]:.3f}",
        ))

    # the same comparison on the ppermute production path (+ faults),
    # in a subprocess with 8 fake host devices; residuals are sampled
    # between cached shard_map(scan) blocks (period-aligned with the
    # fault trace) since per-round traces are a dense-path feature
    import subprocess
    import sys

    code = f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import consensus, dc_elm, engine, gossip
from repro.core.compression import CompressionSpec
from repro.utils import compat
V, Ni, L, M, C = {V}, {Ni}, {L}, {M}, {C}
# block == the fault-trace period: a bare FaultyMixer restarts its
# round counter per run() call, so period-aligned blocks keep the fp32
# baseline on the same certified cyclic trace the compressed schemes
# (which carry an absolute round counter) replay
rounds, tol, block = {rounds}, {tol}, 64
mesh = compat.make_mesh((8,), ('data',))
ks = jax.random.split(jax.random.key(11), 2)
H = (jax.random.normal(ks[0], (V, Ni, L)) / np.sqrt(L)).astype(jnp.float32)
T = jax.random.normal(ks[1], (V, Ni, M)).astype(jnp.float32)
state, P_, Q_ = dc_elm.simulate_init(H, T, C)
beta_star = dc_elm.centralized_from_node_stats(P_, Q_, C)
spec = gossip.GossipSpec(axes=('data',), kinds=('hypercube',))
g = spec.to_graph({{'data': V}})
gamma = g.default_gamma()
fm = consensus.FaultModel.sample_certified(g, 0.2, num_rounds=64, window=16)
keep = fm.edge_keep(64)
for faulted in (False, True):
    tag = 'ppermute_faulty/' if faulted else 'ppermute/'
    base = {{}}
    for name, cs in [('fp32', None),
                     ('int8+ef', CompressionSpec(mode='int8', tile=128)),
                     ('int8+ef+event', CompressionSpec(
                          mode='int8', tile=128, event_threshold=1e-3))]:
        eng = engine.sharded_dc_elm(mesh, spec, C, compress=cs)
        if faulted:
            eng = engine.with_faults(eng, keep)
        betas, stats, r2t, prb = state.betas, None, -1, []
        for b in range(rounds // block):
            betas, _ = eng.run(betas, state.omegas, gamma, block)
            ws = eng.wire_stats
            stats = ws if stats is None else stats + ws
            prb.append(ws.per_round_bytes)
            if r2t < 0 and float(dc_elm.distance_to(betas, beta_star)) < tol:
                r2t = (b + 1) * block
        prb = np.concatenate(prb)
        b2t = float(prb[:r2t].sum()) if r2t > 0 else -1.0
        base[name] = (r2t, stats.bytes_on_wire)
        print(f"ROW,compression/{{tag}}{{name}},0.0,"
              f"rounds_to_tol_le={{r2t}};bytes_to_tol={{b2t:.0f}};"
              f"window_bytes={{stats.bytes_on_wire}};"
              f"window_ratio={{stats.bytes_on_wire/base[list(base)[0]][1]:.3f}};"
              f"final_residual={{float(dc_elm.distance_to(betas, beta_star)):.2e}};"
              f"skip_frac={{stats.links_skipped/max(stats.links_live,1):.2f}}")
    fp, ev = base['fp32'], base['int8+ef+event']
    ok_rounds = 0 < ev[0] <= 10 * max(fp[0], 1)
    ok_bytes = ev[1] <= 0.25 * fp[1]
    print(f"ROW,compression/{{tag}}acceptance,0.0,"
          f"int8_ef_within_10x_rounds={{ok_rounds}};"
          f"bytes_le_25pct_fp32={{ok_bytes}};"
          f"rounds={{ev[0]}}v{{fp[0]}};bytes_ratio={{ev[1]/fp[1]:.3f}}")
print('DONE')
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    if "DONE" not in r.stdout:
        rows.append((
            "compression/ppermute", 0.0,
            f"ERROR:{r.stderr.strip().splitlines()[-1] if r.stderr else 'unknown'}",
        ))
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows, {}


def bench_consensus_profile():
    """Gossip-round arm choice and utilization vs the roofline model.

    For each (graph, V, L) point: the measured wall time of the arm the
    dispatcher actually picks (``elm_gossip_ops.prefers_dense``) next
    to the dense round, and the ``analysis/roofline.py``
    ``gossip_round_terms`` modeled times for both arms — the model that
    drives the autotuner's candidate pruning and the dense-fallback
    heuristic, shown against ground truth so drift is visible.
    """
    import functools

    from repro.analysis.roofline import gossip_round_terms
    from repro.core.consensus import build
    from repro.kernels import elm_gossip_ops
    from repro.kernels.elm_gossip_ref import (
        dense_gossip_rounds,
        neighbor_lists,
    )

    rows = []
    R, M = 8, 8
    for kind, V, L in [
        ("hypercube", 256, 128), ("hypercube", 1024, 128),
        ("complete", 256, 128),
    ]:
        g = build(kind, V)
        d_max = int(round(g.d_max))
        ks = jax.random.split(jax.random.key(0), 2)
        betas = jax.random.normal(ks[0], (V, L, M), jnp.float32)
        omegas = jax.random.normal(ks[1], (V, L, L), jnp.float32) / L
        adj = jnp.asarray(g.adjacency, jnp.float32)[None]
        degd = jnp.sum(adj, axis=-1)
        idx, w, deg = neighbor_lists(adj)
        scale = jnp.float32(0.9 / d_max / (V * 10.0))
        dense = jax.jit(
            functools.partial(dense_gossip_rounds, num_rounds=R)
        )
        dense_us = _timeit_us(dense, betas, omegas, adj, degd, scale)
        to_dense = elm_gossip_ops.prefers_dense(V, d_max, L, M)
        if to_dense:
            fused_us = dense_us
        else:
            fused_us = _timeit_us(
                lambda b: elm_gossip_ops.fused_gossip_rounds(
                    b, omegas, idx, w, deg, scale, num_rounds=R,
                ),
                betas,
            )
        mn = gossip_round_terms(V, d_max, L, M)
        md = gossip_round_terms(V, d_max, L, M, dense=True)
        rows.append((
            f"consensus/{kind}_V{V}_L{L}", fused_us / R,
            f"arm={'dense' if to_dense else 'neighbor'};"
            f"dense_us_per_round={dense_us / R:.0f};"
            f"measured_ratio={dense_us / fused_us:.2f};"
            f"modeled_compute_ratio="
            f"{md['t_compute'] / mn['t_compute']:.2f};"
            f"modeled_round_us={mn['t_round'] * 1e6:.1f}",
        ))
    return rows, {}


PROFILES = {
    "gram": bench_gram,
    "stats": bench_stats_profile,
    "consensus": bench_consensus_profile,
    "ssd": bench_ssd,
    "attn": bench_attention,
    "online": bench_online_vs_direct,
    "comm": bench_consensus_vs_incremental,
    "topology": bench_gossip_topologies,
    "streaming": bench_streaming_driver,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="single-profile microbench")
    ap.add_argument(
        "--profile", default="stats", choices=sorted(PROFILES),
        help="which microbench rows to print (default: stats)",
    )
    args = ap.parse_args(argv)
    rows, _ = PROFILES[args.profile]()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
