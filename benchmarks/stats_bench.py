"""Statistics-plane benchmark: fused feature->moment pipeline vs the
unfused materialize-H-then-gram path.

Measures wall time and peak temporary memory across an N sweep and
writes a machine-readable ``BENCH_stats.json`` at the repo root — the
bench trajectory for the paper's compute hot spot (Algorithm 1 steps
1-3). The acceptance point is (N=65536, L=512, bf16): the fused path
must be reported no slower than the unfused matmul path.

Paths under test (both jit-compiled, never interpret mode):
  * unfused — H = g(XW + b) materialized at (N, L), then the gram /
    cross oracles (two extra HBM round trips of H).
  * fused   — on TPU the Pallas kernel (kernels/elm_stats.py, H lives
    in VMEM tiles only); elsewhere the lax.scan streaming
    implementation (kernels/elm_stats_ref.elm_stats_scan), whose peak
    temp is one chunk's working set.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks._bench_util import fused_vs_unfused_sweep

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_stats.json")

# the acceptance point from the issue: N=65536, L=512, bf16
DEFAULT_POINT = dict(N=65536, D=64, L=512, M=8, dtype="bfloat16")
SCAN_CHUNK = 8192


def _problem(N, D, L, M, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dt)
    W = jax.random.normal(ks[1], (D, L)).astype(dt)
    b = jax.random.normal(ks[2], (L,)).astype(jnp.float32)
    T = jax.random.normal(ks[3], (N, M)).astype(dt)
    return X, W, b, T


def _paths():
    from repro.kernels.elm_stats_ref import (
        elm_stats_scan, hidden_reference,
    )
    from repro.kernels.gram_ref import cross_reference, gram_reference

    @jax.jit
    def unfused(X, W, b, T):
        H = hidden_reference(X, W, b, "sigmoid")
        return gram_reference(H), cross_reference(H, T)

    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        from repro.kernels.elm_stats import elm_stats_pallas

        def fused(X, W, b, T):
            return elm_stats_pallas(X, W, b, T, activation="sigmoid")

        fused = jax.jit(fused)
        fused_name = "pallas"
    else:

        @jax.jit
        def fused(X, W, b, T):
            return elm_stats_scan(
                X, W, b, T, activation="sigmoid", chunk=SCAN_CHUNK
            )

        fused_name = f"scan(chunk={SCAN_CHUNK})"
    return unfused, fused, fused_name


def bench_stats(fast: bool = False):
    """fused-vs-unfused wall time + peak memory, N sweep + acceptance.

    Emits CSV rows and writes BENCH_stats.json at the repo root.
    """
    rows = []
    records = []
    unfused, fused, fused_name = _paths()
    acceptance = fused_vs_unfused_sweep(
        fast, rows, records,
        unfused=unfused, fused=fused, fused_name=fused_name,
        problem=_problem,
        flops_fn=lambda pt: (
            2 * pt["N"] * pt["D"] * pt["L"]
            + 2 * pt["N"] * pt["L"] * (pt["L"] + pt["M"])
        ),
        tag_prefix="stats", default_point=DEFAULT_POINT,
    )

    payload = dict(
        suite="stats",
        backend=jax.default_backend(),
        fused_impl=fused_name,
        default_point=DEFAULT_POINT,
        rows=records,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append(("stats/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"))
    return rows, {"json": BENCH_JSON}
