"""Statistics-plane benchmark: fused feature->moment pipeline vs the
unfused materialize-H-then-gram path.

Measures wall time and peak temporary memory across an N sweep and
writes a machine-readable ``BENCH_stats.json`` at the repo root — the
bench trajectory for the paper's compute hot spot (Algorithm 1 steps
1-3). The acceptance point is (N=65536, L=512, bf16): the fused path
must be reported no slower than the unfused matmul path — and with the
tuned cache (kernels/autotune.py) the same must hold at *every* swept
row (tools/bench_gate.py enforces it on the committed JSON).

Paths under test (both jit-compiled, never interpret mode):
  * unfused — H = g(XW + b) materialized at (N, L) in the operand
    dtype (the fused paths' H-tile policy, so both subjects compute
    identical moments), then the gram / cross oracles (two extra HBM
    round trips of H).
  * fused   — on TPU the Pallas kernel (kernels/elm_stats.py, H lives
    in VMEM tiles only); elsewhere the lax.scan streaming
    implementation (kernels/elm_stats_ref.elm_stats_scan), whose peak
    temp is one chunk's working set. The block/chunk config comes from
    the tuned cache per point (``tune=True`` re-measures and refreshes
    TUNED_kernels.json first).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks._bench_util import fused_vs_unfused_sweep, tuned_fused_factory

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_stats.json")

# the acceptance point from the issue: N=65536, L=512, bf16
DEFAULT_POINT = dict(N=65536, D=64, L=512, M=8, dtype="bfloat16")


def _problem(N, D, L, M, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dt)
    W = jax.random.normal(ks[1], (D, L)).astype(dt)
    b = jax.random.normal(ks[2], (L,)).astype(jnp.float32)
    T = jax.random.normal(ks[3], (N, M)).astype(dt)
    return X, W, b, T


def _unfused():
    from repro.kernels.elm_stats_ref import hidden_reference
    from repro.kernels.gram_ref import cross_reference, gram_reference

    @jax.jit
    def unfused(X, W, b, T):
        # materialize H in the operand dtype — the same H-tile dtype
        # policy as the fused kernel/scan (elm_stats.py docstring), so
        # both subjects compute the *same* moments and the comparison
        # is fused-vs-unfused, not bf16-vs-f32 arithmetic
        H = hidden_reference(X, W, b, "sigmoid").astype(X.dtype)
        return gram_reference(H), cross_reference(H, T)

    return unfused


def bench_stats(fast: bool = False, tune: bool = False):
    """fused-vs-unfused wall time + peak memory, N sweep + acceptance.

    Emits CSV rows and writes BENCH_stats.json at the repo root. With
    ``tune=True`` each swept point is re-tuned (sweep-and-cache into
    TUNED_kernels.json) before it is benched.
    """
    rows = []
    records = []
    acceptance = fused_vs_unfused_sweep(
        fast, rows, records,
        unfused=_unfused(),
        fused_factory=tuned_fused_factory("stats", tune=tune, fast=fast),
        problem=_problem,
        flops_fn=lambda pt: (
            2 * pt["N"] * pt["D"] * pt["L"]
            + 2 * pt["N"] * pt["L"] * (pt["L"] + pt["M"])
        ),
        tag_prefix="stats", default_point=DEFAULT_POINT,
    )

    payload = dict(
        suite="stats",
        backend=jax.default_backend(),
        default_point=DEFAULT_POINT,
        tuned=tune,
        rows=records,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append(("stats/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"))
    return rows, {"json": BENCH_JSON}
