"""Statistics-plane benchmark: fused feature->moment pipeline vs the
unfused materialize-H-then-gram path.

Measures wall time and peak temporary memory across an N sweep and
writes a machine-readable ``BENCH_stats.json`` at the repo root — the
bench trajectory for the paper's compute hot spot (Algorithm 1 steps
1-3). The acceptance point is (N=65536, L=512, bf16): the fused path
must be reported no slower than the unfused matmul path.

Paths under test (both jit-compiled, never interpret mode):
  * unfused — H = g(XW + b) materialized at (N, L), then the gram /
    cross oracles (two extra HBM round trips of H).
  * fused   — on TPU the Pallas kernel (kernels/elm_stats.py, H lives
    in VMEM tiles only); elsewhere the lax.scan streaming
    implementation (kernels/elm_stats_ref.elm_stats_scan), whose peak
    temp is one chunk's working set.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_stats.json")

# the acceptance point from the issue: N=65536, L=512, bf16
DEFAULT_POINT = dict(N=65536, D=64, L=512, M=8, dtype="bfloat16")
SCAN_CHUNK = 8192


def _timeit_ms(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e3


def _temp_bytes(jitted, *args):
    """Peak temporary allocation of the compiled program (best effort)."""
    try:
        m = jitted.lower(*args).compile().memory_analysis()
        return int(m.temp_size_in_bytes) if m is not None else -1
    except Exception:  # noqa: BLE001 — backend without memory analysis
        return -1


def _problem(N, D, L, M, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dt)
    W = jax.random.normal(ks[1], (D, L)).astype(dt)
    b = jax.random.normal(ks[2], (L,)).astype(jnp.float32)
    T = jax.random.normal(ks[3], (N, M)).astype(dt)
    return X, W, b, T


def _paths():
    from repro.kernels.elm_stats_ref import (
        elm_stats_scan, hidden_reference,
    )
    from repro.kernels.gram_ref import cross_reference, gram_reference

    @jax.jit
    def unfused(X, W, b, T):
        H = hidden_reference(X, W, b, "sigmoid")
        return gram_reference(H), cross_reference(H, T)

    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        from repro.kernels.elm_stats import elm_stats_pallas

        def fused(X, W, b, T):
            return elm_stats_pallas(X, W, b, T, activation="sigmoid")

        fused = jax.jit(fused)
        fused_name = "pallas"
    else:

        @jax.jit
        def fused(X, W, b, T):
            return elm_stats_scan(
                X, W, b, T, activation="sigmoid", chunk=SCAN_CHUNK
            )

        fused_name = f"scan(chunk={SCAN_CHUNK})"
    return unfused, fused, fused_name


def bench_stats(fast: bool = False):
    """fused-vs-unfused wall time + peak memory, N sweep + acceptance.

    Emits CSV rows and writes BENCH_stats.json at the repo root.
    """
    rows = []
    records = []
    unfused, fused, fused_name = _paths()
    sweep_N = [8192, 32768, 65536] if not fast else [4096, 16384]
    points = [
        dict(DEFAULT_POINT, N=n) for n in sweep_N
    ]
    if not any(p["N"] == DEFAULT_POINT["N"] for p in points):
        points.append(dict(DEFAULT_POINT))
    # a f32 row so the dtype effect is visible next to bf16
    points.append(dict(DEFAULT_POINT, N=sweep_N[-1], dtype="float32"))

    acceptance = None
    for pt in points:
        X, W, b, T = _problem(pt["N"], pt["D"], pt["L"], pt["M"], pt["dtype"])
        reps = 2 if fast else 3
        res = {}
        for name, fn in [("unfused", unfused), ("fused", fused)]:
            ms = _timeit_ms(fn, X, W, b, T, repeats=reps)
            peak = _temp_bytes(fn, X, W, b, T)
            res[name] = dict(wall_ms=ms, peak_temp_bytes=peak)
            tag = (f"stats/{name}_N{pt['N']}_L{pt['L']}_{pt['dtype']}")
            flops = 2 * pt["N"] * pt["D"] * pt["L"] + 2 * pt["N"] * pt[
                "L"
            ] * (pt["L"] + pt["M"])
            rows.append((
                tag, ms * 1e3,
                f"gflops={flops / (ms * 1e3) / 1e3:.2f};"
                f"peak_temp_MiB={peak / 2**20:.1f}" if peak >= 0 else
                f"gflops={flops / (ms * 1e3) / 1e3:.2f};peak_temp_MiB=n/a",
            ))
        rec = dict(
            pt,
            fused_impl=fused_name,
            backend=jax.default_backend(),
            **{f"{k}_{m}": v for k, r in res.items() for m, v in r.items()},
        )
        rec["fused_speedup"] = res["unfused"]["wall_ms"] / max(
            res["fused"]["wall_ms"], 1e-9
        )
        records.append(rec)
        is_default = (
            pt["N"] == DEFAULT_POINT["N"]
            and pt["L"] == DEFAULT_POINT["L"]
            and pt["dtype"] == "bfloat16"
        )
        if is_default:
            acceptance = dict(
                point=pt,
                fused_wall_ms=res["fused"]["wall_ms"],
                unfused_wall_ms=res["unfused"]["wall_ms"],
                fused_not_slower=(
                    res["fused"]["wall_ms"] <= res["unfused"]["wall_ms"]
                ),
            )
            rows.append((
                "stats/acceptance_default_point", 0.0,
                f"fused_not_slower={acceptance['fused_not_slower']};"
                f"fused_ms={acceptance['fused_wall_ms']:.0f};"
                f"unfused_ms={acceptance['unfused_wall_ms']:.0f}",
            ))

    payload = dict(
        suite="stats",
        backend=jax.default_backend(),
        fused_impl=fused_name,
        default_point=DEFAULT_POINT,
        rows=records,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append(("stats/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"))
    return rows, {"json": BENCH_JSON}
