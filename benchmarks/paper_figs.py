"""Reproductions of the paper's experiments (Figs. 3, 4, 7).

Each function returns (rows, summary) where rows are CSV-able tuples
(name, us_per_call, derived). Numbers land in EXPERIMENTS.md.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, dc_elm, elm
from repro.data.partition import partition_equal
from repro.data.sinc import make_sinc_dataset
from repro.data.synthetic_mnist import make_mnist36_dataset


def _timeit(fn, *args, repeats=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6  # us


# ---------------------------------------------------------------------------
# Fig. 3: centralized ELM MSE/DEV vs number of hidden neurons L
# ---------------------------------------------------------------------------


def fig3_centralized_sinc(trials: int = 10):
    rows = []
    Ls = [5, 10, 20, 50, 100, 200]
    C = 2**8
    for L in Ls:
        mses = []
        for t in range(trials):
            X, Y, Xt, Yt = make_sinc_dataset(
                jax.random.key(100 + t), num_nodes=1, per_node=5000,
                num_test=2000,
            )
            model = elm.train_centralized(
                jax.random.key(t), X[0], Y[0], num_features=L, C=C
            )
            mses.append(float(elm.mse(model, Xt, Yt)))
        mse, dev = float(np.mean(mses)), float(np.std(mses))
        rows.append((f"fig3/sinc_centralized_L{L}", 0.0,
                     f"mse={mse:.5f};dev={dev:.5f}"))
    return rows, {"Ls": Ls}


# ---------------------------------------------------------------------------
# Fig. 4: DC-ELM on SinC — convergence and the documented divergence
# ---------------------------------------------------------------------------


def fig4_dcelm_sinc(iters: int = 300):
    from repro.core.features import make_random_features

    rows = []
    graph = consensus.paper_fig2()  # V=4, d_max=2
    X, Y, Xt, Yt = make_sinc_dataset(jax.random.key(0))
    X, Y = X.astype(jnp.float64), Y.astype(jnp.float64)
    settings = [
        ("a", 2**2, 1 / 1.9),  # gamma > 1/d_max: paper shows divergence
        ("b", 2**2, 1 / 2.1),
        ("c", 2**8, 1 / 2.1),
    ]
    fmap = make_random_features(
        jax.random.key(1), 1, 100, "sigmoid", dtype=X.dtype
    )
    for tag, C, gamma in settings:
        H = jax.vmap(fmap)(X)
        _, P_, Q_ = dc_elm.simulate_init(H, Y, C)
        state = dc_elm.simulate_init_from_stats(P_, Q_, C)
        trace_fn = dc_elm.average_empirical_risk_fn(fmap, Xt, Yt)
        # setting (a) deliberately exceeds the Thm. 2 bound (the
        # paper's divergence panel), so opt out of the gamma check
        final, risks = dc_elm.simulate_run(
            state, graph, gamma, C, iters, trace_fn=trace_fn,
            check_gamma=False,
        )
        beta_c = dc_elm.centralized_from_node_stats(P_, Q_, C)
        cent = elm.ELM(feature_map=fmap, beta=beta_c)
        r_c = float(elm.empirical_risk(cent(Xt), Yt))
        r_d0, r_dk = float(risks[0]), float(risks[-1])
        dist = float(dc_elm.distance_to(final.betas, beta_c))
        rows.append((
            f"fig4{tag}/C{C:g}_gamma{gamma:.3f}", 0.0,
            f"Rc={r_c:.4f};Rd0={r_d0:.4f};Rdk={r_dk:.4f};dist={dist:.4f}",
        ))
    return rows, {}


# ---------------------------------------------------------------------------
# Fig. 7: MNIST(3v6 surrogate) over random geometric networks
# ---------------------------------------------------------------------------


def fig7_mnist(iters: int = 1500):
    rows = []
    X, T, Xt, Tt = make_mnist36_dataset(seed=0)
    X, T = jnp.asarray(X), jnp.asarray(T)
    Xt, Tt = jnp.asarray(Xt), jnp.asarray(Tt)
    L, C = 25, 2**-2
    # centralized reference (paper: 0.8989 for V=25 setup, 0.9200 for V=100)
    cent = elm.train_centralized(jax.random.key(0), X, T, num_features=L, C=C)
    acc_c = float(elm.accuracy(cent(Xt), Tt))
    rows.append(("fig7/centralized", 0.0, f"acc={acc_c:.4f}"))
    for V, gamma, radius, seed in [(25, 0.076, 0.35, 1), (100, 0.038, 0.2, 2)]:
        g = consensus.random_geometric(V, radius, seed=seed)
        Xn, Tn = partition_equal(np.asarray(X), np.asarray(T), V)
        fmap = cent.feature_map
        H = jax.vmap(fmap)(jnp.asarray(Xn))
        state, P_, Q_ = dc_elm.simulate_init(H, jnp.asarray(Tn), C)
        trace_fn = dc_elm.test_error_fn(fmap, Xt, Tt)
        final, errs = dc_elm.simulate_run(
            state, g, gamma, C, iters, trace_fn=trace_fn
        )
        rows.append((
            f"fig7/V{V}", 0.0,
            f"err0={float(errs[0]):.4f};errK={float(errs[-1]):.4f};"
            f"acc={1-float(errs[-1]):.4f};lambda2={g.algebraic_connectivity:.4f};"
            f"dmax={g.d_max:.0f};acc_centralized={acc_c:.4f}",
        ))
    return rows, {}
