"""Async-runtime benchmark: barrier cost under stragglers, and the
lossy-network tolerance check the synchronous plane cannot run at all.

Writes a machine-readable ``BENCH_async.json`` at the repo root, the
gossip-plane sibling of ``BENCH_stats.json`` / ``BENCH_serving.json``.
Everything here is measured on the *virtual* clock of
``core.async_engine`` (event time, not wall time), so the numbers are
hardware-independent and deterministic in the seed — the bench gate
only checks the file's own acceptance invariant, never cross-machine
deltas.

Straggler sweep: one node fires k times slower than the rest
(k = 1, 2, 5, 10). A barrier plane pays k per round — every round
waits for the straggler — so its time-to-tolerance is
rounds_to_tol * k, exactly linear in k. The async push-sum runtime
only gates the straggler's own mass releases: the other V-1 nodes
keep gossiping at full rate, and the measured time-to-tolerance grows
sublinearly. The committed JSON pins that separation
(``sublinear_vs_linear`` per row).

Lossy row (the acceptance invariant): on the paper's Fig. 2 network
under a certified jointly-connected 20% loss trace plus per-message
delay jitter, the async engine must reach the residual-to-beta* that
the synchronous DenseMixer run reached on the *fault-free* graph —
convergence to the centralized solution through dropped and delayed
messages, which is the point of gossiping moment masses instead of
betas.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core import async_engine, consensus, dc_elm

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_async.json")

STRAGGLER_FACTORS = (1, 2, 5, 10)
TOL = 1e-5  # relative residual to the f64 centralized beta*


def _problem(V, Ni, L, M, C, seed=7):
    ks = jax.random.split(jax.random.key(seed), 2)
    H = jax.random.normal(ks[0], (V, Ni, L))
    T = jax.random.normal(ks[1], (V, Ni, M))
    state, P_, Q_ = dc_elm.simulate_init(H, T, C)
    beta_star = np.linalg.solve(
        np.eye(L) / C + np.asarray(P_, np.float64).sum(0),
        np.asarray(Q_, np.float64).sum(0),
    )
    return state, P_, Q_, beta_star


def _sync_rounds_to_tol(state, g, C, beta_star, tol, max_rounds):
    """Rounds the barrier plane needs to reach tol (straggler-free)."""
    target = np.asarray(beta_star)
    trace_fn = lambda betas: dc_elm.distance_to(betas, target)  # noqa: E731
    _, traces = dc_elm.simulate_run(
        state, g, g.default_gamma(), C, max_rounds, trace_fn=trace_fn
    )
    hit = np.nonzero(np.asarray(traces) < tol)[0]
    return int(hit[0]) + 1 if hit.size else -1


def _straggler_sweep(fast, rows, records):
    V, Ni, L, M, C = 16, 48, 12, 1, 0.05
    g = consensus.hypercube(4)
    state, P_, Q_, beta_star = _problem(V, Ni, L, M, C)
    # the jax scan makes 2000 sync rounds cheap even in --fast; a cap
    # below rounds-to-tol would poison every t_sync in the sweep
    r2t = _sync_rounds_to_tol(state, g, C, beta_star, TOL, 2000)
    if r2t < 0:
        raise RuntimeError("sync plane did not reach TOL in 2000 rounds")
    factors = STRAGGLER_FACTORS[:: 3 if fast else 1]  # fast: (1, 10)
    for k in factors:
        periods = [float(k)] + [1.0] * (V - 1)
        eng = async_engine.async_dc_elm(
            g, P_, Q_, C, fire_periods=periods, seed=0
        )
        res = eng.run_until(
            residual_tol=TOL, t_max=50.0 * r2t * k, target=beta_star
        )
        t_sync = float(r2t * k)  # every barrier round waits k
        speedup = t_sync / res.t if res.t > 0 else float("inf")
        rec = {
            "straggler_factor": k,
            "graph": g.name,
            "t_tol_sync_vt": t_sync,
            "t_tol_async_vt": res.t,
            "async_speedup_vt": speedup,
            "sync_rounds_to_tol": r2t,
            "async_fires": res.fires,
            "async_sends": res.sends,
            "converged": bool(res.converged),
            # linear-vs-sublinear: sync cost scales as k exactly; the
            # async cost must scale strictly slower once k > 1
            "sublinear_vs_linear": bool(
                k == 1 or res.t < t_sync
            ),
        }
        records.append(rec)
        rows.append((
            f"async/straggler_x{k}", 0.0,
            f"t_sync={t_sync:.0f};t_async={res.t:.1f};"
            f"speedup={speedup:.2f};fires={res.fires};"
            f"converged={res.converged}",
        ))
    return r2t


def _lossy_acceptance(fast, rows):
    """Fig. 2 + certified 20% loss + delay jitter vs fault-free sync."""
    V, Ni, L, M, C = 4, 30, 8, 2, 0.05
    g = consensus.paper_fig2()
    state, P_, Q_, beta_star = _problem(V, Ni, L, M, C, seed=0)
    K = 150 if fast else 300
    dense, _ = dc_elm.simulate_run(state, g, g.default_gamma(), C, K)
    sync_res = float(dc_elm.distance_to(
        np.asarray(dense.betas), np.asarray(beta_star)
    ))
    tol = max(sync_res, TOL)
    fm = consensus.FaultModel.sample_certified(
        g, 0.2, num_rounds=64, window=8
    )
    eng = async_engine.async_dc_elm(
        g, P_, Q_, C,
        faults=fm, delays=consensus.DelayModel(base=0.3, jitter=0.4),
        seed=3,
    )
    res = eng.run_until(residual_tol=tol, t_max=40_000.0, target=beta_star)
    ws = eng.wire_stats
    acceptance = {
        "graph": g.name,
        "sync_rounds": K,
        "sync_residual": sync_res,
        "drop_prob": 0.2,
        "async_residual": res.residual,
        "async_t_vt": res.t,
        "async_drop_frac": res.drops / max(1, res.sends),
        "async_reaches_sync_tol": bool(res.converged),
        "gossip_bytes": int(ws.bytes_on_wire),
    }
    rows.append((
        "async/fig2_lossy_vs_sync", 0.0,
        f"sync_res={sync_res:.2e};async_res={res.residual:.2e};"
        f"t_async={res.t:.1f};drops={res.drops}/{res.sends};"
        f"reaches_sync_tol={res.converged}",
    ))
    return acceptance


def bench_async(fast: bool = False):
    """Straggler sweep + lossy acceptance row; writes BENCH_async.json."""
    rows, records = [], []
    _straggler_sweep(fast, rows, records)
    acceptance = _lossy_acceptance(fast, rows)
    payload = {
        "suite": "async",
        "backend": jax.default_backend(),
        "fast": bool(fast),
        "tol": TOL,
        "rows": records,
        "acceptance": acceptance,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    rows.append((
        "async/json", 0.0,
        f"wrote={os.path.relpath(BENCH_JSON, REPO_ROOT)}",
    ))
    return rows, {"acceptance": acceptance}
