"""Shared measurement helpers for the plane benchmarks.

``stats_bench`` (feature->moment) and ``serving_bench`` (predict) time
the same way on purpose — one warm-up call, block_until_ready-bracketed
repeats interleaved between the unfused and fused subjects (so
machine-speed drift cancels out of the reported ratio), and best-effort
peak-temp from the compiled program's memory analysis — so their
BENCH_*.json numbers stay methodology-comparable and a timing tweak
lands in both. The timing harness itself lives in
``repro.kernels.autotune`` (re-exported here) so the autotuner's sweep
measurements and the committed bench numbers are produced by the exact
same code path.
"""

from __future__ import annotations

import jax

from repro.kernels.autotune import (  # noqa: F401  (shared harness)
    paired_timeit_ms,
    timeit_ms,
)


def temp_bytes(jitted, *args):
    """Peak temporary allocation of the compiled program (best effort;
    -1 when the backend has no memory analysis)."""
    try:
        m = jitted.lower(*args).compile().memory_analysis()
        return int(m.temp_size_in_bytes) if m is not None else -1
    except Exception:  # noqa: BLE001 — backend without memory analysis
        return -1


def fused_vs_unfused_sweep(
    fast, rows, records, *,
    unfused, fused_factory, problem, flops_fn, tag_prefix,
    default_point,
):
    """The shared N-sweep + acceptance scaffold of both plane benches.

    Times `unfused` and the factory-built fused path over an N sweep of
    `default_point` (plus one f32 row), appends CSV `rows` and JSON
    `records` in the schema tools/bench_gate.py matches on (identity =
    N/D/L/M/dtype), and returns the acceptance record for the default
    point: fused reported no slower than unfused.

    fused_factory(pt) -> (fn, fused_name, degenerate): the fused
    callable for one point — per-point so a tuned block config
    (kernels/autotune.py) can differ across the sweep; fused_name
    records which config ran. `degenerate` marks a config whose fused
    program is *identical* to the unfused subject (scan chunk >= N):
    there is only one executable, so it is timed once and the speedup
    is 1.0 by identity — not a coin flip between two timings of the
    same program.
    problem(N, D, L, M, dtype) -> the positional args both paths take;
    flops_fn(pt) -> useful flops for the derived gflops column.
    """
    sweep_N = [8192, 32768, 65536] if not fast else [4096, 16384]
    points = [dict(default_point, N=n) for n in sweep_N]
    if not any(p["N"] == default_point["N"] for p in points):
        points.append(dict(default_point))
    # a f32 row so the dtype effect is visible next to bf16
    points.append(dict(default_point, N=sweep_N[-1], dtype="float32"))

    acceptance = None
    for pt in points:
        args = problem(pt["N"], pt["D"], pt["L"], pt["M"], pt["dtype"])
        reps = 2 if fast else 5
        fused, fused_name, degenerate = fused_factory(pt)
        if degenerate:
            # one executable: chunk >= N makes the fused scan the
            # unfused program; time it once, the ratio is 1 by identity
            u_ms = f_ms = timeit_ms(fused, *args, repeats=2 * reps)
        else:
            # interleaved timing: the ratio survives machine-speed drift
            u_ms, f_ms = paired_timeit_ms(
                [unfused, fused], *args, repeats=reps
            )
        res = {}
        for name, fn, ms in [
            ("unfused", unfused, u_ms), ("fused", fused, f_ms),
        ]:
            peak = temp_bytes(fn, *args)
            res[name] = dict(wall_ms=ms, peak_temp_bytes=peak)
            tag = f"{tag_prefix}/{name}_N{pt['N']}_L{pt['L']}_{pt['dtype']}"
            flops = flops_fn(pt)
            peak_s = (
                f"peak_temp_MiB={peak / 2**20:.1f}" if peak >= 0 else
                "peak_temp_MiB=n/a"
            )
            rows.append((
                tag, ms * 1e3,
                f"gflops={flops / (ms * 1e3) / 1e3:.2f};{peak_s}",
            ))
        rec = dict(
            pt,
            fused_impl=fused_name,
            backend=jax.default_backend(),
            **{f"{k}_{m}": v for k, r in res.items() for m, v in r.items()},
        )
        rec["fused_speedup"] = res["unfused"]["wall_ms"] / max(
            res["fused"]["wall_ms"], 1e-9
        )
        records.append(rec)
        is_default = (
            pt["N"] == default_point["N"]
            and pt["L"] == default_point["L"]
            and pt["dtype"] == "bfloat16"
        )
        if is_default:
            acceptance = dict(
                point=pt,
                fused_wall_ms=res["fused"]["wall_ms"],
                unfused_wall_ms=res["unfused"]["wall_ms"],
                fused_not_slower=(
                    res["fused"]["wall_ms"] <= res["unfused"]["wall_ms"]
                ),
            )
            rows.append((
                f"{tag_prefix}/acceptance_default_point", 0.0,
                f"fused_not_slower={acceptance['fused_not_slower']};"
                f"fused_ms={acceptance['fused_wall_ms']:.0f};"
                f"unfused_ms={acceptance['unfused_wall_ms']:.0f}",
            ))
    return acceptance


def tuned_fused_factory(op, *, tune=False, fast=False):
    """A fused_factory consulting (or regenerating) the tuned cache.

    tune=False: per-point config from ``autotune.lookup`` (the committed
    TUNED_kernels.json), falling back to the hard-coded defaults on a
    miss — exactly what the dispatch wrappers do at tuning="cached".
    tune=True: run the sweep-and-cache ``autotune.tune`` for the point
    first (force=True: re-measure even over an existing entry), so a
    ``--tune`` bench run refreshes TUNED_kernels.json as it goes.
    """
    from repro.kernels import autotune

    backend = jax.default_backend()
    impl = "pallas" if backend == "tpu" else "scan"

    def factory(pt):
        dims = dict(
            N=pt["N"], D=pt["D"], L=pt["L"], M=pt["M"], dtype=pt["dtype"],
        )
        if tune:
            cfg = autotune.tune(
                op, **dims, impl=impl, repeats=2 if fast else 3, force=True,
            )
            tag = "tuned"
        else:
            cfg = autotune.lookup(op, **dims, impl=impl)
            tag = "cached" if cfg is not None else "default"
            if cfg is None:
                cfg = dict(autotune.DEFAULTS[(op, impl)])
        point = autotune.TunePoint(op=op, impl=impl, backend=backend, **dims)
        fn = autotune.candidate_fn(point, cfg)
        # scan chunk >= N: the streaming path degenerates to the exact
        # unfused program (see elm_stats_scan / elm_predict_scan)
        degenerate = impl == "scan" and cfg.get("chunk", 0) >= pt["N"]
        cfg_s = ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))
        name = f"{impl}({cfg_s};{tag}" + (";=unfused)" if degenerate else ")")
        return fn, name, degenerate

    return factory
