"""Shared measurement helpers for the plane benchmarks.

``stats_bench`` (feature->moment) and ``serving_bench`` (predict) time
the same way on purpose — one warm-up call, block_until_ready-bracketed
repeats, and best-effort peak-temp from the compiled program's memory
analysis — so their BENCH_*.json numbers stay methodology-comparable
and a timing tweak lands in both.
"""

from __future__ import annotations

import time

import jax


def timeit_ms(fn, *args, repeats=3):
    """Mean wall ms over `repeats` calls after one warm-up call."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e3


def temp_bytes(jitted, *args):
    """Peak temporary allocation of the compiled program (best effort;
    -1 when the backend has no memory analysis)."""
    try:
        m = jitted.lower(*args).compile().memory_analysis()
        return int(m.temp_size_in_bytes) if m is not None else -1
    except Exception:  # noqa: BLE001 — backend without memory analysis
        return -1


def fused_vs_unfused_sweep(
    fast, rows, records, *,
    unfused, fused, fused_name, problem, flops_fn, tag_prefix,
    default_point,
):
    """The shared N-sweep + acceptance scaffold of both plane benches.

    Times `unfused` and `fused` over an N sweep of `default_point`
    (plus one f32 row), appends CSV `rows` and JSON `records` in the
    schema tools/bench_gate.py matches on (identity = N/D/L/M/dtype),
    and returns the acceptance record for the default point: fused
    reported no slower than unfused.

    problem(N, D, L, M, dtype) -> the positional args both paths take;
    flops_fn(pt) -> useful flops for the derived gflops column.
    """
    sweep_N = [8192, 32768, 65536] if not fast else [4096, 16384]
    points = [dict(default_point, N=n) for n in sweep_N]
    if not any(p["N"] == default_point["N"] for p in points):
        points.append(dict(default_point))
    # a f32 row so the dtype effect is visible next to bf16
    points.append(dict(default_point, N=sweep_N[-1], dtype="float32"))

    acceptance = None
    for pt in points:
        args = problem(pt["N"], pt["D"], pt["L"], pt["M"], pt["dtype"])
        reps = 2 if fast else 3
        res = {}
        for name, fn in [("unfused", unfused), ("fused", fused)]:
            ms = timeit_ms(fn, *args, repeats=reps)
            peak = temp_bytes(fn, *args)
            res[name] = dict(wall_ms=ms, peak_temp_bytes=peak)
            tag = f"{tag_prefix}/{name}_N{pt['N']}_L{pt['L']}_{pt['dtype']}"
            flops = flops_fn(pt)
            peak_s = (
                f"peak_temp_MiB={peak / 2**20:.1f}" if peak >= 0 else
                "peak_temp_MiB=n/a"
            )
            rows.append((
                tag, ms * 1e3,
                f"gflops={flops / (ms * 1e3) / 1e3:.2f};{peak_s}",
            ))
        rec = dict(
            pt,
            fused_impl=fused_name,
            backend=jax.default_backend(),
            **{f"{k}_{m}": v for k, r in res.items() for m, v in r.items()},
        )
        rec["fused_speedup"] = res["unfused"]["wall_ms"] / max(
            res["fused"]["wall_ms"], 1e-9
        )
        records.append(rec)
        is_default = (
            pt["N"] == default_point["N"]
            and pt["L"] == default_point["L"]
            and pt["dtype"] == "bfloat16"
        )
        if is_default:
            acceptance = dict(
                point=pt,
                fused_wall_ms=res["fused"]["wall_ms"],
                unfused_wall_ms=res["unfused"]["wall_ms"],
                fused_not_slower=(
                    res["fused"]["wall_ms"] <= res["unfused"]["wall_ms"]
                ),
            )
            rows.append((
                f"{tag_prefix}/acceptance_default_point", 0.0,
                f"fused_not_slower={acceptance['fused_not_slower']};"
                f"fused_ms={acceptance['fused_wall_ms']:.0f};"
                f"unfused_ms={acceptance['unfused_wall_ms']:.0f}",
            ))
    return acceptance
