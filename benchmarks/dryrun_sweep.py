"""Sweep the full (arch x shape x mesh) dry-run matrix.

Each combo runs in its own subprocess (fresh XLA with 512 placeholder
devices); results land in benchmarks/results/dryrun/*.json and the
aggregate table in benchmarks/results/dryrun_table.json.

Usage:
  PYTHONPATH=src python -m benchmarks.dryrun_sweep [--only arch[,arch]]
      [--shapes s1,s2] [--meshes single,multi] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

ARCHS = [
    "grok-1-314b",
    "qwen2-72b",
    "starcoder2-3b",
    "internvl2-2b",
    "mamba2-780m",
    "h2o-danube-1.8b",
    "dbrx-132b",
    "musicgen-large",
    "gemma2-2b",
    "zamba2-1.2b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MESHES = {"single": [], "multi": ["--multi-pod"]}


def run_one(arch: str, shape: str, mesh: str, force: bool) -> dict:
    tag = f"{arch}_{shape}_{mesh}".replace("/", "-")
    out = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(out) and not force:
        with open(out) as f:
            return json.load(f)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", out, "--quiet",
        *MESHES[mesh],
    ]
    t0 = time.time()
    env = dict(os.environ)
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=1800
    )
    if not os.path.exists(out):
        rec = {
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if mesh == "multi" else "16x16",
            "ok": False, "skipped": False,
            "reason": f"subprocess rc={proc.returncode}: "
            + proc.stderr[-1500:],
            "wall_s": time.time() - t0,
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        return rec
    with open(out) as f:
        rec = json.load(f)
    rec["wall_s"] = time.time() - t0
    with open(out, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    archs = args.only.split(",") if args.only else ARCHS
    shapes = args.shapes.split(",")
    meshes = args.meshes.split(",")

    rows = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                rec = run_one(arch, shape, mesh, args.force)
                rows.append(rec)
                status = (
                    "SKIP" if rec.get("skipped")
                    else ("OK" if rec.get("ok") else "FAIL")
                )
                extra = ""
                if rec.get("roofline"):
                    r = rec["roofline"]
                    extra = (
                        f" bottleneck={r['bottleneck']}"
                        f" t=({r['t_compute_s']:.3g},{r['t_memory_s']:.3g},"
                        f"{r['t_collective_s']:.3g})s"
                        f" peak={rec['memory']['peak_bytes_per_chip']/2**30:.1f}GiB"
                    )
                print(
                    f"[{status}] {arch:18s} {shape:12s} {mesh:6s}"
                    f" wall={rec.get('wall_s', 0):.0f}s{extra}",
                    flush=True,
                )
    table = os.path.join(os.path.dirname(RESULTS_DIR), "dryrun_table.json")
    with open(table, "w") as f:
        json.dump(rows, f, indent=2)
    n_ok = sum(r.get("ok", False) for r in rows)
    n_skip = sum(r.get("skipped", False) for r in rows)
    n_fail = sum(
        (not r.get("ok", False)) and (not r.get("skipped", False))
        for r in rows
    )
    print(f"\n{n_ok} ok ({n_skip} skips) / {n_fail} FAILED of {len(rows)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
