"""Consensus-plane benchmark: fused neighbor-gather gossip rounds vs
the dense ``(V,V) @ (V, L*M)`` round program.

Measures wall time and peak temporary memory over a (graph, V, L)
grid and writes a machine-readable ``BENCH_consensus.json`` at the
repo root — the bench trajectory for the paper's communication hot
loop (eq. (20) / Algorithm 1 step 8). The acceptance point is the
flagship sparse topology (hypercube, V=1024, L=128, f32 — fan-in
log2 V = 10, so the dense round burns ~100x the edge MACs): the fused
neighbor path must be reported no slower than the dense round — and
``tools/bench_gate.py`` enforces ``fused_speedup >= 1.0`` on every
committed row.

Paths under test (both jit-compiled, never interpret mode):
  * unfused — ``elm_gossip_ref.dense_gossip_rounds``: the exact
    DenseMixer.laplacian + DCELMRule composition as one jittable scan,
    touching all V^2 adjacency slots (zeros included) per round.
  * fused   — on TPU the Pallas kernel plane (kernels/elm_gossip.py:
    the in-kernel multi-round arm when state + snapshots fit VMEM,
    else one launch per round); elsewhere the neighbor-list scan
    (``elm_gossip_ref.elm_gossip_scan``) gathering only the d_max
    padded slots. The chunk/block config comes from the tuned cache
    per point (op="gossip", N <- V, D <- d_max; ``tune=True``
    re-measures and refreshes TUNED_kernels.json first).

Rows where ``elm_gossip_ops.prefers_dense`` holds (complete graphs;
small V; L large relative to V) follow the PR 6 degenerate-row
convention: the dispatcher lowers to the dense program there, so the
single executable is timed once and the speedup is 1.0 by identity.
Two wire-format rows ride on the flagship point: a bf16-payload run of
the full round loop, and an int8 single explicit-payload round (the
CompressedMixer arm — its stateful replica loop is not jittable, so
the stateless per-round kernels are what can be raced).
"""

from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._bench_util import temp_bytes
from repro.core.consensus import build
from repro.kernels import autotune
from repro.kernels.autotune import paired_timeit_ms, timeit_ms
from repro.kernels.elm_gossip_ops import prefers_dense
from repro.kernels.elm_gossip_ref import (
    dense_gossip_rounds,
    elm_gossip_scan,
    gossip_round_payload,
    neighbor_lists,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_consensus.json")

M = 8  # targets-per-node; the wide axis is L (hidden width)
ROUNDS = 16  # gossip rounds per timed program (one lax.scan)
C = 10.0  # ridge constant entering scale = gamma / (V C)


def _problem(V, L, kind, dtype):
    """State + topology operands for one grid point.

    Explicit f32/bf16 arrays — benchmarks.run enables x64, so every
    literal here must pin its dtype or the dense matmul silently
    doubles its bytes.
    """
    g = build(kind, V)
    d_max = int(round(g.d_max))
    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(0)
    betas = jnp.asarray(rng.normal(size=(V, L, M)), jnp.float32)
    omegas = jnp.asarray(rng.normal(size=(V, L, L)) / L, jnp.float32)
    betas, omegas = betas.astype(dt), omegas.astype(dt)
    adj = jnp.asarray(g.adjacency, jnp.float32)[None]
    deg_dense = jnp.sum(adj, axis=-1)
    idx, w, deg = neighbor_lists(adj)
    # Thm. 2 step size: gamma < 1/d_max (0.9 safety), scale = gamma/(VC)
    scale = jnp.float32(0.9 / d_max / (V * C))
    return dict(
        d_max=d_max, betas=betas, omegas=omegas, adj=adj,
        deg_dense=deg_dense, idx=idx, w=w, deg=deg, scale=scale,
    )


def _gossip_cfg(V, d_max, L, *, impl, tune, fast):
    """Tuned (or default) block config for one gossip point."""
    dims = dict(N=V, D=d_max, L=L, M=M, dtype="float32")
    if tune:
        cfg = autotune.tune(
            "gossip", **dims, impl=impl, repeats=2 if fast else 3,
            force=True,
        )
        tag = "tuned"
    else:
        cfg = autotune.lookup("gossip", **dims, impl=impl)
        tag = "cached" if cfg is not None else "default"
        if cfg is None:
            cfg = dict(autotune.DEFAULTS[("gossip", impl)])
    cfg_s = ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))
    return cfg, f"{impl}({cfg_s};{tag})"


def _fused_rounds_fn(prob, *, impl, cfg, compress):
    """The jitted fused multi-round program for one point.

    Built once per row (not through elm_gossip_ops per call) so the
    timing loop hits a stable jit cache entry.
    """
    if impl == "pallas":
        from repro.kernels.elm_gossip import (
            elm_gossip_pallas,
            elm_gossip_pallas_multiround,
            multiround_vmem_bytes,
        )

        V, L, _ = prob["betas"].shape
        S, _, d_max = prob["idx"].shape
        if multiround_vmem_bytes(V, L, M, S, d_max) <= autotune.VMEM_BUDGET:
            return jax.jit(functools.partial(
                elm_gossip_pallas_multiround, num_rounds=ROUNDS,
                compress=compress,
            ))
        return jax.jit(functools.partial(
            elm_gossip_pallas, num_rounds=ROUNDS, compress=compress,
            block_v=int(cfg.get("block_n", 8)),
        ))
    return jax.jit(functools.partial(
        elm_gossip_scan, num_rounds=ROUNDS, compress=compress,
        chunk=int(cfg.get("chunk", 8)),
    ))


@jax.jit
def _dense_round_payload(betas, payload, omegas, adj_k, deg_k, scale):
    # the dense single explicit-payload round (CompressedMixer's
    # _run_dense body via DenseMixer.apply_round, as one jittable step)
    V, L, Mq = betas.shape
    p = payload.reshape(V, L * Mq)
    lap = (adj_k[0] @ p - deg_k[0][:, None] * p).reshape(V, L, Mq)
    upd = jnp.einsum("vlk,vkm->vlm", omegas, lap)
    return betas + scale * upd


def _int8_roundtrip(betas):
    """Per-node symmetric int8 quantize-dequantize — the receivers'
    decoded-replica view that the CompressedMixer arm mixes over."""
    amax = jnp.maximum(
        jnp.max(jnp.abs(betas), axis=(1, 2), keepdims=True), 1e-12
    )
    q = jnp.clip(jnp.round(betas / amax * 127.0), -127, 127)
    return (q * (amax / 127.0)).astype(betas.dtype)


def _time_pair(unfused, u_args, fused, f_args, *, degenerate, reps):
    """(unfused_ms, fused_ms, peaks) — degenerate rows timed once."""
    if degenerate:
        ms = timeit_ms(unfused, *u_args, repeats=2 * reps)
        peak = temp_bytes(unfused, *u_args)
        return ms, ms, peak, peak
    u_ms, f_ms = paired_timeit_ms(
        [lambda: unfused(*u_args), lambda: fused(*f_args)], repeats=reps,
    )
    return u_ms, f_ms, temp_bytes(unfused, *u_args), temp_bytes(fused, *f_args)


def bench_consensus(fast: bool = False, tune: bool = False):
    """fused-vs-dense gossip wall time + peak memory over the grid.

    Emits CSV rows and writes BENCH_consensus.json at the repo root.
    With ``tune=True`` each non-degenerate point is re-tuned
    (sweep-and-cache into TUNED_kernels.json) before it is benched.
    """
    backend = jax.default_backend()
    impl = "pallas" if backend == "tpu" else "scan"
    reps = 2 if fast else 5
    if fast:
        grid = [
            ("hypercube", 16, 128), ("hypercube", 64, 128),
            ("complete", 16, 128), ("complete", 64, 128),
        ]
    else:
        # hypercube is the paper's sparse topology (d_max = log2 V);
        # the V=1024 row is the flagship: V/L large enough that the
        # dense round's zero-edge MACs dominate on every backend
        grid = [
            ("hypercube", 16, 128), ("hypercube", 64, 128),
            ("hypercube", 64, 512), ("hypercube", 256, 128),
            ("hypercube", 256, 512), ("hypercube", 1024, 128),
            ("complete", 16, 128), ("complete", 64, 128),
            ("complete", 256, 128), ("complete", 256, 512),
        ]
    flagship = dict(kind="hypercube", V=64 if fast else 1024, L=128)

    rows, records = [], []
    acceptance = None

    def add_record(pt, extra, u_ms, f_ms, u_pk, f_pk, name):
        rec = dict(
            pt, **extra, fused_impl=name, backend=backend,
            unfused_wall_ms=u_ms, fused_wall_ms=f_ms,
            unfused_peak_temp_bytes=u_pk, fused_peak_temp_bytes=f_pk,
            fused_speedup=u_ms / max(f_ms, 1e-9),
        )
        records.append(rec)
        tag = (
            f"consensus/{extra['graph']}_V{pt['N']}_L{pt['L']}_"
            f"{pt['dtype']}"
        )
        peak_s = (
            f"peak_temp_MiB={f_pk / 2**20:.1f}" if f_pk >= 0
            else "peak_temp_MiB=n/a"
        )
        rows.append((
            tag, f_ms,
            f"speedup={rec['fused_speedup']:.2f}x;impl={name};{peak_s}",
        ))
        return rec

    for kind, V, L in grid:
        prob = _problem(V, L, kind, "float32")
        d_max = prob["d_max"]
        pt = dict(N=V, D=d_max, L=L, M=M, dtype="float32")
        dense_fn = jax.jit(functools.partial(
            dense_gossip_rounds, num_rounds=ROUNDS,
        ))
        u_args = (
            prob["betas"], prob["omegas"], prob["adj"],
            prob["deg_dense"], prob["scale"],
        )
        degenerate = prefers_dense(V, d_max, L, M)
        if degenerate:
            # the dispatcher lowers these to the dense program:
            # one executable, speedup 1.0 by identity (PR 6)
            name = "dense(=unfused)"
            fused_fn, f_args = dense_fn, u_args
        else:
            cfg, name = _gossip_cfg(
                V, d_max, L, impl=impl, tune=tune, fast=fast,
            )
            fused_fn = _fused_rounds_fn(
                prob, impl=impl, cfg=cfg, compress=None,
            )
            f_args = (
                prob["betas"], prob["omegas"], prob["idx"],
                prob["w"], prob["deg"], prob["scale"],
            )
        u_ms, f_ms, u_pk, f_pk = _time_pair(
            dense_fn, u_args, fused_fn, f_args,
            degenerate=degenerate, reps=reps,
        )
        extra = dict(graph=kind, d_max=d_max, rounds=ROUNDS)
        add_record(pt, extra, u_ms, f_ms, u_pk, f_pk, name)

        is_flagship = (
            kind == flagship["kind"] and V == flagship["V"]
            and L == flagship["L"]
        )
        if is_flagship:
            acceptance = dict(
                point=pt,
                fused_wall_ms=f_ms,
                unfused_wall_ms=u_ms,
                fused_not_slower=f_ms <= u_ms,
            )
            rows.append((
                "consensus/acceptance_flagship", 0.0,
                f"fused_not_slower={f_ms <= u_ms};"
                f"fused_ms={f_ms:.2f};unfused_ms={u_ms:.2f}",
            ))

    # wire-format rows at the flagship sparse point ------------------
    V, L, kind = flagship["V"], flagship["L"], flagship["kind"]
    prob = _problem(V, L, kind, "float32")
    d_max = prob["d_max"]
    wire_degenerate = prefers_dense(V, d_max, L, M)

    # bf16 payload: the full fused round loop casts the gathered
    # payload to bf16 inside the program (wire dtype), f32 state
    cfg, name = _gossip_cfg(V, d_max, L, impl=impl, tune=False, fast=fast)
    dense_bf16 = jax.jit(functools.partial(
        dense_gossip_rounds, num_rounds=ROUNDS, compress="bf16",
    ))
    u_args = (
        prob["betas"], prob["omegas"], prob["adj"], prob["deg_dense"],
        prob["scale"],
    )
    if wire_degenerate:
        fused_bf16, f_args = dense_bf16, u_args
        name = "dense(=unfused)"
    else:
        fused_bf16 = _fused_rounds_fn(
            prob, impl=impl, cfg=cfg, compress="bf16",
        )
        f_args = (
            prob["betas"], prob["omegas"], prob["idx"], prob["w"],
            prob["deg"], prob["scale"],
        )
    u_ms, f_ms, u_pk, f_pk = _time_pair(
        dense_bf16, u_args, fused_bf16, f_args,
        degenerate=wire_degenerate, reps=reps,
    )
    add_record(
        dict(N=V, D=d_max, L=L, M=M, dtype="bfloat16"),
        dict(graph=kind, d_max=d_max, rounds=ROUNDS),
        u_ms, f_ms, u_pk, f_pk, name + ";wire=bf16",
    )

    # int8 payload: single explicit-payload round (the CompressedMixer
    # arm; its replica loop is host-stateful, so the stateless round
    # kernels are the raceable unit)
    payload = jax.block_until_ready(_int8_roundtrip(prob["betas"]))
    chunk = int(cfg.get("chunk", 8)) if impl == "scan" else None
    u_args = (
        prob["betas"], payload, prob["omegas"], prob["adj"],
        prob["deg_dense"], prob["scale"],
    )
    if wire_degenerate:
        fpay, f_args, int8_name = (
            _dense_round_payload, u_args, "dense(=unfused)"
        )
    elif impl == "pallas":
        from repro.kernels.elm_gossip import elm_gossip_pallas

        fpay = jax.jit(functools.partial(
            elm_gossip_pallas, num_rounds=1,
            block_v=int(cfg.get("block_n", 8)), payload=payload,
        ))
        f_args = (
            prob["betas"], prob["omegas"], prob["idx"], prob["w"],
            prob["deg"], prob["scale"],
        )
        int8_name = f"pallas(block_v={int(cfg.get('block_n', 8))})"
    else:
        fpay = jax.jit(functools.partial(
            gossip_round_payload, chunk=chunk,
        ))
        f_args = (
            prob["betas"], payload, prob["omegas"], prob["idx"][0],
            prob["w"][0], prob["deg"][0], prob["scale"],
        )
        int8_name = f"scan(chunk={chunk})"
    u_ms, f_ms, u_pk, f_pk = _time_pair(
        _dense_round_payload, u_args, fpay, f_args,
        degenerate=wire_degenerate, reps=reps,
    )
    add_record(
        dict(N=V, D=d_max, L=L, M=M, dtype="int8"),
        dict(graph=kind, d_max=d_max, rounds=1),
        u_ms, f_ms, u_pk, f_pk, int8_name + ";wire=int8;payload-round",
    )

    payload_json = dict(
        suite="consensus",
        backend=backend,
        default_point=dict(
            N=flagship["V"], D=d_max, L=flagship["L"], M=M,
            dtype="float32",
        ),
        tuned=tune,
        rows=records,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload_json, fh, indent=2)
    rows.append((
        "consensus/json", 0.0, f"written={os.path.basename(BENCH_JSON)}",
    ))
    return rows, {"json": BENCH_JSON}
