"""Benchmark entry point. One function per paper table/figure, plus the
kernel / online / communication microbenches and the roofline table from
the dry-run sweep. Prints ``name,us_per_call,derived`` CSV.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--suite fig3,fig4,...] [--fast]
      [--tune]

``--tune`` makes the stats/serving suites re-run the kernel autotuner
(kernels/autotune.py) at every swept point before benching it,
refreshing TUNED_kernels.json — the nightly CI job runs
``--tune --fast`` and uploads the fresh cache as an artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _roofline_table():
    """Summarize the dry-run sweep results (benchmarks/dryrun_sweep.py)."""
    path = os.path.join(
        os.path.dirname(__file__), "results", "dryrun_table.json"
    )
    rows = []
    if not os.path.exists(path):
        rows.append(("roofline/table", 0.0, "missing: run benchmarks.dryrun_sweep"))
        return rows, {}
    results_dir = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    recs = []
    for f in sorted(os.listdir(results_dir)):
        if f.endswith(".json"):
            with open(os.path.join(results_dir, f)) as fh:
                recs.append(json.load(fh))
    for rec in recs:
        tag = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("skipped"):
            rows.append((tag, 0.0, f"skipped:{rec['reason'][:60]}"))
            continue
        if not rec.get("ok"):
            rows.append((tag, 0.0, "FAILED"))
            continue
        r = rec["roofline"]
        rows.append((
            tag, 0.0,
            f"bottleneck={r['bottleneck']};"
            f"t_compute={r['t_compute_s']:.4g};t_memory={r['t_memory_s']:.4g};"
            f"t_collective={r['t_collective_s']:.4g};"
            f"peak_GiB={rec['memory']['peak_bytes_per_chip']/2**30:.1f};"
            f"useful_flops={r['useful_flops_ratio']:.3f}",
        ))
    return rows, {}


SUITES = {}


def _register():
    from benchmarks import (
        async_bench,
        consensus_bench,
        micro,
        paper_figs,
        serving_bench,
        stats_bench,
        vertical_bench,
    )

    SUITES.update({
        "fig3": paper_figs.fig3_centralized_sinc,
        "fig4": paper_figs.fig4_dcelm_sinc,
        "fig7": paper_figs.fig7_mnist,
        "gram": micro.bench_gram,
        "stats": stats_bench.bench_stats,
        "serving": serving_bench.bench_serving,
        "multitenant": serving_bench.bench_multitenant,
        "consensus": consensus_bench.bench_consensus,
        "vertical": vertical_bench.bench_vertical,
        "async": async_bench.bench_async,
        "ssd": micro.bench_ssd,
        "attn": micro.bench_attention,
        "online": micro.bench_online_vs_direct,
        "comm": micro.bench_consensus_vs_incremental,
        "topology": micro.bench_gossip_topologies,
        "streaming": micro.bench_streaming_driver,
        "faults": micro.bench_fault_tolerance,
        "compression": micro.bench_compression_pareto,
        "roofline": _roofline_table,
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default=None, help="comma-separated subset")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument(
        "--tune", action="store_true",
        help="re-run the kernel autotuner at each stats/serving sweep "
        "point (refreshes TUNED_kernels.json) before benching",
    )
    args = ap.parse_args()
    # The fidelity reproductions invert ill-conditioned Gram matrices
    # (C up to 2^14); the paper's MATLAB runs were f64 — match it.
    import jax

    jax.config.update("jax_enable_x64", True)
    _register()
    names = args.suite.split(",") if args.suite else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        fn = SUITES[name]
        t0 = time.time()
        try:
            kw = {}
            if args.fast and name == "fig3":
                kw = {"trials": 3}
            if args.fast and name == "fig7":
                kw = {"iters": 300}
            if args.fast and name == "faults":
                kw = {"rounds": 1000}
            if args.fast and name == "compression":
                kw = {"rounds": 600}
            if name in ("stats", "serving", "multitenant", "consensus",
                        "vertical"):
                kw = {"fast": args.fast, "tune": args.tune}
            if name == "async":
                kw = {"fast": args.fast}
            rows, _ = fn(**kw)
            for r in rows:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
        print(
            f"# suite {name} finished in {time.time()-t0:.1f}s",
            file=sys.stderr,
        )
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
