"""Serving-plane benchmark: the fused predict pipeline vs the unfused
materialize-H-then-matmul path, plus the micro-batching server under a
scripted request stream with hot-swap on and off.

Writes a machine-readable ``BENCH_serving.json`` at the repo root —
the inference-side twin of ``BENCH_stats.json``. The acceptance point
is (N=65536, L=512, bf16): the fused predict must be reported no slower
than the unfused H @ beta path.

Paths under test (both jit-compiled, never interpret mode):
  * unfused — H = g(XW + b) materialized at (N, L), then H @ beta (one
    extra HBM round trip of H).
  * fused   — on TPU the Pallas kernel (kernels/elm_predict.py, H lives
    in VMEM tiles only); elsewhere the lax.scan streaming
    implementation (kernels/elm_predict_ref.elm_predict_scan).

Server rows: a deterministic mixed-size request stream drained through
``serving.ELMServer`` — throughput (rows/s) and p50/p99 request latency
with the beta store hot-swapping mid-traffic (a publish every few
flushes, as ``stream_chunk(publish_to=...)`` would produce) vs frozen
on one snapshot.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._bench_util import fused_vs_unfused_sweep

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_serving.json")

# the acceptance point from the issue: N=65536, L=512, bf16
DEFAULT_POINT = dict(N=65536, D=64, L=512, M=8, dtype="bfloat16")
SCAN_CHUNK = 4096
BUCKETS = (64, 256, 1024)


def _problem(N, D, L, M, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.key(0), 4)
    X = jax.random.normal(ks[0], (N, D)).astype(dt)
    W = jax.random.normal(ks[1], (D, L)).astype(dt)
    b = jax.random.normal(ks[2], (L,)).astype(jnp.float32)
    beta = jax.random.normal(ks[3], (L, M)).astype(jnp.float32)
    return X, W, b, beta


def _paths():
    from repro.kernels.elm_predict_ref import (
        elm_predict_scan, predict_reference,
    )

    @jax.jit
    def unfused(X, W, b, beta):
        return predict_reference(X, W, b, beta, activation="sigmoid")

    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        from repro.kernels.elm_predict import elm_predict_pallas

        def fused(X, W, b, beta):
            return elm_predict_pallas(X, W, b, beta, activation="sigmoid")

        fused = jax.jit(fused)
        fused_name = "pallas"
    else:

        @jax.jit
        def fused(X, W, b, beta):
            return elm_predict_scan(
                X, W, b, beta, activation="sigmoid", chunk=SCAN_CHUNK
            )

        fused_name = f"scan(chunk={SCAN_CHUNK})"
    return unfused, fused, fused_name


def _bench_kernel(fast, rows, records):
    unfused, fused, fused_name = _paths()
    acceptance = fused_vs_unfused_sweep(
        fast, rows, records,
        unfused=unfused, fused=fused, fused_name=fused_name,
        problem=_problem,
        flops_fn=lambda pt: 2 * pt["N"] * pt["L"] * (pt["D"] + pt["M"]),
        tag_prefix="serving", default_point=DEFAULT_POINT,
    )
    return acceptance, fused_name


def _request_sizes(num_requests, rng):
    """Mixed traffic: mostly small queries, a tail of bulk scoring."""
    sizes = rng.choice(
        [1, 4, 16, 48, 200, 900], size=num_requests,
        p=[0.25, 0.25, 0.2, 0.15, 0.1, 0.05],
    )
    return [int(s) for s in sizes]


def _bench_server(fast, rows):
    from repro.core.features import make_random_features
    from repro.serving import BetaStore, ELMServer

    D, L, M, V = DEFAULT_POINT["D"], DEFAULT_POINT["L"], DEFAULT_POINT["M"], 4
    fmap = make_random_features(jax.random.key(1), D, L)
    # pin f32: benchmarks.run enables x64 for the fidelity suites, and
    # f64 betas would (correctly) push predict off the fused path
    betas0 = jax.random.normal(
        jax.random.key(2), (V, L, M), dtype=jnp.float32
    )
    num_requests = 60 if fast else 240
    submits_per_flush = 8
    publish_every = 3  # flushes between publishes on the hot-swap arm
    rng = np.random.default_rng(0)
    sizes = _request_sizes(num_requests, rng)
    queries = [
        rng.standard_normal((n, D)).astype(np.float32) for n in sizes
    ]

    # precomputed publish payloads (what stream_chunk(publish_to=...)
    # would hand over) so the timed region measures the server's swap
    # cost, not the noise generation standing in for training
    num_pubs = num_requests // submits_per_flush // publish_every + 1
    pub_betas = [
        jax.block_until_ready(betas0 + 0.01 * jax.random.normal(
            k, betas0.shape, dtype=betas0.dtype
        ))
        for k in jax.random.split(jax.random.key(3), num_pubs)
    ]

    out = {}
    for arm in ("hotswap", "frozen"):
        store = BetaStore(betas0)
        srv = ELMServer(fmap, store, buckets=BUCKETS)
        # warm the bucket programs out of the timed region (compile-once),
        # then zero ALL counters so the published stats describe only
        # the measured stream (not the warm-up's padded full buckets)
        for b in BUCKETS:
            srv.predict(np.zeros((b, D), np.float32))
        for k in srv.metrics:
            srv.metrics[k] = [] if k == "latencies_s" else 0
        if arm == "frozen":
            srv.freeze()
        flushes = 0
        t0 = time.perf_counter()
        for i, q in enumerate(queries):
            srv.submit(q)
            if (i + 1) % submits_per_flush == 0:
                srv.flush()
                flushes += 1
                if flushes % publish_every == 0:
                    store.publish(pub_betas[flushes // publish_every - 1])
        srv.flush()
        wall_s = time.perf_counter() - t0
        st = srv.stats()
        total_rows = int(sum(sizes))
        out[arm] = dict(
            wall_ms=wall_s * 1e3,
            rows_per_s=total_rows / wall_s,
            p50_ms=st["p50_ms"], p99_ms=st["p99_ms"],
            batches=st["batches"], swaps=st["swaps"],
            padding_frac=st["padding_frac"],
            served_version=srv.served_version,
        )
        rows.append((
            f"serving/server_{arm}_req{num_requests}", wall_s * 1e6,
            f"rows_per_s={out[arm]['rows_per_s']:.0f};"
            f"p50_ms={st['p50_ms']:.1f};p99_ms={st['p99_ms']:.1f};"
            f"swaps={st['swaps']};padding_frac={st['padding_frac']:.2f}",
        ))
    out["hotswap_overhead"] = out["frozen"]["rows_per_s"] / max(
        out["hotswap"]["rows_per_s"], 1e-9
    )
    out["num_requests"] = num_requests
    out["buckets"] = list(BUCKETS)
    return out


def bench_serving(fast: bool = False):
    """fused-vs-unfused predict + server traffic; CSV rows + JSON.

    Emits CSV rows and writes BENCH_serving.json at the repo root.
    """
    rows = []
    records = []
    acceptance, fused_name = _bench_kernel(fast, rows, records)
    server = _bench_server(fast, rows)

    payload = dict(
        suite="serving",
        backend=jax.default_backend(),
        fused_impl=fused_name,
        default_point=DEFAULT_POINT,
        rows=records,
        server=server,
        acceptance=acceptance,
    )
    with open(BENCH_JSON, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows.append((
        "serving/json", 0.0, f"written={os.path.basename(BENCH_JSON)}"
    ))
    return rows, {"json": BENCH_JSON}
